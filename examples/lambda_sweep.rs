//! λ_b / λ_d sweep: trace the full accuracy-vs-KV trade-off surface of the
//! ETS cost model on one dataset — the knob a deployment would tune.
//!
//!     cargo run --release --example lambda_sweep [-- --width 64 --problems 60]

use ets::eval::{evaluate, EvalConfig, PolicySpec};
use ets::metrics::{pct, ratio, Table};
use ets::util::argparse::Spec;
use ets::workload::{WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

fn main() {
    let args = Spec::new(&["width", "problems"]).parse(std::env::args()).unwrap();
    let width = args.get_usize("width", 64).unwrap();
    let n_problems = args.get_usize("problems", 60).unwrap();
    let spec = WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM);
    let mk = |policy| EvalConfig {
        spec: spec.clone(),
        policy,
        width,
        n_problems,
        seed: 20260710,
        max_steps: SYNTH_MATH500.n_steps + 6,
    };
    let rebase = evaluate(&mk(PolicySpec::Rebase));
    let mut table = Table::new(
        &format!("λ sweep — synth-math500, width {width} ({n_problems} problems)"),
        &["policy", "λb", "λd", "acc%", "KV red."],
    );
    table.row(vec![
        "rebase".into(),
        "-".into(),
        "-".into(),
        pct(rebase.accuracy()),
        "1.00x".into(),
    ]);
    for &ld in &[0.0, 0.5, 1.0] {
        for &lb in &[0.5, 0.75, 1.0, 1.25, 1.5, 2.0] {
            let r = evaluate(&mk(if ld == 0.0 {
                PolicySpec::EtsKv { lambda_b: lb }
            } else {
                PolicySpec::Ets { lambda_b: lb, lambda_d: ld }
            }));
            table.row(vec![
                if ld == 0.0 { "ets-kv".into() } else { "ets".into() },
                format!("{lb}"),
                format!("{ld}"),
                pct(r.accuracy()),
                ratio(rebase.mean_kv_tokens, r.mean_kv_tokens),
            ]);
        }
    }
    table.emit();
}
