//! Developer probe: accuracy / KV orderings across policies and widths.
//! Used while calibrating the synthetic workload against the paper's shape.
//!
//! Run: `cargo run --release --example calibration_probe [-- --problems 60]`

use ets::eval::{evaluate, EvalConfig, PolicySpec};
use ets::util::argparse::Spec;
use ets::workload::{WorkloadSpec, LLEMMA_34B_SIM, SYNTH_GSM8K, SYNTH_MATH500};

fn main() {
    let args = Spec::new(&["problems", "widths"])
        .parse(std::env::args())
        .unwrap();
    let n = args.get_usize("problems", 60).unwrap();
    let widths = args.get_usize_list("widths", &[16, 64, 256]).unwrap();

    for (ds, dsname) in [(&SYNTH_MATH500, "MATH"), (&SYNTH_GSM8K, "GSM")] {
        println!("=== {dsname} (llemma-34b-sim, {n} problems) ===");
        println!(
            "{:<22} {:>5} {:>7} {:>12} {:>9} {:>10}",
            "policy", "width", "acc%", "kv-tokens", "kv-red", "tokens"
        );
        for &w in &widths {
            let mut rebase_kv = 0.0;
            for pol in [
                PolicySpec::Beam { keep: 4 },
                PolicySpec::BeamSqrt,
                PolicySpec::Dvts { subtrees: 4 },
                PolicySpec::DvtsSqrt,
                PolicySpec::Rebase,
                PolicySpec::Ets { lambda_b: 1.0, lambda_d: 1.0 },
                PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 },
                PolicySpec::Ets { lambda_b: 2.0, lambda_d: 1.0 },
                PolicySpec::EtsKv { lambda_b: 0.75 },
                PolicySpec::EtsKv { lambda_b: 1.25 },
            ] {
                let cfg = EvalConfig {
                    spec: WorkloadSpec::new(ds, &LLEMMA_34B_SIM),
                    policy: pol.clone(),
                    width: w,
                    n_problems: n,
                    seed: 20260710,
                    max_steps: ds.n_steps + 6,
                };
                let r = evaluate(&cfg);
                if pol == PolicySpec::Rebase {
                    rebase_kv = r.mean_kv_tokens;
                }
                let red = if rebase_kv > 0.0 && r.mean_kv_tokens > 0.0 {
                    rebase_kv / r.mean_kv_tokens
                } else {
                    0.0
                };
                println!(
                    "{:<22} {:>5} {:>7.1} {:>12.0} {:>8.2}x {:>10.0}",
                    r.policy,
                    w,
                    100.0 * r.accuracy(),
                    r.mean_kv_tokens,
                    red,
                    r.mean_new_tokens
                );
            }
            println!();
        }
    }
}
