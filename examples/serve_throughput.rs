//! End-to-end serving driver (the repository's E2E validation): serve
//! batched search requests against the REAL AOT-compiled transformer via
//! PJRT — prefill, batched lock-step decode through the Pallas attention
//! kernel, PRM scoring, and the ETS cost model with the PJRT embedder —
//! reporting latency and throughput for REBASE vs ETS.
//!
//! Python never runs here; the artifacts in `artifacts/` are the only model
//! input. Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts && cargo run --release --example serve_throughput

use ets::engine::pjrt_lm::{PjrtEmbedder, PjrtLm, PjrtLmConfig, PjrtPrm};
use ets::search::{run_search, EtsPolicy, RebasePolicy, SearchParams};
use ets::util::error::Result;
use ets::util::rng::Rng;
use ets::util::stats;
use std::rc::Rc;

fn main() -> Result<()> {
    let dir = ets::runtime::default_artifacts_dir();
    if !dir.join("meta.json").exists() {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    }
    let arts = Rc::new(ets::runtime::Artifacts::open(dir)?);
    println!(
        "platform={} model: d={} L={} H={} S={} V={}",
        arts.runtime.platform_name(),
        arts.dims.d_model,
        arts.dims.n_layers,
        arts.dims.n_heads,
        arts.dims.max_seq,
        arts.dims.vocab
    );

    let n_requests = 6;
    let width = 8;
    for (label, use_ets) in [("REBASE", false), ("ETS(λb=1.5,λd=1)", true)] {
        let mut latencies = vec![];
        let (mut kv_sum, mut tok_sum, mut decode_calls, mut radix_unique) =
            (0u64, 0u64, 0u64, 0u64);
        let t0 = std::time::Instant::now();
        for req in 0..n_requests {
            let mut rng = Rng::new(5000 + req);
            let prompt: Vec<u32> = (0..12).map(|_| 2 + rng.below(200) as u32).collect();
            let mut lm =
                PjrtLm::new(arts.clone(), prompt.clone(), req, PjrtLmConfig::default());
            let mut prm = PjrtPrm::new(arts.clone(), prompt);
            let params = SearchParams { width, max_steps: 6 };
            let t = std::time::Instant::now();
            let out = if use_ets {
                let mut pol = EtsPolicy::new(1.5, 1.0, PjrtEmbedder::new(arts.clone()));
                run_search(&mut lm, &mut prm, &mut pol, &params)
            } else {
                let mut pol = RebasePolicy::default();
                run_search(&mut lm, &mut prm, &mut pol, &params)
            };
            latencies.push(t.elapsed().as_secs_f64());
            kv_sum += out.total_kv_tokens();
            tok_sum += out.total_new_tokens();
            decode_calls += lm.decode_calls;
            radix_unique += lm.radix.live_tokens() as u64;
            assert!(out.answer.is_some(), "request {req} produced no answer");
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "\n{label}: {} requests, width {width}",
            n_requests
        );
        println!(
            "  latency p50 {:.2}s  p95 {:.2}s | throughput {:.2} req/s, {:.0} tok/s",
            stats::median(&latencies),
            stats::percentile(&latencies, 95.0),
            n_requests as f64 / wall,
            tok_sum as f64 / wall
        );
        println!(
            "  ΣKV {} tokens | decode batches {} | radix-unique {} tokens",
            kv_sum, decode_calls, radix_unique
        );
    }
    Ok(())
}
