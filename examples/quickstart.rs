//! Quickstart: run ETS on a handful of synthetic MATH-like problems and
//! compare against REBASE — accuracy, KV footprint, and the ILP-pruning
//! telemetry, in under a minute on a laptop.
//!
//!     cargo run --release --example quickstart

use ets::embed::HashEmbedder;
use ets::lm::SynthLm;
use ets::reward::OraclePrm;
use ets::search::{run_search, EtsPolicy, RebasePolicy, SearchParams};
use ets::workload::{ProblemSet, WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

fn main() {
    let spec = WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM);
    let problems = ProblemSet::generate(&spec, 12, 42);
    let params = SearchParams { width: 64, max_steps: SYNTH_MATH500.n_steps + 4 };

    println!("width = {}, dataset = {}, model = {}\n", params.width, spec.dataset.name, spec.model.name);
    println!(
        "{:<6} {:>8} {:>8} | {:>8} {:>8} {:>7} | per-problem (REBASE vs ETS λb=1.5)",
        "prob", "reb-kv", "reb-ok", "ets-kv", "ets-ok", "pruned"
    );

    let (mut reb_correct, mut ets_correct) = (0, 0);
    let (mut reb_kv, mut ets_kv) = (0u64, 0u64);
    for p in &problems.problems {
        let truth = p.answer;

        let mut lm = SynthLm::new(p.clone(), p.id);
        let mut prm = OraclePrm::for_profile(&spec.model, p.id ^ 0xBEEF);
        let mut rebase = RebasePolicy::default();
        let r = run_search(&mut lm, &mut prm, &mut rebase, &params);
        let r_ok = r.answer == Some(truth);

        let mut lm = SynthLm::new(p.clone(), p.id);
        let mut prm = OraclePrm::for_profile(&spec.model, p.id ^ 0xBEEF);
        let mut ets = EtsPolicy::new(1.5, 1.0, HashEmbedder::default());
        let e = run_search(&mut lm, &mut prm, &mut ets, &params);
        let e_ok = e.answer == Some(truth);

        println!(
            "{:<6} {:>8} {:>8} | {:>8} {:>8} {:>7}",
            p.id,
            r.total_kv_tokens(),
            r_ok,
            e.total_kv_tokens(),
            e_ok,
            ets.pruned_total
        );
        reb_correct += r_ok as usize;
        ets_correct += e_ok as usize;
        reb_kv += r.total_kv_tokens();
        ets_kv += e.total_kv_tokens();
    }
    println!(
        "\nREBASE: {}/{} correct, ΣKV {}\nETS:    {}/{} correct, ΣKV {}  (reduction {:.2}x)",
        reb_correct,
        problems.problems.len(),
        reb_kv,
        ets_correct,
        problems.problems.len(),
        ets_kv,
        reb_kv as f64 / ets_kv as f64
    );
}
