//! Microbenchmarks for the L3 substrates on the ETS hot path: the selection
//! solver (ILP / tree B&B), agglomerative clustering, the radix KV cache,
//! and REBASE allocation. These are the per-step costs the coordinator adds
//! on top of model execution — §Perf in EXPERIMENTS.md tracks them.
//!
//! Besides the absolute timings, the bench carries **before/after** cases
//! for the mechanical-sympathy substrates: each pits the shipped
//! implementation against an in-bench reference that preserves the old data
//! layout (HashMap radix edges, sequential-scalar distance reduction,
//! `Vec<Vec<f64>>` simplex tableau). Both sides run in the same process and
//! build, so one invocation yields the comparison without checking out the
//! old tree. `--json PATH` dumps the comparison rows machine-readably
//! (`-` for stdout); CI uses it for the scalar/SIMD identity smoke.

use ets::cluster::agglomerative;
use ets::coordinator::ServeOptions;
use ets::engine::{PerfModel, H100_NVL};
use ets::eval::{evaluate_serve_with, EvalConfig, PolicySpec};
use ets::ilp::select::{solve_tree, Candidate, SelectionProblem};
use ets::ilp::simplex::{solve, Lp, LpOutcome};
use ets::kvcache::coldtier::SpillArena;
use ets::kvcache::{payload_word, RadixCache};
use ets::metrics::Table;
use ets::search::sampling::rebase_allocate;
use ets::util::json::Json;
use ets::util::rng::Rng;
use ets::util::simd;
use ets::util::stats::cosine;
use ets::workload::{WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};
use std::collections::{BTreeSet, HashMap};
use std::time::Duration;

#[path = "common/mod.rs"]
mod common;
use common::{bench, speedup};

// ---------------------------------------------------------------------------
// Reference substrates (the "before" layouts).
// ---------------------------------------------------------------------------

/// Radix tree with per-node `HashMap` child edges — the edge layout the
/// flat [`EdgeArena`] replaced. Same algorithm as `RadixCache` (walk /
/// split / LRU-ordered leaf eviction); only the edge store differs, so the
/// timing delta isolates the data-layout change. Block accounting is
/// mirrored as token counting (identical on both sides, cancels out).
struct RefNode {
    key: Vec<u32>,
    parent: Option<usize>,
    children: HashMap<u32, usize>,
    last_access: u64,
}

struct RefRadix {
    nodes: Vec<RefNode>,
    free: Vec<usize>,
    clock: u64,
    live_tokens: usize,
    evictable: BTreeSet<(u64, usize)>,
}

impl RefRadix {
    fn new() -> Self {
        let root = RefNode {
            key: vec![],
            parent: None,
            children: HashMap::new(),
            last_access: 0,
        };
        Self {
            nodes: vec![root],
            free: vec![],
            clock: 0,
            live_tokens: 0,
            evictable: BTreeSet::new(),
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn touch(&mut self, idx: usize) {
        let now = self.clock;
        let last = self.nodes[idx].last_access;
        let leaf = self.nodes[idx].children.is_empty() && self.nodes[idx].parent.is_some();
        if leaf {
            self.evictable.remove(&(last, idx));
            self.evictable.insert((now, idx));
        }
        self.nodes[idx].last_access = now;
    }

    fn alloc_node(&mut self, n: RefNode) -> usize {
        if let Some(idx) = self.free.pop() {
            self.nodes[idx] = n;
            idx
        } else {
            self.nodes.push(n);
            self.nodes.len() - 1
        }
    }

    /// Longest cached prefix of `tokens` (read-only walk).
    fn peek_prefix(&self, tokens: &[u32]) -> usize {
        let mut cur = 0usize;
        let mut matched = 0usize;
        while matched < tokens.len() {
            let Some(&child) = self.nodes[cur].children.get(&tokens[matched]) else {
                break;
            };
            let key = &self.nodes[child].key;
            let lim = key.len().min(tokens.len() - matched);
            let mut k = 0;
            while k < lim && key[k] == tokens[matched + k] {
                k += 1;
            }
            matched += k;
            if k < key.len() {
                break;
            }
            cur = child;
        }
        matched
    }

    fn split(&mut self, node: usize, at: usize) -> usize {
        let lower_key = self.nodes[node].key.split_off(at);
        let upper_key = std::mem::take(&mut self.nodes[node].key);
        let parent = self.nodes[node].parent.unwrap();
        let now = self.nodes[node].last_access;
        let upper = self.alloc_node(RefNode {
            key: upper_key,
            parent: Some(parent),
            children: HashMap::new(),
            last_access: now,
        });
        let first_upper = self.nodes[upper].key[0];
        self.nodes[parent].children.insert(first_upper, upper); // relabel
        self.nodes[node].key = lower_key;
        self.nodes[node].parent = Some(upper);
        let first_lower = self.nodes[node].key[0];
        self.nodes[upper].children.insert(first_lower, node);
        upper
    }

    fn insert(&mut self, tokens: &[u32]) -> usize {
        self.tick();
        let mut cur = 0usize;
        let mut pos = 0usize;
        let mut new_tokens = 0usize;
        while pos < tokens.len() {
            match self.nodes[cur].children.get(&tokens[pos]).copied() {
                Some(child) => {
                    let key_len = self.nodes[child].key.len();
                    let lim = key_len.min(tokens.len() - pos);
                    let mut k = 0;
                    while k < lim && self.nodes[child].key[k] == tokens[pos + k] {
                        k += 1;
                    }
                    if k < key_len {
                        let upper = self.split(child, k);
                        self.touch(upper);
                        pos += k;
                        cur = upper;
                        if pos == tokens.len() {
                            break;
                        }
                        continue;
                    }
                    pos += key_len;
                    self.touch(child);
                    cur = child;
                }
                None => {
                    let key: Vec<u32> = tokens[pos..].to_vec();
                    new_tokens += key.len();
                    let first = key[0];
                    let now = self.clock;
                    let idx = self.alloc_node(RefNode {
                        key,
                        parent: Some(cur),
                        children: HashMap::new(),
                        last_access: now,
                    });
                    // `cur` gains a child: no longer evictable.
                    self.evictable.remove(&(self.nodes[cur].last_access, cur));
                    self.nodes[cur].children.insert(first, idx);
                    self.evictable.insert((now, idx));
                    pos = tokens.len();
                }
            }
        }
        self.live_tokens += new_tokens;
        new_tokens
    }

    /// Evict LRU leaves until the tree is empty; returns tokens freed.
    fn evict_all(&mut self) -> usize {
        let mut freed = 0usize;
        loop {
            let Some(&(stamp, idx)) = self.evictable.iter().next() else { break };
            self.evictable.remove(&(stamp, idx));
            let parent = self.nodes[idx].parent.unwrap();
            let first = self.nodes[idx].key[0];
            self.nodes[parent].children.remove(&first);
            freed += self.nodes[idx].key.len();
            self.live_tokens -= self.nodes[idx].key.len();
            self.nodes[idx] = RefNode {
                key: vec![],
                parent: None,
                children: HashMap::new(),
                last_access: 0,
            };
            self.free.push(idx);
            if self.nodes[parent].children.is_empty() && self.nodes[parent].parent.is_some() {
                self.evictable.insert((self.nodes[parent].last_access, parent));
            }
        }
        freed
    }
}

/// Sequential-scalar cosine — the reduction the blocked 8-lane kernel in
/// `util::simd` replaced (one accumulator per statistic, strict order).
fn ref_cosine(a: &[f32], b: &[f32]) -> f64 {
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for (&xa, &xb) in a.iter().zip(b) {
        let (x, y) = (xa as f64, xb as f64);
        dot += x * y;
        na += x * x;
        nb += y * y;
    }
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na.sqrt() * nb.sqrt())
    }
}

// ---------------------------------------------------------------------------
// Reference simplex: the pre-flattening `Vec<Vec<f64>>` tableau with scalar
// row operations. Same pivoting rules as `ilp::simplex`, so iteration counts
// match and the timing delta isolates layout + vectorized row kernels.
// ---------------------------------------------------------------------------

mod ref_simplex {
    use ets::ilp::simplex::{Lp, LpOutcome};

    const EPS: f64 = 1e-9;
    const MAX_ITERS: usize = 50_000;

    enum Status {
        Ok,
        Unbounded,
        IterLimit,
    }

    pub fn solve(lp: &Lp) -> LpOutcome {
        let n = lp.c.len();
        let mut rows: Vec<Vec<f64>> = lp.a.clone();
        let mut rhs: Vec<f64> = lp.b.clone();
        for (i, &u) in lp.ub.iter().enumerate() {
            if u.is_finite() {
                let mut row = vec![0.0; n];
                row[i] = 1.0;
                rows.push(row);
                rhs.push(u);
            }
        }
        let m = rows.len();
        let mut needs_artificial = vec![false; m];
        for i in 0..m {
            if rhs[i] < 0.0 {
                for v in rows[i].iter_mut() {
                    *v = -*v;
                }
                rhs[i] = -rhs[i];
                needs_artificial[i] = true;
            }
        }
        let k: usize = needs_artificial.iter().filter(|&&x| x).count();
        let total = n + m + k;

        let mut t = vec![vec![0.0f64; total + 1]; m + 1];
        let mut basis = vec![0usize; m];
        let mut art_col = n + m;
        for i in 0..m {
            t[i][..n].copy_from_slice(&rows[i]);
            t[i][total] = rhs[i];
            if needs_artificial[i] {
                t[i][n + i] = -1.0;
                t[i][art_col] = 1.0;
                basis[i] = art_col;
                art_col += 1;
            } else {
                t[i][n + i] = 1.0;
                basis[i] = n + i;
            }
        }

        if k > 0 {
            t[m][n + m..total].fill(-1.0);
            for i in 0..m {
                if basis[i] >= n + m {
                    for j in 0..=total {
                        t[m][j] += t[i][j];
                    }
                }
            }
            match run(&mut t, &mut basis, total, m) {
                Status::Ok => {}
                Status::Unbounded | Status::IterLimit => return LpOutcome::Infeasible,
            }
            if t[m][total] > 1e-6 {
                return LpOutcome::Infeasible;
            }
            for i in 0..m {
                if basis[i] >= n + m {
                    let mut found = None;
                    for j in 0..n + m {
                        if t[i][j].abs() > EPS {
                            found = Some(j);
                            break;
                        }
                    }
                    if let Some(j) = found {
                        pivot(&mut t, i, j, total, m);
                        basis[i] = j;
                    }
                }
            }
            for row in t.iter_mut() {
                row[n + m..total].fill(0.0);
            }
        }

        t[m].fill(0.0);
        t[m][..n].copy_from_slice(&lp.c);
        for i in 0..m {
            let coef = t[m][basis[i]];
            if coef.abs() > EPS {
                for j in 0..=total {
                    t[m][j] -= coef * t[i][j];
                }
            }
        }
        match run(&mut t, &mut basis, total, m) {
            Status::Ok => {}
            Status::Unbounded => return LpOutcome::Unbounded,
            Status::IterLimit => return LpOutcome::Infeasible,
        }

        let mut x = vec![0.0; n];
        for i in 0..m {
            if basis[i] < n {
                x[basis[i]] = t[i][total];
            }
        }
        let objective: f64 = lp.c.iter().zip(&x).map(|(c, v)| c * v).sum();
        LpOutcome::Optimal { objective, x }
    }

    fn run(t: &mut [Vec<f64>], basis: &mut [usize], total: usize, m: usize) -> Status {
        let mut iters = 0usize;
        loop {
            iters += 1;
            if iters > MAX_ITERS {
                return Status::IterLimit;
            }
            let bland = iters > 10_000;
            let mut enter = None;
            let mut best = EPS;
            for (j, &rc) in t[m][..total].iter().enumerate() {
                if rc > EPS {
                    if bland {
                        enter = Some(j);
                        break;
                    }
                    if rc > best {
                        best = rc;
                        enter = Some(j);
                    }
                }
            }
            let Some(j) = enter else { return Status::Ok };
            let mut leave = None;
            let mut best_ratio = f64::INFINITY;
            for i in 0..m {
                if t[i][j] > EPS {
                    let ratio = t[i][total] / t[i][j];
                    if ratio < best_ratio - EPS
                        || (bland
                            && (ratio - best_ratio).abs() <= EPS
                            && leave.map(|l| basis[l] > basis[i]).unwrap_or(false))
                    {
                        best_ratio = ratio;
                        leave = Some(i);
                    }
                }
            }
            let Some(i) = leave else { return Status::Unbounded };
            pivot(t, i, j, total, m);
            basis[i] = j;
        }
    }

    fn pivot(t: &mut [Vec<f64>], pr: usize, pc: usize, total: usize, m: usize) {
        let inv = 1.0 / t[pr][pc];
        for v in t[pr].iter_mut() {
            *v *= inv;
        }
        for i in 0..=m {
            if i == pr {
                continue;
            }
            let factor = t[i][pc];
            if factor.abs() > EPS {
                for j in 0..=total {
                    let s = t[pr][j];
                    t[i][j] -= factor * s;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Workload builders.
// ---------------------------------------------------------------------------

fn selection_problem(rng: &mut Rng, n_leaves: usize, depth: usize) -> SelectionProblem {
    // chain-ish shared tree with n_leaves fresh leaves
    let mut parents: Vec<Option<usize>> = vec![None];
    for d in 1..depth {
        parents.push(Some(d - 1));
    }
    let mut candidates = vec![];
    let n_clusters = (n_leaves / 3).max(1);
    for i in 0..n_leaves {
        parents.push(Some(rng.index(depth)));
        candidates.push(Candidate {
            weight: 1.0 + rng.index(8) as f64,
            leaf_node: parents.len() - 1,
            cluster: i % n_clusters,
        });
    }
    SelectionProblem {
        candidates,
        node_weight: (0..parents.len()).map(|_| 20.0 + rng.index(60) as f64).collect(),
        parents,
        num_clusters: n_clusters,
        lambda_b: 1.5,
        lambda_d: 1.0,
    }
}

/// Branching radix workload: many short sequences over a small alphabet so
/// the tree fragments into lots of internal nodes — per-node child lookup
/// (the substrate under test) dominates the walk.
fn radix_workload(rng: &mut Rng, n_seqs: usize, len: usize, alphabet: u32) -> Vec<Vec<u32>> {
    (0..n_seqs)
        .map(|_| (0..len).map(|_| rng.index(alphabet as usize) as u32).collect())
        .collect()
}

/// Feasible, bounded LP with a few `>=` rows (exercises phase 1).
fn bench_lp(rng: &mut Rng, n: usize, m: usize) -> Lp {
    let mut lp = Lp::new(n);
    lp.c = (0..n).map(|_| rng.f64()).collect();
    lp.ub = vec![1.0; n];
    for _ in 0..m {
        let row: Vec<f64> =
            (0..n).map(|_| if rng.index(3) == 0 { rng.f64() } else { 0.0 }).collect();
        let budget = 1.0 + rng.f64() * n as f64 * 0.05;
        lp.leq(row, budget);
    }
    lp.geq(vec![1.0; n], 1.0);
    lp
}

fn objective_of(out: &LpOutcome) -> f64 {
    match out {
        LpOutcome::Optimal { objective, .. } => *objective,
        other => panic!("bench LP should be optimal, got {other:?}"),
    }
}

/// Check that the vectorized kernels are byte-identical to their forced
/// scalar duals — the contract CI smoke-tests via this bench.
fn assert_simd_identity(rng: &mut Rng) {
    let a: Vec<f32> = (0..1021).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..1021).map(|_| rng.normal() as f32).collect();
    let xs: Vec<f64> = (0..517).map(|_| rng.normal()).collect();
    let ys: Vec<f64> = (0..517).map(|_| rng.normal()).collect();

    let fast = (simd::dot_norms(&a, &b), simd::sum_sq(&a));
    let mut sc = xs.clone();
    simd::scale(&mut sc, 1.7);
    let mut ss = xs.clone();
    simd::sub_scaled(&mut ss, &ys, 0.3);
    let mut lw = xs.clone();
    simd::lw_merge(&mut lw, &ys, 3.0, 5.0);

    simd::force_scalar(true);
    let slow = (simd::dot_norms(&a, &b), simd::sum_sq(&a));
    let mut sc2 = xs.clone();
    simd::scale(&mut sc2, 1.7);
    let mut ss2 = xs.clone();
    simd::sub_scaled(&mut ss2, &ys, 0.3);
    let mut lw2 = xs.clone();
    simd::lw_merge(&mut lw2, &ys, 3.0, 5.0);
    simd::force_scalar(false);

    let bits = |x: f64| x.to_bits();
    assert_eq!(bits(fast.0 .0), bits(slow.0 .0), "dot mismatch simd vs scalar");
    assert_eq!(bits(fast.0 .1), bits(slow.0 .1), "norm-a mismatch simd vs scalar");
    assert_eq!(bits(fast.0 .2), bits(slow.0 .2), "norm-b mismatch simd vs scalar");
    assert_eq!(bits(fast.1), bits(slow.1), "sum_sq mismatch simd vs scalar");
    assert_eq!(sc, sc2, "scale mismatch simd vs scalar");
    assert_eq!(ss, ss2, "sub_scaled mismatch simd vs scalar");
    assert_eq!(lw, lw2, "lw_merge mismatch simd vs scalar");
}

struct CompareCase {
    name: &'static str,
    size: String,
    new: Duration,
    reference: Duration,
}

fn main() {
    let mut json_path: Option<String> = None;
    let mut argv = std::env::args().skip(1);
    while let Some(a) = argv.next() {
        if a == "--json" {
            json_path = Some(argv.next().expect("--json needs a path (or `-` for stdout)"));
        }
        // anything else (e.g. cargo's --bench) is ignored
    }

    let mut rng = Rng::new(7);
    assert_simd_identity(&mut rng);
    println!(
        "simd identity: OK (runtime dispatch: {})",
        if simd::simd_active() { "avx" } else { "scalar" }
    );

    let mut table = Table::new(
        "Microbenchmarks — per-step coordinator costs",
        &["op", "size", "time"],
    );

    for &n in &[16usize, 64, 256] {
        let p = selection_problem(&mut rng, n, 10);
        let d = bench(5, || {
            std::hint::black_box(solve_tree(&p, Duration::from_millis(10)));
        });
        table.row(vec!["ets-select (tree B&B)".into(), format!("{n} leaves"), format!("{d:?}")]);
    }

    for &n in &[16usize, 64, 256, 512] {
        let embs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..32).map(|_| rng.normal() as f32).collect())
            .collect();
        let d = bench(5, || {
            std::hint::black_box(agglomerative(&embs, 0.3));
        });
        table.row(vec!["clustering (UPGMA)".into(), format!("{n} vecs"), format!("{d:?}")]);
    }

    // agglomerative clustering across merge-threshold regimes: a high
    // threshold forces the full merge cascade (worst case), a low one stops
    // early. The cascade is O(n² log n) via the lazy pair min-heap (the
    // seed's best-pair rescan was O(n³)) — the spread and the win are
    // documented by cluster/mod.rs
    for &thr in &[0.1f64, 0.5, 0.9] {
        let embs: Vec<Vec<f32>> = (0..128)
            .map(|_| (0..32).map(|_| rng.normal() as f32).collect())
            .collect();
        let d = bench(5, || {
            std::hint::black_box(agglomerative(&embs, thr));
        });
        table.row(vec![
            "clustering (UPGMA, threshold sweep)".into(),
            format!("128 vecs, thr {thr}"),
            format!("{d:?}"),
        ]);
    }

    {
        let seqs: Vec<Vec<u32>> = (0..256)
            .map(|i| {
                let mut s: Vec<u32> = (0..120).map(|t| (t % 97) as u32).collect();
                s.extend((0..80).map(|t| ((i * 31 + t) % 211) as u32));
                s
            })
            .collect();
        let d = bench(10, || {
            let mut c = RadixCache::new(1 << 22);
            for s in &seqs {
                std::hint::black_box(c.insert(s));
            }
        });
        table.row(vec!["radix insert".into(), "256 × 200 tok".into(), format!("{d:?}")]);

        // LRU eviction under pressure: O(log n) per freed leaf via the
        // ordered evictable set (the seed rescanned the whole arena)
        let d = bench(10, || {
            let mut c = RadixCache::new(1 << 22);
            for s in &seqs {
                c.insert(s);
            }
            std::hint::black_box(c.evict(usize::MAX));
        });
        table.row(vec![
            "radix insert + LRU evict-all".into(),
            "256 × 200 tok".into(),
            format!("{d:?}"),
        ]);
    }

    for &n in &[64usize, 256] {
        let rewards: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let d = bench(200, || {
            std::hint::black_box(rebase_allocate(&rewards, n, 0.2));
        });
        table.row(vec!["rebase allocation".into(), format!("{n} cands"), format!("{d:?}")]);
    }

    // -----------------------------------------------------------------------
    // Before/after: shipped substrate vs old-layout reference.
    // -----------------------------------------------------------------------
    let mut cases: Vec<CompareCase> = vec![];

    // (1) Radix prefix-walk: flat sorted edge spans vs per-node HashMap.
    {
        let seqs = radix_workload(&mut rng, 1024, 32, 5);
        let probes = radix_workload(&mut rng, 512, 32, 5);
        let mut flat = RadixCache::new(1 << 24);
        let mut reference = RefRadix::new();
        for s in &seqs {
            flat.insert(s);
            reference.insert(s);
        }
        // Same bytes cached on both sides — walks must agree before timing.
        assert_eq!(flat.live_tokens(), reference.live_tokens, "cached-token divergence");
        for p in seqs.iter().chain(&probes) {
            assert_eq!(flat.peek_prefix(p), reference.peek_prefix(p), "walk divergence");
        }
        let new = bench(20, || {
            let mut total = 0usize;
            for p in seqs.iter().chain(&probes) {
                total += flat.peek_prefix(p);
            }
            std::hint::black_box(total);
        });
        let old = bench(20, || {
            let mut total = 0usize;
            for p in seqs.iter().chain(&probes) {
                total += reference.peek_prefix(p);
            }
            std::hint::black_box(total);
        });
        cases.push(CompareCase {
            name: "radix prefix-walk (flat edges vs hashmap)",
            size: "1024 cached + 1536 probes × 32 tok".into(),
            new,
            reference: old,
        });
    }

    // (2) Radix eviction sweep: span recycling vs HashMap removal + realloc.
    {
        let seqs = radix_workload(&mut rng, 512, 32, 5);
        let new = bench(10, || {
            let mut c = RadixCache::new(1 << 24);
            for s in &seqs {
                c.insert(s);
            }
            std::hint::black_box(c.evict(usize::MAX));
        });
        let old = bench(10, || {
            let mut c = RefRadix::new();
            for s in &seqs {
                c.insert(s);
            }
            std::hint::black_box(c.evict_all());
        });
        cases.push(CompareCase {
            name: "radix insert + eviction sweep (flat edges vs hashmap)",
            size: "512 × 32 tok, branchy".into(),
            new,
            reference: old,
        });
    }

    // (3) Embed distance kernel: blocked 8-lane reduction vs sequential scalar.
    {
        let dim = 512usize;
        let vecs: Vec<Vec<f32>> = (0..128)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        // Numerical sanity (reduction order differs, so approximate).
        for w in vecs.windows(2).take(8) {
            let d = (cosine(&w[0], &w[1]) - ref_cosine(&w[0], &w[1])).abs();
            assert!(d < 1e-9, "cosine kernel drifted from reference: {d}");
        }
        let new = bench(50, || {
            let mut acc = 0.0f64;
            for w in vecs.windows(2) {
                acc += cosine(&w[0], &w[1]);
            }
            std::hint::black_box(acc);
        });
        let old = bench(50, || {
            let mut acc = 0.0f64;
            for w in vecs.windows(2) {
                acc += ref_cosine(&w[0], &w[1]);
            }
            std::hint::black_box(acc);
        });
        cases.push(CompareCase {
            name: "embed cosine kernel (blocked/simd vs scalar)",
            size: format!("127 pairs × {dim}d"),
            new,
            reference: old,
        });
    }

    // (4) Simplex: flat row-major tableau + vectorized pivots vs Vec<Vec>.
    {
        for &(n, m) in &[(24usize, 32usize), (56, 72)] {
            let lp = bench_lp(&mut rng, n, m);
            let z_new = objective_of(&solve(&lp));
            let z_old = objective_of(&ref_simplex::solve(&lp));
            assert!(
                (z_new - z_old).abs() < 1e-6,
                "simplex drifted from reference: {z_new} vs {z_old}"
            );
            let new = bench(10, || {
                std::hint::black_box(solve(&lp));
            });
            let old = bench(10, || {
                std::hint::black_box(ref_simplex::solve(&lp));
            });
            cases.push(CompareCase {
                name: "simplex solve (flat tableau vs vec-of-vec)",
                size: format!("{n} vars × {m} rows"),
                new,
                reference: old,
            });
        }
    }

    // (5) Cold-tier spill/restore: a demote → restore roundtrip through the
    // host-DRAM SpillArena (block-copy in, block-copy out) vs regenerating
    // the payload words from scratch — the data-plane alternative the
    // demote-instead-of-destroy ladder exists to avoid. The restored words
    // must be bit-identical to regeneration (the tier's whole correctness
    // contract) before either side is timed.
    {
        let n_spans = 64usize;
        let len = 2048usize;
        let spans: Vec<Vec<u32>> = (0..n_spans)
            .map(|i| (0..len).map(|t| ((i * 131 + t * 7) % 50_021) as u32).collect())
            .collect();
        let payloads: Vec<Vec<u64>> = spans
            .iter()
            .map(|s| s.iter().map(|&t| payload_word(t)).collect())
            .collect();
        let mut arena = SpillArena::new(n_spans * len, 16);
        for (s, w) in spans.iter().zip(&payloads) {
            assert!(arena.admit(s, 0, w), "ample arena must admit every span");
            assert_eq!(arena.probe_back(s, 0), 0, "admitted span must cover fully");
        }
        arena.check_invariants().expect("spill arena invariants");
        for (s, w) in spans.iter().zip(&payloads) {
            assert_eq!(
                arena.restore(s, 0).as_deref(),
                Some(w.as_slice()),
                "restored words must be bit-identical to regeneration"
            );
        }
        let new = bench(20, || {
            let mut arena = SpillArena::new(n_spans * len, 16);
            for (s, w) in spans.iter().zip(&payloads) {
                arena.admit(s, 0, w);
            }
            let mut acc = 0u64;
            for s in &spans {
                acc ^= arena.restore(s, 0).expect("admitted above")[len - 1];
            }
            std::hint::black_box(acc);
        });
        let old = bench(20, || {
            let mut acc = 0u64;
            for s in &spans {
                let words: Vec<u64> = s.iter().map(|&t| payload_word(t)).collect();
                acc ^= words[len - 1];
            }
            std::hint::black_box(acc);
        });
        cases.push(CompareCase {
            name: "kv spill/restore roundtrip (cold-tier copy vs payload regen)",
            size: format!("{n_spans} spans × {len} tok"),
            new,
            reference: old,
        });
    }

    // (6) Trace recording overhead: the identical serve run with the
    // two-track recorder on vs off. Tracing is a fixed handful of
    // ring-buffer pushes per round plus one per lifecycle edge, into
    // preallocated buffers — the <5% assert keeps "tracing is cheap enough
    // to leave on" an enforced property rather than a hope.
    {
        let cfg = EvalConfig {
            spec: WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM),
            policy: PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 },
            width: 16,
            n_problems: 8,
            seed: 20260730,
            max_steps: SYNTH_MATH500.n_steps + 6,
        };
        let perf = PerfModel::new(H100_NVL, true, 8);
        let on = ServeOptions::with_concurrency(8).traced(true);
        let off = ServeOptions::with_concurrency(8);
        // tracing must be read-only before it is worth timing (the
        // determinism suite pins the full contract; this is a spot check)
        let traced_run = evaluate_serve_with(&cfg, &on, &perf);
        let plain_run = evaluate_serve_with(&cfg, &off, &perf);
        assert_eq!(
            traced_run.report.n_correct,
            plain_run.report.n_correct,
            "tracing changed serve results"
        );
        let events = traced_run.serve.trace.as_ref().map_or(0, |t| t.exec.len() + t.modeled.len());
        assert!(events > 0, "traced serve must record events");
        // min-of-3 means: these serve runs are short, so a single mean is
        // noise-prone on shared runners
        let best = |opts: &ServeOptions| {
            (0..3)
                .map(|_| {
                    bench(8, || {
                        std::hint::black_box(evaluate_serve_with(&cfg, opts, &perf));
                    })
                })
                .min()
                .unwrap()
        };
        let traced = best(&on);
        let untraced = best(&off);
        let overhead = traced.as_secs_f64() / untraced.as_secs_f64() - 1.0;
        assert!(
            overhead < 0.05,
            "trace recording overhead {:.1}% exceeds 5% (on {traced:?} vs off {untraced:?})",
            overhead * 100.0
        );
        if json_path.is_some() {
            let doc = Json::obj(vec![
                ("bench", Json::str("micro_substrates/trace_overhead")),
                ("events", Json::num(events as f64)),
                ("traced_ns", Json::num(traced.as_nanos() as f64)),
                ("untraced_ns", Json::num(untraced.as_nanos() as f64)),
                ("overhead_frac", Json::num(overhead)),
            ]);
            std::fs::write("BENCH_obs.json", doc.to_string_compact() + "\n")
                .expect("write BENCH_obs.json");
            println!("wrote BENCH_obs.json");
        }
        cases.push(CompareCase {
            name: "serve round + lifecycle tracing (recorder on vs off)",
            size: format!("8 problems × width 16, {events} events"),
            new: traced,
            reference: untraced,
        });
    }

    let mut cmp = Table::new(
        "Substrate before/after — shipped vs old-layout reference",
        &["substrate", "size", "new", "reference", "speedup"],
    );
    for c in &cases {
        cmp.row(vec![
            c.name.into(),
            c.size.clone(),
            format!("{:?}", c.new),
            format!("{:?}", c.reference),
            format!("{:.2}×", speedup(c.reference, c.new)),
        ]);
    }

    table.emit();
    cmp.emit();

    if let Some(path) = json_path {
        let doc = Json::obj(vec![
            ("bench", Json::str("micro_substrates")),
            ("simd_active", Json::num(if simd::simd_active() { 1.0 } else { 0.0 })),
            (
                "cases",
                Json::arr(cases.iter().map(|c| {
                    Json::obj(vec![
                        ("name", Json::str(c.name)),
                        ("size", Json::str(c.size.clone())),
                        ("new_ns", Json::num(c.new.as_nanos() as f64)),
                        ("ref_ns", Json::num(c.reference.as_nanos() as f64)),
                        ("speedup", Json::num(speedup(c.reference, c.new))),
                    ])
                })),
            ),
        ]);
        let text = doc.to_string_compact();
        if path == "-" {
            println!("{text}");
        } else {
            std::fs::write(&path, text + "\n").expect("write --json output");
            println!("wrote {path}");
        }
    }
}
