//! Microbenchmarks for the L3 substrates on the ETS hot path: the selection
//! solver (ILP / tree B&B), agglomerative clustering, the radix KV cache,
//! and REBASE allocation. These are the per-step costs the coordinator adds
//! on top of model execution — §Perf in EXPERIMENTS.md tracks them.

use ets::cluster::agglomerative;
use ets::ilp::select::{solve_tree, Candidate, SelectionProblem};
use ets::kvcache::RadixCache;
use ets::metrics::Table;
use ets::search::sampling::rebase_allocate;
use ets::util::rng::Rng;
use std::time::{Duration, Instant};

fn bench<F: FnMut()>(iters: usize, mut f: F) -> Duration {
    // warmup
    f();
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    t.elapsed() / iters as u32
}

fn selection_problem(rng: &mut Rng, n_leaves: usize, depth: usize) -> SelectionProblem {
    // chain-ish shared tree with n_leaves fresh leaves
    let mut parents: Vec<Option<usize>> = vec![None];
    for d in 1..depth {
        parents.push(Some(d - 1));
    }
    let mut candidates = vec![];
    let n_clusters = (n_leaves / 3).max(1);
    for i in 0..n_leaves {
        parents.push(Some(rng.index(depth)));
        candidates.push(Candidate {
            weight: 1.0 + rng.index(8) as f64,
            leaf_node: parents.len() - 1,
            cluster: i % n_clusters,
        });
    }
    SelectionProblem {
        candidates,
        node_weight: (0..parents.len()).map(|_| 20.0 + rng.index(60) as f64).collect(),
        parents,
        num_clusters: n_clusters,
        lambda_b: 1.5,
        lambda_d: 1.0,
    }
}

fn main() {
    let mut table = Table::new(
        "Microbenchmarks — per-step coordinator costs",
        &["op", "size", "time"],
    );
    let mut rng = Rng::new(7);

    for &n in &[16usize, 64, 256] {
        let p = selection_problem(&mut rng, n, 10);
        let d = bench(5, || {
            std::hint::black_box(solve_tree(&p, Duration::from_millis(10)));
        });
        table.row(vec!["ets-select (tree B&B)".into(), format!("{n} leaves"), format!("{d:?}")]);
    }

    for &n in &[16usize, 64, 256, 512] {
        let embs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..32).map(|_| rng.normal() as f32).collect())
            .collect();
        let d = bench(5, || {
            std::hint::black_box(agglomerative(&embs, 0.3));
        });
        table.row(vec!["clustering (UPGMA)".into(), format!("{n} vecs"), format!("{d:?}")]);
    }

    // agglomerative clustering across merge-threshold regimes: a high
    // threshold forces the full merge cascade (worst case), a low one stops
    // early. The cascade is O(n² log n) via the lazy pair min-heap (the
    // seed's best-pair rescan was O(n³)) — the spread and the win are
    // documented by cluster/mod.rs
    for &thr in &[0.1f64, 0.5, 0.9] {
        let embs: Vec<Vec<f32>> = (0..128)
            .map(|_| (0..32).map(|_| rng.normal() as f32).collect())
            .collect();
        let d = bench(5, || {
            std::hint::black_box(agglomerative(&embs, thr));
        });
        table.row(vec![
            "clustering (UPGMA, threshold sweep)".into(),
            format!("128 vecs, thr {thr}"),
            format!("{d:?}"),
        ]);
    }

    {
        let seqs: Vec<Vec<u32>> = (0..256)
            .map(|i| {
                let mut s: Vec<u32> = (0..120).map(|t| (t % 97) as u32).collect();
                s.extend((0..80).map(|t| ((i * 31 + t) % 211) as u32));
                s
            })
            .collect();
        let d = bench(10, || {
            let mut c = RadixCache::new(1 << 22);
            for s in &seqs {
                std::hint::black_box(c.insert(s));
            }
        });
        table.row(vec!["radix insert".into(), "256 × 200 tok".into(), format!("{d:?}")]);

        // LRU eviction under pressure: O(log n) per freed leaf via the
        // ordered evictable set (the seed rescanned the whole arena)
        let d = bench(10, || {
            let mut c = RadixCache::new(1 << 22);
            for s in &seqs {
                c.insert(s);
            }
            std::hint::black_box(c.evict(usize::MAX));
        });
        table.row(vec![
            "radix insert + LRU evict-all".into(),
            "256 × 200 tok".into(),
            format!("{d:?}"),
        ]);
    }

    for &n in &[64usize, 256] {
        let rewards: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let d = bench(200, || {
            std::hint::black_box(rebase_allocate(&rewards, n, 0.2));
        });
        table.row(vec!["rebase allocation".into(), format!("{n} cands"), format!("{d:?}")]);
    }

    table.emit();
}
