//! Figure 2 reproduction: proxy efficiency metrics (FLOPs≈tokens, model
//! calls, total KV size) vs modeled runtime, normalized to Beam Search, for
//! Beam / DVTS / REBASE at width 256 (√N retention), llemma-34b-sim on
//! synth-math500 — 100 problems, 8 co-scheduled threads on the H100 roofline.
//!
//! Paper's claim to reproduce: REBASE has ~the same FLOPs and model calls as
//! beam/DVTS but much larger KV and much higher runtime — FLOPs/calls are
//! bad proxies; KV size is the driver.

use ets::engine::{PerfModel, H100_NVL};
use ets::eval::{EvalConfig, PolicySpec};
use ets::lm::SynthLm;
use ets::metrics::Table;
use ets::reward::OraclePrm;
use ets::search::{run_search, SearchParams};
use ets::workload::{ProblemSet, WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

fn main() {
    let width = 256;
    let n_problems = 100;
    let spec = WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM);
    let pm = PerfModel::new(H100_NVL, true, 8);

    let policies =
        [PolicySpec::BeamSqrt, PolicySpec::DvtsSqrt, PolicySpec::Rebase];
    let mut rows: Vec<(String, f64, f64, f64, f64)> = vec![];
    for pol in &policies {
        let cfg = EvalConfig {
            spec: spec.clone(),
            policy: pol.clone(),
            width,
            n_problems,
            seed: 20260710,
            max_steps: SYNTH_MATH500.n_steps + 6,
        };
        // run searches and feed the outcomes through the roofline
        let problems = ProblemSet::generate(&cfg.spec, cfg.n_problems, cfg.seed);
        let (mut toks, mut calls, mut kv, mut secs) = (0f64, 0f64, 0f64, 0f64);
        for p in problems.problems {
            let id = p.id;
            let mut lm = SynthLm::new(p, cfg.seed ^ id);
            let mut prm = OraclePrm::for_profile(&spec.model, cfg.seed ^ 0xBEEF ^ id);
            let mut policy: Box<dyn ets::search::SearchPolicy> = match pol {
                PolicySpec::BeamSqrt => Box::new(ets::search::BeamPolicy { keep: 16 }),
                PolicySpec::DvtsSqrt => Box::new(ets::search::DvtsPolicy::new(16)),
                _ => Box::new(ets::search::RebasePolicy::default()),
            };
            let out = run_search(
                &mut lm,
                &mut prm,
                &mut policy,
                &SearchParams { width, max_steps: cfg.max_steps },
            );
            toks += out.total_new_tokens() as f64;
            calls += out.total_model_calls() as f64;
            kv += out.total_kv_tokens() as f64;
            secs += pm.latency(&out, &spec.model).seconds;
        }
        rows.push((pol.name(width), toks, calls, kv, secs));
    }

    let base = rows[0].clone();
    let mut table = Table::new(
        "Figure 2 — proxy metrics vs runtime (normalized to Beam Search, width 256)",
        &["method", "FLOPs(≈tokens)", "model calls", "KV size", "runtime"],
    );
    for (name, toks, calls, kv, secs) in &rows {
        table.row(vec![
            name.clone(),
            format!("{:.2}", toks / base.1),
            format!("{:.2}", calls / base.2),
            format!("{:.2}", kv / base.3),
            format!("{:.2}", secs / base.4),
        ]);
    }
    table.emit();
    println!(
        "shape check: REBASE FLOPs/calls ≈ beam (±10%), KV and runtime substantially higher."
    );
}
