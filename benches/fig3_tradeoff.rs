//! Figure 3 reproduction: accuracy vs efficiency (total KV cache size)
//! trade-off curves for all search strategies at widths {16, 64, 256} on
//! synth-math500 and synth-gsm8k (llemma-34b-sim).
//!
//! Series: Beam-4, Beam-√N, DVTS-4, DVTS-√N, REBASE, ETS (λ_b per the
//! paper's selection, λ_d = 1). Claim to reproduce: ETS sits up-left of
//! REBASE (same accuracy, less KV); beams sit low; REBASE tops accuracy
//! among baselines but at the largest KV.

use ets::eval::{evaluate, EvalConfig, PolicySpec};
use ets::metrics::{pct, Table};
use ets::workload::{WorkloadSpec, LLEMMA_34B_SIM, SYNTH_GSM8K, SYNTH_MATH500};

fn main() {
    let widths = [16usize, 64, 256];
    for dataset in [&SYNTH_MATH500, &SYNTH_GSM8K] {
        let spec = WorkloadSpec::new(dataset, &LLEMMA_34B_SIM);
        let mut table = Table::new(
            &format!("Figure 3 — accuracy vs total KV ({}, llemma-34b-sim)", dataset.name),
            &["method", "width", "acc%", "kv-tokens(mean)"],
        );
        for &width in &widths {
            let n_problems = if width == 256 { 60 } else { 100 };
            let mk = |policy| EvalConfig {
                spec: spec.clone(),
                policy,
                width,
                n_problems,
                seed: 20260710,
                max_steps: dataset.n_steps + 6,
            };
            for pol in [
                PolicySpec::Beam { keep: 4 },
                PolicySpec::BeamSqrt,
                PolicySpec::Dvts { subtrees: 4 },
                PolicySpec::DvtsSqrt,
                PolicySpec::Rebase,
                PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 },
            ] {
                let r = evaluate(&mk(pol.clone()));
                table.row(vec![
                    pol.name(width),
                    width.to_string(),
                    pct(r.accuracy()),
                    format!("{:.0}", r.mean_kv_tokens),
                ]);
            }
        }
        table.emit();
    }
    println!("shape check: per width, ETS ≈ REBASE accuracy at materially less KV; beam/DVTS below.");
}
