//! Table 2 reproduction: serving throughput of ETS vs REBASE at width 256
//! (synth-math500, llemma-34b-sim) — measured through the *batched serve
//! path*: concurrent problems interleave steps through one engine/radix
//! cache, and every merged batch is costed on the H100-NVL roofline
//! (`PerfModel::batch_latency`). Concurrency sweep {4, 8, 16, 32}, best
//! configuration per method.
//!
//! Claim to reproduce: ETS's KV reduction (~1.8x) converts into higher
//! throughput (~1.4x) without custom kernels, because a smaller resident
//! working set means fewer bytes per decode iteration and less batch
//! fragmentation.
//!
//! Second scenario — **oversubscription**: hold concurrency fixed and sweep
//! the *hard* KV block budget below the natural working set. The scheduler
//! gates admission on free-block watermarks and preempts/resumes sessions
//! under pressure, so the question becomes: at equal capacity, how many
//! concurrent problems does each method actually sustain, and at what
//! throughput? ETS's smaller per-problem footprint should buy admission
//! headroom (more problems resident) and fewer preemptions.
//!
//! Third scenario — **sharding**: the same oversubscription workload at a
//! fixed global budget, partitioned over shard-per-core engines
//! (`ServeOptions::shards`). Per-problem outcomes are byte-identical for
//! every shard count (asserted below); host wall-clock drops with shard
//! count on a multi-core machine because shard rounds execute on parallel
//! OS threads, and the cross-shard migration counter shows the scheduler
//! spilling stuck sessions to shards with free blocks instead of
//! thrashing preempt/resume locally.

use ets::coordinator::{serve, ServeJob, ServeOptions, ServeReport};
use ets::engine::{PerfModel, H100_NVL};
use ets::eval::{
    evaluate_serve, evaluate_serve_duplicate_prompts, evaluate_serve_mixed,
    evaluate_serve_with, EvalConfig, PolicySpec, ServeEvalReport,
};
use ets::lm::{AsyncLm, InjectedLatency, StepGenerator, SynthLm};
use ets::metrics::{ms, pct, ratio, Table};
use ets::reward::OraclePrm;
use ets::search::{RebasePolicy, SearchParams};
use ets::tree::{NodeId, SearchTree, StepInfo};
use ets::util::json::Json;
use ets::util::stats;
use ets::workload::{ProblemSet, WorkloadSpec, LLEMMA_34B_SIM, SYNTH_GSM8K, SYNTH_MATH500};

fn eval_cfg(policy: &PolicySpec, width: usize, n: usize) -> EvalConfig {
    EvalConfig {
        spec: WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM),
        policy: policy.clone(),
        width,
        n_problems: n,
        seed: 20260710,
        max_steps: SYNTH_MATH500.n_steps + 6,
    }
}

fn serve_at(policy: &PolicySpec, width: usize, n: usize, concurrency: usize) -> ServeEvalReport {
    let perf = PerfModel::new(H100_NVL, true, concurrency);
    evaluate_serve(&eval_cfg(policy, width, n), concurrency, &perf)
}

fn serve_capped(
    policy: &PolicySpec,
    width: usize,
    n: usize,
    concurrency: usize,
    capacity_tokens: usize,
) -> ServeEvalReport {
    serve_sharded(policy, width, n, concurrency, capacity_tokens, 1)
}

fn serve_sharded(
    policy: &PolicySpec,
    width: usize,
    n: usize,
    concurrency: usize,
    capacity_tokens: usize,
    shards: usize,
) -> ServeEvalReport {
    let perf = PerfModel::new(H100_NVL, true, concurrency);
    let opts = ServeOptions { concurrency, capacity_tokens, shards, ..Default::default() };
    evaluate_serve_with(&eval_cfg(policy, width, n), &opts, &perf)
}

/// Sweep concurrency and keep the best modeled throughput.
fn best_serve(policy: &PolicySpec, width: usize, n: usize) -> (usize, ServeEvalReport) {
    [4usize, 8, 16, 32]
        .iter()
        .map(|&c| (c, serve_at(policy, width, n, c)))
        .max_by(|a, b| {
            a.1.serve
                .throughput_problems_per_sec()
                .partial_cmp(&b.1.serve.throughput_problems_per_sec())
                .unwrap()
        })
        .unwrap()
}

fn main() {
    let width = 256;
    let n = 60;
    let rebase = best_serve(&PolicySpec::Rebase, width, n);
    let ets = best_serve(&PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 }, width, n);

    let mut table = Table::new(
        "Table 2 — batched serving throughput at width 256 (H100-NVL roofline, best of {4,8,16,32} concurrent)",
        &["method", "acc%", "KV red.", "throughput", "concurrency", "batch p50"],
    );
    let row = |label: &str, r: &(usize, ServeEvalReport), base: &ServeEvalReport| {
        let secs = r.1.serve.batch_seconds();
        vec![
            label.to_string(),
            pct(r.1.report.accuracy()),
            ratio(base.report.mean_kv_tokens, r.1.report.mean_kv_tokens),
            format!(
                "{:.2}x",
                r.1.serve.throughput_problems_per_sec()
                    / base.serve.throughput_problems_per_sec()
            ),
            r.0.to_string(),
            ms(stats::median(&secs)),
        ]
    };
    table.row(row("REBASE", &rebase, &rebase.1));
    table.row(row("ETS(λb=1.5)", &ets, &rebase.1));
    table.emit();
    println!(
        "absolute modeled throughput: REBASE {:.3} problems/s (peak resident {} kv-tok), ETS {:.3} problems/s (peak resident {} kv-tok)",
        rebase.1.serve.throughput_problems_per_sec(),
        rebase.1.serve.peak_resident_kv_tokens,
        ets.1.serve.throughput_problems_per_sec(),
        ets.1.serve.peak_resident_kv_tokens
    );
    println!("shape check: ETS KV reduction translates to >1x throughput at equal accuracy.");

    // ---- oversubscription: capacity sweep under a hard block budget ------
    let (o_width, o_n, o_conc) = (64usize, 24usize, 16usize);
    // probe the natural (uncapped) working set with the heavier method
    let probe = serve_at(&PolicySpec::Rebase, o_width, o_n, o_conc);
    let natural = probe.serve.peak_resident_kv_tokens;
    let solo_peak = probe
        .serve
        .outcomes
        .iter()
        .map(|o| o.peak_kv_tokens())
        .max()
        .unwrap_or(0) as usize;
    // floor: never below one problem's working set (scheduler livelock);
    // dedup clamped points so a low natural peak doesn't repeat runs
    let floor = 2 * solo_peak + 4096;
    let mut caps =
        vec![natural.max(floor), (natural / 2).max(floor), (natural / 4).max(floor)];
    caps.dedup();
    if caps.len() == 1 {
        // degenerate workload (no co-residency headroom): still report two
        // capacity points, one ample and one at the floor
        caps.insert(0, caps[0] * 2);
    }
    let mut over = Table::new(
        "Oversubscription — hard KV budget sweep at width 64, concurrency 16 \
         (admitted = in the scheduler incl. swapped-out; resident = most \
         problems advancing in one round)",
        &["method", "capacity", "admitted", "resident", "preempt", "recompute", "acc%", "throughput"],
    );
    for &cap in &caps {
        let rb = serve_capped(&PolicySpec::Rebase, o_width, o_n, o_conc, cap);
        let et = serve_capped(
            &PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 },
            o_width,
            o_n,
            o_conc,
            cap,
        );
        let base_tp = rb.serve.throughput_problems_per_sec();
        for (label, r) in [("REBASE", &rb), ("ETS(λb=1.5)", &et)] {
            over.row(vec![
                label.to_string(),
                format!("{} tok", cap),
                r.serve.max_concurrent.to_string(),
                r.serve.peak_step_concurrency.to_string(),
                r.serve.preemptions.to_string(),
                format!("{} tok", r.serve.recompute_tokens),
                pct(r.report.accuracy()),
                format!(
                    "{:.2}x",
                    r.serve.throughput_problems_per_sec() / base_tp
                ),
            ]);
        }
    }
    over.emit();
    println!(
        "shape check: at equal hard capacity, ETS keeps >= as many problems \
         resident (advancing per round) as REBASE and pays fewer preemption/\
         recompute penalties; answers are capacity-invariant by construction."
    );

    // ---- sharding: shard-count sweep at a fixed global budget ------------
    // Budget: the natural working set, floored so every shard's partition
    // still holds one problem's working set with slack (no scheduler
    // livelock at 4 shards).
    let shard_cap = natural.max(4 * (solo_peak + 4096));
    let mut shard_table = Table::new(
        "Sharded serve — shard sweep at width 64, concurrency 16, fixed global \
         budget (modeled = per-round max across shards; wall = host time, \
         shards step on parallel OS threads)",
        &["method", "shards", "migrations", "preempt", "throughput", "wall", "identical"],
    );
    let mut divergent: Vec<String> = Vec::new();
    for (label, policy) in [
        ("REBASE", PolicySpec::Rebase),
        ("ETS(λb=1.5)", PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 }),
    ] {
        let mut base: Option<(f64, Vec<(bool, u64, u64)>)> = None;
        for &shards in &[1usize, 2, 4] {
            let t0 = std::time::Instant::now();
            let r = serve_sharded(&policy, o_width, o_n, o_conc, shard_cap, shards);
            let wall = t0.elapsed();
            let fp = &r.report.per_problem;
            if base.is_none() {
                base = Some((r.serve.throughput_problems_per_sec(), fp.clone()));
            }
            let (base_tp, base_fp) = base.as_ref().expect("seeded above");
            let (base_tp, identical) = (*base_tp, base_fp == fp);
            shard_table.row(vec![
                label.to_string(),
                shards.to_string(),
                r.serve.migrations.to_string(),
                r.serve.preemptions.to_string(),
                format!("{:.2}x", r.serve.throughput_problems_per_sec() / base_tp),
                format!("{:.0} ms", wall.as_secs_f64() * 1e3),
                if identical { "yes".into() } else { "NO".into() },
            ]);
            if !identical {
                divergent.push(format!("{label} shards={shards}"));
            }
        }
    }
    shard_table.emit();
    assert!(
        divergent.is_empty(),
        "sharding must be invisible to results; diverged: {divergent:?}"
    );
    println!(
        "shape check: per-problem outcomes are byte-identical for shards in \
         {{1, 2, 4}}; host wall-clock improves with shard count on a \
         multi-core machine (shards are parallel OS threads), and tight \
         multi-shard runs migrate stuck sessions instead of thrashing."
    );

    // ---- cross-shard prefix sharing: duplicate-heavy prompt sweep --------
    // Real-traffic prompts repeat (retries, templated queries, multi-sample
    // users). Problems draw real prompt ids from a pool of `distinct`
    // prompts and are served over 4 shared-nothing shards; `--prefix-share`
    // turns on the global prefix hub, so duplicates route to the shard
    // already holding (or warmly retaining) their prefix and re-pin it
    // instead of duplicating KV fleet-wide. Per-problem outcomes must be
    // byte-identical with sharing on or off — only placement, resident
    // blocks, and modeled time may move.
    let (d_width, d_n, d_conc, d_shards) = (32usize, 24usize, 6usize, 4usize);
    let mut hub_table = Table::new(
        "Global prefix hub — duplicate-heavy prompts at width 32, 24 problems, \
         concurrency 6, 4 shards (hit rate = affinity-routed admissions / \
         problems; avg KV blocks = mean fleet-resident blocks per round)",
        &["distinct prompts", "share", "hub hits", "hit rate", "avg KV blocks", "throughput", "identical"],
    );
    // pool sizes deliberately misaligned with the 4-shard admission
    // rotation (6 and 3, vs a 6-wide admission wave): an aligned pool can
    // let the least-loaded fallback colocate duplicates by accident, which
    // would flatter the sharing-off baseline
    for &distinct in &[d_n, 6usize, 3] {
        let run = |share: bool| {
            let opts = ServeOptions {
                concurrency: d_conc,
                shards: d_shards,
                prefix_share: share,
                ..Default::default()
            };
            let perf = PerfModel::new(H100_NVL, true, d_conc);
            evaluate_serve_duplicate_prompts(
                &eval_cfg(&PolicySpec::Rebase, d_width, d_n),
                &opts,
                &perf,
                distinct,
            )
        };
        let off = run(false);
        let on = run(true);
        let identical = off.report.per_problem == on.report.per_problem;
        assert!(
            identical,
            "prefix sharing changed results at distinct={distinct}"
        );
        if distinct < d_n {
            assert!(
                on.serve.hub_hits > 0,
                "duplicate prompts must produce hub hits (distinct={distinct})"
            );
            assert!(
                on.serve.mean_used_blocks() < off.serve.mean_used_blocks(),
                "sharing must shrink mean resident blocks at distinct={distinct}: \
                 on {} vs off {}",
                on.serve.mean_used_blocks(),
                off.serve.mean_used_blocks()
            );
        }
        let base_tp = off.serve.throughput_problems_per_sec();
        for (label, r) in [("off", &off), ("on", &on)] {
            hub_table.row(vec![
                distinct.to_string(),
                label.to_string(),
                r.serve.hub_hits.to_string(),
                pct(r.serve.hub_hit_rate()),
                format!("{:.0}", r.serve.mean_used_blocks()),
                format!("{:.2}x", r.serve.throughput_problems_per_sec() / base_tp),
                if identical { "yes".into() } else { "NO".into() },
            ]);
        }
    }
    hub_table.emit();
    println!(
        "shape check: the duplicate-heavier the workload, the higher the hub \
         hit rate and the lower the mean resident KV blocks with sharing on; \
         per-problem outcomes are byte-identical either way."
    );

    // ---- pipelining: lockstep vs pipelined rounds, decode-bound sweep ----
    // An injected per-round decode latency stands in for a slow real-model
    // backend (PJRT device time, a network hop). With `pipeline` on, a
    // round is costed max(decode, plan + commit) — shard k+1's decode
    // overlapping shard k's commit — so for a decode-bound workload the
    // modeled round cost collapses to the decode phase and the whole
    // plan + commit bill is the overlap saving.
    let mut pipe_table = Table::new(
        "Pipelined vs lockstep rounds — injected decode-latency sweep at \
         width 32, concurrency 8, 4 shards (savings = lockstep - pipelined \
         modeled seconds; identical = per-problem outcomes byte-identical)",
        &["inj decode/round", "lockstep", "pipelined", "savings", "identical"],
    );
    for &latency in &[0.0f64, 0.02, 0.05] {
        let run = |pipeline: bool| -> ServeReport {
            let opts = ServeOptions { concurrency: 8, shards: 4, pipeline, ..Default::default() };
            let perf = PerfModel::new(H100_NVL, true, 8);
            let params = SearchParams { width: 32, max_steps: SYNTH_MATH500.n_steps + 6 };
            serve(injected_jobs(12, 20260710, latency), &params, &opts, &perf, &LLEMMA_34B_SIM)
        };
        let lockstep = run(false);
        let pipelined = run(true);
        let identical = outcome_fingerprints(&lockstep) == outcome_fingerprints(&pipelined);
        assert!(identical, "pipelining changed outcomes at latency {latency}");
        // every pipelined round collapses to its slower phase; decode-bound
        // rounds cost exactly their decode
        for b in &pipelined.batches {
            assert_eq!(b.seconds, b.decode_seconds.max(b.overhead_seconds), "{b:?}");
            if b.decode_seconds >= b.overhead_seconds {
                assert_eq!(b.seconds, b.decode_seconds);
            }
        }
        for b in &lockstep.batches {
            assert_eq!(b.seconds, b.decode_seconds + b.overhead_seconds, "{b:?}");
        }
        let savings = lockstep.modeled_seconds - pipelined.modeled_seconds;
        assert!(
            savings > 0.0,
            "a workload with commit work must save under pipelining \
             (lockstep {} vs pipelined {})",
            lockstep.modeled_seconds,
            pipelined.modeled_seconds
        );
        pipe_table.row(vec![
            ms(latency),
            format!("{:.3} s", lockstep.modeled_seconds),
            format!("{:.3} s", pipelined.modeled_seconds),
            format!("{:.3} s ({:.1}%)", savings, 100.0 * savings / lockstep.modeled_seconds),
            if identical { "yes".into() } else { "NO".into() },
        ]);
    }
    pipe_table.emit();
    println!(
        "shape check: pipelined rounds cost max(decode, plan+commit); the \
         more decode-bound the backend (injected latency up), the closer \
         the pipelined run gets to hiding the entire plan+commit bill."
    );

    // ---- true-async overlap: executed wall-clock, not modeled seconds ----
    // The pipelining table above *prices* the overlap on the H100 roofline;
    // this section *executes* it on the host. The lockstep baseline really
    // sleeps the injected latency on the shard worker, once per submitted
    // session batch ([`BlockingLatency`]) — so a shard's sessions serialize
    // their decode stalls exactly like a synchronous backend. The async run
    // hands the same jobs to [`AsyncLm`], whose completion workers realize
    // the same hint off-thread: a shard's session sleeps overlap, and a
    // round's decode wall collapses to ~one latency. Both walls are checked
    // against the realized-sleep folds reconstructed from the batch records
    // (grouped back into rounds via their documented (round, shard) order):
    // the async wall must land within 10% of the overlapped
    // max(decode, plan+commit) fold and strictly below the lockstep sum.
    let emit_json = std::env::args().any(|a| a == "--json");
    let mut overlap_rows: Vec<Json> = Vec::new();
    let mut overlap_table = Table::new(
        "True-async data plane — executed injected-latency sweep at width 32, \
         concurrency 8, 4 shards (folds = realized decode sleeps: lockstep \
         serializes a shard's sessions, async overlaps them per round)",
        &["inj decode/round", "lockstep wall", "lockstep fold", "async wall", "async fold", "identical"],
    );
    for &latency in &[0.04f64, 0.08] {
        let params = SearchParams { width: 32, max_steps: SYNTH_MATH500.n_steps + 6 };
        let perf = PerfModel::new(H100_NVL, true, 8);

        let lock_opts = ServeOptions { concurrency: 8, shards: 4, ..Default::default() };
        let t0 = std::time::Instant::now();
        let lockstep = serve(
            blocking_jobs(12, 20260710, latency),
            &params,
            &lock_opts,
            &perf,
            &LLEMMA_34B_SIM,
        );
        let lockstep_wall = t0.elapsed().as_secs_f64();

        let async_opts = ServeOptions {
            concurrency: 8,
            shards: 4,
            pipeline: true,
            async_decode: true,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let asynced = serve(
            async_jobs(12, 20260710, latency),
            &params,
            &async_opts,
            &perf,
            &LLEMMA_34B_SIM,
        );
        let async_wall = t0.elapsed().as_secs_f64();

        let identical = outcome_fingerprints(&lockstep) == outcome_fingerprints(&asynced);
        assert!(identical, "the async data plane changed outcomes at latency {latency}");
        assert!(
            asynced.spec_plan_hits > 0,
            "speculative planning never hit over a full sweep run"
        );
        let (async_fold, _) = realized_decode_folds(&asynced, latency);
        let (_, lockstep_fold) = realized_decode_folds(&lockstep, latency);
        assert!(
            (async_wall - async_fold).abs() <= 0.10 * async_fold,
            "async wall {async_wall:.3}s strayed >10% from the realized \
             max(decode, plan+commit) fold {async_fold:.3}s at latency {latency}"
        );
        assert!(
            async_wall < lockstep_fold,
            "async wall {async_wall:.3}s must land strictly below the lockstep \
             sleep sum {lockstep_fold:.3}s at latency {latency}"
        );
        assert!(
            async_wall < lockstep_wall,
            "async wall {async_wall:.3}s must beat the measured lockstep wall \
             {lockstep_wall:.3}s at latency {latency}"
        );
        overlap_table.row(vec![
            ms(latency),
            format!("{:.3} s", lockstep_wall),
            format!("{:.3} s", lockstep_fold),
            format!("{:.3} s", async_wall),
            format!("{:.3} s", async_fold),
            if identical { "yes".into() } else { "NO".into() },
        ]);
        overlap_rows.push(Json::obj(vec![
            ("latency_s", Json::num(latency)),
            ("rounds", Json::num(asynced.rounds as f64)),
            ("lockstep_wall_s", Json::num(lockstep_wall)),
            ("lockstep_fold_s", Json::num(lockstep_fold)),
            ("async_wall_s", Json::num(async_wall)),
            ("async_fold_s", Json::num(async_fold)),
            ("modeled_pipelined_s", Json::num(asynced.modeled_seconds)),
            ("spec_plan_hits", Json::num(asynced.spec_plan_hits as f64)),
            ("spec_plan_misses", Json::num(asynced.spec_plan_misses as f64)),
        ]));
    }
    overlap_table.emit();
    println!(
        "shape check: with the latency actually executed, the async data \
         plane's measured wall tracks the overlapped decode fold (within \
         10%) and lands strictly below the lockstep sleep sum — the modeled \
         overlap from the table above, realized on host threads."
    );
    if emit_json {
        let doc = Json::obj(vec![
            ("bench", Json::str("true_async_overlap")),
            ("sweep", Json::arr(overlap_rows)),
        ]);
        std::fs::write("BENCH_overlap.json", doc.to_string_compact() + "\n")
            .expect("write BENCH_overlap.json");
        println!("wrote BENCH_overlap.json");
    }

    // ---- tiered KV: three-tier oversubscription story --------------------
    // The headline for the host-DRAM spill tier: sweep the hard HBM budget
    // from the natural working set down to ~10x oversubscribed over a
    // duplicate-heavy pool (6 distinct prompts behind 24 problems — the
    // workload whose evictions are most worth keeping), with the cold tier
    // off (evict = destroy = recompute on resume) vs on (evict = demote to
    // host DRAM = PCIe restore on resume). Every token a resume needs lands
    // in exactly one of three tiers: still HBM-resident, restored from the
    // spill tier, or recomputed from scratch — and the cold-on run must
    // convert recompute into restores one-for-one without moving a single
    // per-problem result byte.
    let mut tier_rows: Vec<Json> = Vec::new();
    let mut tier_table = Table::new(
        "Tiered KV — oversubscription sweep at width 64, 24 problems (6 \
         distinct prompts), concurrency 16 (over = natural peak / HBM \
         budget; restored = tokens re-filled from host DRAM over PCIe; \
         goodput = modeled problems/s)",
        &["over", "cold", "demoted", "restored", "recompute", "preempt", "goodput", "identical"],
    );
    let tier_cfg = eval_cfg(&PolicySpec::Rebase, o_width, o_n);
    let tier_perf = PerfModel::new(H100_NVL, true, o_conc);
    let cold_budget = 2 * natural.max(1);
    let mut tightest: Option<(f64, ServeEvalReport, ServeEvalReport)> = None;
    for &factor in &[1usize, 4, 10] {
        let cap = (natural / factor).max(floor);
        let over = natural as f64 / cap as f64;
        let run = |cold: usize| {
            let opts = ServeOptions {
                concurrency: o_conc,
                capacity_tokens: cap,
                block_size: 16,
                ..Default::default()
            }
            .cold_tiered(cold);
            evaluate_serve_duplicate_prompts(&tier_cfg, &opts, &tier_perf, 6)
        };
        let off = run(0);
        let on = run(cold_budget);
        let identical = off.report.per_problem == on.report.per_problem;
        assert!(identical, "the cold tier changed results at {over:.1}x oversubscription");
        // token conservation: every token the evict-only run recomputed is
        // either restored from the spill tier or still recomputed — demotion
        // may never invent or lose work
        assert_eq!(
            on.serve.recompute_tokens + on.serve.restored_kv_tokens,
            off.serve.recompute_tokens,
            "restored + recomputed must equal the evict-only recompute bill \
             at {over:.1}x"
        );
        let off_tp = off.serve.throughput_problems_per_sec();
        let on_tp = on.serve.throughput_problems_per_sec();
        if over >= 4.0 && off.serve.recompute_tokens > 0 {
            assert!(
                on.serve.restored_kv_tokens > 0,
                "a {over:.1}x oversubscribed run must restore from the spill \
                 tier"
            );
            assert!(
                on.serve.recompute_tokens < off.serve.recompute_tokens,
                "the spill tier must strictly cut recompute at {over:.1}x: \
                 {} vs {}",
                on.serve.recompute_tokens,
                off.serve.recompute_tokens
            );
            assert!(
                on_tp > off_tp,
                "PCIe restores must beat recompute prefill at {over:.1}x \
                 oversubscription: {on_tp:.3} vs {off_tp:.3} problems/s"
            );
        }
        for (label, r, tp) in [("off", &off, off_tp), ("on", &on, on_tp)] {
            tier_table.row(vec![
                format!("{over:.1}x"),
                label.to_string(),
                format!("{} tok", r.serve.demoted_kv_tokens),
                format!("{} tok", r.serve.restored_kv_tokens),
                format!("{} tok", r.serve.recompute_tokens),
                r.serve.preemptions.to_string(),
                format!("{:.2}x", tp / off_tp),
                if identical { "yes".into() } else { "NO".into() },
            ]);
        }
        for (label, r) in [("off", &off), ("on", &on)] {
            tier_rows.push(Json::obj(vec![
                ("oversubscription", Json::num(over)),
                ("capacity_tokens", Json::num(cap as f64)),
                ("cold", Json::str(label)),
                ("cold_capacity_tokens", Json::num(r.serve.cold_capacity_tokens as f64)),
                ("peak_resident_kv_tokens", Json::num(r.serve.peak_resident_kv_tokens as f64)),
                ("demoted_kv_tokens", Json::num(r.serve.demoted_kv_tokens as f64)),
                ("restored_kv_tokens", Json::num(r.serve.restored_kv_tokens as f64)),
                ("recompute_tokens", Json::num(r.serve.recompute_tokens as f64)),
                ("cold_dropped_kv_tokens", Json::num(r.serve.cold_dropped_kv_tokens as f64)),
                ("preemptions", Json::num(r.serve.preemptions as f64)),
                ("modeled_seconds", Json::num(r.serve.modeled_seconds)),
                ("goodput_problems_per_sec", Json::num(r.serve.throughput_problems_per_sec())),
            ]));
        }
        if tightest.as_ref().map_or(true, |(o, _, _)| over > *o) {
            tightest = Some((over, off, on));
        }
    }
    tier_table.emit();
    if let Some((over, off, on)) = &tightest {
        println!(
            "shape check: at {over:.1}x oversubscription the spill tier turns \
             {} of {} recomputed tokens into PCIe restores ({} demoted), \
             lifting modeled goodput {:.2}x — with byte-identical answers.",
            on.serve.restored_kv_tokens,
            off.serve.recompute_tokens,
            on.serve.demoted_kv_tokens,
            on.serve.throughput_problems_per_sec()
                / off.serve.throughput_problems_per_sec().max(f64::MIN_POSITIVE),
        );
    }
    if emit_json {
        let doc = Json::obj(vec![
            ("bench", Json::str("tiered_kv_oversubscription")),
            ("sweep", Json::arr(tier_rows)),
        ]);
        std::fs::write("BENCH_tiers.json", doc.to_string_compact() + "\n")
            .expect("write BENCH_tiers.json");
        println!("wrote BENCH_tiers.json");
    }

    // ---- adaptive budgeting: mixed-difficulty fleet at equal KV budget ---
    // The compute-optimal claim: over a fleet mixing easy (synth-gsm8k) and
    // hard (synth-math500) problems at one global block budget, predicting
    // per-problem difficulty and reallocating width/KV mid-flight must not
    // cost accuracy while spending strictly fewer modeled block-seconds
    // (Σ resident blocks × round seconds) than the fixed-width baseline:
    // easy and hopeless sessions release budget they cannot convert,
    // contested ones spend it.
    let (a_width, a_hard, a_easy, a_conc) = (32usize, 12usize, 12usize, 8usize);
    let a_cfg = eval_cfg(&PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 }, a_width, a_hard);
    let gsm = WorkloadSpec::new(&SYNTH_GSM8K, &LLEMMA_34B_SIM);
    let a_perf = PerfModel::new(H100_NVL, true, a_conc);
    let a_probe = evaluate_serve_mixed(
        &a_cfg,
        &gsm,
        a_easy,
        &ServeOptions::with_concurrency(a_conc),
        &a_perf,
    );
    let a_natural = a_probe.serve.peak_resident_kv_tokens;
    let a_solo = a_probe
        .serve
        .outcomes
        .iter()
        .map(|o| o.peak_kv_tokens())
        .max()
        .unwrap_or(0) as usize;
    let a_floor = 2 * a_solo + 4096;
    let mut a_caps = vec![a_natural.max(a_floor), (a_natural / 2).max(a_floor)];
    a_caps.dedup();
    let mut adaptive_rows: Vec<Json> = Vec::new();
    let mut adaptive_table = Table::new(
        "Adaptive budgeting — mixed synth-gsm8k + synth-math500 fleet at \
         width 32, concurrency 8, equal global KV budget (block-s = Σ \
         resident blocks × round seconds; reall. = width shrinks/grants)",
        &["capacity", "adaptive", "acc%", "block-s", "modeled", "reall.", "blocks -/+"],
    );
    for &cap in &a_caps {
        let run = |adaptive: bool| {
            let opts = ServeOptions {
                concurrency: a_conc,
                capacity_tokens: cap,
                block_size: 16,
                ..Default::default()
            }
            .adaptive_budgeted(adaptive);
            evaluate_serve_mixed(&a_cfg, &gsm, a_easy, &opts, &a_perf)
        };
        let fixed = run(false);
        let adapt = run(true);
        let (f_acc, a_acc) = (fixed.report.accuracy(), adapt.report.accuracy());
        let f_bs = fixed.serve.modeled_block_seconds();
        let a_bs = adapt.serve.modeled_block_seconds();
        assert!(
            adapt.serve.width_shrinks > 0,
            "the easy half of a mixed fleet must trigger width shrinks \
             (capacity {cap})"
        );
        // the compute-optimal dominance check: never trade accuracy away,
        // and convert the reclaimed budget into strictly cheaper serving
        // (or into strictly more accuracy at no extra block cost)
        assert!(
            (a_acc >= f_acc && a_bs < f_bs) || (a_acc > f_acc && a_bs <= f_bs),
            "adaptive budgeting must dominate the fixed-width baseline at \
             capacity {cap}: acc {a_acc:.4} vs {f_acc:.4}, block-seconds \
             {a_bs:.1} vs {f_bs:.1}"
        );
        for (label, r, acc, bs) in
            [("off", &fixed, f_acc, f_bs), ("on", &adapt, a_acc, a_bs)]
        {
            adaptive_table.row(vec![
                format!("{} tok", cap),
                label.to_string(),
                pct(acc),
                format!("{:.1}", bs),
                format!("{:.3} s", r.serve.modeled_seconds),
                format!("{}/{}", r.serve.width_shrinks, r.serve.width_grants),
                format!(
                    "{}/{}",
                    r.serve.reclaimed_kv_blocks, r.serve.granted_kv_blocks
                ),
            ]);
            adaptive_rows.push(Json::obj(vec![
                ("capacity_tokens", Json::num(cap as f64)),
                ("adaptive", Json::str(label)),
                ("accuracy", Json::num(acc)),
                ("modeled_block_seconds", Json::num(bs)),
                ("modeled_seconds", Json::num(r.serve.modeled_seconds)),
                ("width_shrinks", Json::num(r.serve.width_shrinks as f64)),
                ("width_grants", Json::num(r.serve.width_grants as f64)),
                ("reclaimed_kv_blocks", Json::num(r.serve.reclaimed_kv_blocks as f64)),
                ("granted_kv_blocks", Json::num(r.serve.granted_kv_blocks as f64)),
                ("budget_decisions", Json::num(r.serve.budget_decisions.len() as f64)),
                ("peak_resident_kv_tokens", Json::num(r.serve.peak_resident_kv_tokens as f64)),
            ]));
        }
    }
    adaptive_table.emit();
    println!(
        "shape check: at equal global KV budget the adaptive controller \
         matches or beats fixed-width accuracy while spending strictly \
         fewer modeled block-seconds — the reclaimed easy-session budget \
         funds the contested tail."
    );
    if emit_json {
        let doc = Json::obj(vec![
            ("bench", Json::str("adaptive_budget")),
            ("sweep", Json::arr(adaptive_rows)),
        ]);
        std::fs::write("BENCH_adaptive.json", doc.to_string_compact() + "\n")
            .expect("write BENCH_adaptive.json");
        println!("wrote BENCH_adaptive.json");
    }
}

/// Jobs whose generator reports a fixed modeled decode latency per round —
/// identical sampling to the plain SynthLm jobs, decode-bound costing.
fn injected_jobs(
    n: usize,
    seed: u64,
    latency: f64,
) -> Vec<ServeJob<InjectedLatency<SynthLm>, OraclePrm, RebasePolicy>> {
    let spec = WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM);
    ProblemSet::generate(&spec, n, seed)
        .problems
        .into_iter()
        .map(|p| {
            let id = p.id;
            let prm = OraclePrm::for_profile(&spec.model, seed ^ 0xBEEF ^ id);
            ServeJob {
                lm: InjectedLatency::new(SynthLm::new(p, seed ^ id), latency),
                prm,
                policy: RebasePolicy::default(),
            }
        })
        .collect()
}

/// Bench-local wrapper that *executes* the injected latency: sleeps the
/// modeled hint on the caller thread once per submitted batch, exactly where
/// a synchronous backend would stall the shard worker. This is the measured
/// lockstep baseline the true-async overlap section compares against —
/// identical sampling and identical modeled costs to [`InjectedLatency`]
/// (same hint), the stall is just real.
struct BlockingLatency {
    inner: InjectedLatency<SynthLm>,
}

impl StepGenerator for BlockingLatency {
    fn expand(&mut self, tree: &SearchTree, leaf: NodeId, n: usize) -> Vec<StepInfo> {
        std::thread::sleep(std::time::Duration::from_secs_f64(self.inner.seconds_per_round));
        self.inner.expand(tree, leaf, n)
    }

    fn expand_batch(
        &mut self,
        tree: &SearchTree,
        requests: &[(NodeId, usize)],
    ) -> Vec<Vec<StepInfo>> {
        std::thread::sleep(std::time::Duration::from_secs_f64(self.inner.seconds_per_round));
        self.inner.expand_batch(tree, requests)
    }

    fn decode_overhead_seconds(&self) -> f64 {
        self.inner.decode_overhead_seconds()
    }

    fn prompt_tokens(&self) -> usize {
        self.inner.prompt_tokens()
    }

    fn prompt_token_ids(&self) -> Option<Vec<u32>> {
        self.inner.prompt_token_ids()
    }
}

/// The injected jobs with the latency *executed* synchronously (measured
/// lockstep baseline).
fn blocking_jobs(
    n: usize,
    seed: u64,
    latency: f64,
) -> Vec<ServeJob<BlockingLatency, OraclePrm, RebasePolicy>> {
    injected_jobs(n, seed, latency)
        .into_iter()
        .map(|j| ServeJob { lm: BlockingLatency { inner: j.lm }, prm: j.prm, policy: j.policy })
        .collect()
}

/// A serve job whose injected decode latency is realized off-thread by the
/// completion-queue backend.
type AsyncInjectedJob = ServeJob<AsyncLm<InjectedLatency<SynthLm>>, OraclePrm, RebasePolicy>;

/// The injected jobs behind the completion-queue backend: [`AsyncLm`]'s
/// worker realizes the latency hint off-thread, so concurrent sessions'
/// stalls overlap.
fn async_jobs(n: usize, seed: u64, latency: f64) -> Vec<AsyncInjectedJob> {
    injected_jobs(n, seed, latency)
        .into_iter()
        .map(|j| ServeJob { lm: AsyncLm::new(j.lm), prm: j.prm, policy: j.policy })
        .collect()
}

/// Realized decode-sleep folds of a run, reconstructed from the batch
/// records (regrouped into rounds via their documented (round, shard) order:
/// a non-increasing shard index starts a new round). Returns
/// `(overlap_fold, lockstep_fold)`:
///
/// * overlap fold — the async data plane sleeps the hint once per decoding
///   shard with every session's completion worker overlapping, and shards
///   step on parallel OS threads, so a decode round's realized wall is one
///   `latency`;
/// * lockstep fold — the blocking baseline sleeps once per submitted session
///   batch, serialized on the shard worker, so a round's realized wall is
///   `max over shards (decoding sessions x latency)`.
fn realized_decode_folds(report: &ServeReport, latency: f64) -> (f64, f64) {
    let mut overlap = 0.0f64;
    let mut lockstep = 0.0f64;
    let mut round_max_sessions = 0usize;
    let mut prev_shard = usize::MAX;
    for b in &report.batches {
        if prev_shard != usize::MAX && b.shard <= prev_shard {
            overlap += latency;
            lockstep += round_max_sessions as f64 * latency;
            round_max_sessions = 0;
        }
        prev_shard = b.shard;
        round_max_sessions = round_max_sessions.max(b.problems);
    }
    if prev_shard != usize::MAX {
        overlap += latency;
        lockstep += round_max_sessions as f64 * latency;
    }
    (overlap, lockstep)
}

fn outcome_fingerprints(report: &ServeReport) -> Vec<(Option<i64>, u64, u64)> {
    report
        .outcomes
        .iter()
        .map(|o| (o.answer, o.total_kv_tokens(), o.total_new_tokens()))
        .collect()
}
