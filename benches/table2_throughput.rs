//! Table 2 reproduction: serving throughput of ETS vs REBASE at width 256
//! (synth-math500, llemma-34b-sim), on the H100-NVL roofline model with the
//! paper's thread sweep {4, 8, 16, 32} — best configuration per method.
//!
//! Claim to reproduce: ETS's KV reduction (~1.8x) converts into higher
//! throughput (~1.4x) without custom kernels, because smaller working sets
//! mean fewer bytes and less batch fragmentation.

use ets::engine::{PerfModel, H100_NVL};
use ets::eval::PolicySpec;
use ets::lm::SynthLm;
use ets::metrics::{pct, ratio, Table};
use ets::reward::OraclePrm;
use ets::search::{run_search, SearchOutcome, SearchParams};
use ets::workload::{ProblemSet, WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

fn outcomes(policy: &PolicySpec, width: usize, n: usize) -> (Vec<SearchOutcome>, f64) {
    let spec = WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM);
    let seed = 20260710u64;
    let problems = ProblemSet::generate(&spec, n, seed);
    let mut outs = Vec::with_capacity(n);
    let mut correct = 0usize;
    for p in problems.problems {
        let truth = p.answer;
        let id = p.id;
        let mut lm = SynthLm::new(p, seed ^ id);
        let mut prm = OraclePrm::for_profile(&spec.model, seed ^ 0xBEEF ^ id);
        let mut pol: Box<dyn ets::search::SearchPolicy> = match policy {
            PolicySpec::Rebase => Box::new(ets::search::RebasePolicy::default()),
            PolicySpec::Ets { lambda_b, lambda_d } => Box::new(ets::search::EtsPolicy::new(
                *lambda_b,
                *lambda_d,
                ets::embed::HashEmbedder::default(),
            )),
            _ => unreachable!(),
        };
        let out = run_search(
            &mut lm,
            &mut prm,
            &mut pol,
            &SearchParams { width, max_steps: SYNTH_MATH500.n_steps + 6 },
        );
        if out.answer == Some(truth) {
            correct += 1;
        }
        outs.push(out);
    }
    (outs, correct as f64 / n as f64)
}

fn main() {
    let width = 256;
    let n = 60;
    let model = &LLEMMA_34B_SIM;
    let (rebase_outs, rebase_acc) = outcomes(&PolicySpec::Rebase, width, n);
    let (ets_outs, ets_acc) =
        outcomes(&PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 }, width, n);

    let kv = |outs: &[SearchOutcome]| -> f64 {
        outs.iter().map(|o| o.total_kv_tokens() as f64).sum::<f64>() / outs.len() as f64
    };
    let best_tp = |outs: &[SearchOutcome]| -> (usize, f64) {
        [4usize, 8, 16, 32]
            .iter()
            .map(|&t| (t, PerfModel::new(H100_NVL, true, t).throughput(outs, model)))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap()
    };
    let (rt, rtp) = best_tp(&rebase_outs);
    let (et, etp) = best_tp(&ets_outs);

    let mut table = Table::new(
        "Table 2 — throughput at width 256 (H100-NVL roofline, best of {4,8,16,32} threads)",
        &["method", "acc%", "KV red.", "throughput", "threads"],
    );
    table.row(vec![
        "REBASE".into(),
        pct(rebase_acc),
        "1.00x".into(),
        "1.00x".into(),
        rt.to_string(),
    ]);
    table.row(vec![
        "ETS(λb=1.5)".into(),
        pct(ets_acc),
        ratio(kv(&rebase_outs), kv(&ets_outs)),
        format!("{:.2}x", etp / rtp),
        et.to_string(),
    ]);
    table.emit();
    println!(
        "absolute modeled throughput: REBASE {:.3} problems/s, ETS {:.3} problems/s",
        rtp, etp
    );
    println!("shape check: ETS KV reduction translates to >1x throughput at equal accuracy.");
}
