//! Table 2 reproduction: serving throughput of ETS vs REBASE at width 256
//! (synth-math500, llemma-34b-sim) — measured through the *batched serve
//! path*: concurrent problems interleave steps through one engine/radix
//! cache, and every merged batch is costed on the H100-NVL roofline
//! (`PerfModel::batch_latency`). Concurrency sweep {4, 8, 16, 32}, best
//! configuration per method.
//!
//! Claim to reproduce: ETS's KV reduction (~1.8x) converts into higher
//! throughput (~1.4x) without custom kernels, because a smaller resident
//! working set means fewer bytes per decode iteration and less batch
//! fragmentation.

use ets::engine::{PerfModel, H100_NVL};
use ets::eval::{evaluate_serve, EvalConfig, PolicySpec, ServeEvalReport};
use ets::metrics::{ms, pct, ratio, Table};
use ets::util::stats;
use ets::workload::{WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

fn serve_at(policy: &PolicySpec, width: usize, n: usize, concurrency: usize) -> ServeEvalReport {
    let cfg = EvalConfig {
        spec: WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM),
        policy: policy.clone(),
        width,
        n_problems: n,
        seed: 20260710,
        max_steps: SYNTH_MATH500.n_steps + 6,
    };
    let perf = PerfModel::new(H100_NVL, true, concurrency);
    evaluate_serve(&cfg, concurrency, &perf)
}

/// Sweep concurrency and keep the best modeled throughput.
fn best_serve(policy: &PolicySpec, width: usize, n: usize) -> (usize, ServeEvalReport) {
    [4usize, 8, 16, 32]
        .iter()
        .map(|&c| (c, serve_at(policy, width, n, c)))
        .max_by(|a, b| {
            a.1.serve
                .throughput_problems_per_sec()
                .partial_cmp(&b.1.serve.throughput_problems_per_sec())
                .unwrap()
        })
        .unwrap()
}

fn main() {
    let width = 256;
    let n = 60;
    let rebase = best_serve(&PolicySpec::Rebase, width, n);
    let ets = best_serve(&PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 }, width, n);

    let mut table = Table::new(
        "Table 2 — batched serving throughput at width 256 (H100-NVL roofline, best of {4,8,16,32} concurrent)",
        &["method", "acc%", "KV red.", "throughput", "concurrency", "batch p50"],
    );
    let row = |label: &str, r: &(usize, ServeEvalReport), base: &ServeEvalReport| {
        let secs = r.1.serve.batch_seconds();
        vec![
            label.to_string(),
            pct(r.1.report.accuracy()),
            ratio(base.report.mean_kv_tokens, r.1.report.mean_kv_tokens),
            format!(
                "{:.2}x",
                r.1.serve.throughput_problems_per_sec()
                    / base.serve.throughput_problems_per_sec()
            ),
            r.0.to_string(),
            ms(stats::median(&secs)),
        ]
    };
    table.row(row("REBASE", &rebase, &rebase.1));
    table.row(row("ETS(λb=1.5)", &ets, &rebase.1));
    table.emit();
    println!(
        "absolute modeled throughput: REBASE {:.3} problems/s (peak resident {} kv-tok), ETS {:.3} problems/s (peak resident {} kv-tok)",
        rebase.1.serve.throughput_problems_per_sec(),
        rebase.1.serve.peak_resident_kv_tokens,
        ets.1.serve.throughput_problems_per_sec(),
        ets.1.serve.peak_resident_kv_tokens
    );
    println!("shape check: ETS KV reduction translates to >1x throughput at equal accuracy.");
}
