//! Helpers shared by the bench targets (each bench is its own crate, so
//! this module is wired in with `#[path = "common/mod.rs"]`).

use std::time::{Duration, Instant};

/// Mean wall time of `f` over `iters` runs, after one warmup run — the
/// timing loop every bench target used to copy-paste.
pub fn bench<F: FnMut()>(iters: usize, mut f: F) -> Duration {
    // warmup
    f();
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    t.elapsed() / iters as u32
}

/// `a / b` as a speedup factor (0.0 when `b` is zero).
pub fn speedup(baseline: Duration, new: Duration) -> f64 {
    let b = baseline.as_secs_f64();
    let n = new.as_secs_f64();
    if n > 0.0 {
        b / n
    } else {
        0.0
    }
}
