//! Table 3 reproduction: ablation of the coverage term. ETS-KV (λ_d = 0,
//! λ_b swept in {0.75, 1.0, 1.25}) vs full ETS (λ_d = 1, λ_b in
//! {1.0, 1.5, 2.0}) on synth-math500, llemma-34b-sim.
//!
//! Claim to reproduce: without the diversity term the cost model cannot
//! distinguish redundant from necessary trajectories, so holding accuracy
//! requires weaker compression (smaller achievable KV reduction at equal
//! accuracy), and pushing compression degrades accuracy.

use ets::eval::{evaluate, EvalConfig, PolicySpec};
use ets::metrics::{pct, ratio, Table};
use ets::workload::{WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

fn main() {
    let widths = [16usize, 64, 256];
    let spec = WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM);
    let mut table = Table::new(
        "Table 3 — ablation (synth-math500, llemma-34b-sim)",
        &["method", "width", "acc%", "KV red."],
    );
    for &width in &widths {
        let n_problems = if width == 256 { 60 } else { 100 };
        let mk = |policy| EvalConfig {
            spec: spec.clone(),
            policy,
            width,
            n_problems,
            seed: 20260710,
            max_steps: SYNTH_MATH500.n_steps + 6,
        };
        let rebase = evaluate(&mk(PolicySpec::Rebase));
        table.row(vec![
            "REBASE".into(),
            width.to_string(),
            pct(rebase.accuracy()),
            "1.00x".into(),
        ]);
        // ETS-KV: paper sweeps λ_b ∈ [0.75, 1.25]
        let mut best_kv = None;
        for &lb in &[0.75f64, 1.0, 1.25] {
            let r = evaluate(&mk(PolicySpec::EtsKv { lambda_b: lb }));
            if r.accuracy() + 0.002 >= rebase.accuracy() {
                best_kv = Some((lb, r));
            }
        }
        match best_kv {
            Some((lb, r)) => table.row(vec![
                format!("ETS-KV(λb={lb})"),
                width.to_string(),
                pct(r.accuracy()),
                ratio(rebase.mean_kv_tokens, r.mean_kv_tokens),
            ]),
            None => {
                let r = evaluate(&mk(PolicySpec::EtsKv { lambda_b: 0.75 }));
                table.row(vec![
                    "ETS-KV(λb=0.75, acc loss)".into(),
                    width.to_string(),
                    pct(r.accuracy()),
                    ratio(rebase.mean_kv_tokens, r.mean_kv_tokens),
                ]);
            }
        }
        // full ETS: λ_b ∈ [1, 2]
        let mut best = None;
        for &lb in &[1.0f64, 1.5, 2.0] {
            let r = evaluate(&mk(PolicySpec::Ets { lambda_b: lb, lambda_d: 1.0 }));
            if r.accuracy() + 0.002 >= rebase.accuracy() {
                best = Some((lb, r));
            }
        }
        if let Some((lb, r)) = best {
            table.row(vec![
                format!("ETS(λb={lb})"),
                width.to_string(),
                pct(r.accuracy()),
                ratio(rebase.mean_kv_tokens, r.mean_kv_tokens),
            ]);
        }
    }
    table.emit();
    println!("shape check: at matched accuracy, full ETS sustains a larger KV reduction than ETS-KV; aggressive ETS-KV trades accuracy.");
}
