//! Table 1 reproduction: REBASE vs ETS accuracy and KV-cache reduction on
//! synth-math500 and synth-gsm8k for llemma-34b-sim and mistral-7b-sim at
//! widths {16, 64, 256}.
//!
//! λ_b follows the paper's procedure: sweep λ_b ∈ {1.0, 1.5, 2.0} and pick
//! the largest value whose accuracy is within 0.2% of REBASE (or better);
//! λ_d = 1 throughout.

use ets::eval::{evaluate, EvalConfig, PolicySpec};
use ets::metrics::{pct, ratio, Table};
use ets::workload::{WorkloadSpec, LLEMMA_34B_SIM, MISTRAL_7B_SIM, SYNTH_GSM8K, SYNTH_MATH500};

fn main() {
    let widths = [16usize, 64, 256];
    let lambdas = [1.0f64, 1.5, 2.0];
    for dataset in [&SYNTH_MATH500, &SYNTH_GSM8K] {
        for model in [&LLEMMA_34B_SIM, &MISTRAL_7B_SIM] {
            let mut table = Table::new(
                &format!("Table 1 — {} / {}", dataset.name, model.name),
                &["method", "width", "acc%", "KV red."],
            );
            for &width in &widths {
                let n_problems = if width == 256 { 60 } else { 100 };
                let spec = WorkloadSpec::new(dataset, model);
                let mk = |policy| EvalConfig {
                    spec: spec.clone(),
                    policy,
                    width,
                    n_problems,
                    seed: 20260710,
                    max_steps: dataset.n_steps + 6,
                };
                let rebase = evaluate(&mk(PolicySpec::Rebase));
                table.row(vec![
                    "REBASE".into(),
                    width.to_string(),
                    pct(rebase.accuracy()),
                    "1.00x".into(),
                ]);
                // paper's λ_b selection procedure
                let mut best = None;
                for &lb in &lambdas {
                    let r = evaluate(&mk(PolicySpec::Ets { lambda_b: lb, lambda_d: 1.0 }));
                    if r.accuracy() + 0.002 >= rebase.accuracy() {
                        best = Some((lb, r));
                    }
                }
                let (lb, ets) = best.unwrap_or_else(|| {
                    let r = evaluate(&mk(PolicySpec::Ets { lambda_b: 1.0, lambda_d: 1.0 }));
                    (1.0, r)
                });
                table.row(vec![
                    format!("ETS(λb={lb})"),
                    width.to_string(),
                    pct(ets.accuracy()),
                    ratio(rebase.mean_kv_tokens, ets.mean_kv_tokens),
                ]);
            }
            table.emit();
        }
    }
}
