//! The persistent worker runtime behind [`super::serve`]: shard state, the
//! three-phase round pipeline, and the long-lived worker pool.
//!
//! A serve round used to be one opaque `run_round` call per shard that
//! interleaved scheduling, generator calls, and KV commits. It is now three
//! phases with a serializable boundary between them:
//!
//! 1. **plan** ([`Shard::plan_round`], parallel on the shard's worker) —
//!    retire finished sessions, prune frontiers (KV *release* only — plan
//!    never allocates), and build the round's [`RoundPlan`]: plain
//!    expand-request data, no generator calls. Planning includes the
//!    policy's allocation (for ETS: embedding + clustering + the ILP
//!    solve — the dominant host-side cost per `micro_substrates`), so it
//!    runs shard-parallel exactly like decode and commit; the coordinator
//!    only merges the resulting plans and finished outcomes.
//! 2. **decode** ([`Shard::decode`], worker thread) — the *only* phase that
//!    touches the [`StepGenerator`]: every planned session's batch is
//!    submitted through the two-phase `submit_batch`/`poll_batch` surface
//!    (all submits first, then all polls, so a pipelined backend keeps
//!    several decodes in flight), and the backend's modeled decode-overhead
//!    hint is folded into the round telemetry.
//! 3. **commit** ([`Shard::commit_round`], worker thread) — the reserve →
//!    commit KV application in admission-priority order with the
//!    evict → preempt → defer pressure ladder, closed out by the perf
//!    model's [`crate::engine::RoundCost`] decode/overhead decomposition.
//!
//! Workers are **persistent**: [`WorkerPool::spawn`] starts one per shard
//! when a `serve` call begins, and each round the coordinator *moves* a
//! shard to its worker over an mpsc channel twice — once to plan (getting
//! back the shard plus its [`RoundPlan`]) and once, [`RoundPlan`] message
//! in hand, to decode + commit (getting back the shard plus a
//! [`RoundResult`]). The in-shard-index-order receive loop after each
//! dispatch is the round barrier, and because every reply lands in its own
//! pre-sized slot there is no lock and no post-hoc sort — merge order is
//! deterministic by construction, so results are byte-identical to the
//! single-threaded schedule for any worker count, pipelined or not.

use super::{BatchRecord, ShardStats};
use crate::engine::batch::{BatchEngine, ExpandRequest, ImportSource};
use crate::obs::trace::{TraceBuf, TraceEvent};
use crate::engine::perfmodel::{BatchStats, PerfModel};
use crate::kvcache::prefixhub::PrefixHub;
use crate::kvcache::RadixCache;
use crate::lm::StepGenerator;
use crate::reward::RewardModel;
use crate::search::driver::{SearchOutcome, SearchSession};
use crate::search::policy::SearchPolicy;
use crate::workload::ModelProfile;
use std::sync::mpsc;
use std::thread;

/// One admitted problem in the scheduler: its outcome slot and admission
/// sequence number (lower = admitted earlier = higher priority; preemption
/// victims are picked from the highest sequence numbers, vLLM-style).
pub(crate) struct Slot<G, R, P> {
    pub(crate) id: usize,
    pub(crate) seq: u64,
    /// Consecutive failed resume attempts while suspended — the per-session
    /// sustained-pressure signal the migration policy keys on. Reset on any
    /// successful resume and on migration (the new shard gets a fresh try).
    pub(crate) stalled: u32,
    /// Policy-estimated KV footprint of this session, in blocks
    /// (prompt blocks + retained-frontier estimate) — the workload-aware
    /// load unit the admission router balances instead of raw session
    /// counts. Travels with the session on migration.
    pub(crate) predicted_blocks: usize,
    pub(crate) session: SearchSession<G, R, P>,
}

/// What one round's resume pass (local resumes plus migrated-in resumes)
/// costs a shard, split by the `min(transfer, recompute)` import decision:
/// `recompute_tokens` are re-prefilled locally, `transfer_tokens` arrive as
/// cross-shard block copies over the interconnect. Purely a costing split —
/// the cache ends up identical either way.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct ResumeBill {
    pub(crate) recompute_tokens: usize,
    pub(crate) transfer_tokens: usize,
    /// Tokens whose payload came back from the host-DRAM cold tier over the
    /// modeled PCIe link instead of being re-prefilled — the third costing
    /// class next to recompute and cross-shard transfer. Like the others,
    /// purely a costing split: the cache ends up identical either way.
    pub(crate) restored_tokens: usize,
    /// Whether a `min(transfer, recompute)` decision actually ran — i.e.
    /// the import source held a non-empty span. A resume with nothing
    /// importable is billed plain recompute without any "choice" having
    /// been made, and telemetry must not pretend otherwise.
    pub(crate) import_decided: bool,
}

impl ResumeBill {
    pub(crate) fn add(&mut self, other: ResumeBill) {
        self.recompute_tokens += other.recompute_tokens;
        self.transfer_tokens += other.transfer_tokens;
        self.restored_tokens += other.restored_tokens;
        self.import_decided |= other.import_decided;
    }

    pub(crate) fn any(&self) -> bool {
        self.recompute_tokens > 0 || self.transfer_tokens > 0 || self.restored_tokens > 0
    }
}

/// One shard of the serve scheduler: a shared-nothing engine plus the
/// sessions resident on it. Cross-shard state (the admission queue, the
/// migration policy, round merging) lives in [`super::serve`]; everything
/// here is touched by at most one thread per round.
pub(crate) struct Shard<G, R, P> {
    pub(crate) index: usize,
    pub(crate) engine: BatchEngine,
    pub(crate) running: Vec<Slot<G, R, P>>,
    pub(crate) suspended: Vec<Slot<G, R, P>>,
    /// Whether the serve run publishes to the global prefix hub. Gates the
    /// `retired_prompts` bookkeeping: with sharing off nothing ever drains
    /// that list, so recording into it would only leak.
    pub(crate) prefix_share: bool,
    /// Prompts of finished real-surface-id sessions, whose prompt KV was
    /// kept *warm* (unpinned, evictable — see
    /// `BatchEngine::close_keep_cached`; decode branches were released).
    /// The publication barrier fingerprints whatever of them is still
    /// cached into the prefix hub, so future duplicate requests route here
    /// and re-pin the warm prefix for free; entries fully evicted by LRU
    /// pressure are pruned at the barrier. Only maintained when
    /// `prefix_share` is on.
    pub(crate) retired_prompts: Vec<Vec<u32>>,
    /// Real-surface-id sessions that finished here with a lazy close —
    /// i.e. this shard may hold retired-but-warm KV that no resident
    /// session owns. The admission router uses this (hub on or off) to
    /// know the shard's evictable surplus is safe to trim for admission.
    pub(crate) lazy_closed: u64,
    /// Speculatively plan round *r + 1* at the end of round *r*'s execute
    /// (on the worker thread, overlapping peers' decodes and the
    /// coordinator's barrier work) instead of waiting for the next plan
    /// dispatch. On by [`super::ServeOptions::async_decode`].
    pub(crate) speculate: bool,
    /// The staged speculative plan, if any. Valid for exactly the sessions
    /// that were running when it was built: commit is the only session
    /// mutation and it precedes staging, so a staged entry can never go
    /// stale — the only *mispredict* is frontier growth (resumes,
    /// migrations, admissions landing before the next plan), which
    /// [`Shard::plan_round`] repairs by planning just the new tail.
    pub(crate) staged: Option<PlannedRound>,
    /// Bytes queued on this shard's host↔device (PCIe) lane so far this
    /// round — cold-tier spills and restores share it, so earlier traffic
    /// (deterministic resume order) delays later restore decisions and can
    /// flip them back to recompute, exactly like `link_queued_bytes` does
    /// for the cross-shard interconnect. Per-shard, unlike the shared
    /// NVLink lane: each GPU owns its own PCIe link. Reset by the
    /// coordinator at the top of every round.
    pub(crate) cold_lane_bytes: f64,
    pub(crate) stats: ShardStats,
    /// Preallocated trace ring ([`super::ServeOptions::trace`]): lifecycle
    /// events recorded on the owning worker thread, drained by the
    /// coordinator at the round barrier in shard-index order. Worker events
    /// carry a zero modeled timestamp — the drain restamps them onto the
    /// global modeled clock — plus the wall-clock diagnostic arg. `None`
    /// (tracing off) makes every hook a no-op.
    pub(crate) trace: Option<TraceBuf>,
}

/// The serializable plan → decode/commit boundary: one shard round's expand
/// work as plain data. Built by [`Shard::plan_round`] (no generator calls,
/// no KV allocation); handed back to the coordinator, which drives the
/// worker's decode + commit phases with it.
#[derive(Clone, Debug)]
pub(crate) struct RoundPlan {
    /// Shard (and worker) this plan belongs to.
    pub(crate) shard: usize,
    /// Expand requests per running slot, parallel to `Shard::running` at
    /// plan time. An empty entry marks a slot that already holds a prepared
    /// step (deferred or preempted mid-commit) and only needs recommit.
    pub(crate) expands: Vec<Vec<ExpandRequest>>,
    /// What this shard's resume pass (and migrated-in resumes) ahead of
    /// this round costs — recompute prefill vs imported block transfers —
    /// charged to the round's commit cost.
    pub(crate) bill: ResumeBill,
}

/// What [`Shard::plan_round`] produced: the plan plus the outcomes of
/// sessions that finished during planning (merged into the report by the
/// coordinator; they take no part in decode or commit).
pub(crate) struct PlannedRound {
    pub(crate) plan: RoundPlan,
    pub(crate) finished: Vec<(usize, SearchOutcome)>,
    /// Finishing a session is real progress (the livelock guard counts it).
    pub(crate) progressed: bool,
}

/// What one shard produced in one decode + commit execution.
pub(crate) struct RoundResult {
    pub(crate) record: Option<BatchRecord>,
    pub(crate) progressed: bool,
    pub(crate) deferred_commits: u64,
}

impl<G: StepGenerator, R: RewardModel, P: SearchPolicy> Shard<G, R, P> {
    pub(crate) fn new(
        index: usize,
        n_shards: usize,
        capacity_tokens: usize,
        block_size: usize,
        prefix_share: bool,
        cold_capacity_tokens: usize,
    ) -> Self {
        // Disjoint minted-id residue classes per shard keep the "ids are
        // never reused" invariant fleet-wide, so a migrated session can
        // never falsely share cache with the target shard's unrelated
        // problems (see BatchEngine::for_shard).
        let mut engine = BatchEngine::for_shard(
            capacity_tokens,
            block_size,
            index as u32,
            n_shards as u32,
        );
        if cold_capacity_tokens > 0 {
            // third rung of the pressure ladder: eviction demotes into a
            // host-DRAM spill arena instead of destroying, and resumes may
            // restore from it over the modeled PCIe lane
            engine.attach_cold_tier(cold_capacity_tokens);
        }
        let stats = ShardStats {
            shard: index,
            total_blocks: engine.total_blocks(),
            ..Default::default()
        };
        Self {
            index,
            engine,
            running: Vec::new(),
            suspended: Vec::new(),
            prefix_share,
            retired_prompts: Vec::new(),
            lazy_closed: 0,
            speculate: false,
            staged: None,
            cold_lane_bytes: 0.0,
            stats,
            trace: None,
        }
    }

    /// Problems resident on this shard (running + suspended) — the
    /// deterministic load unit the admission router sorts by.
    pub(crate) fn resident(&self) -> usize {
        self.running.len() + self.suspended.len()
    }

    /// Monotone count of tokens this shard's evictions have demoted into
    /// its cold tier so far (0 with the tier off). Deltas around a relieve
    /// measure that relieve's spill traffic.
    pub(crate) fn cold_demoted_tokens(&self) -> u64 {
        self.engine.cache().cold().map_or(0, |c| c.demoted_tokens())
    }

    /// Σ policy-predicted KV blocks of the sessions resident here — the
    /// workload-aware tiebreak the admission router balances (ETS policies
    /// predict smaller footprints, so footprint balancing packs more of
    /// them before pressure — and migrations — start).
    pub(crate) fn predicted_load(&self) -> usize {
        self.running.iter().chain(self.suspended.iter()).map(|s| s.predicted_blocks).sum()
    }

    /// One resume attempt for `slot` on this shard's engine, with a single
    /// relieve-and-retry on pressure. On success the resume is billed
    /// through the `min(transfer, recompute)` import decision (`import`
    /// names where missing spans could be copied from: the prefix hub for
    /// local resumes, the source shard's cache for migrations). The resume
    /// protocol lives only here — both paths go through it.
    pub(crate) fn try_resume_slot(
        &mut self,
        slot: &mut Slot<G, R, P>,
        import: Option<ImportSource<'_>>,
        perf: &PerfModel,
        model: &ModelProfile,
        link_queued_bytes: &mut f64,
    ) -> Option<ResumeBill> {
        for attempt in 0..2 {
            match slot.session.try_resume_imported(&mut self.engine, import) {
                Ok(stats) => {
                    self.stats.resumes += 1;
                    let mut bill = ResumeBill {
                        recompute_tokens: stats.recomputed_tokens,
                        transfer_tokens: 0,
                        restored_tokens: 0,
                        import_decided: stats.imported_tokens > 0,
                    };
                    let mut copied = 0usize;
                    let mut imported_transfer = false;
                    if stats.imported_tokens > 0 {
                        // Same-round transfers share the interconnect:
                        // earlier queued bytes (deterministic shard order)
                        // delay this one, and a congested link can flip the
                        // decision back to recompute.
                        let d = perf.import_choice_contended(
                            stats.imported_tokens,
                            self.engine.block_size(),
                            model,
                            *link_queued_bytes,
                        );
                        if d.use_transfer() {
                            imported_transfer = true;
                            bill.transfer_tokens = stats.imported_tokens;
                            bill.recompute_tokens -= stats.imported_tokens;
                            self.stats.import_transfers += 1;
                            self.stats.imported_kv_tokens += stats.imported_tokens as u64;
                            *link_queued_bytes += perf.link_bytes(
                                stats.imported_tokens,
                                self.engine.block_size(),
                                model,
                            );
                            // Execute the transfer: copy the payload words
                            // out of the source arena. Spans whose source
                            // vanished since costing keep their locally
                            // recomputed words — the fallback is free
                            // because insert always materializes first.
                            if let Some(src) = import {
                                copied = self.engine.commit_pending_imports(src);
                            }
                        } else {
                            self.stats.import_recomputes += 1;
                            self.engine.discard_pending_imports();
                        }
                    }
                    // Cold-tier rung: spans this shard itself demoted to
                    // host DRAM earlier. Only when no cross-shard transfer
                    // already covers the resume (the import span and the
                    // demoted span overlap — they describe the same path),
                    // and priced against recompute on this shard's PCIe
                    // lane, shared with every spill and restore queued
                    // earlier this round.
                    let mut cold_copied = 0usize;
                    let restorable = self.engine.restorable_tokens();
                    if imported_transfer {
                        self.engine.discard_pending_restores();
                    } else if restorable > 0 {
                        let d = perf.tier_choice(
                            restorable,
                            self.engine.block_size(),
                            model,
                            self.cold_lane_bytes,
                        );
                        if d.use_transfer() {
                            bill.restored_tokens = restorable;
                            bill.recompute_tokens -= restorable;
                            self.stats.cold_restores += 1;
                            self.stats.restored_kv_tokens += restorable as u64;
                            self.cold_lane_bytes += perf.link_bytes(
                                restorable,
                                self.engine.block_size(),
                                model,
                            );
                            // Execute the restore: splice the demoted spans'
                            // words back over the locally recomputed ones
                            // (bit-identical by construction — asserted at
                            // the write site in debug builds). A span the
                            // arena dropped since probing keeps its
                            // recomputed words, same fallback as imports.
                            cold_copied = self.engine.commit_pending_restores();
                        } else {
                            self.stats.cold_recomputes += 1;
                            self.engine.discard_pending_restores();
                        }
                    }
                    let word = std::mem::size_of::<u64>();
                    let rebuilt =
                        stats.recomputed_tokens.saturating_sub(copied + cold_copied);
                    self.stats.transferred_kv_bytes += (copied * word) as u64;
                    self.stats.restored_kv_bytes += (cold_copied * word) as u64;
                    self.stats.recomputed_kv_bytes += (rebuilt * word) as u64;
                    if let Some(buf) = self.trace.as_mut() {
                        buf.push(
                            TraceEvent::instant("resumed", 1 + self.index, 2, 0)
                                .arg("job", slot.id as f64)
                                .arg("recompute_tokens", bill.recompute_tokens as f64)
                                .arg("transfer_tokens", bill.transfer_tokens as f64)
                                .arg("restored_tokens", bill.restored_tokens as f64),
                        );
                    }
                    return Some(bill);
                }
                Err(p) => {
                    if attempt == 0 {
                        // The relieve may *demote* spans to the cold tier;
                        // those spill bytes queue on the same PCIe lane the
                        // round's restores contend for.
                        let spilled_before = self.cold_demoted_tokens();
                        if self.engine.relieve(&p) > 0 {
                            let spilled =
                                (self.cold_demoted_tokens() - spilled_before) as usize;
                            if spilled > 0 {
                                self.cold_lane_bytes += perf.link_bytes(
                                    spilled,
                                    self.engine.block_size(),
                                    model,
                                );
                            }
                            continue;
                        }
                    }
                    break;
                }
            }
        }
        None
    }

    /// Round step 1: resume preempted sessions, oldest admission first
    /// (FIFO — younger sessions never leapfrog a blocked elder). Returns
    /// the round's resume bill; a failed attempt bumps that session's
    /// `stalled` counter (the migration trigger), a success clears it.
    /// With the prefix hub on, spans a peer shard published are importable
    /// instead of recomputed; `peers` maps shard index → that shard's cache
    /// so a transfer decision can actually copy the blocks (a `None` slot is
    /// unreachable this round and falls back to recompute at copy time).
    pub(crate) fn resume_pass(
        &mut self,
        hub: Option<&PrefixHub>,
        peers: &[Option<&RadixCache>],
        perf: &PerfModel,
        model: &ModelProfile,
        link_queued_bytes: &mut f64,
    ) -> ResumeBill {
        let mut pending = std::mem::take(&mut self.suspended);
        pending.sort_by_key(|s| s.seq);
        let mut bill = ResumeBill::default();
        for mut slot in pending {
            // self.suspended doubles as the still-suspended list: attempt
            // resumes only while it is empty (strict FIFO)
            let resumed = if self.suspended.is_empty() {
                let import = hub.map(|hub| ImportSource::Hub {
                    hub,
                    local_shard: self.index,
                    peers,
                });
                match self.try_resume_slot(&mut slot, import, perf, model, link_queued_bytes) {
                    Some(b) => {
                        bill.add(b);
                        true
                    }
                    None => {
                        slot.stalled += 1;
                        false
                    }
                }
            } else {
                false
            };
            if resumed {
                slot.stalled = 0;
                self.running.push(slot);
            } else {
                self.suspended.push(slot);
            }
        }
        bill
    }

    /// Phase 1 (worker thread, shard-parallel): finish drained sessions and
    /// build the round's expand plan — including the policy's allocation,
    /// the expensive host-side part of a round. Prunes retired trajectories
    /// — releasing their KV — but never calls the generator and never
    /// *allocates* KV: everything the execute phase needs is in the
    /// returned [`RoundPlan`]'s plain data.
    pub(crate) fn plan_round(&mut self, bill: ResumeBill) -> PlannedRound {
        if let Some(mut staged) = self.staged.take() {
            let m = staged.plan.expands.len();
            debug_assert!(
                m <= self.running.len(),
                "speculative plan on shard {} covers slots that vanished",
                self.index
            );
            staged.plan.bill = bill;
            if self.running.len() == m {
                // Prediction held: between staging and now the frontier
                // only could have grown, and it didn't. The staged plan is
                // the round plan, with the (unknown-at-staging-time) resume
                // bill patched in.
                self.stats.spec_plan_hits += 1;
                if let Some(buf) = self.trace.as_mut() {
                    buf.push(TraceEvent::instant("spec_plan_hit", 1 + self.index, 2, 0));
                }
                return staged;
            }
            // Mispredict: resumes / migrations / admissions appended slots
            // after staging. The staged entries are still exact for the
            // first `m` slots (commit is the only session mutation), so
            // only the new tail is planned — never a double `next_requests`
            // on an already-planned session.
            self.stats.spec_plan_misses += 1;
            if let Some(buf) = self.trace.as_mut() {
                buf.push(TraceEvent::instant("spec_plan_miss", 1 + self.index, 2, 0));
            }
            let tail = self.running.split_off(m);
            let (active, expands, finished, progressed) = self.plan_slots(tail);
            self.running.extend(active);
            staged.plan.expands.extend(expands);
            staged.finished.extend(finished);
            staged.progressed |= progressed;
            return staged;
        }
        let slots = std::mem::take(&mut self.running);
        let (active, expands, finished, progressed) = self.plan_slots(slots);
        self.running = active;
        PlannedRound {
            plan: RoundPlan { shard: self.index, expands, bill },
            finished,
            progressed,
        }
    }

    /// The per-slot half of [`Shard::plan_round`]: drain `slots`, finishing
    /// sessions with no work left and planning an expand batch for the
    /// rest. Factored out so a speculative mispredict can plan just the
    /// newly appended tail.
    fn plan_slots(
        &mut self,
        slots: Vec<Slot<G, R, P>>,
    ) -> (Vec<Slot<G, R, P>>, Vec<Vec<ExpandRequest>>, Vec<(usize, SearchOutcome)>, bool) {
        let mut finished: Vec<(usize, SearchOutcome)> = Vec::new();
        let mut progressed = false;
        let mut active: Vec<Slot<G, R, P>> = Vec::new();
        let mut expands: Vec<Vec<ExpandRequest>> = Vec::new();
        for mut slot in slots {
            if slot.session.has_pending() {
                // deferred or preempted mid-commit: recommit only
                active.push(slot);
                expands.push(Vec::new());
                continue;
            }
            // This is where a pending budget-controller width override
            // lands: next_requests applies it in session-step coordinates
            // (steps_taken >= from_step) before the policy allocates, so a
            // lockstep plan, a speculative async plan, and a repair-tail
            // plan all resolve the same committed step to the same width.
            let requests = slot.session.next_requests(&mut self.engine);
            if requests.is_empty() {
                // real-surface-id sessions finish with a *lazy* close (KV
                // stays warm and evictable): remember the prompt so the
                // publication barrier can advertise the retired span for
                // cross-request reuse. Minted-id sessions release eagerly
                // so their blocks refill slots on the next admission pass.
                if !slot.session.ledger().exact_accounting() {
                    self.lazy_closed += 1;
                    if self.prefix_share {
                        let ids = slot.session.prompt_ids();
                        if !self.retired_prompts.iter().any(|p| p == ids) {
                            self.retired_prompts.push(ids.to_vec());
                        }
                    }
                }
                finished.push((slot.id, slot.session.finish(&mut self.engine)));
                progressed = true;
            } else {
                active.push(slot);
                expands.push(requests);
            }
        }
        (active, expands, finished, progressed)
    }

    /// Phase 2 (worker thread): the only phase that touches the generator.
    /// Submits every planned slot's batch first, then polls them — the
    /// two-phase surface that lets a pipelined backend overlap the decodes
    /// — and returns the largest modeled decode-overhead hint among the
    /// decoding sessions (the lockstep-fused decode is bounded by its
    /// slowest backend).
    pub(crate) fn decode(&mut self, plan: &RoundPlan) -> f64 {
        debug_assert_eq!(
            plan.expands.len(),
            self.running.len(),
            "round plan out of sync with shard {}",
            self.index
        );
        for (slot, requests) in self.running.iter_mut().zip(&plan.expands) {
            if !requests.is_empty() {
                slot.session.submit(&mut self.engine, requests);
            }
        }
        let mut injected = 0.0f64;
        for (slot, requests) in self.running.iter_mut().zip(&plan.expands) {
            if !requests.is_empty() {
                slot.session.collect(&mut self.engine);
                injected = injected.max(slot.session.lm.decode_overhead_seconds());
            }
        }
        injected
    }

    /// Phase 3 (worker thread): commit the decoded batch in priority order
    /// with the evict → preempt → defer pressure ladder, then close the
    /// round with telemetry and the perf model's decode/overhead cost
    /// split. `pipeline` picks how the two phases combine into the round's
    /// modeled seconds (`max` vs sum) — it cannot affect anything else.
    pub(crate) fn commit_round(
        &mut self,
        perf: &PerfModel,
        model: &ModelProfile,
        bill: ResumeBill,
        injected_decode_seconds: f64,
        pipeline: bool,
    ) -> RoundResult {
        let mut progressed = false;
        let mut deferred_commits = 0u64;

        // commit the merged batch in priority order; on reservation
        // failure: evict unpinned branches, then preempt from the tail
        // (never the committing slot), then defer to the next round
        self.running.sort_by_key(|s| s.seq);
        let mut rec = BatchRecord {
            shard: self.index,
            recompute_tokens: bill.recompute_tokens,
            transfer_kv_tokens: bill.transfer_tokens,
            restored_kv_tokens: bill.restored_tokens,
            ..Default::default()
        };
        let mut i = 0usize;
        while i < self.running.len() {
            let n_requests = self.running[i].session.pending_requests();
            let committed = loop {
                match self.running[i].session.try_commit(&mut self.engine) {
                    Ok(m) => break Some(m),
                    Err(p) => {
                        // first remedy: reclaim unpinned branches (LRU),
                        // evicting only the deficit so other suspended
                        // sessions keep as much warm KV as possible
                        if self.engine.relieve(&p) > 0 {
                            continue;
                        }
                        // second remedy: preempt the lowest-priority
                        // not-yet-committed session (sorted tail)
                        if self.running.len() > i + 1 {
                            let mut victim = self.running.pop().expect("len > i + 1");
                            victim.session.suspend(&mut self.engine);
                            self.stats.preemptions += 1;
                            rec.preemptions += 1;
                            if let Some(buf) = self.trace.as_mut() {
                                buf.push(
                                    TraceEvent::instant("preempted", 1 + self.index, 2, 0)
                                        .arg("job", victim.id as f64),
                                );
                            }
                            self.suspended.push(victim);
                            continue;
                        }
                        break None; // defer this step to the next round
                    }
                }
            };
            match committed {
                Some(m) => {
                    rec.problems += 1;
                    rec.requests += n_requests;
                    rec.model_calls += m.model_calls;
                    rec.new_tokens += m.new_tokens;
                    rec.pinned_kv_tokens += m.live_kv_tokens;
                    rec.unshared_kv_tokens += m.unshared_kv_tokens;
                    progressed = true;
                    i += 1;
                }
                None => {
                    // everything evictable is gone and no lower-priority
                    // victim remains; later slots need even more room
                    deferred_commits += 1;
                    break;
                }
            }
        }

        // close the round: telemetry, hard-budget assertion, perf cost
        rec.resident_kv_tokens = self.engine.live_tokens();
        rec.used_blocks = self.engine.used_blocks();
        self.stats.peak_resident_kv_tokens =
            self.stats.peak_resident_kv_tokens.max(rec.resident_kv_tokens);
        self.stats.peak_used_blocks =
            self.stats.peak_used_blocks.max(self.engine.used_blocks());
        debug_assert!(
            self.engine.used_blocks() <= self.engine.total_blocks(),
            "shard {} exceeded the hard block budget: {} > {}",
            self.index,
            self.engine.used_blocks(),
            self.engine.total_blocks()
        );
        // Cold-tier occupancy telemetry (monotone arena counters, so a
        // plain snapshot is the running total; the serve teardown takes a
        // final snapshot *before* its flush so the drain does not count).
        if let Some(cold) = self.engine.cache().cold() {
            self.stats.demoted_kv_tokens = cold.demoted_tokens();
            self.stats.cold_dropped_kv_tokens = cold.dropped_tokens();
            self.stats.peak_cold_used_blocks =
                self.stats.peak_cold_used_blocks.max(cold.used_blocks() as u64);
        }
        // A record exists when the round did costed work: commits, resume
        // recompute or imported transfers, cold-tier restores, or backend
        // decode time spent on steps whose commits all deferred under
        // pressure (the device ran either way).
        let record = if rec.problems > 0
            || rec.recompute_tokens > 0
            || rec.transfer_kv_tokens > 0
            || rec.restored_kv_tokens > 0
            || injected_decode_seconds > 0.0
        {
            // decode reads only what the committed sessions pin; wave
            // fragmentation is driven by physical occupancy (which, under
            // lazy suspend, may include warm suspended working sets)
            let (read, resident) = if perf.shared_kv {
                (rec.pinned_kv_tokens, rec.resident_kv_tokens)
            } else {
                (rec.unshared_kv_tokens, rec.unshared_kv_tokens)
            };
            let stats = BatchStats {
                model_calls: rec.model_calls,
                new_tokens: rec.new_tokens,
                read_kv_tokens: read,
                resident_kv_tokens: resident,
                recompute_prefill_tokens: rec.recompute_tokens,
                transfer_kv_tokens: rec.transfer_kv_tokens,
                restored_kv_tokens: rec.restored_kv_tokens,
                block_size: self.engine.block_size(),
                injected_decode_seconds,
            };
            let cost = perf.round_cost(&stats, model);
            rec.decode_seconds = cost.decode_seconds;
            rec.overhead_seconds = cost.overhead_seconds;
            rec.seconds = cost.seconds(pipeline);
            self.stats.busy_seconds += rec.seconds;
            self.stats.recompute_tokens += rec.recompute_tokens as u64;
            Some(rec)
        } else {
            None
        };
        RoundResult { record, progressed, deferred_commits }
    }

    /// Phases 2 + 3 back to back — what a worker runs per [`RoundPlan`].
    /// With speculation on, the worker then immediately plans the *next*
    /// round from the post-commit frontier before handing the shard back —
    /// that planning (frontier pruning, policy allocation) overlaps peers'
    /// decodes and the coordinator's barrier work instead of serializing
    /// behind them.
    pub(crate) fn run_round(
        &mut self,
        plan: RoundPlan,
        perf: &PerfModel,
        model: &ModelProfile,
        pipeline: bool,
    ) -> RoundResult {
        let injected = self.decode(&plan);
        let result = self.commit_round(perf, model, plan.bill, injected, pipeline);
        if self.speculate {
            debug_assert!(self.staged.is_none(), "staged plan survived a round");
            let staged = self.plan_round(ResumeBill::default());
            // Stage only real content: an all-empty stage would keep the
            // shard "busy" forever without ever making progress.
            if !staged.plan.expands.is_empty() || !staged.finished.is_empty() {
                self.staged = Some(staged);
            }
        }
        result
    }
}

/// The coordinator's shard store. Between rounds every shard is resident
/// and borrowable; during the execute window a shard is *moved* to its
/// worker and back (`take`/`put`), which is what makes the worker protocol
/// lock-free: ownership, not sharing.
pub(crate) struct ShardSet<G, R, P> {
    slots: Vec<Option<Shard<G, R, P>>>,
}

impl<G, R, P> ShardSet<G, R, P> {
    pub(crate) fn new(shards: Vec<Shard<G, R, P>>) -> Self {
        Self { slots: shards.into_iter().map(Some).collect() }
    }

    pub(crate) fn len(&self) -> usize {
        self.slots.len()
    }

    pub(crate) fn get(&self, i: usize) -> &Shard<G, R, P> {
        self.slots[i].as_ref().expect("shard is out with its worker")
    }

    /// Like [`ShardSet::get`] but tolerant of a taken slot — the resume
    /// pass peeks every *other* shard's cache while one shard is out.
    pub(crate) fn peek(&self, i: usize) -> Option<&Shard<G, R, P>> {
        self.slots[i].as_ref()
    }

    pub(crate) fn get_mut(&mut self, i: usize) -> &mut Shard<G, R, P> {
        self.slots[i].as_mut().expect("shard is out with its worker")
    }

    pub(crate) fn take(&mut self, i: usize) -> Shard<G, R, P> {
        self.slots[i].take().expect("shard already out with its worker")
    }

    pub(crate) fn put(&mut self, i: usize, shard: Shard<G, R, P>) {
        debug_assert!(self.slots[i].is_none(), "shard slot {i} already occupied");
        self.slots[i] = Some(shard);
    }

    /// Borrow shard `a` mutably and shard `b` immutably at once — the
    /// migration path resumes on the target while probing the *source*
    /// shard's cache read-only for transferable warm spans.
    pub(crate) fn pair_mut(
        &mut self,
        a: usize,
        b: usize,
    ) -> (&mut Shard<G, R, P>, &Shard<G, R, P>) {
        assert_ne!(a, b, "pair_mut of a shard with itself");
        let expect_a = "shard is out with its worker";
        if a < b {
            let (lo, hi) = self.slots.split_at_mut(b);
            (lo[a].as_mut().expect(expect_a), hi[0].as_ref().expect(expect_a))
        } else {
            let (lo, hi) = self.slots.split_at_mut(a);
            (hi[0].as_mut().expect(expect_a), lo[b].as_ref().expect(expect_a))
        }
    }

    pub(crate) fn iter(&self) -> impl Iterator<Item = &Shard<G, R, P>> {
        self.slots.iter().map(|s| s.as_ref().expect("shard is out with its worker"))
    }

    pub(crate) fn iter_mut(&mut self) -> impl Iterator<Item = &mut Shard<G, R, P>> {
        self.slots.iter_mut().map(|s| s.as_mut().expect("shard is out with its worker"))
    }

    /// Tear down the set, returning every shard (all must be resident).
    pub(crate) fn into_inner(self) -> Vec<Shard<G, R, P>> {
        self.slots.into_iter().map(|s| s.expect("shard is out with its worker")).collect()
    }
}

/// A unit of round work moving coordinator → worker.
enum RoundMsg<G, R, P> {
    /// Run [`Shard::plan_round`] (frontier pruning + policy allocation).
    Plan { shard: Shard<G, R, P>, bill: ResumeBill },
    /// Run decode + commit for an already-built [`RoundPlan`].
    Execute { shard: Shard<G, R, P>, plan: RoundPlan },
}

/// A finished unit moving worker → coordinator.
enum RoundReply<G, R, P> {
    Planned { shard: Shard<G, R, P>, planned: PlannedRound },
    Executed { shard: Shard<G, R, P>, result: RoundResult },
}

/// N long-lived workers, one per shard, spawned once per `serve` call
/// (replacing the per-round `std::thread::scope` re-spawn). Each worker
/// loops on its own mpsc channel, serving two message kinds per round:
/// plan (shard in, shard + [`RoundPlan`] out) and execute (shard + plan in,
/// shard + [`RoundResult`] out). Dropping the pool closes the channels and
/// the workers exit; the enclosing `thread::scope` then joins them.
pub(crate) struct WorkerPool<G, R, P> {
    to_workers: Vec<mpsc::Sender<RoundMsg<G, R, P>>>,
    from_workers: Vec<mpsc::Receiver<RoundReply<G, R, P>>>,
    /// Core worker *i* pinned itself to at spawn (`None`: pinning off or
    /// the kernel refused the mask — the worker runs under OS placement).
    worker_cores: Vec<Option<usize>>,
}

impl<G, R, P> WorkerPool<G, R, P>
where
    G: StepGenerator + Send,
    R: RewardModel + Send,
    P: SearchPolicy + Send,
{
    /// Spawn `workers` persistent round workers inside `scope`. With
    /// `pin_cores` on, worker *i* pins its own thread to core
    /// `i % num_cores` before serving any round — every touch of the
    /// shard's engine (its radix nodes, its [`crate::kvcache::BlockAllocator`]
    /// free-list arena) then happens from that core, so first-touch page
    /// locality follows the pin. The spawn barrier below collects each
    /// worker's actual assignment before any work is dispatched.
    pub(crate) fn spawn<'scope, 'env>(
        scope: &'scope thread::Scope<'scope, 'env>,
        workers: usize,
        perf: &'env PerfModel,
        model: &'env ModelProfile,
        pipeline: bool,
        pin_cores: bool,
    ) -> Self
    where
        G: 'scope,
        R: 'scope,
        P: 'scope,
    {
        let mut to_workers = Vec::with_capacity(workers);
        let mut from_workers = Vec::with_capacity(workers);
        let (pin_tx, pin_rx) = mpsc::channel::<(usize, Option<usize>)>();
        for index in 0..workers {
            let (tx, rx) = mpsc::channel::<RoundMsg<G, R, P>>();
            let (reply_tx, reply_rx) = mpsc::channel::<RoundReply<G, R, P>>();
            let pin_tx = pin_tx.clone();
            scope.spawn(move || {
                let pinned = if pin_cores {
                    let core = index % crate::util::affinity::num_cores();
                    crate::util::affinity::pin_to_core(core).then_some(core)
                } else {
                    None
                };
                let _ = pin_tx.send((index, pinned));
                drop(pin_tx);
                // NUMA-aware first touch: with pinning on, the first time
                // this worker holds its shard it faults the whole payload
                // arena in *from the pinned core*, so the kernel's
                // first-touch policy places the arena's pages on this
                // core's memory node before any round traffic hits them.
                let mut faulted = false;
                let first_touch = |shard: &mut Shard<G, R, P>, faulted: &mut bool| {
                    if pin_cores && !*faulted {
                        *faulted = true;
                        let bytes = shard.engine.fault_in_arena();
                        shard.stats.arena_touch_worker = Some(index);
                        shard.stats.arena_touch_bytes = bytes as u64;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    let reply = match msg {
                        RoundMsg::Plan { mut shard, bill } => {
                            first_touch(&mut shard, &mut faulted);
                            let planned = shard.plan_round(bill);
                            RoundReply::Planned { shard, planned }
                        }
                        RoundMsg::Execute { mut shard, plan } => {
                            first_touch(&mut shard, &mut faulted);
                            let result = shard.run_round(plan, perf, model, pipeline);
                            RoundReply::Executed { shard, result }
                        }
                    };
                    if reply_tx.send(reply).is_err() {
                        return; // coordinator gone
                    }
                }
            });
            to_workers.push(tx);
            from_workers.push(reply_rx);
        }
        drop(pin_tx);
        // spawn barrier: every worker reports its placement before the
        // first round is dispatched
        let mut worker_cores: Vec<Option<usize>> = vec![None; workers];
        for _ in 0..workers {
            let (index, core) = pin_rx.recv().expect("round worker died during spawn");
            worker_cores[index] = core;
        }
        Self { to_workers, from_workers, worker_cores }
    }

    /// Core each worker pinned itself to (index = shard).
    pub(crate) fn worker_cores(&self) -> &[Option<usize>] {
        &self.worker_cores
    }

    fn send(&self, worker: usize, msg: RoundMsg<G, R, P>) {
        self.to_workers[worker].send(msg).expect("round worker died");
    }

    fn recv(&self, worker: usize) -> RoundReply<G, R, P> {
        self.from_workers[worker].recv().expect("round worker died")
    }

    fn collect_planned(&self, worker: usize) -> (Shard<G, R, P>, PlannedRound) {
        match self.recv(worker) {
            RoundReply::Planned { shard, planned } => (shard, planned),
            RoundReply::Executed { .. } => unreachable!("worker replied out of phase"),
        }
    }

    fn collect_executed(&self, worker: usize) -> (Shard<G, R, P>, RoundResult) {
        match self.recv(worker) {
            RoundReply::Executed { shard, result } => (shard, result),
            RoundReply::Planned { .. } => unreachable!("worker replied out of phase"),
        }
    }
}

/// Plan one global round: every busy shard (running sessions, or resume
/// recompute to bill) plans **on its own worker** — planning carries the
/// policy's allocation, the expensive host-side part of a round — and the
/// coordinator receives shards back in index order. Inline when no pool
/// exists (the single-shard scheduler).
pub(crate) fn plan_rounds<G, R, P>(
    set: &mut ShardSet<G, R, P>,
    pool: Option<&WorkerPool<G, R, P>>,
    round_bills: &[ResumeBill],
) -> Vec<Option<PlannedRound>>
where
    G: StepGenerator + Send,
    R: RewardModel + Send,
    P: SearchPolicy + Send,
{
    debug_assert_eq!(round_bills.len(), set.len());
    let n = set.len();
    let busy = |set: &ShardSet<G, R, P>, i: usize| {
        !set.get(i).running.is_empty() || round_bills[i].any() || set.get(i).staged.is_some()
    };
    let mut planned: Vec<Option<PlannedRound>> = (0..n).map(|_| None).collect();
    match pool {
        Some(pool) => {
            let mut dispatched: Vec<usize> = Vec::new();
            for i in 0..n {
                if busy(set, i) {
                    let shard = set.take(i);
                    pool.send(i, RoundMsg::Plan { shard, bill: round_bills[i] });
                    dispatched.push(i);
                }
            }
            for i in dispatched {
                let (shard, p) = pool.collect_planned(i);
                set.put(i, shard);
                planned[i] = Some(p);
            }
        }
        None => {
            for i in 0..n {
                if busy(set, i) {
                    planned[i] = Some(set.get_mut(i).plan_round(round_bills[i]));
                }
            }
        }
    }
    planned
}

/// Execute one global round: hand every planned shard to its worker (or run
/// inline when no pool exists — the single-shard scheduler), then receive
/// the shards back **in shard index order**. The in-order receive is the
/// round barrier, and each result lands in its own pre-sized slot — no
/// lock, no post-hoc sort — so the merge the coordinator performs next is
/// deterministic regardless of worker timing.
pub(crate) fn execute_round<G, R, P>(
    set: &mut ShardSet<G, R, P>,
    pool: Option<&WorkerPool<G, R, P>>,
    plans: Vec<Option<RoundPlan>>,
    perf: &PerfModel,
    model: &ModelProfile,
    pipeline: bool,
) -> Vec<Option<RoundResult>>
where
    G: StepGenerator + Send,
    R: RewardModel + Send,
    P: SearchPolicy + Send,
{
    debug_assert_eq!(plans.len(), set.len());
    let mut results: Vec<Option<RoundResult>> = (0..set.len()).map(|_| None).collect();
    match pool {
        Some(pool) => {
            let mut dispatched: Vec<usize> = Vec::new();
            for (i, plan) in plans.into_iter().enumerate() {
                if let Some(plan) = plan {
                    let shard = set.take(i);
                    pool.send(i, RoundMsg::Execute { shard, plan });
                    dispatched.push(i);
                }
            }
            for i in dispatched {
                let (shard, result) = pool.collect_executed(i);
                set.put(i, shard);
                results[i] = Some(result);
            }
        }
        None => {
            for (i, plan) in plans.into_iter().enumerate() {
                if let Some(plan) = plan {
                    results[i] = Some(set.get_mut(i).run_round(plan, perf, model, pipeline));
                }
            }
        }
    }
    results
}
