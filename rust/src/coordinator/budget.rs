//! Compute-optimal adaptive budgeting: per-problem difficulty prediction
//! with mid-flight width/KV reallocation.
//!
//! The [`BudgetController`] runs at the coordinator's round barrier (between
//! admission and round planning, when every shard is resident) and reads
//! *only committed round telemetry* — the
//! [`DifficultySignals`](crate::search::driver::DifficultySignals) snapshot
//! of each session's frontier. From that it scores difficulty and
//! reallocates the serve's fixed block budget mid-flight: confidently easy
//! sessions and hopeless ones (a collapsed, low-reward frontier that will
//! burn decode slots to the step cap without converting) get their width
//! shrunk, and the reclaimed KV blocks and decode slots are granted to
//! contested sessions whose accuracy is actually budget-limited. That is the
//! compute-optimal allocation of Snell et al.: marginal blocks flow to the
//! sessions with the highest expected-accuracy return per modeled
//! block-second.
//!
//! Determinism contract (the ROADMAP's sanctioned form): adaptive mode
//! changes *what* is searched, so it is its own mode — but every decision
//! here is a pure function of one session's committed telemetry at a fixed
//! step index. Sessions are classified when the barrier observes
//! `steps_taken == stage step`; since a round commits at most one step per
//! session and a barrier precedes every round, every step count is observed
//! at some barrier regardless of shard layout, pipelining, async decode, or
//! capacity-induced stalls. Width overrides apply in session-step
//! coordinates ([`SearchSession::set_width_override`]), after every
//! allocation already planned at the decision step. Net: at a fixed seed,
//! results and the decision log itself are byte-identical across shards
//! {1,2,4} × pipeline × async-decode × prefix-share × ample/tight capacity
//! — which the serve determinism suite asserts.
//!
//! [`SearchSession::set_width_override`]: crate::search::driver::SearchSession::set_width_override

use crate::search::driver::DifficultySignals;
use std::collections::BTreeMap;

/// Controller thresholds and width factors. Defaults are calibrated against
/// the synthetic workloads' reward model (see `difficulty_score`): open
/// problems at depth 1 score ≈ 0.54, root-closed ones ≈ 0.62; by depth 3
/// confidently-easy frontiers score below 0.50 and still-doomed ones above
/// 0.65.
#[derive(Clone, Debug)]
pub struct BudgetConfig {
    /// Stage A (early hopeless) runs when a session is first observed at
    /// this committed step count.
    pub stage_a_step: usize,
    /// Stage B (easy / hard / late-hopeless) runs at this step count for
    /// sessions stage A left open.
    pub stage_b_step: usize,
    /// Stage A: score at or above this means the frontier already looks
    /// doomed — shrink to the floor immediately.
    pub hopeless_cut_a: f64,
    /// Stage B: score below this means confidently easy — the frontier
    /// converged on high-reward steps, half the width converts just as well.
    pub easy_cut: f64,
    /// Stage B: score at or above this means still-doomed — floor it.
    pub hopeless_cut_b: f64,
    /// Width floor for shrunk sessions (keeps voting populated).
    pub min_width: usize,
}

impl Default for BudgetConfig {
    fn default() -> Self {
        Self {
            stage_a_step: 1,
            stage_b_step: 3,
            hopeless_cut_a: 0.60,
            easy_cut: 0.50,
            hopeless_cut_b: 0.65,
            min_width: 2,
        }
    }
}

/// Which controller stage produced a decision.
pub const STAGE_A: u8 = 1;
pub const STAGE_B: u8 = 2;

/// One controller evaluation, logged for telemetry and for the determinism
/// suite (the sorted decision list must be identical across every serve
/// configuration). `width_to == width_from` records a stage-A "still open"
/// evaluation that changed nothing.
#[derive(Clone, Debug, PartialEq)]
pub struct BudgetDecision {
    /// Serve job id of the session.
    pub session: u64,
    /// [`STAGE_A`] or [`STAGE_B`].
    pub stage: u8,
    /// Shard the session was resident on at decision time (placement
    /// telemetry only — not part of the cross-configuration identity).
    pub shard: usize,
    /// The difficulty score that drove the decision.
    pub score: f64,
    /// Base width the target is expressed against.
    pub width_from: usize,
    /// New target width (applied as a delta in session-step coordinates).
    pub width_to: usize,
    /// Predicted KV blocks moved by this decision: reclaimed when
    /// `width_to < width_from`, granted when larger, 0 for a no-op.
    pub blocks: usize,
}

impl BudgetDecision {
    /// The schedule-invariant identity of this decision — everything except
    /// the placement-dependent `shard`. Equal across serve configurations
    /// at a fixed seed.
    pub fn identity(&self) -> (u64, u8, u64, usize, usize, usize) {
        (
            self.session,
            self.stage,
            self.score.to_bits(),
            self.width_from,
            self.width_to,
            self.blocks,
        )
    }
}

/// Difficulty in [0, 1] — a pure function of one session's committed round
/// telemetry. Higher is harder:
///
/// * `1 − reward_mean` (weight 0.7): the PRM's own verdict on the frontier.
///   On the synthetic workloads the oracle PRM separates alive from doomed
///   frontiers by ≈ 0.14 at depth 1, growing with the margin ramp.
/// * contestedness (weight 0.15): frontier reward spread, saturating at
///   0.6 — a wide spread means the search is still deciding between
///   live alternatives, i.e. marginal width still buys information.
/// * `1 − diversity` (weight 0.15): distinct semantic clusters over
///   frontier size. A collapsed frontier (all paraphrases of one step)
///   converts no extra width into new information.
///
/// The entropy signal rides along in [`DifficultySignals`] for telemetry
/// but does not enter the score: normalized softmax entropy at the REBASE
/// temperature is near-degenerate with spread on small frontiers.
pub fn difficulty_score(sig: &DifficultySignals) -> f64 {
    let contest = (sig.reward_spread.min(0.6)) / 0.6;
    let diversity = if sig.frontier == 0 {
        0.0
    } else {
        sig.sem_clusters as f64 / sig.frontier as f64
    };
    let raw = 0.7 * (1.0 - sig.reward_mean) + 0.15 * contest + 0.15 * (1.0 - diversity);
    raw.clamp(0.0, 1.0)
}

/// Predicted whole-serve KV footprint of a session, in blocks: the prompt's
/// blocks plus the retained-leaf working set. This is the one formula shared
/// by hub admission routing and the budget controller — `retention` is
/// either the policy's static [`kv_retention`] heuristic (round 0) or the
/// fleet's online-calibrated ratio.
///
/// [`kv_retention`]: crate::search::policy::SearchPolicy::kv_retention
pub fn predicted_footprint_blocks(prompt_blocks: usize, width: usize, retention: f64) -> usize {
    prompt_blocks + leaf_blocks(width, retention)
}

/// The working-set half of [`predicted_footprint_blocks`]: blocks predicted
/// for `width` trajectories at a retained fraction `retention`.
pub fn leaf_blocks(width: usize, retention: f64) -> usize {
    (width as f64 * retention).ceil() as usize
}

/// Blocks moved by a width reallocation under a given retention curve:
/// `(blocks, is_shrink)`.
pub fn reallocation_blocks(
    width_from: usize,
    ret_from: f64,
    width_to: usize,
    ret_to: f64,
) -> (usize, bool) {
    let from = leaf_blocks(width_from, ret_from);
    let to = leaf_blocks(width_to, ret_to);
    if to < from {
        (from - to, true)
    } else {
        (to - from, false)
    }
}

/// Per-session controller progress: stage A ran and left the session open,
/// or a final decision was issued (each stage runs at most once).
#[derive(Clone, Copy, Debug, PartialEq)]
enum Progress {
    PassedA,
    Decided,
}

/// The deterministic per-serve budget controller. One instance lives for
/// the whole serve; [`BudgetController::classify`] is called for every
/// resident or suspended session at every round barrier.
#[derive(Debug, Default)]
pub struct BudgetController {
    cfg: BudgetConfig,
    progress: BTreeMap<u64, Progress>,
    decisions: Vec<BudgetDecision>,
}

impl BudgetController {
    pub fn new(cfg: BudgetConfig) -> Self {
        Self { cfg, progress: BTreeMap::new(), decisions: Vec::new() }
    }

    /// Every evaluation issued so far, in issue order.
    pub fn decisions(&self) -> &[BudgetDecision] {
        &self.decisions
    }

    pub fn into_decisions(self) -> Vec<BudgetDecision> {
        self.decisions
    }

    /// Width floor for shrunk sessions: a quarter of the base width, never
    /// below `min_width`.
    pub fn floor_width(&self, base_width: usize) -> usize {
        (base_width / 4).max(self.cfg.min_width)
    }

    /// Evaluate one session at a round barrier. Returns the new target
    /// width together with the session step the override applies from
    /// (`observed step + 1` — strictly after every allocation already
    /// planned at the decision step), or `None` when nothing changes.
    ///
    /// Decisions are only issued while actionable: an override from step
    /// `k + 1` needs an allocation with `steps_taken >= k + 1`, i.e.
    /// `k + 2 <= max_steps`. Sessions past that point are left alone — this
    /// is also what keeps the decision log identical between sync and async
    /// schedules, where a session finishing exactly at the cap is harvested
    /// on different sides of the barrier.
    pub fn classify(
        &mut self,
        session: u64,
        shard: usize,
        base_width: usize,
        max_steps: usize,
        sig: &DifficultySignals,
    ) -> Option<(usize, usize)> {
        if sig.steps_taken + 2 > max_steps || sig.frontier == 0 {
            return None;
        }
        let state = self.progress.get(&session).copied();
        let (stage, score) = if sig.steps_taken == self.cfg.stage_a_step && state.is_none() {
            (STAGE_A, difficulty_score(sig))
        } else if sig.steps_taken == self.cfg.stage_b_step && state == Some(Progress::PassedA) {
            (STAGE_B, difficulty_score(sig))
        } else {
            return None;
        };
        let target = if stage == STAGE_A {
            if score >= self.cfg.hopeless_cut_a {
                self.floor_width(base_width)
            } else {
                base_width // still open: logged, nothing applied
            }
        } else if score < self.cfg.easy_cut {
            (base_width / 2).max(self.cfg.min_width)
        } else if score < self.cfg.hopeless_cut_b {
            (base_width + base_width / 2).min(base_width * 2)
        } else {
            self.floor_width(base_width)
        };
        let decided = target != base_width;
        self.progress.insert(
            session,
            if stage == STAGE_B || decided { Progress::Decided } else { Progress::PassedA },
        );
        self.decisions.push(BudgetDecision {
            session,
            stage,
            shard,
            score,
            width_from: base_width,
            width_to: target,
            blocks: 0, // the coordinator fills this from the retention curve
        });
        if decided {
            Some((sig.steps_taken + 1, target))
        } else {
            None
        }
    }

    /// Attach the block cost to the most recent decision (the coordinator
    /// computes it from the session's retention curve, which the controller
    /// does not hold).
    pub fn bill_last(&mut self, blocks: usize) {
        if let Some(d) = self.decisions.last_mut() {
            d.blocks = blocks;
        }
    }
}

/// Online `kv_retention` calibration: observed retained-leaves / width
/// ratios per policy name, folded into admission's predicted footprint once
/// real telemetry exists (the static heuristic seeds round 0). Keyed by
/// [`SearchPolicy::name`](crate::search::policy::SearchPolicy::name), so
/// every session running the same policy shares one estimate — the fleet
/// learns, not the problem.
#[derive(Debug, Default)]
pub struct RetentionCalibration {
    /// policy name → (Σ retained span leaves, Σ live width) over samples.
    samples: BTreeMap<String, (u64, u64)>,
}

impl RetentionCalibration {
    /// Fold one committed-barrier observation of a session: how many step
    /// span leaves its ledger actually retains against its live width.
    pub fn observe(&mut self, policy: &str, retained_leaves: usize, width: usize) {
        if width == 0 {
            return;
        }
        let e = self.samples.entry(policy.to_string()).or_insert((0, 0));
        e.0 += retained_leaves as u64;
        e.1 += width as u64;
    }

    /// Calibrated retention for a policy, or `fallback` (the static
    /// heuristic) before any observation. Clamped to [0.05, 1.0]: a ratio
    /// of 0 would predict a zero working set and over-admit.
    pub fn retention_or(&self, policy: &str, fallback: f64) -> f64 {
        match self.samples.get(policy) {
            Some(&(retained, width)) if width > 0 => {
                (retained as f64 / width as f64).clamp(0.05, 1.0)
            }
            _ => fallback,
        }
    }

    /// (Σ retained, Σ width) telemetry for reporting.
    pub fn totals(&self) -> (u64, u64) {
        self.samples.values().fold((0, 0), |(r, w), &(sr, sw)| (r + sr, w + sw))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::HashEmbedder;
    use crate::search::policy::{EtsPolicy, SearchPolicy};

    fn sig(
        steps: usize,
        frontier: usize,
        mean: f64,
        spread: f64,
        clusters: usize,
    ) -> DifficultySignals {
        DifficultySignals {
            steps_taken: steps,
            frontier,
            reward_mean: mean,
            reward_spread: spread,
            entropy: 0.5,
            sem_clusters: clusters,
        }
    }

    #[test]
    fn score_is_a_pure_function_of_committed_telemetry() {
        // Same snapshot → bit-identical score, no matter how many times or
        // in what order it is evaluated (the determinism suite leans on
        // this: scores must agree across shard layouts and schedules).
        let a = sig(1, 16, 0.57, 0.11, 9);
        let b = a.clone();
        assert_eq!(difficulty_score(&a).to_bits(), difficulty_score(&b).to_bits());
        // ...and the entropy channel is telemetry-only: it must not move
        // the score.
        let mut c = a.clone();
        c.entropy = 0.0;
        assert_eq!(difficulty_score(&a).to_bits(), difficulty_score(&c).to_bits());
    }

    #[test]
    fn score_orders_easy_below_contested_below_hopeless() {
        // Shapes taken from the synthetic workloads' reward model: an easy
        // frontier is high-reward and converged, a contested one mid-reward
        // with live spread, a doomed one low-reward and collapsed.
        let easy = difficulty_score(&sig(3, 12, 0.78, 0.05, 10));
        let contested = difficulty_score(&sig(3, 14, 0.45, 0.35, 7));
        let hopeless = difficulty_score(&sig(3, 14, 0.22, 0.05, 2));
        assert!(easy < contested, "{easy} vs {contested}");
        assert!(contested < hopeless, "{contested} vs {hopeless}");
        assert!(easy < 0.50, "easy frontier must clear the easy cut: {easy}");
        assert!(hopeless > 0.65, "doomed frontier must clear the hopeless cut: {hopeless}");
        for s in [easy, contested, hopeless] {
            assert!((0.0..=1.0).contains(&s));
        }
    }

    #[test]
    fn footprint_helper_matches_old_inline_admission_formula() {
        // The helper replaced the inline expression at the admission site:
        //   engine.blocks_for(prompt)
        //       + (width as f64 * policy.kv_retention(width)).ceil() as usize
        // Pin them equal over a grid (prompt blocks × width) for a policy
        // with a non-trivial retention curve.
        let pol = EtsPolicy::new(1.5, 1.0, HashEmbedder::default());
        for prompt_blocks in [0usize, 1, 7, 130] {
            for width in [1usize, 2, 16, 64, 257] {
                let old = prompt_blocks
                    + (width as f64 * pol.kv_retention(width)).ceil() as usize;
                let new = predicted_footprint_blocks(
                    prompt_blocks,
                    width,
                    pol.kv_retention(width),
                );
                assert_eq!(old, new, "prompt {prompt_blocks} width {width}");
            }
        }
    }

    #[test]
    fn controller_maps_scores_to_width_targets() {
        let mut c = BudgetController::new(BudgetConfig::default());
        let base = 16;
        let steps = 24;
        // session 1: hopeless at stage A → floored immediately, from step 2
        let d = c.classify(1, 0, base, steps, &sig(1, 14, 0.30, 0.08, 3));
        assert_eq!(d, Some((2, 4)));
        // session 2: open at stage A (no-op logged), easy at stage B → half
        assert_eq!(c.classify(2, 0, base, steps, &sig(1, 16, 0.60, 0.12, 9)), None);
        assert_eq!(c.classify(2, 0, base, steps, &sig(3, 12, 0.78, 0.05, 10)), Some((4, 8)));
        // session 3: open at A, contested at B → granted 1.5×
        assert_eq!(c.classify(3, 1, base, steps, &sig(1, 16, 0.60, 0.12, 9)), None);
        assert_eq!(c.classify(3, 1, base, steps, &sig(3, 14, 0.45, 0.35, 7)), Some((4, 24)));
        // decided sessions are never re-evaluated
        assert_eq!(c.classify(1, 0, base, steps, &sig(3, 14, 0.45, 0.35, 7)), None);
        assert_eq!(c.classify(2, 0, base, steps, &sig(3, 14, 0.45, 0.35, 7)), None);
        // near the step cap nothing is actionable (and nothing is logged)
        let n = c.decisions().len();
        assert_eq!(c.classify(9, 0, base, 3, &sig(2, 14, 0.30, 0.08, 3)), None);
        assert_eq!(c.decisions().len(), n);
        // the log kept every evaluation, including stage-A no-ops
        let stages: Vec<(u64, u8, usize)> = c
            .decisions()
            .iter()
            .map(|d| (d.session, d.stage, d.width_to))
            .collect();
        let expect = vec![
            (1, STAGE_A, 4),
            (2, STAGE_A, 16),
            (2, STAGE_B, 8),
            (3, STAGE_A, 16),
            (3, STAGE_B, 24),
        ];
        assert_eq!(stages, expect);
    }

    #[test]
    fn reallocation_blocks_are_symmetric_and_ceil_consistent() {
        let pol = EtsPolicy::new(1.5, 1.0, HashEmbedder::default());
        let (r16, r8) = (pol.kv_retention(16), pol.kv_retention(8));
        let (shrunk, is_shrink) = reallocation_blocks(16, r16, 8, r8);
        let (grown, is_grow_shrink) = reallocation_blocks(8, r8, 16, r16);
        assert!(is_shrink && !is_grow_shrink);
        assert_eq!(shrunk, grown, "shrink and regrow must move the same blocks");
        assert_eq!(shrunk, leaf_blocks(16, r16) - leaf_blocks(8, r8));
        assert_eq!(reallocation_blocks(16, r16, 16, r16), (0, false));
    }

    #[test]
    fn calibration_seeds_with_fallback_then_tracks_observations() {
        let mut cal = RetentionCalibration::default();
        assert_eq!(cal.retention_or("ets", 0.4), 0.4, "round 0 uses the static heuristic");
        cal.observe("ets", 6, 16);
        cal.observe("ets", 10, 16);
        let got = cal.retention_or("ets", 0.4);
        assert!((got - 0.5).abs() < 1e-12, "16/32 observed: {got}");
        // other policies keep their own curve
        assert_eq!(cal.retention_or("rebase", 1.0), 1.0);
        cal.observe("rebase", 16, 16);
        assert_eq!(cal.retention_or("rebase", 0.3), 1.0);
        // degenerate observations clamp away from zero
        cal.observe("beam", 0, 16);
        assert_eq!(cal.retention_or("beam", 1.0), 0.05);
        assert_eq!(cal.totals(), (32 + 16, 48 + 16));
    }
}
