//! L3 coordinator: request routing, the generic parallel-map helper, and the
//! sharded, memory-pressure-aware batched `serve` scheduler.
//!
//! Execution shapes:
//!
//! * [`par_map`] — generic embarrassingly-parallel fan-out (`std::thread`
//!   scoped workers + mpsc; tokio is unavailable offline). Retained as a
//!   utility; the eval path now rides [`serve`] instead so there is a single
//!   execution engine.
//! * [`serve`] — continuous batching at simulator scale, sharded
//!   shard-per-core: [`ServeOptions::shards`] workers each own a
//!   shared-nothing [`BatchEngine`] (radix cache) holding a
//!   `capacity_tokens / shards` partition of the *hard* global block budget.
//!   The scheduler runs deterministic lockstep rounds:
//!
//!   1. **resume** — each shard retries its preempted sessions (oldest
//!      admission first), recomputing evicted prefixes through its cache;
//!   2. **migrate** — a suspended session whose resume failed
//!      [`MIGRATION_PATIENCE`] times in a row (sustained pressure) is handed
//!      to the best peer shard that can cover its worst-case resume
//!      reservation (`resume_need_blocks_with`), instead of thrashing
//!      preempt/resume locally. Correct by construction: a suspended
//!      session holds no cache node indices, so `try_resume` simply
//!      recomputes the prefix through whichever cache it lands in — and
//!      per-shard minted-id bases keep the "ids are never reused" invariant
//!      fleet-wide, so a migrant can never falsely share cache with the
//!      target's unrelated problems;
//!   3. **admit** — a deterministic global queue routes each job to the
//!      least-loaded shard (load = resident sessions, then total admissions,
//!      then shard index — all deterministic units, so routing is
//!      reproducible for a fixed seed regardless of thread timing), gated on
//!      each shard's free-block watermark and the global concurrency cap;
//!   4. **step** — every shard with work runs one engine round (prepare →
//!      merged-batch commit with LRU-evict-then-preempt pressure handling →
//!      telemetry) on its own OS thread. Shards are shared-nothing, so the
//!      rounds are embarrassingly parallel; results merge in shard index
//!      order, keeping the whole run deterministic.
//!
//!   Each shard round is costed by [`PerfModel::batch_latency`] (including
//!   resumed sessions' recompute prefill); a global round costs its
//!   *slowest shard* ([`ServeReport::modeled_seconds`] sums the per-round
//!   maxima — shards model parallel serving replicas).
//!
//! All shapes are deterministic for a fixed seed, and — because sessions
//! advance their RNG streams only in `prepare` and commit steps atomically —
//! *scheduling cannot change search results*: worker count, concurrency,
//! shard count, preemption, and cross-shard migration all leave every
//! problem's answer and KV/token accounting identical
//! (`tests/serve_determinism.rs` pins this for shards ∈ {1, 2, 4} under both
//! ample and tight capacity).

use crate::engine::batch::{BatchEngine, DEFAULT_KV_CAPACITY};
use crate::engine::perfmodel::{BatchStats, PerfModel};
use crate::kvcache::DEFAULT_BLOCK_SIZE;
use crate::lm::StepGenerator;
use crate::reward::RewardModel;
use crate::search::driver::{SearchOutcome, SearchParams, SearchSession};
use crate::search::policy::SearchPolicy;
use crate::workload::ModelProfile;
use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Mutex;

/// Consecutive failed resume attempts after which a suspended session is
/// considered *stuck* (sustained pressure) and the coordinator tries to
/// migrate it to a shard with free blocks instead of retrying locally.
pub const MIGRATION_PATIENCE: u32 = 2;

/// Parallel map over `items` with `workers` threads, preserving order.
///
/// Workers pull indices from a shared queue (work stealing by index), so
/// uneven per-item costs (hard problems search longer) balance out.
pub fn par_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    // index queue
    let queue: Mutex<std::vec::IntoIter<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>().into_iter());
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let queue = &queue;
            let f = &f;
            scope.spawn(move || loop {
                let item = queue.lock().unwrap().next();
                match item {
                    Some((i, t)) => {
                        let r = f(i, t);
                        if tx.send((i, r)).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker died before finishing")).collect()
    })
}

/// Shared throughput fold: `completed` problems over `seconds`, guarding
/// the zero/negative-denominator case (no batches executed, zero wall
/// clock). Both the modeled [`ServeReport`] and the wall-clock
/// [`CoordinatorStats`] throughputs fold through here.
pub fn throughput_problems_per_sec(completed: usize, seconds: f64) -> f64 {
    if seconds > 0.0 {
        completed as f64 / seconds
    } else {
        0.0
    }
}

/// A request to the serving coordinator.
#[derive(Clone, Debug)]
pub struct SearchRequest {
    pub request_id: u64,
    pub problem_id: u64,
}

/// One problem's ingredients for the batched serve loop.
pub struct ServeJob<G, R, P> {
    pub lm: G,
    pub prm: R,
    pub policy: P,
}

/// Scheduler configuration for [`serve`].
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Most problems admitted (running + suspended, across all shards) at a
    /// time.
    pub concurrency: usize,
    /// Hard global KV budget in tokens; each shard owns an equal partition
    /// (`capacity_tokens / shards`), rounded up to whole blocks.
    pub capacity_tokens: usize,
    /// Tokens per KV block (paged-allocator page size).
    pub block_size: usize,
    /// Shard-per-core engines: `shards` workers, each owning a
    /// shared-nothing radix cache and stepped on its own OS thread.
    /// 1 (the default) is the single-engine scheduler.
    pub shards: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            concurrency: 8,
            capacity_tokens: DEFAULT_KV_CAPACITY,
            block_size: DEFAULT_BLOCK_SIZE,
            shards: 1,
        }
    }
}

impl ServeOptions {
    pub fn with_concurrency(concurrency: usize) -> Self {
        Self { concurrency, ..Default::default() }
    }

    pub fn with_shards(concurrency: usize, shards: usize) -> Self {
        Self { concurrency, shards, ..Default::default() }
    }
}

/// Telemetry of one engine round on one shard: the merged expansion batch of
/// every active problem, plus its modeled cost.
#[derive(Clone, Debug, Default)]
pub struct BatchRecord {
    /// Shard that executed this round's batch.
    pub shard: usize,
    /// Problems that committed expansions this round.
    pub problems: usize,
    /// Leaves expanded (requests in the merged batch).
    pub requests: usize,
    /// Continuations sampled (lockstep decode batch size).
    pub model_calls: usize,
    /// Tokens generated this round.
    pub new_tokens: usize,
    /// Unique KV tokens resident in the shard's cache after the round —
    /// physical occupancy, including warm (unpinned) working sets of
    /// suspended sessions awaiting eviction. Drives wave fragmentation.
    pub resident_kv_tokens: usize,
    /// Unique KV tokens pinned by the sessions that committed this round —
    /// what the decode actually reads (suspended sessions' warm KV is not
    /// touched by any running sequence).
    pub pinned_kv_tokens: usize,
    /// What the same round would pin without radix sharing.
    pub unshared_kv_tokens: usize,
    /// Tokens re-prefilled by sessions resumed (or migrated in) this round.
    pub recompute_tokens: usize,
    /// Sessions preempted during this round's commits.
    pub preemptions: usize,
    /// Modeled wall-clock of this round ([`PerfModel::batch_latency`]).
    pub seconds: f64,
}

/// Per-shard aggregate telemetry of a [`serve`] run.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    pub shard: usize,
    /// Problems admitted to this shard by the global router (migrations in
    /// are counted separately).
    pub admitted: u64,
    /// Sessions preempted on this shard under memory pressure.
    pub preemptions: u64,
    /// Sessions resumed on this shard (local resumes + migrated-in resumes).
    pub resumes: u64,
    /// Tokens re-prefilled by resumes through this shard's cache.
    pub recompute_tokens: u64,
    /// Suspended sessions this shard received from pressured peers.
    pub migrations_in: u64,
    /// Suspended sessions this shard handed to peers with free blocks.
    pub migrations_out: u64,
    /// High-water mark of this shard's cache (unique tokens).
    pub peak_resident_kv_tokens: usize,
    /// High-water mark of this shard's allocated blocks.
    pub peak_used_blocks: usize,
    /// This shard's partition of the global block budget.
    pub total_blocks: usize,
    /// Σ of this shard's modeled batch latencies (its busy time).
    pub busy_seconds: f64,
}

/// Result of a [`serve`] run.
pub struct ServeReport {
    /// Per-problem outcomes, in job order.
    pub outcomes: Vec<SearchOutcome>,
    /// One record per shard per executed round, in (round, shard) order.
    pub batches: Vec<BatchRecord>,
    /// Modeled serving time: Σ over rounds of the *slowest shard's* batch
    /// latency (shards model parallel replicas). For `shards == 1` this is
    /// exactly Σ batch seconds — the serving-time denominator for
    /// throughput.
    pub modeled_seconds: f64,
    /// High-water mark across rounds of the summed shard caches (unique
    /// tokens).
    pub peak_resident_kv_tokens: usize,
    /// Most problems ever simultaneously admitted (running + suspended,
    /// all shards).
    pub max_concurrent: usize,
    /// Most problems that actually advanced (committed a step) in a single
    /// round across all shards — the *resident* concurrency, excluding
    /// swapped-out suspended sessions. This is the number oversubscription
    /// throttles.
    pub peak_step_concurrency: usize,
    /// Sessions preempted under memory pressure (suspend events).
    pub preemptions: u64,
    /// Sessions resumed after preemption (including migrated resumes).
    pub resumes: u64,
    /// Tokens re-prefilled by resumes (the recompute bill of preemption and
    /// migration).
    pub recompute_tokens: u64,
    /// Rounds where admission was blocked by every shard's free-block
    /// watermark.
    pub admission_blocked_rounds: u64,
    /// Step commits deferred to a later round because nothing could be
    /// evicted or preempted to make room.
    pub deferred_commits: u64,
    /// Σ per-shard high-water marks of allocated blocks (≤ `total_blocks`
    /// by construction — each shard's budget is hard).
    pub peak_used_blocks: usize,
    /// The hard global block budget (Σ shard partitions).
    pub total_blocks: usize,
    /// Shard count the run was scheduled with.
    pub shards: usize,
    /// Suspended sessions moved across shards under sustained pressure.
    pub migrations: u64,
    /// Per-shard telemetry, indexed by shard.
    pub shard_stats: Vec<ShardStats>,
}

impl ServeReport {
    pub fn throughput_problems_per_sec(&self) -> f64 {
        throughput_problems_per_sec(self.outcomes.len(), self.modeled_seconds)
    }

    pub fn batch_seconds(&self) -> Vec<f64> {
        self.batches.iter().map(|b| b.seconds).collect()
    }

    /// Total memory-pressure interventions: preemptions, watermark-blocked
    /// admissions, and deferred commits. 0 means the budget never bound.
    pub fn kv_pressure_events(&self) -> u64 {
        self.preemptions + self.admission_blocked_rounds + self.deferred_commits
    }
}

/// One admitted problem in the scheduler: its outcome slot and admission
/// sequence number (lower = admitted earlier = higher priority; preemption
/// victims are picked from the highest sequence numbers, vLLM-style).
struct Slot<G, R, P> {
    id: usize,
    seq: u64,
    /// Consecutive failed resume attempts while suspended — the per-session
    /// sustained-pressure signal the migration policy keys on. Reset on any
    /// successful resume and on migration (the new shard gets a fresh try).
    stalled: u32,
    session: SearchSession<G, R, P>,
}

/// One shard of the serve scheduler: a shared-nothing engine plus the
/// sessions resident on it. Cross-shard state (the admission queue, the
/// migration policy, round merging) lives in [`serve`]; everything here is
/// touched by at most one thread per round.
struct Shard<G, R, P> {
    index: usize,
    engine: BatchEngine,
    running: Vec<Slot<G, R, P>>,
    suspended: Vec<Slot<G, R, P>>,
    stats: ShardStats,
}

/// What one shard produced in one round.
struct RoundResult {
    record: Option<BatchRecord>,
    finished: Vec<(usize, SearchOutcome)>,
    progressed: bool,
    deferred_commits: u64,
}

impl<G: StepGenerator, R: RewardModel, P: SearchPolicy> Shard<G, R, P> {
    fn new(index: usize, n_shards: usize, capacity_tokens: usize, block_size: usize) -> Self {
        // Disjoint minted-id residue classes per shard keep the "ids are
        // never reused" invariant fleet-wide, so a migrated session can
        // never falsely share cache with the target shard's unrelated
        // problems (see BatchEngine::for_shard).
        let engine = BatchEngine::for_shard(
            capacity_tokens,
            block_size,
            index as u32,
            n_shards as u32,
        );
        let stats = ShardStats {
            shard: index,
            total_blocks: engine.total_blocks(),
            ..Default::default()
        };
        Self { index, engine, running: Vec::new(), suspended: Vec::new(), stats }
    }

    /// Problems resident on this shard (running + suspended) — the
    /// deterministic load unit the admission router sorts by.
    fn resident(&self) -> usize {
        self.running.len() + self.suspended.len()
    }

    /// One resume attempt for `slot` on this shard's engine, with a single
    /// relieve-and-retry on pressure. Returns the recomputed tokens on
    /// success. The resume protocol lives only here — both the local
    /// resume pass and the migration path go through it.
    fn try_resume_slot(&mut self, slot: &mut Slot<G, R, P>) -> Option<usize> {
        for attempt in 0..2 {
            match slot.session.try_resume(&mut self.engine) {
                Ok(recomputed) => {
                    self.stats.resumes += 1;
                    return Some(recomputed);
                }
                Err(p) => {
                    if attempt == 0 && self.engine.relieve(&p) > 0 {
                        continue;
                    }
                    break;
                }
            }
        }
        None
    }

    /// Round step 1: resume preempted sessions, oldest admission first
    /// (FIFO — younger sessions never leapfrog a blocked elder). Returns
    /// tokens recomputed; a failed attempt bumps that session's `stalled`
    /// counter (the migration trigger), a success clears it.
    fn resume_pass(&mut self) -> usize {
        let mut pending = std::mem::take(&mut self.suspended);
        pending.sort_by_key(|s| s.seq);
        let mut recompute = 0usize;
        for mut slot in pending {
            // self.suspended doubles as the still-suspended list: attempt
            // resumes only while it is empty (strict FIFO)
            let resumed = if self.suspended.is_empty() {
                match self.try_resume_slot(&mut slot) {
                    Some(recomputed) => {
                        recompute += recomputed;
                        true
                    }
                    None => {
                        slot.stalled += 1;
                        false
                    }
                }
            } else {
                false
            };
            if resumed {
                slot.stalled = 0;
                self.running.push(slot);
            } else {
                self.suspended.push(slot);
            }
        }
        recompute
    }

    /// Round steps 3–5 (thread-parallel across shards): finish drained
    /// sessions, prepare the merged batch, commit it in priority order with
    /// evict-then-preempt pressure handling, and close the round with
    /// telemetry + the perf-model cost.
    fn run_round(
        &mut self,
        perf: &PerfModel,
        model: &ModelProfile,
        round_recompute: usize,
    ) -> RoundResult {
        let mut progressed = false;
        let mut finished: Vec<(usize, SearchOutcome)> = Vec::new();
        let mut deferred_commits = 0u64;

        // collect each resident session's next allocation and run the
        // generator (prepare — no KV charged yet). Sessions with no work
        // left finish *now* (release-on-complete) so their blocks refill
        // slots on the next admission pass. Sessions that already hold a
        // prepared step (deferred or preempted mid-commit) keep it.
        let mut active: Vec<Slot<G, R, P>> = Vec::new();
        for mut slot in self.running.drain(..) {
            if slot.session.has_pending() {
                active.push(slot);
                continue;
            }
            let requests = slot.session.next_requests(&mut self.engine);
            if requests.is_empty() {
                finished.push((slot.id, slot.session.finish(&mut self.engine)));
                progressed = true;
            } else {
                slot.session.prepare(&mut self.engine, &requests);
                active.push(slot);
            }
        }
        self.running = active;

        // commit the merged batch in priority order; on reservation
        // failure: evict unpinned branches, then preempt from the tail
        // (never the committing slot), then defer to the next round
        self.running.sort_by_key(|s| s.seq);
        let mut rec = BatchRecord {
            shard: self.index,
            recompute_tokens: round_recompute,
            ..Default::default()
        };
        let mut i = 0usize;
        while i < self.running.len() {
            let n_requests = self.running[i].session.pending_requests();
            let committed = loop {
                match self.running[i].session.try_commit(&mut self.engine) {
                    Ok(m) => break Some(m),
                    Err(p) => {
                        // first remedy: reclaim unpinned branches (LRU),
                        // evicting only the deficit so other suspended
                        // sessions keep as much warm KV as possible
                        if self.engine.relieve(&p) > 0 {
                            continue;
                        }
                        // second remedy: preempt the lowest-priority
                        // not-yet-committed session (sorted tail)
                        if self.running.len() > i + 1 {
                            let mut victim = self.running.pop().expect("len > i + 1");
                            victim.session.suspend(&mut self.engine);
                            self.stats.preemptions += 1;
                            rec.preemptions += 1;
                            self.suspended.push(victim);
                            continue;
                        }
                        break None; // defer this step to the next round
                    }
                }
            };
            match committed {
                Some(m) => {
                    rec.problems += 1;
                    rec.requests += n_requests;
                    rec.model_calls += m.model_calls;
                    rec.new_tokens += m.new_tokens;
                    rec.pinned_kv_tokens += m.live_kv_tokens;
                    rec.unshared_kv_tokens += m.unshared_kv_tokens;
                    progressed = true;
                    i += 1;
                }
                None => {
                    // everything evictable is gone and no lower-priority
                    // victim remains; later slots need even more room
                    deferred_commits += 1;
                    break;
                }
            }
        }

        // close the round: telemetry, hard-budget assertion, perf cost
        rec.resident_kv_tokens = self.engine.live_tokens();
        self.stats.peak_resident_kv_tokens =
            self.stats.peak_resident_kv_tokens.max(rec.resident_kv_tokens);
        self.stats.peak_used_blocks =
            self.stats.peak_used_blocks.max(self.engine.used_blocks());
        debug_assert!(
            self.engine.used_blocks() <= self.engine.total_blocks(),
            "shard {} exceeded the hard block budget: {} > {}",
            self.index,
            self.engine.used_blocks(),
            self.engine.total_blocks()
        );
        let record = if rec.problems > 0 || rec.recompute_tokens > 0 {
            // decode reads only what the committed sessions pin; wave
            // fragmentation is driven by physical occupancy (which, under
            // lazy suspend, may include warm suspended working sets)
            let (read, resident) = if perf.shared_kv {
                (rec.pinned_kv_tokens, rec.resident_kv_tokens)
            } else {
                (rec.unshared_kv_tokens, rec.unshared_kv_tokens)
            };
            let stats = BatchStats {
                model_calls: rec.model_calls,
                new_tokens: rec.new_tokens,
                read_kv_tokens: read,
                resident_kv_tokens: resident,
                recompute_prefill_tokens: rec.recompute_tokens,
                block_size: self.engine.block_size(),
            };
            rec.seconds = perf.batch_latency(&stats, model).seconds;
            self.stats.busy_seconds += rec.seconds;
            self.stats.recompute_tokens += rec.recompute_tokens as u64;
            Some(rec)
        } else {
            None
        };
        RoundResult { record, finished, progressed, deferred_commits }
    }
}

/// Serve `jobs` through `opts.shards` shared-nothing engines with
/// continuous batching under a hard, partitioned KV block budget: at most
/// `opts.concurrency` searches are admitted at a time across all shards, a
/// deterministic router assigns each to the least-loaded shard, each global
/// round advances every shard's resident sessions by one step (shards on
/// parallel OS threads, one merged batch per shard), and finished searches
/// hand their slot to the next queued job mid-flight.
///
/// Memory pressure is handled in escalating order per shard: (1) admission
/// is gated on a free-block watermark, (2) a failed step reservation
/// LRU-evicts unpinned branches, (3) still failing, the lowest-priority
/// resident session is preempted — its blocks released, its tree kept — and
/// resumed later by recomputing the evicted prefix. Under *sustained*
/// pressure ([`MIGRATION_PATIENCE`]), a stuck suspended session migrates to
/// the shard with the most reclaimable headroom instead of thrashing
/// preempt/resume locally. Because a session's RNG advances only in
/// prepare/commit (both atomic w.r.t. preemption and migration), neither
/// the schedule, the shard count, nor any migration can change search
/// results.
///
/// Panics when even a single session cannot advance alone at the per-shard
/// budget — the partitioned capacity is below one problem's working set.
pub fn serve<G, R, P>(
    jobs: Vec<ServeJob<G, R, P>>,
    params: &SearchParams,
    opts: &ServeOptions,
    perf: &PerfModel,
    model: &ModelProfile,
) -> ServeReport
where
    G: StepGenerator + Send,
    R: RewardModel + Send,
    P: SearchPolicy + Send,
{
    let concurrency = opts.concurrency.max(1);
    let n_shards = opts.shards.max(1);
    let per_shard_capacity = (opts.capacity_tokens / n_shards).max(opts.block_size);
    let n = jobs.len();
    let mut shards: Vec<Shard<G, R, P>> = (0..n_shards)
        .map(|index| Shard::new(index, n_shards, per_shard_capacity, opts.block_size))
        .collect();
    let mut queue: VecDeque<(usize, ServeJob<G, R, P>)> =
        jobs.into_iter().enumerate().collect();
    let mut outcomes: Vec<Option<SearchOutcome>> = (0..n).map(|_| None).collect();
    let mut batches: Vec<BatchRecord> = Vec::new();
    let mut peak = 0usize;
    let mut max_concurrent = 0usize;
    let mut peak_step_concurrency = 0usize;
    let mut modeled_seconds = 0.0f64;
    let mut admit_seq = 0u64;
    let mut migrations = 0u64;
    let mut admission_blocked_rounds = 0u64;
    let mut deferred_commits = 0u64;
    // Livelock guard: rounds that neither commit, finish, nor admit make no
    // real progress (a resume or migration alone does not count — resume →
    // preempt can thrash); several in a row means the per-shard budget is
    // below one working set.
    let mut stalled_rounds = 0u32;

    loop {
        let mut progressed = false;
        let mut round_recompute = vec![0usize; n_shards];

        // 1. per-shard resume pass, serial in shard index order (cheap:
        //    cache bookkeeping only, no generator calls)
        for shard in shards.iter_mut() {
            round_recompute[shard.index] = shard.resume_pass();
        }

        // 2. cross-shard migration: a session whose resume failed
        //    MIGRATION_PATIENCE times in a row (sustained pressure) is
        //    handed to the best peer that can actually cover its worst-case
        //    resume reservation — peers ranked by (no suspended backlog of
        //    their own, reclaimable headroom, index), every viable one
        //    considered. The move is a plain ownership transfer — a
        //    suspended ledger holds no cache node indices — and the resume
        //    recomputes the prefix through the target cache, charged to the
        //    target's round recompute.
        if n_shards > 1 {
            for src in 0..n_shards {
                let stuck = shards[src]
                    .suspended
                    .first()
                    .map_or(false, |s| s.stalled >= MIGRATION_PATIENCE);
                if !stuck {
                    continue;
                }
                let mut candidates: Vec<usize> =
                    (0..n_shards).filter(|&d| d != src).collect();
                candidates.sort_by_key(|&d| {
                    let sig = shards[d].engine.pressure();
                    (
                        !shards[d].suspended.is_empty(), // unloaded peers first
                        std::cmp::Reverse(sig.free_blocks + sig.evictable_blocks),
                        d,
                    )
                });
                // the migrant's working-set sequences are engine-independent:
                // build them once, size every candidate against them
                let seqs = shards[src].suspended[0].session.suspended_sequences();
                let dst = candidates.into_iter().find(|&d| {
                    let need = shards[src].suspended[0]
                        .session
                        .resume_need_blocks_with(&shards[d].engine, &seqs);
                    let sig = shards[d].engine.pressure();
                    sig.free_blocks + sig.evictable_blocks >= need
                });
                let Some(dst) = dst else {
                    continue; // genuinely no shard can host it — retry locally
                };
                let mut slot = shards[src].suspended.remove(0);
                slot.stalled = 0; // fresh patience on the new shard
                shards[src].stats.migrations_out += 1;
                let dst_shard = &mut shards[dst];
                dst_shard.stats.migrations_in += 1;
                match dst_shard.try_resume_slot(&mut slot) {
                    Some(recomputed) => {
                        round_recompute[dst] += recomputed;
                        dst_shard.running.push(slot);
                    }
                    None => dst_shard.suspended.push(slot),
                }
                migrations += 1;
            }
        }

        // 3. deterministic global admission: route each queued job to the
        //    least-loaded shard — (resident sessions, admissions so far,
        //    shard index), all deterministic units — skipping shards whose
        //    free-block watermark leaves no headroom. Continuous batching:
        //    finished slots refill mid-flight.
        loop {
            let resident_total: usize = shards.iter().map(|s| s.resident()).sum();
            if resident_total >= concurrency {
                break;
            }
            let prompt = match queue.front() {
                Some((_, job)) => job.lm.prompt_tokens(),
                None => break,
            };
            let mut order: Vec<usize> = (0..n_shards).collect();
            order.sort_by_key(|&s| (shards[s].resident(), shards[s].stats.admitted, s));
            let mut target: Option<usize> = None;
            for &s in &order {
                if shards[s].engine.can_admit(prompt) {
                    target = Some(s);
                    break;
                }
                // Second chance for an *empty* shard sitting on reclaimable
                // memory: warm KV orphaned by sessions that migrated away
                // serves nobody once nothing is resident, but still counts
                // against the free-block watermark — flush it so the
                // shard's partition of the budget cannot stay blocked for
                // the rest of the run. (A shard with resident sessions
                // keeps its warm KV: its own commit/resume pressure paths
                // reclaim lazily, and on a single shard resident == 0
                // implies an empty cache, so behavior there is unchanged.)
                if shards[s].resident() == 0
                    && shards[s].engine.pressure().evictable_blocks > 0
                {
                    shards[s].engine.relieve_pressure(usize::MAX);
                    if shards[s].engine.can_admit(prompt) {
                        target = Some(s);
                        break;
                    }
                }
            }
            let Some(target) = target else {
                admission_blocked_rounds += 1;
                break;
            };
            let (id, job) = queue.pop_front().expect("front checked above");
            let session =
                SearchSession::new(&mut shards[target].engine, job.lm, job.prm, job.policy, params);
            shards[target].running.push(Slot { id, seq: admit_seq, stalled: 0, session });
            shards[target].stats.admitted += 1;
            admit_seq += 1;
            progressed = true;
        }
        let total_resident: usize = shards.iter().map(|s| s.resident()).sum();
        if total_resident == 0 && queue.is_empty() {
            break;
        }
        max_concurrent = max_concurrent.max(total_resident);

        // 4. run every shard that has work on its own thread (shared-
        //    nothing, so embarrassingly parallel); merge in shard index
        //    order so the run stays deterministic regardless of timing
        let work: Vec<usize> = shards
            .iter()
            .enumerate()
            .filter(|(i, s)| !s.running.is_empty() || round_recompute[*i] > 0)
            .map(|(i, _)| i)
            .collect();
        let mut results: Vec<(usize, RoundResult)> = Vec::new();
        if work.len() <= 1 {
            for &i in &work {
                let r = shards[i].run_round(perf, model, round_recompute[i]);
                results.push((i, r));
            }
        } else {
            let collected: Mutex<Vec<(usize, RoundResult)>> = Mutex::new(Vec::new());
            std::thread::scope(|scope| {
                for (i, shard) in shards.iter_mut().enumerate() {
                    if !work.contains(&i) {
                        continue;
                    }
                    let recompute = round_recompute[i];
                    let collected = &collected;
                    scope.spawn(move || {
                        let r = shard.run_round(perf, model, recompute);
                        collected.lock().unwrap().push((i, r));
                    });
                }
            });
            results = collected.into_inner().expect("shard thread panicked");
            results.sort_by_key(|&(i, _)| i);
        }

        // 5. merge the round: outcomes, telemetry, and the round's modeled
        //    cost — its slowest shard (shards are parallel replicas)
        let mut round_seconds = 0.0f64;
        let mut round_step_problems = 0usize;
        for (_, result) in results {
            for (id, outcome) in result.finished {
                outcomes[id] = Some(outcome);
            }
            progressed |= result.progressed;
            deferred_commits += result.deferred_commits;
            if let Some(rec) = result.record {
                round_seconds = round_seconds.max(rec.seconds);
                round_step_problems += rec.problems;
                batches.push(rec);
            }
        }
        modeled_seconds += round_seconds;
        peak_step_concurrency = peak_step_concurrency.max(round_step_problems);
        peak = peak.max(shards.iter().map(|s| s.engine.live_tokens()).sum());

        if progressed {
            stalled_rounds = 0;
        } else {
            stalled_rounds += 1;
            assert!(
                stalled_rounds < 4,
                "serve stalled: per-shard KV capacity ({} blocks x {} tokens, {} shard(s)) \
                 is below a single problem's working set",
                shards[0].engine.total_blocks(),
                shards[0].engine.block_size(),
                n_shards
            );
        }
    }

    for shard in shards.iter_mut() {
        // flush warm KV orphaned by sessions that migrated away (lazy
        // suspend leaves it cached) so the all-pins-released invariant is
        // meaningful per shard
        shard.engine.relieve_pressure(usize::MAX);
        debug_assert_eq!(
            shard.engine.live_tokens(),
            0,
            "shard {} left pinned KV behind",
            shard.index
        );
    }
    let preemptions: u64 = shards.iter().map(|s| s.stats.preemptions).sum();
    let resumes: u64 = shards.iter().map(|s| s.stats.resumes).sum();
    let recompute_tokens: u64 = shards.iter().map(|s| s.stats.recompute_tokens).sum();
    let peak_used_blocks: usize = shards.iter().map(|s| s.stats.peak_used_blocks).sum();
    let total_blocks: usize = shards.iter().map(|s| s.engine.total_blocks()).sum();
    ServeReport {
        outcomes: outcomes
            .into_iter()
            .map(|o| o.expect("every job produces an outcome"))
            .collect(),
        batches,
        modeled_seconds,
        peak_resident_kv_tokens: peak,
        max_concurrent,
        peak_step_concurrency,
        preemptions,
        resumes,
        recompute_tokens,
        admission_blocked_rounds,
        deferred_commits,
        peak_used_blocks,
        total_blocks,
        shards: n_shards,
        migrations,
        shard_stats: shards.into_iter().map(|s| s.stats).collect(),
    }
}

/// Aggregated coordinator statistics.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorStats {
    pub completed: u64,
    pub correct: u64,
    pub total_kv_tokens: u64,
    pub total_new_tokens: u64,
    pub total_model_calls: u64,
    pub wall_seconds: f64,
}

impl CoordinatorStats {
    pub fn throughput_problems_per_sec(&self) -> f64 {
        throughput_problems_per_sec(self.completed as usize, self.wall_seconds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::H100_NVL;
    use crate::lm::SynthLm;
    use crate::reward::OraclePrm;
    use crate::search::policy::RebasePolicy;
    use crate::workload::{ProblemSet, WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

    fn jobs(n: usize, seed: u64) -> Vec<ServeJob<SynthLm, OraclePrm, RebasePolicy>> {
        let spec = WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM);
        ProblemSet::generate(&spec, n, seed)
            .problems
            .into_iter()
            .map(|p| {
                let id = p.id;
                let prm = OraclePrm::for_profile(&spec.model, seed ^ 0xBEEF ^ id);
                ServeJob {
                    lm: SynthLm::new(p, seed ^ id),
                    prm,
                    policy: RebasePolicy::default(),
                }
            })
            .collect()
    }

    fn fingerprints(report: &ServeReport) -> Vec<(Option<i64>, u64, u64)> {
        report
            .outcomes
            .iter()
            .map(|o| (o.answer, o.total_kv_tokens(), o.total_new_tokens()))
            .collect()
    }

    #[test]
    fn serve_interleaves_concurrent_problems_through_one_engine() {
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        let opts = ServeOptions::with_concurrency(3);
        let report = serve(jobs(5, 42), &params, &opts, &perf, &LLEMMA_34B_SIM);
        assert_eq!(report.outcomes.len(), 5);
        assert!(report.max_concurrent >= 2, "batching must co-schedule problems");
        assert!(!report.batches.is_empty());
        assert!(report.modeled_seconds > 0.0);
        assert!(report.throughput_problems_per_sec() > 0.0);
        // ample capacity: the pressure machinery must stay dormant
        assert_eq!(report.kv_pressure_events(), 0);
        assert_eq!(report.resumes, 0);
        assert!(report.peak_used_blocks <= report.total_blocks);
        // per-batch latency from the perf model on every executed round
        let multi: Vec<&BatchRecord> =
            report.batches.iter().filter(|b| b.problems >= 2).collect();
        assert!(!multi.is_empty(), "no round ever held >= 2 problems");
        for b in &report.batches {
            assert!(b.seconds > 0.0, "{b:?}");
            assert!(b.model_calls > 0);
            assert!(b.resident_kv_tokens > 0);
            assert!(b.resident_kv_tokens <= b.unshared_kv_tokens + 5_000);
        }
        // the shared cache's high-water mark covers the co-scheduled set
        let solo_peak = report.outcomes.iter().map(|o| o.peak_kv_tokens()).max().unwrap();
        assert!(report.peak_resident_kv_tokens as u64 >= solo_peak);
        for o in &report.outcomes {
            assert!(o.answer.is_some());
        }
    }

    #[test]
    fn serve_results_do_not_depend_on_concurrency() {
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        let summary = |c: usize| -> Vec<(Option<i64>, u64, u64)> {
            let opts = ServeOptions::with_concurrency(c);
            fingerprints(&serve(jobs(6, 7), &params, &opts, &perf, &LLEMMA_34B_SIM))
        };
        let base = summary(1);
        assert_eq!(base, summary(2));
        assert_eq!(base, summary(4));
    }

    #[test]
    fn serve_results_do_not_depend_on_shard_count() {
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 4);
        let run = |shards: usize| {
            let opts = ServeOptions::with_shards(4, shards);
            serve(jobs(6, 7), &params, &opts, &perf, &LLEMMA_34B_SIM)
        };
        let base = run(1);
        assert_eq!(base.shards, 1);
        assert_eq!(base.migrations, 0);
        assert_eq!(base.shard_stats.len(), 1);
        for shards in [2usize, 4] {
            let sharded = run(shards);
            assert_eq!(
                fingerprints(&base),
                fingerprints(&sharded),
                "shard count {shards} changed results"
            );
            assert_eq!(sharded.shards, shards);
            assert_eq!(sharded.shard_stats.len(), shards);
            // ample capacity: no pressure, hence no migration
            assert_eq!(sharded.kv_pressure_events(), 0);
            assert_eq!(sharded.migrations, 0);
            // the deterministic router actually spread the load
            let used: usize =
                sharded.shard_stats.iter().filter(|s| s.admitted > 0).count();
            assert!(used >= 2, "least-loaded routing left all jobs on one shard");
            // every problem admitted exactly once across shards
            let admitted: u64 = sharded.shard_stats.iter().map(|s| s.admitted).sum();
            assert_eq!(admitted, 6);
        }
    }

    #[test]
    fn serve_matches_run_search_per_problem() {
        // The batched path must report exactly what a solo run reports: the
        // cache views are per-ledger, so co-scheduling changes nothing.
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        let opts = ServeOptions::with_concurrency(4);
        let report = serve(jobs(4, 11), &params, &opts, &perf, &LLEMMA_34B_SIM);
        for (job, served) in jobs(4, 11).into_iter().zip(&report.outcomes) {
            let mut lm = job.lm;
            let mut prm = job.prm;
            let mut policy = job.policy;
            let solo = crate::search::run_search(&mut lm, &mut prm, &mut policy, &params);
            assert_eq!(solo.answer, served.answer);
            assert_eq!(solo.total_kv_tokens(), served.total_kv_tokens());
            assert_eq!(solo.total_new_tokens(), served.total_new_tokens());
            assert_eq!(solo.steps.len(), served.steps.len());
        }
    }

    #[test]
    fn tight_capacity_preempts_but_cannot_change_results() {
        // Oversubscribe: a budget well below the uncapped working set but
        // comfortably above any single problem's peak. The scheduler must
        // keep every answer and every per-problem KV/token count identical
        // while visibly intervening (preempting / blocking admission /
        // deferring commits).
        let params = SearchParams { width: 16, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        let uncapped = serve(
            jobs(6, 42),
            &params,
            &ServeOptions::with_concurrency(6),
            &perf,
            &LLEMMA_34B_SIM,
        );
        let solo_peak = uncapped
            .outcomes
            .iter()
            .map(|o| o.peak_kv_tokens())
            .max()
            .unwrap() as usize;
        assert!(
            uncapped.peak_resident_kv_tokens > 2 * solo_peak + 4096,
            "precondition: co-scheduling must oversubscribe the tight budget \
             (shared peak {} vs solo peak {})",
            uncapped.peak_resident_kv_tokens,
            solo_peak
        );
        let tight = ServeOptions {
            concurrency: 6,
            capacity_tokens: 2 * solo_peak + 4096,
            block_size: 16,
            shards: 1,
        };
        let capped = serve(jobs(6, 42), &params, &tight, &perf, &LLEMMA_34B_SIM);
        assert_eq!(
            fingerprints(&uncapped),
            fingerprints(&capped),
            "memory pressure changed search results"
        );
        assert!(
            capped.kv_pressure_events() > 0,
            "a below-working-set budget must trigger interventions"
        );
        assert!(
            capped.peak_used_blocks <= capped.total_blocks,
            "hard budget violated: {} > {}",
            capped.peak_used_blocks,
            capped.total_blocks
        );
        assert!(
            capped.peak_resident_kv_tokens
                <= capped.total_blocks * tight.block_size,
            "resident tokens exceeded the block budget"
        );
        // preempted sessions recompute on resume; if any session was
        // preempted the recompute bill must be visible in the batches
        if capped.preemptions > 0 {
            assert!(capped.resumes > 0, "preempted sessions must resume");
            assert!(capped.recompute_tokens > 0);
            assert!(capped.batches.iter().any(|b| b.recompute_tokens > 0));
        }
        for o in &capped.outcomes {
            assert!(o.answer.is_some());
        }
    }

    #[test]
    #[should_panic(expected = "below a single problem's working set")]
    fn serve_panics_when_capacity_cannot_hold_one_problem() {
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        // 512 tokens barely covers the prompt (120) — the first real step
        // cannot commit and there is nothing to preempt
        let opts = ServeOptions {
            concurrency: 2,
            capacity_tokens: 512,
            block_size: 16,
            shards: 1,
        };
        let _ = serve(jobs(2, 3), &params, &opts, &perf, &LLEMMA_34B_SIM);
    }

    #[test]
    fn throughput_helper_guards_zero_seconds() {
        assert_eq!(throughput_problems_per_sec(10, 0.0), 0.0);
        assert_eq!(throughput_problems_per_sec(10, 2.0), 5.0);
        assert_eq!(throughput_problems_per_sec(0, 1.0), 0.0);
    }

    #[test]
    fn par_map_preserves_order_and_results() {
        let items: Vec<u64> = (0..100).collect();
        let out = par_map(items.clone(), 8, |_, x| x * x);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i as u64) * (i as u64));
        }
    }

    #[test]
    fn par_map_single_worker_fallback() {
        let out = par_map(vec![1, 2, 3], 1, |i, x| i + x);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<u32> = par_map(Vec::<u32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn par_map_uneven_work_balances() {
        // items with wildly different costs still all complete
        let items: Vec<u64> = (0..32).collect();
        let out = par_map(items, 4, |_, x| {
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i);
            }
            (x, acc).0
        });
        assert_eq!(out.len(), 32);
    }
}
