//! L3 coordinator: request routing, the generic parallel-map helper, and the
//! sharded, memory-pressure-aware batched `serve` scheduler. This module
//! holds the *policy* — admission, migration, and round merging; the
//! *mechanism* (shard state, the plan → decode → commit round pipeline, and
//! the persistent worker pool) lives in [`runtime`].
//!
//! Execution shapes:
//!
//! * [`par_map`] — generic embarrassingly-parallel fan-out (`std::thread`
//!   scoped workers + mpsc; tokio is unavailable offline). Retained as a
//!   utility; the eval path now rides [`serve`] instead so there is a single
//!   execution engine.
//! * [`serve`] — continuous batching at simulator scale, sharded
//!   shard-per-core: [`ServeOptions::shards`] shards each own a
//!   shared-nothing [`crate::engine::BatchEngine`] (radix cache) holding a
//!   `capacity_tokens / shards` partition of the *hard* global block budget,
//!   driven by N **persistent workers** (one per shard, spawned once per
//!   `serve` call and fed [`runtime::RoundPlan`] messages over mpsc — no
//!   per-round thread spawning). The scheduler runs deterministic rounds:
//!
//!   0. **publish** (with [`ServeOptions::prefix_share`]) — the round
//!      barrier audits and rebuilds the **global prefix hub**
//!      ([`crate::kvcache::prefixhub::PrefixHub`]): each shard's
//!      committed prompt prefixes are fingerprinted (token-block hash
//!      chains, sized by the read-only `peek_prefix` walk) into one
//!      versioned snapshot that everything later in the round reads —
//!      shards stay shared-nothing, the hub is a read-only directory;
//!   1. **resume** — each shard retries its preempted sessions (oldest
//!      admission first), recomputing evicted prefixes through its cache;
//!      spans a *peer* shard published in the hub are importable instead,
//!      billed `min(block transfer over the interconnect, recompute
//!      prefill)` ([`crate::engine::TransferDecision`]);
//!   2. **migrate** — a suspended session whose resume failed
//!      [`MIGRATION_PATIENCE`] times in a row (sustained pressure) is handed
//!      to the best peer shard that can cover its worst-case resume
//!      reservation (`resume_need_blocks_with`), instead of thrashing
//!      preempt/resume locally. Correct by construction: a suspended
//!      session holds no cache node indices, so `try_resume` simply
//!      recomputes the prefix through whichever cache it lands in — and
//!      per-shard minted-id bases keep the "ids are never reused" invariant
//!      fleet-wide, so a migrant can never falsely share cache with the
//!      target's unrelated problems. The rebuild is billed by the
//!      **migration cost model**: the source's still-warm spans (probed
//!      read-only) may arrive as an interconnect block copy instead of a
//!      recompute prefill, whichever the perf model prices cheaper, with
//!      the per-migration choice recorded in [`ShardStats`];
//!   3. **admit** — a deterministic global queue routes each job by
//!      **prompt affinity** first (the shard holding the request's longest
//!      hub-published prefix — sharing recovered by placement, no copying
//!      needed), falling back to the least-loaded shard by **predicted KV
//!      footprint** (Σ policy-estimated blocks of resident sessions, then
//!      total admissions, then shard index — all deterministic units, so
//!      routing is reproducible for a fixed seed regardless of thread
//!      timing), gated on each shard's free-block watermark and the global
//!      concurrency cap;
//!   4. **plan** — each busy shard builds its [`runtime::RoundPlan`] on its
//!      own worker (shard-parallel: planning carries the policy allocation,
//!      the expensive host-side part of a round): finished sessions retire,
//!      frontiers are pruned (KV release only), and the round's expand
//!      requests are laid out as plain data — no generator calls; the
//!      coordinator merges plans and outcomes in shard index order;
//!   5. **decode + commit** — every planned shard moves to its persistent
//!      worker, which runs the only generator-touching phase (two-phase
//!      `submit`/`poll` decode) followed by the reserve → commit KV
//!      application with LRU-evict-then-preempt pressure handling. Shards
//!      are shared-nothing, so rounds are embarrassingly parallel; the
//!      coordinator receives shards back in index order (the round
//!      barrier), so merging stays deterministic regardless of timing.
//!
//!   Each shard round is costed by the perf model's
//!   [`crate::engine::RoundCost`] decomposition — decode vs plan + commit.
//!   With [`ServeOptions::pipeline`] off the phases serialize (sum); with
//!   it on, shard *k+1*'s decode overlaps shard *k*'s commit on the modeled
//!   accelerator timeline and a round costs `max(decode, plan + commit)`.
//!   A global round costs its *slowest shard*
//!   ([`ServeReport::modeled_seconds`] sums the per-round maxima — shards
//!   model parallel serving replicas).
//!
//! All shapes are deterministic for a fixed seed, and — because sessions
//! advance their RNG streams only at decode submit and in commit steps
//! atomically — *scheduling cannot change search results*: worker count,
//! concurrency, shard count, preemption, cross-shard migration, and
//! pipelining on/off all leave every problem's answer and KV/token
//! accounting identical (`tests/serve_determinism.rs` pins this for
//! shards ∈ {1, 2, 4} × pipeline {on, off} under both ample and tight
//! capacity).

pub mod budget;
pub(crate) mod runtime;

use crate::engine::batch::{ImportSource, DEFAULT_KV_CAPACITY};
use crate::engine::perfmodel::PerfModel;
use crate::kvcache::prefixhub::PrefixHub;
use crate::kvcache::{RadixCache, DEFAULT_BLOCK_SIZE};
use crate::lm::StepGenerator;
use crate::obs::hist::ServeLatency;
use crate::obs::trace::{modeled_track, to_us, CoordTracer, ServeTrace, TraceBuf, TraceEvent};
use crate::reward::RewardModel;
use crate::search::driver::{SearchOutcome, SearchParams, SearchSession};
use crate::search::policy::SearchPolicy;
use crate::workload::ModelProfile;
use runtime::{ResumeBill, Shard, ShardSet, Slot, WorkerPool};
use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Mutex;

/// Consecutive failed resume attempts after which a suspended session is
/// considered *stuck* (sustained pressure) and the coordinator tries to
/// migrate it to a shard with free blocks instead of retrying locally.
pub const MIGRATION_PATIENCE: u32 = 2;

/// Parallel map over `items` with `workers` threads, preserving order.
///
/// Workers pull indices from a shared queue (work stealing by index), so
/// uneven per-item costs (hard problems search longer) balance out.
pub fn par_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    // index queue
    let queue: Mutex<std::vec::IntoIter<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>().into_iter());
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let queue = &queue;
            let f = &f;
            scope.spawn(move || loop {
                let item = queue.lock().unwrap().next();
                match item {
                    Some((i, t)) => {
                        let r = f(i, t);
                        if tx.send((i, r)).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker died before finishing")).collect()
    })
}

/// Shared throughput fold: `completed` problems over `seconds`, guarding
/// the zero/negative-denominator case (no batches executed, zero wall
/// clock). Both the modeled [`ServeReport`] and the wall-clock
/// [`CoordinatorStats`] throughputs fold through here.
pub fn throughput_problems_per_sec(completed: usize, seconds: f64) -> f64 {
    if seconds > 0.0 {
        completed as f64 / seconds
    } else {
        0.0
    }
}

/// A request to the serving coordinator.
#[derive(Clone, Debug)]
pub struct SearchRequest {
    pub request_id: u64,
    pub problem_id: u64,
}

/// One problem's ingredients for the batched serve loop.
pub struct ServeJob<G, R, P> {
    pub lm: G,
    pub prm: R,
    pub policy: P,
}

/// Scheduler configuration for [`serve`].
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Most problems admitted (running + suspended, across all shards) at a
    /// time.
    pub concurrency: usize,
    /// Hard global KV budget in tokens; each shard owns an equal partition
    /// (`capacity_tokens / shards`), rounded up to whole blocks.
    pub capacity_tokens: usize,
    /// Host-DRAM cold-tier budget in tokens (a *second* hard budget under
    /// the hot one), partitioned per shard like `capacity_tokens`. With it
    /// nonzero, eviction *demotes* unpinned spans into the shard's
    /// [`crate::kvcache::coldtier::SpillArena`] instead of destroying them,
    /// and resumes may restore demoted payload over the modeled PCIe lane
    /// when that is priced below recompute
    /// ([`crate::engine::PerfModel::tier_choice`]). True destruction
    /// happens only when both tiers are full. 0 (the default) keeps the
    /// evict-to-nothing ladder. Purely a costing/placement feature —
    /// results are byte-identical with the tier on or off (pinned by
    /// `tests/serve_determinism.rs`).
    pub cold_capacity_tokens: usize,
    /// Tokens per KV block (paged-allocator page size).
    pub block_size: usize,
    /// Shard-per-core engines: `shards` persistent workers, each owning a
    /// shared-nothing radix cache and stepped on its own long-lived OS
    /// thread. 1 (the default) is the single-engine scheduler.
    pub shards: usize,
    /// Pipeline the decode phase: model shard *k+1*'s decode overlapping
    /// shard *k*'s plan + commit on the accelerator timeline, so a round
    /// costs `max(decode, plan + commit)` instead of their sum. Purely a
    /// costing choice — results are byte-identical either way (pinned by
    /// `tests/serve_determinism.rs`).
    pub pipeline: bool,
    /// Cross-shard prefix sharing through the global prefix hub
    /// ([`crate::kvcache::prefixhub::PrefixHub`]): shards publish
    /// committed-prefix fingerprints at round barriers, admission gains
    /// prompt-affinity routing (a request lands on the shard holding its
    /// longest published prefix), and resumes may *import* published spans
    /// from peers with `min(transfer, recompute)` costing. A
    /// placement/costing feature only — per-problem results are
    /// byte-identical with it on or off (pinned by
    /// `tests/serve_determinism.rs`).
    pub prefix_share: bool,
    /// Pin each persistent worker thread to a CPU core (worker *i* → core
    /// `i % num_cores` via [`crate::util::affinity`]). The pinned thread is
    /// the only one that touches its shard's engine — including the
    /// [`crate::kvcache::BlockAllocator`] free-list arena — so first-touch
    /// page locality follows the pin. Placement only: results are
    /// byte-identical with pinning on or off (pinned by
    /// `tests/serve_determinism.rs`); a core the kernel refuses degrades to
    /// OS placement for that worker.
    pub pin_cores: bool,
    /// True-async data plane: each shard *speculatively plans* round `r+1`
    /// on its worker right after committing round `r` (overlapping peers'
    /// decodes and the coordinator barrier), with frontier-growth
    /// mispredicts repaired by planning only the appended tail. Pairs with
    /// wrapping generators in [`crate::lm::AsyncLm`] at the job-building
    /// layer so decode sleeps are actually served off-thread. Scheduling
    /// only: per-problem results are byte-identical with it on or off
    /// (pinned by `tests/serve_determinism.rs`).
    pub async_decode: bool,
    /// Compute-optimal adaptive budgeting ([`budget`]): a deterministic
    /// controller at the round barrier scores each session's difficulty
    /// from committed telemetry and reallocates width/KV mid-flight —
    /// easy and hopeless sessions shrink, contested ones get the reclaimed
    /// blocks — and admission's predicted-footprint routing switches to the
    /// online-calibrated `kv_retention` once real samples exist. Adaptive
    /// mode changes *what* is searched (its own mode, not
    /// results-invariant against `false`), but at a fixed seed its results
    /// are byte-identical across shards × pipeline × async-decode ×
    /// prefix-share × ample/tight capacity (pinned by
    /// `tests/serve_determinism.rs`).
    pub adaptive_budget: bool,
    /// Two-track serve tracing ([`crate::obs::trace`]): per-shard
    /// ring-buffer lifecycle/phase recording merged at round barriers, a
    /// modeled session track rebuilt from committed outcomes at teardown,
    /// and the trace payload on [`ServeReport::trace`]. Strictly read-only
    /// over scheduling state — results AND decision logs are byte-identical
    /// with it on or off (pinned by `tests/serve_determinism.rs`). Off by
    /// default (`serve --trace-out` turns it on).
    pub trace: bool,
    /// Per-request TTFT/TPOT/completion and per-phase round-duration
    /// histograms ([`crate::obs::hist`]) folded into
    /// [`ServeReport::latency`]. On by default (cheap: a few fixed-size
    /// counter arrays); the off switch exists so the determinism suite can
    /// prove observability on ≡ off in both directions.
    pub latency_hists: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            concurrency: 8,
            capacity_tokens: DEFAULT_KV_CAPACITY,
            cold_capacity_tokens: 0,
            block_size: DEFAULT_BLOCK_SIZE,
            shards: 1,
            pipeline: false,
            prefix_share: false,
            pin_cores: false,
            async_decode: false,
            adaptive_budget: false,
            trace: false,
            latency_hists: true,
        }
    }
}

impl ServeOptions {
    pub fn with_concurrency(concurrency: usize) -> Self {
        Self { concurrency, ..Default::default() }
    }

    pub fn with_shards(concurrency: usize, shards: usize) -> Self {
        Self { concurrency, shards, ..Default::default() }
    }

    pub fn pipelined(mut self, pipeline: bool) -> Self {
        self.pipeline = pipeline;
        self
    }

    pub fn prefix_shared(mut self, prefix_share: bool) -> Self {
        self.prefix_share = prefix_share;
        self
    }

    pub fn core_pinned(mut self, pin_cores: bool) -> Self {
        self.pin_cores = pin_cores;
        self
    }

    pub fn async_decoded(mut self, async_decode: bool) -> Self {
        self.async_decode = async_decode;
        self
    }

    pub fn cold_tiered(mut self, cold_capacity_tokens: usize) -> Self {
        self.cold_capacity_tokens = cold_capacity_tokens;
        self
    }

    pub fn adaptive_budgeted(mut self, adaptive_budget: bool) -> Self {
        self.adaptive_budget = adaptive_budget;
        self
    }

    pub fn traced(mut self, trace: bool) -> Self {
        self.trace = trace;
        self
    }

    pub fn latency_histograms(mut self, latency_hists: bool) -> Self {
        self.latency_hists = latency_hists;
        self
    }
}

/// Telemetry of one engine round on one shard: the merged expansion batch of
/// every active problem, plus its modeled cost.
#[derive(Clone, Debug, Default)]
pub struct BatchRecord {
    /// Shard that executed this round's batch.
    pub shard: usize,
    /// Problems that committed expansions this round.
    pub problems: usize,
    /// Leaves expanded (requests in the merged batch).
    pub requests: usize,
    /// Continuations sampled (lockstep decode batch size).
    pub model_calls: usize,
    /// Tokens generated this round.
    pub new_tokens: usize,
    /// Unique KV tokens resident in the shard's cache after the round —
    /// physical occupancy, including warm (unpinned) working sets of
    /// suspended sessions awaiting eviction. Drives wave fragmentation.
    pub resident_kv_tokens: usize,
    /// Unique KV tokens pinned by the sessions that committed this round —
    /// what the decode actually reads (suspended sessions' warm KV is not
    /// touched by any running sequence).
    pub pinned_kv_tokens: usize,
    /// What the same round would pin without radix sharing.
    pub unshared_kv_tokens: usize,
    /// Tokens re-prefilled by sessions resumed (or migrated in) this round.
    pub recompute_tokens: usize,
    /// Tokens whose KV arrived as cross-shard block transfers this round
    /// (the `min(transfer, recompute)` import decision chose the copy) —
    /// charged over the interconnect instead of as recompute prefill.
    pub transfer_kv_tokens: usize,
    /// Tokens whose KV came back from the host-DRAM cold tier this round
    /// (the `tier_choice` decision chose the PCIe restore) — charged over
    /// the host link instead of as recompute prefill.
    pub restored_kv_tokens: usize,
    /// Blocks allocated in this shard's cache after the round — per-shard
    /// occupancy telemetry. (The duplicate-prompt sweeps' headline number,
    /// [`ServeReport::mean_used_blocks`], is summed coordinator-side per
    /// global round instead, so it also sees shards idle that round.)
    pub used_blocks: usize,
    /// Sessions preempted during this round's commits.
    pub preemptions: usize,
    /// Modeled decode-phase seconds of this round (the generator-bound
    /// side of the pipeline boundary, incl. backend-injected overhead).
    pub decode_seconds: f64,
    /// Modeled plan + commit seconds (recompute prefill + paged KV commit
    /// writes).
    pub overhead_seconds: f64,
    /// Modeled wall-clock of this round: `decode + overhead` in lockstep
    /// mode, `max(decode, overhead)` when [`ServeOptions::pipeline`] is on
    /// ([`crate::engine::RoundCost`]).
    pub seconds: f64,
}

/// Per-shard aggregate telemetry of a [`serve`] run.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    pub shard: usize,
    /// Problems admitted to this shard by the global router (migrations in
    /// are counted separately).
    pub admitted: u64,
    /// Sessions preempted on this shard under memory pressure.
    pub preemptions: u64,
    /// Sessions resumed on this shard (local resumes + migrated-in resumes).
    pub resumes: u64,
    /// Tokens re-prefilled by resumes through this shard's cache.
    pub recompute_tokens: u64,
    /// Suspended sessions this shard received from pressured peers.
    pub migrations_in: u64,
    /// Suspended sessions this shard handed to peers with free blocks.
    pub migrations_out: u64,
    /// Admissions routed here by prompt-affinity (longest published prefix
    /// in the hub) rather than the least-loaded fallback.
    pub hub_hits: u64,
    /// KV tokens imported into this shard as cross-shard block transfers.
    pub imported_kv_tokens: u64,
    /// Resumes whose `min(transfer, recompute)` decision chose the block
    /// transfer (the importable span arrived over the interconnect).
    /// Migrated-in resumes are included — `migration_transfers` is the
    /// migration-only sub-count.
    pub import_transfers: u64,
    /// Resumes that had an importable span but recomputed anyway (the
    /// prefill was modeled cheaper than the link copy). Includes
    /// migrated-in resumes, like `import_transfers`.
    pub import_recomputes: u64,
    /// Migrated-in resumes whose cost-model choice picked the transfer…
    pub migration_transfers: u64,
    /// …or the recompute (an importable span existed but the prefill was
    /// modeled cheaper)…
    pub migration_recomputes: u64,
    /// …or that had *nothing importable* — the source had already evicted
    /// the migrant's spans, so no transfer-vs-recompute decision ran and
    /// the rebuild is plain recompute prefill.
    pub migration_cold: u64,
    /// High-water mark of this shard's cache (unique tokens).
    pub peak_resident_kv_tokens: usize,
    /// High-water mark of this shard's allocated blocks.
    pub peak_used_blocks: usize,
    /// This shard's partition of the global block budget.
    pub total_blocks: usize,
    /// Σ of this shard's modeled batch latencies (its busy time).
    pub busy_seconds: f64,
    /// Speculative round plans that were used as-is — the frontier did not
    /// grow between staging and the next plan dispatch.
    pub spec_plan_hits: u64,
    /// Speculative round plans whose frontier grew (resumes, migrations,
    /// admissions landed after staging): the staged entries were kept and
    /// only the appended tail was planned.
    pub spec_plan_misses: u64,
    /// Payload-arena bytes that actually arrived over the block-transport
    /// plane (cross-shard arena copies the import decision chose).
    pub transferred_kv_bytes: u64,
    /// Payload-arena bytes rebuilt locally on resume — the recompute side
    /// of the reconciliation: `transferred + restored + recomputed` covers
    /// every byte a resume rematerialized.
    pub recomputed_kv_bytes: u64,
    /// Tokens eviction demoted into this shard's host-DRAM cold tier over
    /// the run (monotone arena counter, snapshotted before the teardown
    /// flush so the final drain does not count). 0 with the tier off.
    pub demoted_kv_tokens: u64,
    /// KV tokens billed as cold-tier PCIe restores (the `tier_choice`
    /// decision chose the copy over recompute).
    pub restored_kv_tokens: u64,
    /// Payload-arena bytes that actually arrived from the cold tier — the
    /// executed-restore reconciliation next to the modeled
    /// `restored_kv_tokens`.
    pub restored_kv_bytes: u64,
    /// Resumes whose cold-tier decision chose the restore…
    pub cold_restores: u64,
    /// …vs recomputed anyway (a demoted span existed but the prefill was
    /// modeled cheaper, e.g. under a congested PCIe lane).
    pub cold_recomputes: u64,
    /// Tokens truly destroyed at the cold tier: demoted spans dropped
    /// because the second budget overflowed (or a span outsized it).
    pub cold_dropped_kv_tokens: u64,
    /// High-water mark of the cold arena's occupancy, in blocks.
    pub peak_cold_used_blocks: u64,
    /// Worker that first-touch faulted this shard's payload arena from its
    /// pinned core (`None`: pinning off or inline single-shard scheduler).
    pub arena_touch_worker: Option<usize>,
    /// Arena bytes faulted in by that first touch.
    pub arena_touch_bytes: u64,
    /// Adaptive-budget controller decisions that shrank a session resident
    /// here (easy or hopeless difficulty). 0 with the controller off.
    pub width_shrinks: u64,
    /// …and that granted extra width to a contested session resident here.
    pub width_grants: u64,
    /// Predicted KV blocks those shrinks reclaimed from this shard's
    /// sessions. Reconciles exactly against the controller's decision log
    /// grouped by shard (pinned by `tests/serve_determinism.rs`).
    pub reclaimed_kv_blocks: u64,
    /// Predicted KV blocks granted to this shard's contested sessions.
    pub granted_kv_blocks: u64,
    /// Online `kv_retention` calibration samples taken on this shard:
    /// Σ retained step-span leaves and Σ live width at the controller
    /// barrier. Their ratio is the shard's observed retention.
    pub retention_retained_leaves: u64,
    pub retention_width_samples: u64,
}

/// Result of a [`serve`] run.
pub struct ServeReport {
    /// Per-problem outcomes, in job order.
    pub outcomes: Vec<SearchOutcome>,
    /// One record per shard per executed round, in (round, shard) order.
    pub batches: Vec<BatchRecord>,
    /// Modeled serving time: Σ over rounds of the *slowest shard's* batch
    /// latency (shards model parallel replicas). For `shards == 1` this is
    /// exactly Σ batch seconds — the serving-time denominator for
    /// throughput.
    pub modeled_seconds: f64,
    /// High-water mark across rounds of the summed shard caches (unique
    /// tokens).
    pub peak_resident_kv_tokens: usize,
    /// Most problems ever simultaneously admitted (running + suspended,
    /// all shards).
    pub max_concurrent: usize,
    /// Most problems that actually advanced (committed a step) in a single
    /// round across all shards — the *resident* concurrency, excluding
    /// swapped-out suspended sessions. This is the number oversubscription
    /// throttles.
    pub peak_step_concurrency: usize,
    /// Sessions preempted under memory pressure (suspend events).
    pub preemptions: u64,
    /// Sessions resumed after preemption (including migrated resumes).
    pub resumes: u64,
    /// Tokens re-prefilled by resumes (the recompute bill of preemption and
    /// migration).
    pub recompute_tokens: u64,
    /// Rounds where admission was blocked by every shard's free-block
    /// watermark.
    pub admission_blocked_rounds: u64,
    /// Step commits deferred to a later round because nothing could be
    /// evicted or preempted to make room.
    pub deferred_commits: u64,
    /// Σ per-shard high-water marks of allocated blocks (≤ `total_blocks`
    /// by construction — each shard's budget is hard).
    pub peak_used_blocks: usize,
    /// The hard global block budget (Σ shard partitions).
    pub total_blocks: usize,
    /// Shard count the run was scheduled with.
    pub shards: usize,
    /// Whether rounds were costed pipelined (`max(decode, plan + commit)`)
    /// rather than lockstep (sum).
    pub pipeline: bool,
    /// Suspended sessions moved across shards under sustained pressure.
    pub migrations: u64,
    /// Whether the global prefix hub was on ([`ServeOptions::prefix_share`]).
    pub prefix_share: bool,
    /// Admissions routed by prompt-affinity (Σ over shards).
    pub hub_hits: u64,
    /// Committed-prefix fingerprints published across all round barriers.
    pub hub_published: u64,
    /// Hub-consistency audit, accumulated over barriers: entries of the
    /// previous snapshot still fully resident on their owner…
    pub hub_live_entries: u64,
    /// …entries the owner evicted mid-round (accounted, never lost).
    /// `hub_published == hub_live_entries + hub_evicted_entries +
    /// hub_demoted_entries` whenever a final audit ran for every snapshot.
    pub hub_evicted_entries: u64,
    /// …and entries evicted from the hot tier but still reconstructible
    /// from the owner's host-DRAM cold tier (hot prefix + demoted spans
    /// cover the whole fingerprinted span). Always 0 with the tier off.
    pub hub_demoted_entries: u64,
    /// KV tokens imported as cross-shard block transfers (Σ over shards).
    pub imported_kv_tokens: u64,
    /// Import decisions that chose the transfer vs the recompute prefill.
    pub import_transfers: u64,
    pub import_recomputes: u64,
    /// Migrated-in resumes billed as transfer vs recompute (the migration
    /// cost model's per-migration choice, Σ over shards), plus the ones
    /// with nothing importable (source already evicted — no choice ran).
    pub migration_transfers: u64,
    pub migration_recomputes: u64,
    pub migration_cold: u64,
    /// Whether the true-async data plane was on
    /// ([`ServeOptions::async_decode`]).
    pub async_decode: bool,
    /// Speculative round plans used as-is vs repaired (Σ over shards); both
    /// zero when `async_decode` is off.
    pub spec_plan_hits: u64,
    pub spec_plan_misses: u64,
    /// Payload-arena bytes moved by the block-transport plane vs rebuilt
    /// locally on resume (Σ over shards) — the executed-transfer
    /// reconciliation next to the modeled `imported_kv_tokens`.
    pub transferred_kv_bytes: u64,
    pub recomputed_kv_bytes: u64,
    /// Cold-tier (host-DRAM spill) telemetry, Σ over shards: tokens
    /// demoted by eviction, tokens billed as PCIe restores, the bytes
    /// those restores actually copied, the per-resume decision counts, and
    /// tokens truly destroyed when the second budget overflowed. All 0
    /// with [`ServeOptions::cold_capacity_tokens`] = 0.
    pub demoted_kv_tokens: u64,
    pub restored_kv_tokens: u64,
    pub restored_kv_bytes: u64,
    pub cold_restores: u64,
    pub cold_recomputes: u64,
    pub cold_dropped_kv_tokens: u64,
    /// Cold-tier budget the run was scheduled with (global tokens).
    pub cold_capacity_tokens: usize,
    /// Whether the adaptive budget controller was on
    /// ([`ServeOptions::adaptive_budget`]).
    pub adaptive_budget: bool,
    /// Controller decisions that shrank / grew a session's width (Σ over
    /// shards); all four zero with the controller off.
    pub width_shrinks: u64,
    pub width_grants: u64,
    /// Predicted KV blocks the shrinks reclaimed and the grants handed out
    /// (Σ over shards).
    pub reclaimed_kv_blocks: u64,
    pub granted_kv_blocks: u64,
    /// The controller's full evaluation log, in issue order: per-session
    /// width trajectories (base → target), difficulty scores, and the
    /// blocks each reallocation moved. Sorted by
    /// [`budget::BudgetDecision::identity`] it is byte-identical across
    /// serve configurations at a fixed seed.
    pub budget_decisions: Vec<budget::BudgetDecision>,
    /// Online `kv_retention` calibration totals (Σ over shards): retained
    /// step-span leaves and live width observed at controller barriers.
    pub retention_retained_leaves: u64,
    pub retention_width_samples: u64,
    /// Global scheduler rounds executed.
    pub rounds: u64,
    /// Σ over rounds of the fleet-wide allocated blocks after the round —
    /// `mean_used_blocks` is the duplicate-prompt sweeps' headline number.
    pub sum_round_used_blocks: u64,
    /// Per-shard telemetry, indexed by shard.
    pub shard_stats: Vec<ShardStats>,
    /// Core each persistent worker was pinned to, indexed by shard. `None`
    /// per worker when pinning was off, refused by the kernel, or the run
    /// used the inline single-shard scheduler (no worker threads).
    pub worker_cores: Vec<Option<usize>>,
    /// Per-request TTFT/TPOT/completion and per-phase round-duration
    /// histograms ([`ServeOptions::latency_hists`]; empty when off).
    pub latency: crate::obs::hist::ServeLatency,
    /// The two-track trace ([`ServeOptions::trace`]; `None` when off).
    pub trace: Option<crate::obs::trace::ServeTrace>,
}

/// Schema version of the serve JSON dump (`serve --json` /
/// `--metrics-out`), so bench-diff tooling can detect shape changes.
/// History: 1 — everything before the observability PR (implicit,
/// unversioned); 2 — adds `report_version` itself plus the
/// p50/p90/p99 TTFT/TPOT/completion latency fields.
pub const REPORT_VERSION: u64 = 2;

impl ServeReport {
    pub fn throughput_problems_per_sec(&self) -> f64 {
        throughput_problems_per_sec(self.outcomes.len(), self.modeled_seconds)
    }

    pub fn batch_seconds(&self) -> Vec<f64> {
        self.batches.iter().map(|b| b.seconds).collect()
    }

    /// Total memory-pressure interventions: preemptions, watermark-blocked
    /// admissions, and deferred commits. 0 means the budget never bound.
    pub fn kv_pressure_events(&self) -> u64 {
        self.preemptions + self.admission_blocked_rounds + self.deferred_commits
    }

    /// Fraction of admissions the prompt-affinity router placed via the
    /// hub (0 with the hub off or a duplicate-free workload).
    pub fn hub_hit_rate(&self) -> f64 {
        if self.outcomes.is_empty() {
            0.0
        } else {
            self.hub_hits as f64 / self.outcomes.len() as f64
        }
    }

    /// Mean fleet-wide allocated KV blocks per round — strictly lower with
    /// `--prefix-share` on a duplicate-heavy workload (affinity colocates
    /// identical prompts, so the radix caches deduplicate them).
    pub fn mean_used_blocks(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.sum_round_used_blocks as f64 / self.rounds as f64
        }
    }

    /// Modeled block-seconds of the run: Σ over executed shard rounds of
    /// allocated blocks × modeled round seconds
    /// ([`crate::engine::perfmodel::block_seconds`]). The denominator of
    /// the adaptive budget controller's accuracy-per-block-second objective
    /// — shrinking an easy session's width lowers this without touching its
    /// answer, which is exactly the trade the adaptive bench pins.
    pub fn modeled_block_seconds(&self) -> f64 {
        self.batches
            .iter()
            .map(|b| crate::engine::perfmodel::block_seconds(b.used_blocks, b.seconds))
            .sum()
    }
}

/// Serve `jobs` through `opts.shards` shared-nothing engines with
/// continuous batching under a hard, partitioned KV block budget: at most
/// `opts.concurrency` searches are admitted at a time across all shards, a
/// deterministic router assigns each to the least-loaded shard, each global
/// round advances every shard's resident sessions by one step — each busy
/// shard *plans* its round on its persistent worker (no generator calls),
/// then runs the decode and commit phases there (one merged batch per
/// shard), with the coordinator merging at both phase boundaries — and
/// finished searches hand their slot to the next queued job mid-flight.
///
/// Memory pressure is handled in escalating order per shard: (1) admission
/// is gated on a free-block watermark, (2) a failed step reservation
/// LRU-evicts unpinned branches, (3) still failing, the lowest-priority
/// resident session is preempted — its blocks released, its tree kept — and
/// resumed later by recomputing the evicted prefix. Under *sustained*
/// pressure ([`MIGRATION_PATIENCE`]), a stuck suspended session migrates to
/// the shard with the most reclaimable headroom instead of thrashing
/// preempt/resume locally. Because a session's RNG advances only at decode
/// submit and in commit (both atomic w.r.t. preemption and migration),
/// neither the schedule, the shard count, pipelining, nor any migration can
/// change search results.
///
/// Panics when even a single session cannot advance alone at the per-shard
/// budget — the partitioned capacity is below one problem's working set.
pub fn serve<G, R, P>(
    jobs: Vec<ServeJob<G, R, P>>,
    params: &SearchParams,
    opts: &ServeOptions,
    perf: &PerfModel,
    model: &ModelProfile,
) -> ServeReport
where
    G: StepGenerator + Send,
    R: RewardModel + Send,
    P: SearchPolicy + Send,
{
    let concurrency = opts.concurrency.max(1);
    let n_shards = opts.shards.max(1);
    let per_shard_capacity = (opts.capacity_tokens / n_shards).max(opts.block_size);
    // the cold tier's second budget partitions the same way; 0 keeps the
    // evict-to-nothing ladder on every shard
    let per_shard_cold = if opts.cold_capacity_tokens == 0 {
        0
    } else {
        (opts.cold_capacity_tokens / n_shards).max(opts.block_size)
    };
    let n = jobs.len();
    std::thread::scope(|scope| {
        let mut set: ShardSet<G, R, P> = ShardSet::new(
            (0..n_shards)
                .map(|index| {
                    Shard::new(
                        index,
                        n_shards,
                        per_shard_capacity,
                        opts.block_size,
                        opts.prefix_share,
                        per_shard_cold,
                    )
                })
                .collect(),
        );
        if opts.async_decode {
            for shard in set.iter_mut() {
                shard.speculate = true;
            }
        }
        // N persistent workers, spawned once for the whole serve call and
        // driven by RoundPlan messages (a single shard runs its rounds
        // inline — there is nothing to overlap with).
        let pool: Option<WorkerPool<G, R, P>> = if n_shards > 1 {
            Some(WorkerPool::spawn(
                scope,
                n_shards,
                perf,
                model,
                opts.pipeline,
                opts.pin_cores,
            ))
        } else {
            None
        };
        let worker_cores: Vec<Option<usize>> = match pool.as_ref() {
            Some(pool) => pool.worker_cores().to_vec(),
            None => vec![None; n_shards],
        };
        let mut queue: VecDeque<(usize, ServeJob<G, R, P>)> =
            jobs.into_iter().enumerate().collect();
        let mut outcomes: Vec<Option<SearchOutcome>> = (0..n).map(|_| None).collect();
        let mut batches: Vec<BatchRecord> = Vec::new();
        let mut peak = 0usize;
        let mut max_concurrent = 0usize;
        let mut peak_step_concurrency = 0usize;
        let mut modeled_seconds = 0.0f64;
        let mut admit_seq = 0u64;
        let mut migrations = 0u64;
        let mut admission_blocked_rounds = 0u64;
        let mut deferred_commits = 0u64;
        let mut hub_hits = 0u64;
        let mut hub_published = 0u64;
        let mut hub_live_entries = 0u64;
        let mut hub_evicted_entries = 0u64;
        let mut hub_demoted_entries = 0u64;
        let mut rounds = 0u64;
        let mut sum_round_used_blocks = 0u64;
        // The global prefix hub: rebuilt once per round at the barrier
        // below, read-only everywhere else.
        let mut hub: Option<PrefixHub> =
            opts.prefix_share.then(|| PrefixHub::new(opts.block_size));
        // The adaptive budget controller (one per serve) and the online
        // kv_retention calibration it feeds: both live at the round
        // barrier and read only committed telemetry.
        let mut budgeter: Option<budget::BudgetController> =
            opts.adaptive_budget.then(budget::BudgetController::default);
        let mut calibration = budget::RetentionCalibration::default();
        // Livelock guard: rounds that neither commit, finish, nor admit make
        // no real progress (a resume or migration alone does not count —
        // resume → preempt can thrash); several in a row means the per-shard
        // budget is below one working set.
        let mut stalled_rounds = 0u32;
        // Observability plane ([`crate::obs`]) — strictly read-only over
        // scheduling state. The tracer collects coordinator-side lifecycle
        // events and drains each shard's preallocated ring at the round
        // barrier (shard-index order → deterministic merged stream); the
        // latency table stamps per-request admission/commit times on the
        // global modeled clock for the TTFT/TPOT/completion histograms.
        let trace_t0 = std::time::Instant::now();
        let mut tracer: Option<CoordTracer> = opts.trace.then(|| CoordTracer::new(n_shards, trace_t0));
        if opts.trace {
            for shard in set.iter_mut() {
                shard.trace = Some(TraceBuf::new(TraceBuf::DEFAULT_CAPACITY, trace_t0));
            }
        }
        let mut last_demoted: Vec<u64> = vec![0; n_shards];
        let mut timings: Vec<ReqTiming> = vec![ReqTiming::default(); n];
        let mut lat = ServeLatency::default();

        loop {
            let mut progressed = false;
            let mut round_bills = vec![ResumeBill::default(); n_shards];
            // both exec-track timestamps of this round land at its start on
            // the global modeled clock (modeled time only advances at the
            // barrier below)
            let round_start_us = to_us(modeled_seconds);
            let mut phase_wall = tracer.as_ref().map(|t| t.wall_us());

            // 0. prefix-hub barrier: this is the deterministic merge point
            //    between rounds — first audit the previous snapshot (every
            //    fingerprint must still resolve on its owner or be counted
            //    as evicted mid-round), then rebuild it from each shard's
            //    committed prompt prefixes, in shard/slot order. Sizing
            //    uses the read-only peek_prefix walk, so publication never
            //    perturbs any cache's LRU order; everything later in the
            //    round reads this one fixed, versioned snapshot.
            if let Some(hub) = hub.as_mut() {
                let audit = hub.audit(
                    |s, span| set.get(s).engine.cache().peek_prefix(span),
                    |s, span, hot| set.get(s).engine.cache().cold_probe(span, hot) <= hot,
                );
                hub_live_entries += audit.live;
                hub_evicted_entries += audit.evicted;
                hub_demoted_entries += audit.demoted;
                hub.begin_round();
                for shard in set.iter_mut() {
                    for slot in shard.running.iter().chain(shard.suspended.iter()) {
                        // engine-minted ids are globally unique — a peer can
                        // never hold them, so publishing them is dead weight
                        if slot.session.ledger().exact_accounting() {
                            continue;
                        }
                        let ids = slot.session.prompt_ids();
                        let cached = shard.engine.cache().peek_prefix(ids);
                        hub.publish(shard.index, ids, cached);
                        // mid-tree step spans: fingerprint every committed
                        // step extent (the leaf sequences), not just the
                        // prompt — a hub import or cold-tier restore can
                        // then satisfy *partial trajectories* of preempted
                        // or duplicate work, where prompt-only entries stop
                        // at the first step boundary
                        for seq in slot.session.step_span_sequences() {
                            if seq.len() > ids.len() {
                                let cached = shard.engine.cache().peek_prefix(&seq);
                                hub.publish(shard.index, &seq, cached);
                            }
                        }
                    }
                    // retired-but-warm prompts (lazy close): advertise what
                    // the cache still holds; prune spans LRU pressure has
                    // fully reclaimed since the last barrier
                    let retired = std::mem::take(&mut shard.retired_prompts);
                    for ids in retired {
                        let cached = shard.engine.cache().peek_prefix(&ids);
                        if cached >= hub.block_size() {
                            hub.publish(shard.index, &ids, cached);
                            shard.retired_prompts.push(ids);
                        }
                    }
                }
                hub_published += hub.published();
            }
            phase_mark(&mut tracer, &mut phase_wall, "hub_rebuild");

            // 1. per-shard resume pass, serial in shard index order (cheap:
            //    cache bookkeeping only, no generator calls); with the hub
            //    on, spans published by peers are importable — each resume
            //    is billed min(block transfer, recompute prefill), and a
            //    chosen transfer *executes*: the owning peer's payload
            //    blocks are copied into this shard's arena. Same-round
            //    transfers queue on the shared link (deterministic shard
            //    order), so a congested interconnect prices later imports
            //    back toward recompute.
            let mut link_queued_bytes = 0.0f64;
            for i in 0..n_shards {
                let mut shard = set.take(i);
                // fresh PCIe lane per shard per round: cold-tier spills and
                // restores of *this* round's resume/migration passes queue
                // on it (commit-phase spills are write-behind DMA and are
                // not billed — they drain during the next round's decode)
                shard.cold_lane_bytes = 0.0;
                let peers: Vec<Option<&RadixCache>> =
                    (0..n_shards).map(|j| set.peek(j).map(|s| s.engine.cache())).collect();
                round_bills[i] = shard.resume_pass(
                    hub.as_ref(),
                    &peers,
                    perf,
                    model,
                    &mut link_queued_bytes,
                );
                set.put(i, shard);
            }
            phase_mark(&mut tracer, &mut phase_wall, "resume_pass");

            // 2. cross-shard migration: a session whose resume failed
            //    MIGRATION_PATIENCE times in a row (sustained pressure) is
            //    handed to the best peer that can actually cover its
            //    worst-case resume reservation — peers ranked by (no
            //    suspended backlog of their own, reclaimable headroom,
            //    index), every viable one considered. The move is a plain
            //    ownership transfer — a suspended ledger holds no cache node
            //    indices — and the resume recomputes the prefix through the
            //    target cache, charged to the target's round recompute.
            if n_shards > 1 {
                for src in 0..n_shards {
                    let stuck = set
                        .get(src)
                        .suspended
                        .first()
                        .map_or(false, |s| s.stalled >= MIGRATION_PATIENCE);
                    if !stuck {
                        continue;
                    }
                    let mut candidates: Vec<usize> =
                        (0..n_shards).filter(|&d| d != src).collect();
                    candidates.sort_by_key(|&d| {
                        let sig = set.get(d).engine.pressure();
                        (
                            !set.get(d).suspended.is_empty(), // unloaded peers first
                            std::cmp::Reverse(sig.free_blocks + sig.evictable_blocks),
                            d,
                        )
                    });
                    // the migrant's working-set sequences are engine-
                    // independent: build them once, size every candidate
                    // against them
                    let seqs = set.get(src).suspended[0].session.suspended_sequences();
                    let dst = candidates.into_iter().find(|&d| {
                        let migrant = &set.get(src).suspended[0].session;
                        let need = migrant.resume_need_blocks_with(&set.get(d).engine, &seqs);
                        let sig = set.get(d).engine.pressure();
                        sig.free_blocks + sig.evictable_blocks >= need
                    });
                    let Some(dst) = dst else {
                        continue; // genuinely no shard can host it — retry locally
                    };
                    let mut slot = set.get_mut(src).suspended.remove(0);
                    let migrant_id = slot.id;
                    slot.stalled = 0; // fresh patience on the new shard
                    set.get_mut(src).stats.migrations_out += 1;
                    // The migration cost model: the source shard's cache is
                    // probed read-only for the migrant's still-warm spans —
                    // whatever the target must rebuild and the source still
                    // holds is billed min(NVLink block copy, recompute
                    // prefill), and the per-migration choice lands in the
                    // target's ShardStats.
                    let (dst_shard, src_shard) = set.pair_mut(dst, src);
                    dst_shard.stats.migrations_in += 1;
                    let import =
                        Some(ImportSource::Peer { cache: src_shard.engine.cache() });
                    match dst_shard.try_resume_slot(
                        &mut slot,
                        import,
                        perf,
                        model,
                        &mut link_queued_bytes,
                    ) {
                        Some(bill) => {
                            if bill.transfer_tokens > 0 {
                                dst_shard.stats.migration_transfers += 1;
                            } else if bill.import_decided {
                                dst_shard.stats.migration_recomputes += 1;
                            } else {
                                // the source had nothing warm left to ship
                                dst_shard.stats.migration_cold += 1;
                            }
                            round_bills[dst].add(bill);
                            dst_shard.running.push(slot);
                        }
                        None => dst_shard.suspended.push(slot),
                    }
                    migrations += 1;
                    if let Some(t) = tracer.as_mut() {
                        t.push(
                            TraceEvent::instant("migrated", 1 + dst, 2, round_start_us)
                                .arg("job", migrant_id as f64)
                                .arg("src", src as f64)
                                .arg("dst", dst as f64),
                        );
                    }
                }
            }
            phase_mark(&mut tracer, &mut phase_wall, "migration");

            // 3. deterministic global admission. Prompt-affinity first: a
            //    request whose prompt has a published prefix in the hub is
            //    routed to the shard holding the longest one — sharing is
            //    recovered by *placement*, before any copying is needed.
            //    Fallback: the least-loaded shard by *predicted KV
            //    footprint* — Σ policy-estimated blocks of the resident
            //    sessions (then admissions so far, then shard index; all
            //    deterministic units) — skipping shards whose free-block
            //    watermark leaves no headroom. Balancing footprints instead
            //    of session counts packs shards to what their sessions will
            //    actually hold, cutting downstream migrations. Continuous
            //    batching: finished slots refill mid-flight.
            loop {
                let resident_total: usize = set.iter().map(|s| s.resident()).sum();
                if resident_total >= concurrency {
                    break;
                }
                let (prompt, prompt_ids) = match queue.front() {
                    Some((_, job)) => (job.lm.prompt_tokens(), job.lm.prompt_token_ids()),
                    None => break,
                };
                let mut target: Option<usize> = None;
                let mut via_hub = false;
                if let (Some(hub), Some(ids)) = (hub.as_ref(), prompt_ids.as_ref()) {
                    if let Some(m) = hub.lookup(ids) {
                        if set.get(m.shard).engine.can_admit(prompt) {
                            target = Some(m.shard);
                            via_hub = true;
                        }
                    }
                }
                if target.is_none() {
                    let mut order: Vec<usize> = (0..n_shards).collect();
                    order.sort_by_key(|&s| {
                        (set.get(s).predicted_load(), set.get(s).stats.admitted, s)
                    });
                    for &s in &order {
                        if set.get(s).engine.can_admit(prompt) {
                            target = Some(s);
                            break;
                        }
                        // Second chance for an *empty* shard sitting on
                        // reclaimable memory: warm KV orphaned by sessions
                        // that migrated away serves nobody once nothing is
                        // resident, but still counts against the free-block
                        // watermark — flush it so the shard's partition of
                        // the budget cannot stay blocked for the rest of
                        // the run. (A shard with resident sessions keeps
                        // its warm KV: its own commit/resume pressure paths
                        // reclaim lazily, and on a single shard
                        // resident == 0 implies an empty cache, so behavior
                        // there is unchanged.)
                        if set.get(s).resident() == 0
                            && set.get(s).engine.pressure().evictable_blocks > 0
                        {
                            set.get_mut(s).engine.relieve_pressure(usize::MAX);
                            if set.get(s).engine.can_admit(prompt) {
                                target = Some(s);
                                break;
                            }
                        }
                        // Third chance for a *busy* shard whose evictable
                        // surplus is retired-but-warm KV from lazily closed
                        // real-id sessions (no suspended session of its own
                        // — running sessions keep their working sets
                        // pinned, so the surplus belongs to nobody who can
                        // resume here): trim exactly the admission deficit,
                        // LRU-first. The warm cache exists to help future
                        // requests, never to starve admission — without
                        // this, lazy close would wedge a tight-budget
                        // real-id shard for the rest of the run. Gated on
                        // lazy_closed so minted-id scheduling is untouched.
                        if set.get(s).resident() > 0
                            && set.get(s).lazy_closed > 0
                            && set.get(s).suspended.is_empty()
                        {
                            let sig = set.get(s).engine.pressure();
                            let need = set.get(s).engine.blocks_for_step(prompt)
                                + sig.low_watermark_blocks;
                            let deficit = need.saturating_sub(sig.free_blocks);
                            if deficit > 0 && deficit <= sig.evictable_blocks {
                                set.get_mut(s).engine.relieve_pressure(deficit);
                                if set.get(s).engine.can_admit(prompt) {
                                    target = Some(s);
                                    break;
                                }
                            }
                        }
                    }
                }
                let Some(target) = target else {
                    admission_blocked_rounds += 1;
                    break;
                };
                let (id, job) = queue.pop_front().expect("front checked above");
                // predicted footprint: prompt blocks + the policy's
                // retained-frontier estimate (one block per retained
                // trajectory) — a routing unit, never a reservation. The
                // policy's static kv_retention heuristic seeds the
                // estimate; in adaptive mode the fleet's own observed
                // retained/width ratio replaces it once samples exist.
                let static_retention = job.policy.kv_retention(params.width);
                let retention = if opts.adaptive_budget {
                    calibration.retention_or(job.policy.name(), static_retention)
                } else {
                    static_retention
                };
                let predicted_blocks = budget::predicted_footprint_blocks(
                    set.get(target).engine.blocks_for(prompt),
                    params.width,
                    retention,
                );
                let session = SearchSession::new(
                    &mut set.get_mut(target).engine,
                    job.lm,
                    job.prm,
                    job.policy,
                    params,
                );
                set.get_mut(target).running.push(Slot {
                    id,
                    seq: admit_seq,
                    stalled: 0,
                    predicted_blocks,
                    session,
                });
                set.get_mut(target).stats.admitted += 1;
                if via_hub {
                    set.get_mut(target).stats.hub_hits += 1;
                    hub_hits += 1;
                }
                admit_seq += 1;
                progressed = true;
                timings[id].admit_t = modeled_seconds;
                if let Some(t) = tracer.as_mut() {
                    t.push(
                        TraceEvent::instant("admitted", 1 + target, 2, round_start_us)
                            .arg("job", id as f64)
                            .arg("via_hub", if via_hub { 1.0 } else { 0.0 }),
                    );
                }
            }
            phase_mark(&mut tracer, &mut phase_wall, "admission");
            let total_resident: usize = set.iter().map(|s| s.resident()).sum();
            // A staged speculative plan can hold finished-session outcomes
            // not yet delivered — one more plan round drains it.
            let has_staged = set.iter().any(|s| s.staged.is_some());
            if total_resident == 0 && queue.is_empty() && !has_staged {
                break;
            }
            max_concurrent = max_concurrent.max(total_resident);

            // 3.5 adaptive budget controller barrier: with every shard
            //     resident (admission done, nothing planned yet), classify
            //     each session from its committed difficulty telemetry and
            //     reallocate width mid-flight. Decisions are pure per-
            //     session functions at fixed step indices and overrides
            //     apply in session-step coordinates, so neither shard
            //     layout, pipelining, async decode, nor capacity pressure
            //     can change what gets decided — only *where* the freed
            //     blocks happen to live. The same sweep feeds the online
            //     kv_retention calibration that admission routing reads.
            if let Some(ctl) = budgeter.as_mut() {
                for i in 0..n_shards {
                    let Shard { running, suspended, stats, .. } = set.get_mut(i);
                    for slot in running.iter_mut().chain(suspended.iter_mut()) {
                        let Some(sig) = slot.session.difficulty_signals() else {
                            continue;
                        };
                        // calibration sample: what this session actually
                        // retains against its live width, right now
                        let retained = slot.session.ledger().retained_leaves();
                        let live_width = slot.session.width();
                        calibration.observe(slot.session.policy.name(), retained, live_width);
                        stats.retention_retained_leaves += retained as u64;
                        stats.retention_width_samples += live_width as u64;
                        let base = slot.session.base_width();
                        let Some((from_step, target)) = ctl.classify(
                            slot.id as u64,
                            i,
                            base,
                            slot.session.max_steps(),
                            &sig,
                        ) else {
                            continue;
                        };
                        let (blocks, is_shrink) = budget::reallocation_blocks(
                            base,
                            slot.session.policy.kv_retention(base),
                            target,
                            slot.session.policy.kv_retention(target),
                        );
                        ctl.bill_last(blocks);
                        slot.session.set_width_override(from_step, target);
                        // keep the router's load estimate honest about the
                        // session's new predicted working set
                        if is_shrink {
                            slot.predicted_blocks =
                                slot.predicted_blocks.saturating_sub(blocks);
                            stats.width_shrinks += 1;
                            stats.reclaimed_kv_blocks += blocks as u64;
                        } else {
                            slot.predicted_blocks += blocks;
                            stats.width_grants += 1;
                            stats.granted_kv_blocks += blocks as u64;
                        }
                        if let Some(t) = tracer.as_mut() {
                            let name = if is_shrink { "width_shrink" } else { "width_grant" };
                            t.push(
                                TraceEvent::instant(name, 1 + i, 2, round_start_us)
                                    .arg("job", slot.id as f64)
                                    .arg("target_width", target as f64)
                                    .arg("blocks", blocks as f64),
                            );
                        }
                    }
                }
            }
            phase_mark(&mut tracer, &mut phase_wall, "budget_checkpoint");

            // 4. plan every busy shard's round on its worker (frontier
            //    pruning + policy allocation + expand-request build — no
            //    generator calls, no KV charge), shard-parallel; the
            //    coordinator merges the plans and finished outcomes
            let planned = runtime::plan_rounds(&mut set, pool.as_ref(), &round_bills);
            let mut plans: Vec<Option<runtime::RoundPlan>> = Vec::with_capacity(n_shards);
            for (shard_idx, p) in planned.into_iter().enumerate() {
                let Some(p) = p else {
                    plans.push(None);
                    continue;
                };
                for (id, outcome) in p.finished {
                    // close the request's lifecycle: latency folds on the
                    // global modeled clock (admission → first/last commit,
                    // stamped at the barriers below), trace instant on the
                    // finishing shard's timeline
                    if opts.latency_hists {
                        let t = timings[id];
                        if t.steps_seen > 0 {
                            let after_first = outcome
                                .total_new_tokens()
                                .saturating_sub(outcome.steps.first().map_or(0, |s| s.new_tokens as u64));
                            lat.ttft.record_seconds(t.first_t - t.admit_t);
                            lat.completion.record_seconds(t.last_t - t.admit_t);
                            lat.tpot.record_seconds(
                                (t.last_t - t.first_t) / after_first.max(1) as f64,
                            );
                        }
                    }
                    if let Some(tr) = tracer.as_mut() {
                        tr.push(
                            TraceEvent::instant("finished", 1 + shard_idx, 2, round_start_us)
                                .arg("job", id as f64)
                                .arg("steps", outcome.steps.len() as f64)
                                .arg("answered", if outcome.answer.is_some() { 1.0 } else { 0.0 }),
                        );
                    }
                    outcomes[id] = Some(outcome);
                }
                progressed |= p.progressed;
                plans.push(Some(p.plan));
            }
            phase_mark(&mut tracer, &mut phase_wall, "plan");

            // 5. decode + commit on the persistent workers (inline for a
            //    single shard); results come back in pre-sized per-shard
            //    slots, in index order — the round barrier
            let results =
                runtime::execute_round(&mut set, pool.as_ref(), plans, perf, model, opts.pipeline);
            phase_mark(&mut tracer, &mut phase_wall, "decode_commit");

            // 6. merge the round: telemetry and the round's modeled cost —
            //    its slowest shard (shards are parallel replicas)
            let mut round_seconds = 0.0f64;
            let mut round_step_problems = 0usize;
            let mut round_had_record = false;
            for result in results.into_iter().flatten() {
                progressed |= result.progressed;
                deferred_commits += result.deferred_commits;
                if let Some(rec) = result.record {
                    round_seconds = round_seconds.max(rec.seconds);
                    round_step_problems += rec.problems;
                    round_had_record = true;
                    if opts.latency_hists {
                        lat.round_decode.record_seconds(rec.decode_seconds);
                        lat.round_overhead.record_seconds(rec.overhead_seconds);
                    }
                    if let Some(t) = tracer.as_mut() {
                        // modeled phase spans of this shard's round: decode
                        // on lane 0, plan+commit on lane 1, both from the
                        // round's start — overlapping lanes are exactly how
                        // the pipelined `max(decode, overhead)` fold looks
                        t.push(
                            TraceEvent::span("decode", 1 + rec.shard, 0, round_start_us, to_us(rec.decode_seconds))
                                .arg("model_calls", rec.model_calls as f64)
                                .arg("new_tokens", rec.new_tokens as f64),
                        );
                        t.push(
                            TraceEvent::span("plan_commit", 1 + rec.shard, 1, round_start_us, to_us(rec.overhead_seconds))
                                .arg("problems", rec.problems as f64)
                                .arg("recompute_tokens", rec.recompute_tokens as f64),
                        );
                    }
                    batches.push(rec);
                }
            }
            if opts.latency_hists && round_had_record {
                lat.round_seconds.record_seconds(round_seconds);
            }
            modeled_seconds += round_seconds;
            peak_step_concurrency = peak_step_concurrency.max(round_step_problems);
            peak = peak.max(set.iter().map(|s| s.engine.live_tokens()).sum());
            rounds += 1;
            sum_round_used_blocks +=
                set.iter().map(|s| s.engine.used_blocks() as u64).sum::<u64>();
            // round barrier, observability half: stamp per-request commit
            // times on the freshly advanced global modeled clock, drain the
            // shard rings in index order, and emit cold-tier demotion deltas
            if opts.latency_hists {
                for shard in set.iter() {
                    for slot in &shard.running {
                        let t = &mut timings[slot.id];
                        let steps = slot.session.steps_taken();
                        if steps > t.steps_seen {
                            if t.steps_seen == 0 {
                                t.first_t = modeled_seconds;
                            }
                            t.last_t = modeled_seconds;
                            t.steps_seen = steps;
                        }
                    }
                }
            }
            if let Some(t) = tracer.as_mut() {
                for i in 0..n_shards {
                    let demoted = set.get(i).cold_demoted_tokens();
                    if demoted > last_demoted[i] {
                        t.push(
                            TraceEvent::instant("demoted", 1 + i, 2, round_start_us)
                                .arg("tokens", (demoted - last_demoted[i]) as f64),
                        );
                        last_demoted[i] = demoted;
                    }
                }
                for shard in set.iter_mut() {
                    if let Some(buf) = shard.trace.as_mut() {
                        t.drain_shard(buf, round_start_us);
                    }
                }
            }
            phase_mark(&mut tracer, &mut phase_wall, "barrier");

            if progressed {
                stalled_rounds = 0;
            } else {
                stalled_rounds += 1;
                assert!(
                    stalled_rounds < 4,
                    "serve stalled: per-shard KV capacity ({} blocks x {} tokens, {} shard(s)) \
                     is below a single problem's working set",
                    set.get(0).engine.total_blocks(),
                    set.get(0).engine.block_size(),
                    n_shards
                );
            }
        }
        // final hub audit: the last snapshot's fingerprints are classified
        // too, so published == live + evicted + demoted holds over the
        // whole run
        if let Some(hub) = hub.as_ref() {
            let audit = hub.audit(
                |s, span| set.get(s).engine.cache().peek_prefix(span),
                |s, span, hot| set.get(s).engine.cache().cold_probe(span, hot) <= hot,
            );
            hub_live_entries += audit.live;
            hub_evicted_entries += audit.evicted;
            hub_demoted_entries += audit.demoted;
        }
        // retire the worker pool before folding the report (the enclosing
        // scope joins the exited workers)
        drop(pool);

        for shard in set.iter_mut() {
            // snapshot the cold tier's monotone counters *before* the
            // teardown flush below: the flush demotes every remaining warm
            // span, which is drain traffic, not serving telemetry
            if let Some(cold) = shard.engine.cache().cold() {
                shard.stats.demoted_kv_tokens = cold.demoted_tokens();
                shard.stats.cold_dropped_kv_tokens = cold.dropped_tokens();
                shard.stats.peak_cold_used_blocks =
                    shard.stats.peak_cold_used_blocks.max(cold.used_blocks() as u64);
            }
            // flush warm KV orphaned by sessions that migrated away (lazy
            // suspend leaves it cached) so the all-pins-released invariant
            // is meaningful per shard
            shard.engine.relieve_pressure(usize::MAX);
            debug_assert_eq!(
                shard.engine.live_tokens(),
                0,
                "shard {} left pinned KV behind",
                shard.index
            );
        }
        // Seal the trace: drain any straggler ring events (the final
        // partial iteration runs no worker phases, so these are normally
        // empty), then rebuild the modeled track from the committed
        // outcomes — a pure fold, byte-identical across scheduling modes.
        let trace_payload: Option<ServeTrace> = tracer.map(|mut t| {
            let end_us = to_us(modeled_seconds);
            let mut dropped = 0u64;
            for shard in set.iter_mut() {
                if let Some(buf) = shard.trace.as_mut() {
                    t.drain_shard(buf, end_us);
                    dropped += buf.dropped();
                }
            }
            ServeTrace {
                modeled: modeled_track(&outcomes, perf, model),
                exec: t.events,
                dropped,
            }
        });
        let preemptions: u64 = set.iter().map(|s| s.stats.preemptions).sum();
        let resumes: u64 = set.iter().map(|s| s.stats.resumes).sum();
        let recompute_tokens: u64 = set.iter().map(|s| s.stats.recompute_tokens).sum();
        let peak_used_blocks: usize = set.iter().map(|s| s.stats.peak_used_blocks).sum();
        let total_blocks: usize = set.iter().map(|s| s.engine.total_blocks()).sum();
        let imported_kv_tokens: u64 = set.iter().map(|s| s.stats.imported_kv_tokens).sum();
        let import_transfers: u64 = set.iter().map(|s| s.stats.import_transfers).sum();
        let import_recomputes: u64 = set.iter().map(|s| s.stats.import_recomputes).sum();
        let migration_transfers: u64 =
            set.iter().map(|s| s.stats.migration_transfers).sum();
        let migration_recomputes: u64 =
            set.iter().map(|s| s.stats.migration_recomputes).sum();
        let migration_cold: u64 = set.iter().map(|s| s.stats.migration_cold).sum();
        let spec_plan_hits: u64 = set.iter().map(|s| s.stats.spec_plan_hits).sum();
        let spec_plan_misses: u64 = set.iter().map(|s| s.stats.spec_plan_misses).sum();
        let transferred_kv_bytes: u64 =
            set.iter().map(|s| s.stats.transferred_kv_bytes).sum();
        let recomputed_kv_bytes: u64 =
            set.iter().map(|s| s.stats.recomputed_kv_bytes).sum();
        let demoted_kv_tokens: u64 = set.iter().map(|s| s.stats.demoted_kv_tokens).sum();
        let restored_kv_tokens: u64 =
            set.iter().map(|s| s.stats.restored_kv_tokens).sum();
        let restored_kv_bytes: u64 = set.iter().map(|s| s.stats.restored_kv_bytes).sum();
        let cold_restores: u64 = set.iter().map(|s| s.stats.cold_restores).sum();
        let cold_recomputes: u64 = set.iter().map(|s| s.stats.cold_recomputes).sum();
        let cold_dropped_kv_tokens: u64 =
            set.iter().map(|s| s.stats.cold_dropped_kv_tokens).sum();
        let width_shrinks: u64 = set.iter().map(|s| s.stats.width_shrinks).sum();
        let width_grants: u64 = set.iter().map(|s| s.stats.width_grants).sum();
        let reclaimed_kv_blocks: u64 =
            set.iter().map(|s| s.stats.reclaimed_kv_blocks).sum();
        let granted_kv_blocks: u64 =
            set.iter().map(|s| s.stats.granted_kv_blocks).sum();
        let retention_retained_leaves: u64 =
            set.iter().map(|s| s.stats.retention_retained_leaves).sum();
        let retention_width_samples: u64 =
            set.iter().map(|s| s.stats.retention_width_samples).sum();
        let budget_decisions =
            budgeter.map(|c| c.into_decisions()).unwrap_or_default();
        ServeReport {
            outcomes: outcomes
                .into_iter()
                .map(|o| o.expect("every job produces an outcome"))
                .collect(),
            batches,
            modeled_seconds,
            peak_resident_kv_tokens: peak,
            max_concurrent,
            peak_step_concurrency,
            preemptions,
            resumes,
            recompute_tokens,
            admission_blocked_rounds,
            deferred_commits,
            peak_used_blocks,
            total_blocks,
            shards: n_shards,
            pipeline: opts.pipeline,
            migrations,
            prefix_share: opts.prefix_share,
            hub_hits,
            hub_published,
            hub_live_entries,
            hub_evicted_entries,
            hub_demoted_entries,
            imported_kv_tokens,
            import_transfers,
            import_recomputes,
            migration_transfers,
            migration_recomputes,
            migration_cold,
            async_decode: opts.async_decode,
            spec_plan_hits,
            spec_plan_misses,
            transferred_kv_bytes,
            recomputed_kv_bytes,
            demoted_kv_tokens,
            restored_kv_tokens,
            restored_kv_bytes,
            cold_restores,
            cold_recomputes,
            cold_dropped_kv_tokens,
            cold_capacity_tokens: opts.cold_capacity_tokens,
            adaptive_budget: opts.adaptive_budget,
            width_shrinks,
            width_grants,
            reclaimed_kv_blocks,
            granted_kv_blocks,
            budget_decisions,
            retention_retained_leaves,
            retention_width_samples,
            rounds,
            sum_round_used_blocks,
            shard_stats: set.into_inner().into_iter().map(|s| s.stats).collect(),
            worker_cores,
            latency: lat,
            trace: trace_payload,
        }
    })
}

/// Close the previous coordinator phase span on the wall-clock trace
/// process and open the next (no-ops with tracing off). Wall readings are
/// diagnostic only — they never touch a modeled timestamp.
fn phase_mark(tracer: &mut Option<CoordTracer>, wall: &mut Option<u64>, name: &'static str) {
    if let (Some(t), Some(w)) = (tracer.as_mut(), *wall) {
        t.wall_phase(name, w);
        *wall = Some(t.wall_us());
    }
}

/// Per-request lifecycle timestamps on the global modeled scheduler clock,
/// feeding the TTFT/TPOT/completion histograms. `steps_seen == 0` means no
/// step has committed yet (`first_t`/`last_t` are unset).
#[derive(Clone, Copy, Debug, Default)]
struct ReqTiming {
    admit_t: f64,
    first_t: f64,
    last_t: f64,
    steps_seen: usize,
}

/// Aggregated coordinator statistics.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorStats {
    pub completed: u64,
    pub correct: u64,
    pub total_kv_tokens: u64,
    pub total_new_tokens: u64,
    pub total_model_calls: u64,
    pub wall_seconds: f64,
}

impl CoordinatorStats {
    pub fn throughput_problems_per_sec(&self) -> f64 {
        throughput_problems_per_sec(self.completed as usize, self.wall_seconds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::H100_NVL;
    use crate::lm::SynthLm;
    use crate::reward::OraclePrm;
    use crate::search::policy::RebasePolicy;
    use crate::workload::{ProblemSet, WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

    fn jobs(n: usize, seed: u64) -> Vec<ServeJob<SynthLm, OraclePrm, RebasePolicy>> {
        let spec = WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM);
        ProblemSet::generate(&spec, n, seed)
            .problems
            .into_iter()
            .map(|p| {
                let id = p.id;
                let prm = OraclePrm::for_profile(&spec.model, seed ^ 0xBEEF ^ id);
                ServeJob {
                    lm: SynthLm::new(p, seed ^ id),
                    prm,
                    policy: RebasePolicy::default(),
                }
            })
            .collect()
    }

    fn fingerprints(report: &ServeReport) -> Vec<(Option<i64>, u64, u64)> {
        report
            .outcomes
            .iter()
            .map(|o| (o.answer, o.total_kv_tokens(), o.total_new_tokens()))
            .collect()
    }

    #[test]
    fn serve_interleaves_concurrent_problems_through_one_engine() {
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        let opts = ServeOptions::with_concurrency(3);
        let report = serve(jobs(5, 42), &params, &opts, &perf, &LLEMMA_34B_SIM);
        assert_eq!(report.outcomes.len(), 5);
        assert!(report.max_concurrent >= 2, "batching must co-schedule problems");
        assert!(!report.batches.is_empty());
        assert!(report.modeled_seconds > 0.0);
        assert!(report.throughput_problems_per_sec() > 0.0);
        // ample capacity: the pressure machinery must stay dormant
        assert_eq!(report.kv_pressure_events(), 0);
        assert_eq!(report.resumes, 0);
        assert!(report.peak_used_blocks <= report.total_blocks);
        // per-batch latency from the perf model on every executed round
        let multi: Vec<&BatchRecord> =
            report.batches.iter().filter(|b| b.problems >= 2).collect();
        assert!(!multi.is_empty(), "no round ever held >= 2 problems");
        for b in &report.batches {
            assert!(b.seconds > 0.0, "{b:?}");
            assert!(b.model_calls > 0);
            assert!(b.resident_kv_tokens > 0);
            assert!(b.resident_kv_tokens <= b.unshared_kv_tokens + 5_000);
        }
        // the shared cache's high-water mark covers the co-scheduled set
        let solo_peak = report.outcomes.iter().map(|o| o.peak_kv_tokens()).max().unwrap();
        assert!(report.peak_resident_kv_tokens as u64 >= solo_peak);
        for o in &report.outcomes {
            assert!(o.answer.is_some());
        }
    }

    #[test]
    fn serve_results_do_not_depend_on_concurrency() {
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        let summary = |c: usize| -> Vec<(Option<i64>, u64, u64)> {
            let opts = ServeOptions::with_concurrency(c);
            fingerprints(&serve(jobs(6, 7), &params, &opts, &perf, &LLEMMA_34B_SIM))
        };
        let base = summary(1);
        assert_eq!(base, summary(2));
        assert_eq!(base, summary(4));
    }

    #[test]
    fn serve_results_do_not_depend_on_shard_count() {
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 4);
        let run = |shards: usize| {
            let opts = ServeOptions::with_shards(4, shards);
            serve(jobs(6, 7), &params, &opts, &perf, &LLEMMA_34B_SIM)
        };
        let base = run(1);
        assert_eq!(base.shards, 1);
        assert_eq!(base.migrations, 0);
        assert_eq!(base.shard_stats.len(), 1);
        for shards in [2usize, 4] {
            let sharded = run(shards);
            assert_eq!(
                fingerprints(&base),
                fingerprints(&sharded),
                "shard count {shards} changed results"
            );
            assert_eq!(sharded.shards, shards);
            assert_eq!(sharded.shard_stats.len(), shards);
            // ample capacity: no pressure, hence no migration
            assert_eq!(sharded.kv_pressure_events(), 0);
            assert_eq!(sharded.migrations, 0);
            // the deterministic router actually spread the load
            let used: usize =
                sharded.shard_stats.iter().filter(|s| s.admitted > 0).count();
            assert!(used >= 2, "least-loaded routing left all jobs on one shard");
            // every problem admitted exactly once across shards
            let admitted: u64 = sharded.shard_stats.iter().map(|s| s.admitted).sum();
            assert_eq!(admitted, 6);
        }
    }

    #[test]
    fn pipelining_changes_cost_but_never_results() {
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 4);
        let run = |pipeline: bool| {
            let opts = ServeOptions::with_shards(4, 2).pipelined(pipeline);
            serve(jobs(6, 7), &params, &opts, &perf, &LLEMMA_34B_SIM)
        };
        let lockstep = run(false);
        let pipelined = run(true);
        assert!(!lockstep.pipeline);
        assert!(pipelined.pipeline);
        assert_eq!(
            fingerprints(&lockstep),
            fingerprints(&pipelined),
            "pipelining changed search results"
        );
        // same rounds, same phase decomposition — only the fold differs
        assert_eq!(lockstep.batches.len(), pipelined.batches.len());
        for (l, p) in lockstep.batches.iter().zip(&pipelined.batches) {
            assert_eq!(l.decode_seconds, p.decode_seconds);
            assert_eq!(l.overhead_seconds, p.overhead_seconds);
            assert_eq!(l.seconds, l.decode_seconds + l.overhead_seconds);
            assert_eq!(p.seconds, p.decode_seconds.max(p.overhead_seconds));
        }
        assert!(pipelined.modeled_seconds <= lockstep.modeled_seconds);
        assert!(pipelined.modeled_seconds > 0.0);
    }

    #[test]
    fn serve_matches_run_search_per_problem() {
        // The batched path must report exactly what a solo run reports: the
        // cache views are per-ledger, so co-scheduling changes nothing.
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        let opts = ServeOptions::with_concurrency(4);
        let report = serve(jobs(4, 11), &params, &opts, &perf, &LLEMMA_34B_SIM);
        for (job, served) in jobs(4, 11).into_iter().zip(&report.outcomes) {
            let mut lm = job.lm;
            let mut prm = job.prm;
            let mut policy = job.policy;
            let solo = crate::search::run_search(&mut lm, &mut prm, &mut policy, &params);
            assert_eq!(solo.answer, served.answer);
            assert_eq!(solo.total_kv_tokens(), served.total_kv_tokens());
            assert_eq!(solo.total_new_tokens(), served.total_new_tokens());
            assert_eq!(solo.steps.len(), served.steps.len());
        }
    }

    #[test]
    fn tight_capacity_preempts_but_cannot_change_results() {
        // Oversubscribe: a budget well below the uncapped working set but
        // comfortably above any single problem's peak. The scheduler must
        // keep every answer and every per-problem KV/token count identical
        // while visibly intervening (preempting / blocking admission /
        // deferring commits).
        let params = SearchParams { width: 16, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        let uncapped = serve(
            jobs(6, 42),
            &params,
            &ServeOptions::with_concurrency(6),
            &perf,
            &LLEMMA_34B_SIM,
        );
        let solo_peak = uncapped
            .outcomes
            .iter()
            .map(|o| o.peak_kv_tokens())
            .max()
            .unwrap() as usize;
        assert!(
            uncapped.peak_resident_kv_tokens > 2 * solo_peak + 4096,
            "precondition: co-scheduling must oversubscribe the tight budget \
             (shared peak {} vs solo peak {})",
            uncapped.peak_resident_kv_tokens,
            solo_peak
        );
        let tight = ServeOptions {
            concurrency: 6,
            capacity_tokens: 2 * solo_peak + 4096,
            block_size: 16,
            ..Default::default()
        };
        let capped = serve(jobs(6, 42), &params, &tight, &perf, &LLEMMA_34B_SIM);
        assert_eq!(
            fingerprints(&uncapped),
            fingerprints(&capped),
            "memory pressure changed search results"
        );
        assert!(
            capped.kv_pressure_events() > 0,
            "a below-working-set budget must trigger interventions"
        );
        assert!(
            capped.peak_used_blocks <= capped.total_blocks,
            "hard budget violated: {} > {}",
            capped.peak_used_blocks,
            capped.total_blocks
        );
        assert!(
            capped.peak_resident_kv_tokens
                <= capped.total_blocks * tight.block_size,
            "resident tokens exceeded the block budget"
        );
        // preempted sessions recompute on resume; if any session was
        // preempted the recompute bill must be visible in the batches
        if capped.preemptions > 0 {
            assert!(capped.resumes > 0, "preempted sessions must resume");
            assert!(capped.recompute_tokens > 0);
            assert!(capped.batches.iter().any(|b| b.recompute_tokens > 0));
        }
        for o in &capped.outcomes {
            assert!(o.answer.is_some());
        }
    }

    #[test]
    fn cold_tier_restores_instead_of_recomputing_without_changing_results() {
        // The tight-capacity scenario again, with the host-DRAM spill tier
        // attached: eviction demotes instead of destroying, resumes restore
        // over the modeled PCIe lane — and every answer, every per-problem
        // count, and even the pressure schedule stay byte-identical.
        let params = SearchParams { width: 16, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        let uncapped = serve(
            jobs(6, 42),
            &params,
            &ServeOptions::with_concurrency(6),
            &perf,
            &LLEMMA_34B_SIM,
        );
        let solo_peak =
            uncapped.outcomes.iter().map(|o| o.peak_kv_tokens()).max().unwrap() as usize;
        let tight = ServeOptions {
            concurrency: 6,
            capacity_tokens: 2 * solo_peak + 4096,
            block_size: 16,
            ..Default::default()
        };
        let evict_only = serve(jobs(6, 42), &params, &tight, &perf, &LLEMMA_34B_SIM);
        assert!(evict_only.preemptions > 0, "precondition: the tight budget must preempt");
        assert_eq!(evict_only.demoted_kv_tokens, 0);
        assert_eq!(evict_only.restored_kv_tokens, 0);
        let tiered_opts = tight.clone().cold_tiered(64 * solo_peak);
        let tiered = serve(jobs(6, 42), &params, &tiered_opts, &perf, &LLEMMA_34B_SIM);
        assert_eq!(
            fingerprints(&evict_only),
            fingerprints(&tiered),
            "the cold tier changed search results"
        );
        // demote-instead-of-destroy frees the same hot blocks in the same
        // order, so the pressure schedule is untouched too
        assert_eq!(evict_only.preemptions, tiered.preemptions);
        assert_eq!(evict_only.resumes, tiered.resumes);
        assert!(tiered.demoted_kv_tokens > 0, "evictions must demote with the tier on");
        assert!(tiered.restored_kv_tokens > 0, "resumes must restore demoted spans");
        assert!(tiered.cold_restores > 0);
        assert!(tiered.restored_kv_bytes > 0, "chosen restores must copy real payload");
        // restored tokens come exactly out of the recompute bill: the split
        // is a costing choice, the total rematerialized span is fixed by
        // the (identical) schedule
        assert_eq!(
            tiered.recompute_tokens + tiered.restored_kv_tokens,
            evict_only.recompute_tokens,
            "restore billing must conserve the total resume span"
        );
        // per-shard byte reconciliation: every rematerialized payload byte
        // is either recomputed or restored (no cross-shard transfers
        // without the hub)
        for s in &tiered.shard_stats {
            assert_eq!(s.transferred_kv_bytes, 0);
            assert_eq!(
                s.recomputed_kv_bytes + s.restored_kv_bytes,
                evict_only.shard_stats[s.shard].recomputed_kv_bytes,
                "shard {} byte reconciliation drifted",
                s.shard
            );
        }
        assert!(
            tiered.batches.iter().any(|b| b.restored_kv_tokens > 0),
            "restore billing must reach the round records"
        );
    }

    #[test]
    #[should_panic(expected = "below a single problem's working set")]
    fn serve_panics_when_capacity_cannot_hold_one_problem() {
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        // 512 tokens barely covers the prompt (120) — the first real step
        // cannot commit and there is nothing to preempt
        let opts = ServeOptions {
            concurrency: 2,
            capacity_tokens: 512,
            block_size: 16,
            ..Default::default()
        };
        let _ = serve(jobs(2, 3), &params, &opts, &perf, &LLEMMA_34B_SIM);
    }

    #[test]
    fn throughput_helper_guards_zero_seconds() {
        assert_eq!(throughput_problems_per_sec(10, 0.0), 0.0);
        assert_eq!(throughput_problems_per_sec(10, 2.0), 5.0);
        assert_eq!(throughput_problems_per_sec(0, 1.0), 0.0);
    }

    #[test]
    fn par_map_preserves_order_and_results() {
        let items: Vec<u64> = (0..100).collect();
        let out = par_map(items.clone(), 8, |_, x| x * x);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i as u64) * (i as u64));
        }
    }

    #[test]
    fn par_map_single_worker_fallback() {
        let out = par_map(vec![1, 2, 3], 1, |i, x| i + x);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<u32> = par_map(Vec::<u32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn par_map_uneven_work_balances() {
        // items with wildly different costs still all complete
        let items: Vec<u64> = (0..32).collect();
        let out = par_map(items, 4, |_, x| {
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i);
            }
            (x, acc).0
        });
        assert_eq!(out.len(), 32);
    }
}
