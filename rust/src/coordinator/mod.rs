//! L3 coordinator: request routing and the multi-threaded eval/serve loops.
//!
//! Tokio is unavailable in the offline build environment, so the coordinator
//! is built on `std::thread` scoped workers + mpsc channels: a work queue of
//! problems, N workers running searches, and an aggregator folding results —
//! the same leader/worker shape a vLLM-style router uses, at simulator scale.

use std::sync::mpsc;
use std::sync::Mutex;

/// Parallel map over `items` with `workers` threads, preserving order.
///
/// Workers pull indices from a shared queue (work stealing by index), so
/// uneven per-item costs (hard problems search longer) balance out.
pub fn par_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    // index queue
    let queue: Mutex<std::vec::IntoIter<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>().into_iter());
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let queue = &queue;
            let f = &f;
            scope.spawn(move || loop {
                let item = queue.lock().unwrap().next();
                match item {
                    Some((i, t)) => {
                        let r = f(i, t);
                        if tx.send((i, r)).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker died before finishing")).collect()
    })
}

/// A request to the serving coordinator.
#[derive(Clone, Debug)]
pub struct SearchRequest {
    pub request_id: u64,
    pub problem_id: u64,
}

/// Aggregated coordinator statistics.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorStats {
    pub completed: u64,
    pub correct: u64,
    pub total_kv_tokens: u64,
    pub total_new_tokens: u64,
    pub total_model_calls: u64,
    pub wall_seconds: f64,
}

impl CoordinatorStats {
    pub fn throughput_problems_per_sec(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.completed as f64 / self.wall_seconds
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order_and_results() {
        let items: Vec<u64> = (0..100).collect();
        let out = par_map(items.clone(), 8, |_, x| x * x);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i as u64) * (i as u64));
        }
    }

    #[test]
    fn par_map_single_worker_fallback() {
        let out = par_map(vec![1, 2, 3], 1, |i, x| i + x);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<u32> = par_map(Vec::<u32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn par_map_uneven_work_balances() {
        // items with wildly different costs still all complete
        let items: Vec<u64> = (0..32).collect();
        let out = par_map(items, 4, |_, x| {
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i);
            }
            (x, acc).0
        });
        assert_eq!(out.len(), 32);
    }
}
