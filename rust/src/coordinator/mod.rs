//! L3 coordinator: request routing, the multi-threaded eval loop, and the
//! batched `serve` loop.
//!
//! Two execution shapes:
//!
//! * [`par_map`] — embarrassingly-parallel eval: one search per thread,
//!   fresh engine each (`std::thread` scoped workers + mpsc; tokio is
//!   unavailable offline).
//! * [`serve`] — continuous batching at simulator scale: up to `concurrency`
//!   concurrent [`SearchSession`]s interleave steps through **one**
//!   [`BatchEngine`]/radix cache; each round's merged expansion batch is
//!   costed by [`PerfModel::batch_latency`], and a finished problem's slot
//!   is immediately refilled from the queue — the SGLang-style serving shape
//!   the paper's throughput numbers assume.
//!
//! Both are deterministic for a fixed seed: per-problem RNG streams are
//! independent, so worker count / concurrency never changes results.

use crate::engine::batch::{BatchEngine, ExpandRequest, DEFAULT_KV_CAPACITY};
use crate::engine::perfmodel::{BatchStats, PerfModel};
use crate::lm::StepGenerator;
use crate::reward::RewardModel;
use crate::search::driver::{SearchOutcome, SearchParams, SearchSession};
use crate::search::policy::SearchPolicy;
use crate::workload::ModelProfile;
use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Mutex;

/// Parallel map over `items` with `workers` threads, preserving order.
///
/// Workers pull indices from a shared queue (work stealing by index), so
/// uneven per-item costs (hard problems search longer) balance out.
pub fn par_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    // index queue
    let queue: Mutex<std::vec::IntoIter<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>().into_iter());
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let queue = &queue;
            let f = &f;
            scope.spawn(move || loop {
                let item = queue.lock().unwrap().next();
                match item {
                    Some((i, t)) => {
                        let r = f(i, t);
                        if tx.send((i, r)).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker died before finishing")).collect()
    })
}

/// A request to the serving coordinator.
#[derive(Clone, Debug)]
pub struct SearchRequest {
    pub request_id: u64,
    pub problem_id: u64,
}

/// One problem's ingredients for the batched serve loop.
pub struct ServeJob<G, R, P> {
    pub lm: G,
    pub prm: R,
    pub policy: P,
}

/// Telemetry of one engine round: the merged expansion batch of every active
/// problem, plus its modeled cost.
#[derive(Clone, Debug, Default)]
pub struct BatchRecord {
    /// Problems that contributed expansions this round.
    pub problems: usize,
    /// Leaves expanded (requests in the merged batch).
    pub requests: usize,
    /// Continuations sampled (lockstep decode batch size).
    pub model_calls: usize,
    /// Tokens generated this round.
    pub new_tokens: usize,
    /// Unique KV tokens resident in the shared cache after the round.
    pub resident_kv_tokens: usize,
    /// What the same round would pin without radix sharing.
    pub unshared_kv_tokens: usize,
    /// Modeled wall-clock of this round ([`PerfModel::batch_latency`]).
    pub seconds: f64,
}

/// Result of a [`serve`] run.
pub struct ServeReport {
    /// Per-problem outcomes, in job order.
    pub outcomes: Vec<SearchOutcome>,
    /// One record per engine round.
    pub batches: Vec<BatchRecord>,
    /// Σ per-batch modeled seconds — the serving-time denominator for
    /// throughput.
    pub modeled_seconds: f64,
    /// High-water mark of the shared cache (unique tokens).
    pub peak_resident_kv_tokens: usize,
    /// Most problems ever simultaneously active.
    pub max_concurrent: usize,
}

impl ServeReport {
    pub fn throughput_problems_per_sec(&self) -> f64 {
        if self.modeled_seconds > 0.0 {
            self.outcomes.len() as f64 / self.modeled_seconds
        } else {
            0.0
        }
    }

    pub fn batch_seconds(&self) -> Vec<f64> {
        self.batches.iter().map(|b| b.seconds).collect()
    }
}

/// Serve `jobs` through one shared engine with continuous batching: at most
/// `concurrency` searches are live at a time, each engine round advances all
/// of them by one step in a single merged batch, and finished searches hand
/// their slot to the next queued job mid-flight.
pub fn serve<G, R, P>(
    jobs: Vec<ServeJob<G, R, P>>,
    params: &SearchParams,
    concurrency: usize,
    perf: &PerfModel,
    model: &ModelProfile,
) -> ServeReport
where
    G: StepGenerator,
    R: RewardModel,
    P: SearchPolicy,
{
    let concurrency = concurrency.max(1);
    let n = jobs.len();
    let mut engine = BatchEngine::new(DEFAULT_KV_CAPACITY);
    let mut queue: VecDeque<(usize, ServeJob<G, R, P>)> =
        jobs.into_iter().enumerate().collect();
    let mut active: Vec<(usize, SearchSession<G, R, P>)> = Vec::new();
    let mut outcomes: Vec<Option<SearchOutcome>> = (0..n).map(|_| None).collect();
    let mut batches: Vec<BatchRecord> = Vec::new();
    let mut peak = 0usize;
    let mut max_concurrent = 0usize;

    loop {
        // admit from the queue until the batch is full (continuous batching)
        while active.len() < concurrency {
            let Some((id, job)) = queue.pop_front() else { break };
            let session = SearchSession::new(&mut engine, job.lm, job.prm, job.policy, params);
            active.push((id, session));
        }
        if active.is_empty() {
            break;
        }
        max_concurrent = max_concurrent.max(active.len());

        // Collect every active session's next allocation. Sessions with no
        // work left finish *now* (release-on-complete), so the round's
        // resident-set measurement only covers live problems and their slots
        // refill from the queue on the next admission pass.
        let mut round: Vec<(usize, SearchSession<G, R, P>, Vec<ExpandRequest>)> = Vec::new();
        for (id, mut session) in active.drain(..) {
            let requests = session.next_requests(&mut engine);
            if requests.is_empty() {
                outcomes[id] = Some(session.finish(&mut engine));
            } else {
                round.push((id, session, requests));
            }
        }

        // execute the merged batch: one interleaved engine step
        if !round.is_empty() {
            let mut rec = BatchRecord::default();
            for (_, session, requests) in round.iter_mut() {
                let m = session.step(&mut engine, requests);
                rec.problems += 1;
                rec.requests += requests.len();
                rec.model_calls += m.model_calls;
                rec.new_tokens += m.new_tokens;
                rec.unshared_kv_tokens += m.unshared_kv_tokens;
            }
            rec.resident_kv_tokens = engine.live_tokens();
            peak = peak.max(rec.resident_kv_tokens);
            let stats = BatchStats {
                model_calls: rec.model_calls,
                new_tokens: rec.new_tokens,
                read_kv_tokens: if perf.shared_kv {
                    rec.resident_kv_tokens
                } else {
                    rec.unshared_kv_tokens
                },
                resident_kv_tokens: if perf.shared_kv {
                    rec.resident_kv_tokens
                } else {
                    rec.unshared_kv_tokens
                },
            };
            rec.seconds = perf.batch_latency(&stats, model).seconds;
            batches.push(rec);
        }

        active = round.into_iter().map(|(id, session, _)| (id, session)).collect();
    }

    debug_assert_eq!(engine.live_tokens(), 0, "serve left pinned KV behind");
    let modeled_seconds = batches.iter().map(|b| b.seconds).sum();
    ServeReport {
        outcomes: outcomes
            .into_iter()
            .map(|o| o.expect("every job produces an outcome"))
            .collect(),
        batches,
        modeled_seconds,
        peak_resident_kv_tokens: peak,
        max_concurrent,
    }
}

/// Aggregated coordinator statistics.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorStats {
    pub completed: u64,
    pub correct: u64,
    pub total_kv_tokens: u64,
    pub total_new_tokens: u64,
    pub total_model_calls: u64,
    pub wall_seconds: f64,
}

impl CoordinatorStats {
    pub fn throughput_problems_per_sec(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.completed as f64 / self.wall_seconds
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::H100_NVL;
    use crate::lm::SynthLm;
    use crate::reward::OraclePrm;
    use crate::search::policy::RebasePolicy;
    use crate::workload::{ProblemSet, WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

    fn jobs(n: usize, seed: u64) -> Vec<ServeJob<SynthLm, OraclePrm, RebasePolicy>> {
        let spec = WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM);
        ProblemSet::generate(&spec, n, seed)
            .problems
            .into_iter()
            .map(|p| {
                let id = p.id;
                let prm = OraclePrm::for_profile(&spec.model, seed ^ 0xBEEF ^ id);
                ServeJob {
                    lm: SynthLm::new(p, seed ^ id),
                    prm,
                    policy: RebasePolicy::default(),
                }
            })
            .collect()
    }

    #[test]
    fn serve_interleaves_concurrent_problems_through_one_engine() {
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        let report = serve(jobs(5, 42), &params, 3, &perf, &LLEMMA_34B_SIM);
        assert_eq!(report.outcomes.len(), 5);
        assert!(report.max_concurrent >= 2, "batching must co-schedule problems");
        assert!(!report.batches.is_empty());
        assert!(report.modeled_seconds > 0.0);
        assert!(report.throughput_problems_per_sec() > 0.0);
        // per-batch latency from the perf model on every executed round
        let multi: Vec<&BatchRecord> =
            report.batches.iter().filter(|b| b.problems >= 2).collect();
        assert!(!multi.is_empty(), "no round ever held >= 2 problems");
        for b in &report.batches {
            assert!(b.seconds > 0.0, "{b:?}");
            assert!(b.model_calls > 0);
            assert!(b.resident_kv_tokens > 0);
            assert!(b.resident_kv_tokens <= b.unshared_kv_tokens + 5_000);
        }
        // the shared cache's high-water mark covers the co-scheduled set
        let solo_peak = report.outcomes.iter().map(|o| o.peak_kv_tokens()).max().unwrap();
        assert!(report.peak_resident_kv_tokens as u64 >= solo_peak);
        for o in &report.outcomes {
            assert!(o.answer.is_some());
        }
    }

    #[test]
    fn serve_results_do_not_depend_on_concurrency() {
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        let summary = |c: usize| -> Vec<(Option<i64>, u64, u64)> {
            serve(jobs(6, 7), &params, c, &perf, &LLEMMA_34B_SIM)
                .outcomes
                .iter()
                .map(|o| (o.answer, o.total_kv_tokens(), o.total_new_tokens()))
                .collect()
        };
        let base = summary(1);
        assert_eq!(base, summary(2));
        assert_eq!(base, summary(4));
    }

    #[test]
    fn serve_matches_run_search_per_problem() {
        // The batched path must report exactly what a solo run reports: the
        // cache views are per-ledger, so co-scheduling changes nothing.
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        let report = serve(jobs(4, 11), &params, 4, &perf, &LLEMMA_34B_SIM);
        for (job, served) in jobs(4, 11).into_iter().zip(&report.outcomes) {
            let mut lm = job.lm;
            let mut prm = job.prm;
            let mut policy = job.policy;
            let solo = crate::search::run_search(&mut lm, &mut prm, &mut policy, &params);
            assert_eq!(solo.answer, served.answer);
            assert_eq!(solo.total_kv_tokens(), served.total_kv_tokens());
            assert_eq!(solo.total_new_tokens(), served.total_new_tokens());
            assert_eq!(solo.steps.len(), served.steps.len());
        }
    }

    #[test]
    fn par_map_preserves_order_and_results() {
        let items: Vec<u64> = (0..100).collect();
        let out = par_map(items.clone(), 8, |_, x| x * x);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i as u64) * (i as u64));
        }
    }

    #[test]
    fn par_map_single_worker_fallback() {
        let out = par_map(vec![1, 2, 3], 1, |i, x| i + x);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<u32> = par_map(Vec::<u32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn par_map_uneven_work_balances() {
        // items with wildly different costs still all complete
        let items: Vec<u64> = (0..32).collect();
        let out = par_map(items, 4, |_, x| {
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i);
            }
            (x, acc).0
        });
        assert_eq!(out.len(), 32);
    }
}
