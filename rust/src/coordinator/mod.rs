//! L3 coordinator: request routing, the multi-threaded eval loop, and the
//! memory-pressure-aware batched `serve` scheduler.
//!
//! Two execution shapes:
//!
//! * [`par_map`] — embarrassingly-parallel eval: one search per thread,
//!   fresh engine each (`std::thread` scoped workers + mpsc; tokio is
//!   unavailable offline).
//! * [`serve`] — continuous batching at simulator scale: up to
//!   `concurrency` concurrent [`SearchSession`]s interleave steps through
//!   **one** [`BatchEngine`]/radix cache whose block budget
//!   ([`ServeOptions::capacity_tokens`]) is *hard*. The scheduler keeps an
//!   admission queue, a running set, and a suspended set: admission is
//!   gated on free-block watermarks, every step commit goes through the
//!   engine's reserve → commit protocol, and when a reservation fails the
//!   scheduler first LRU-evicts unpinned branches, then **preempts** the
//!   lowest-priority session (releasing its blocks, keeping its tree) and
//!   later resumes it by recomputing the evicted prefix through the radix
//!   cache. Each round's merged batch is costed by
//!   [`PerfModel::batch_latency`] — including the recompute-prefill of
//!   resumed sessions — and a finished problem's slot is immediately
//!   refilled from the queue: the paged-attention serving shape (vLLM/
//!   SGLang) the paper's throughput numbers assume.
//!
//! Both are deterministic for a fixed seed, and — because sessions advance
//! their RNG streams only in `prepare` and commit steps atomically —
//! *scheduling cannot change search results*: worker count, concurrency,
//! and even preemption under a tight capacity leave every problem's answer
//! and KV/token accounting identical (`tests/serve_determinism.rs` pins
//! this).

use crate::engine::batch::{BatchEngine, DEFAULT_KV_CAPACITY};
use crate::engine::perfmodel::{BatchStats, PerfModel};
use crate::kvcache::DEFAULT_BLOCK_SIZE;
use crate::lm::StepGenerator;
use crate::reward::RewardModel;
use crate::search::driver::{SearchOutcome, SearchParams, SearchSession};
use crate::search::policy::SearchPolicy;
use crate::workload::ModelProfile;
use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Mutex;

/// Parallel map over `items` with `workers` threads, preserving order.
///
/// Workers pull indices from a shared queue (work stealing by index), so
/// uneven per-item costs (hard problems search longer) balance out.
pub fn par_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    // index queue
    let queue: Mutex<std::vec::IntoIter<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>().into_iter());
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let queue = &queue;
            let f = &f;
            scope.spawn(move || loop {
                let item = queue.lock().unwrap().next();
                match item {
                    Some((i, t)) => {
                        let r = f(i, t);
                        if tx.send((i, r)).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker died before finishing")).collect()
    })
}

/// A request to the serving coordinator.
#[derive(Clone, Debug)]
pub struct SearchRequest {
    pub request_id: u64,
    pub problem_id: u64,
}

/// One problem's ingredients for the batched serve loop.
pub struct ServeJob<G, R, P> {
    pub lm: G,
    pub prm: R,
    pub policy: P,
}

/// Scheduler configuration for [`serve`].
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Most problems admitted (running + suspended) at a time.
    pub concurrency: usize,
    /// Hard KV budget in tokens; the engine rounds up to whole blocks.
    pub capacity_tokens: usize,
    /// Tokens per KV block (paged-allocator page size).
    pub block_size: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            concurrency: 8,
            capacity_tokens: DEFAULT_KV_CAPACITY,
            block_size: DEFAULT_BLOCK_SIZE,
        }
    }
}

impl ServeOptions {
    pub fn with_concurrency(concurrency: usize) -> Self {
        Self { concurrency, ..Default::default() }
    }
}

/// Telemetry of one engine round: the merged expansion batch of every active
/// problem, plus its modeled cost.
#[derive(Clone, Debug, Default)]
pub struct BatchRecord {
    /// Problems that committed expansions this round.
    pub problems: usize,
    /// Leaves expanded (requests in the merged batch).
    pub requests: usize,
    /// Continuations sampled (lockstep decode batch size).
    pub model_calls: usize,
    /// Tokens generated this round.
    pub new_tokens: usize,
    /// Unique KV tokens resident in the shared cache after the round —
    /// physical occupancy, including warm (unpinned) working sets of
    /// suspended sessions awaiting eviction. Drives wave fragmentation.
    pub resident_kv_tokens: usize,
    /// Unique KV tokens pinned by the sessions that committed this round —
    /// what the decode actually reads (suspended sessions' warm KV is not
    /// touched by any running sequence).
    pub pinned_kv_tokens: usize,
    /// What the same round would pin without radix sharing.
    pub unshared_kv_tokens: usize,
    /// Tokens re-prefilled by sessions resumed this round.
    pub recompute_tokens: usize,
    /// Sessions preempted during this round's commits.
    pub preemptions: usize,
    /// Modeled wall-clock of this round ([`PerfModel::batch_latency`]).
    pub seconds: f64,
}

/// Result of a [`serve`] run.
pub struct ServeReport {
    /// Per-problem outcomes, in job order.
    pub outcomes: Vec<SearchOutcome>,
    /// One record per engine round.
    pub batches: Vec<BatchRecord>,
    /// Σ per-batch modeled seconds — the serving-time denominator for
    /// throughput.
    pub modeled_seconds: f64,
    /// High-water mark of the shared cache (unique tokens).
    pub peak_resident_kv_tokens: usize,
    /// Most problems ever simultaneously admitted (running + suspended).
    pub max_concurrent: usize,
    /// Most problems that actually advanced (committed a step) in a single
    /// round — the *resident* concurrency, excluding swapped-out suspended
    /// sessions. This is the number oversubscription throttles.
    pub peak_step_concurrency: usize,
    /// Sessions preempted under memory pressure (suspend events).
    pub preemptions: u64,
    /// Sessions resumed after preemption.
    pub resumes: u64,
    /// Tokens re-prefilled by resumes (the recompute bill of preemption).
    pub recompute_tokens: u64,
    /// Rounds where admission was blocked by the free-block watermark.
    pub admission_blocked_rounds: u64,
    /// Step commits deferred to a later round because nothing could be
    /// evicted or preempted to make room.
    pub deferred_commits: u64,
    /// High-water mark of allocated blocks (≤ `total_blocks` by
    /// construction — the hard budget).
    pub peak_used_blocks: usize,
    /// The hard block budget the run was scheduled under.
    pub total_blocks: usize,
}

impl ServeReport {
    pub fn throughput_problems_per_sec(&self) -> f64 {
        if self.modeled_seconds > 0.0 {
            self.outcomes.len() as f64 / self.modeled_seconds
        } else {
            0.0
        }
    }

    pub fn batch_seconds(&self) -> Vec<f64> {
        self.batches.iter().map(|b| b.seconds).collect()
    }

    /// Total memory-pressure interventions: preemptions, watermark-blocked
    /// admissions, and deferred commits. 0 means the budget never bound.
    pub fn kv_pressure_events(&self) -> u64 {
        self.preemptions + self.admission_blocked_rounds + self.deferred_commits
    }
}

/// One admitted problem in the scheduler: its outcome slot and admission
/// sequence number (lower = admitted earlier = higher priority; preemption
/// victims are picked from the highest sequence numbers, vLLM-style).
struct Slot<G, R, P> {
    id: usize,
    seq: u64,
    session: SearchSession<G, R, P>,
}

/// Serve `jobs` through one shared engine with continuous batching under a
/// hard KV block budget: at most `opts.concurrency` searches are admitted
/// at a time, each engine round advances the resident ones by one step in a
/// single merged batch, and finished searches hand their slot to the next
/// queued job mid-flight.
///
/// Memory pressure is handled in escalating order: (1) admission is gated
/// on a free-block watermark, (2) a failed step reservation LRU-evicts
/// unpinned branches, (3) still failing, the lowest-priority resident
/// session is preempted — its blocks released, its tree kept — and resumed
/// later by recomputing the evicted prefix. Because a session's RNG
/// advances only in prepare/commit (both atomic w.r.t. preemption), the
/// schedule cannot change any search's results.
///
/// Panics when even a single session cannot advance alone at this budget —
/// the capacity is below one problem's working set.
pub fn serve<G, R, P>(
    jobs: Vec<ServeJob<G, R, P>>,
    params: &SearchParams,
    opts: &ServeOptions,
    perf: &PerfModel,
    model: &ModelProfile,
) -> ServeReport
where
    G: StepGenerator,
    R: RewardModel,
    P: SearchPolicy,
{
    let concurrency = opts.concurrency.max(1);
    let n = jobs.len();
    let mut engine = BatchEngine::with_block_size(opts.capacity_tokens, opts.block_size);
    let mut queue: VecDeque<(usize, ServeJob<G, R, P>)> =
        jobs.into_iter().enumerate().collect();
    let mut running: Vec<Slot<G, R, P>> = Vec::new();
    let mut suspended: Vec<Slot<G, R, P>> = Vec::new();
    let mut outcomes: Vec<Option<SearchOutcome>> = (0..n).map(|_| None).collect();
    let mut batches: Vec<BatchRecord> = Vec::new();
    let mut peak = 0usize;
    let mut peak_used_blocks = 0usize;
    let mut max_concurrent = 0usize;
    let mut peak_step_concurrency = 0usize;
    let mut admit_seq = 0u64;
    let mut preemptions = 0u64;
    let mut resumes = 0u64;
    let mut recompute_total = 0u64;
    let mut admission_blocked_rounds = 0u64;
    let mut deferred_commits = 0u64;
    // Livelock guard: rounds that neither commit, finish, nor admit make no
    // real progress (a resume alone does not count — resume → preempt can
    // thrash); several in a row means the budget is below one working set.
    let mut stalled_rounds = 0u32;

    loop {
        let mut progressed = false;
        let mut round_recompute = 0usize;

        // 1. resume preempted sessions, oldest admission first (FIFO —
        //    younger sessions never leapfrog a blocked elder)
        suspended.sort_by_key(|s| s.seq);
        let mut still_suspended: Vec<Slot<G, R, P>> = Vec::new();
        for mut slot in suspended.drain(..) {
            let mut resumed = false;
            if still_suspended.is_empty() {
                for attempt in 0..2 {
                    match slot.session.try_resume(&mut engine) {
                        Ok(recomputed) => {
                            resumed = true;
                            resumes += 1;
                            round_recompute += recomputed;
                            break;
                        }
                        Err(p) => {
                            if attempt == 0 && engine.relieve(&p) > 0 {
                                continue;
                            }
                            break;
                        }
                    }
                }
            }
            if resumed {
                running.push(slot);
            } else {
                still_suspended.push(slot);
            }
        }
        suspended = still_suspended;

        // 2. admit from the queue while the watermark leaves headroom
        //    (continuous batching: finished slots refill mid-flight)
        while running.len() + suspended.len() < concurrency {
            let admissible = match queue.front() {
                Some((_, job)) => engine.can_admit(job.lm.prompt_tokens()),
                None => break,
            };
            if !admissible {
                admission_blocked_rounds += 1;
                break;
            }
            let (id, job) = queue.pop_front().expect("front checked above");
            let session = SearchSession::new(&mut engine, job.lm, job.prm, job.policy, params);
            running.push(Slot { id, seq: admit_seq, session });
            admit_seq += 1;
            progressed = true;
        }
        if running.is_empty() && suspended.is_empty() && queue.is_empty() {
            break;
        }
        max_concurrent = max_concurrent.max(running.len() + suspended.len());

        // 3. collect each resident session's next allocation and run the
        //    generator (prepare — no KV charged yet). Sessions with no work
        //    left finish *now* (release-on-complete) so their blocks refill
        //    slots on the next admission pass. Sessions that already hold a
        //    prepared step (deferred or preempted mid-commit) keep it.
        let mut active: Vec<Slot<G, R, P>> = Vec::new();
        for mut slot in running.drain(..) {
            if slot.session.has_pending() {
                active.push(slot);
                continue;
            }
            let requests = slot.session.next_requests(&mut engine);
            if requests.is_empty() {
                outcomes[slot.id] = Some(slot.session.finish(&mut engine));
                progressed = true;
            } else {
                slot.session.prepare(&mut engine, &requests);
                active.push(slot);
            }
        }
        running = active;

        // 4. commit the merged batch in priority order; on reservation
        //    failure: evict unpinned branches, then preempt from the tail
        //    (never the committing slot), then defer to the next round
        running.sort_by_key(|s| s.seq);
        let mut rec =
            BatchRecord { recompute_tokens: round_recompute, ..Default::default() };
        let mut i = 0usize;
        while i < running.len() {
            let n_requests = running[i].session.pending_requests();
            let committed = loop {
                match running[i].session.try_commit(&mut engine) {
                    Ok(m) => break Some(m),
                    Err(p) => {
                        // first remedy: reclaim unpinned branches (LRU),
                        // evicting only the deficit so other suspended
                        // sessions keep as much warm KV as possible
                        if engine.relieve(&p) > 0 {
                            continue;
                        }
                        // second remedy: preempt the lowest-priority
                        // not-yet-committed session (sorted tail)
                        if running.len() > i + 1 {
                            let mut victim = running.pop().expect("len > i + 1");
                            victim.session.suspend(&mut engine);
                            preemptions += 1;
                            rec.preemptions += 1;
                            suspended.push(victim);
                            continue;
                        }
                        break None; // defer this step to the next round
                    }
                }
            };
            match committed {
                Some(m) => {
                    rec.problems += 1;
                    rec.requests += n_requests;
                    rec.model_calls += m.model_calls;
                    rec.new_tokens += m.new_tokens;
                    rec.pinned_kv_tokens += m.live_kv_tokens;
                    rec.unshared_kv_tokens += m.unshared_kv_tokens;
                    progressed = true;
                    i += 1;
                }
                None => {
                    // everything evictable is gone and no lower-priority
                    // victim remains; later slots need even more room
                    deferred_commits += 1;
                    break;
                }
            }
        }

        // 5. close the round: telemetry, hard-budget assertion, perf cost
        peak_step_concurrency = peak_step_concurrency.max(rec.problems);
        rec.resident_kv_tokens = engine.live_tokens();
        peak = peak.max(rec.resident_kv_tokens);
        peak_used_blocks = peak_used_blocks.max(engine.used_blocks());
        debug_assert!(
            engine.used_blocks() <= engine.total_blocks(),
            "serve exceeded the hard block budget: {} > {}",
            engine.used_blocks(),
            engine.total_blocks()
        );
        if rec.problems > 0 || rec.recompute_tokens > 0 {
            // decode reads only what the committed sessions pin; wave
            // fragmentation is driven by physical occupancy (which, under
            // lazy suspend, may include warm suspended working sets)
            let (read, resident) = if perf.shared_kv {
                (rec.pinned_kv_tokens, rec.resident_kv_tokens)
            } else {
                (rec.unshared_kv_tokens, rec.unshared_kv_tokens)
            };
            let stats = BatchStats {
                model_calls: rec.model_calls,
                new_tokens: rec.new_tokens,
                read_kv_tokens: read,
                resident_kv_tokens: resident,
                recompute_prefill_tokens: rec.recompute_tokens,
                block_size: engine.block_size(),
            };
            rec.seconds = perf.batch_latency(&stats, model).seconds;
            recompute_total += rec.recompute_tokens as u64;
            batches.push(rec);
        }
        if progressed {
            stalled_rounds = 0;
        } else {
            stalled_rounds += 1;
            assert!(
                stalled_rounds < 4,
                "serve stalled: KV capacity ({} blocks x {} tokens) is below a \
                 single problem's working set",
                engine.total_blocks(),
                engine.block_size()
            );
        }
    }

    debug_assert_eq!(engine.live_tokens(), 0, "serve left pinned KV behind");
    let modeled_seconds = batches.iter().map(|b| b.seconds).sum();
    ServeReport {
        outcomes: outcomes
            .into_iter()
            .map(|o| o.expect("every job produces an outcome"))
            .collect(),
        batches,
        modeled_seconds,
        peak_resident_kv_tokens: peak,
        max_concurrent,
        peak_step_concurrency,
        preemptions,
        resumes,
        recompute_tokens: recompute_total,
        admission_blocked_rounds,
        deferred_commits,
        peak_used_blocks,
        total_blocks: engine.total_blocks(),
    }
}

/// Aggregated coordinator statistics.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorStats {
    pub completed: u64,
    pub correct: u64,
    pub total_kv_tokens: u64,
    pub total_new_tokens: u64,
    pub total_model_calls: u64,
    pub wall_seconds: f64,
}

impl CoordinatorStats {
    pub fn throughput_problems_per_sec(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.completed as f64 / self.wall_seconds
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::H100_NVL;
    use crate::lm::SynthLm;
    use crate::reward::OraclePrm;
    use crate::search::policy::RebasePolicy;
    use crate::workload::{ProblemSet, WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

    fn jobs(n: usize, seed: u64) -> Vec<ServeJob<SynthLm, OraclePrm, RebasePolicy>> {
        let spec = WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM);
        ProblemSet::generate(&spec, n, seed)
            .problems
            .into_iter()
            .map(|p| {
                let id = p.id;
                let prm = OraclePrm::for_profile(&spec.model, seed ^ 0xBEEF ^ id);
                ServeJob {
                    lm: SynthLm::new(p, seed ^ id),
                    prm,
                    policy: RebasePolicy::default(),
                }
            })
            .collect()
    }

    fn fingerprints(report: &ServeReport) -> Vec<(Option<i64>, u64, u64)> {
        report
            .outcomes
            .iter()
            .map(|o| (o.answer, o.total_kv_tokens(), o.total_new_tokens()))
            .collect()
    }

    #[test]
    fn serve_interleaves_concurrent_problems_through_one_engine() {
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        let opts = ServeOptions::with_concurrency(3);
        let report = serve(jobs(5, 42), &params, &opts, &perf, &LLEMMA_34B_SIM);
        assert_eq!(report.outcomes.len(), 5);
        assert!(report.max_concurrent >= 2, "batching must co-schedule problems");
        assert!(!report.batches.is_empty());
        assert!(report.modeled_seconds > 0.0);
        assert!(report.throughput_problems_per_sec() > 0.0);
        // ample capacity: the pressure machinery must stay dormant
        assert_eq!(report.kv_pressure_events(), 0);
        assert_eq!(report.resumes, 0);
        assert!(report.peak_used_blocks <= report.total_blocks);
        // per-batch latency from the perf model on every executed round
        let multi: Vec<&BatchRecord> =
            report.batches.iter().filter(|b| b.problems >= 2).collect();
        assert!(!multi.is_empty(), "no round ever held >= 2 problems");
        for b in &report.batches {
            assert!(b.seconds > 0.0, "{b:?}");
            assert!(b.model_calls > 0);
            assert!(b.resident_kv_tokens > 0);
            assert!(b.resident_kv_tokens <= b.unshared_kv_tokens + 5_000);
        }
        // the shared cache's high-water mark covers the co-scheduled set
        let solo_peak = report.outcomes.iter().map(|o| o.peak_kv_tokens()).max().unwrap();
        assert!(report.peak_resident_kv_tokens as u64 >= solo_peak);
        for o in &report.outcomes {
            assert!(o.answer.is_some());
        }
    }

    #[test]
    fn serve_results_do_not_depend_on_concurrency() {
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        let summary = |c: usize| -> Vec<(Option<i64>, u64, u64)> {
            let opts = ServeOptions::with_concurrency(c);
            fingerprints(&serve(jobs(6, 7), &params, &opts, &perf, &LLEMMA_34B_SIM))
        };
        let base = summary(1);
        assert_eq!(base, summary(2));
        assert_eq!(base, summary(4));
    }

    #[test]
    fn serve_matches_run_search_per_problem() {
        // The batched path must report exactly what a solo run reports: the
        // cache views are per-ledger, so co-scheduling changes nothing.
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        let opts = ServeOptions::with_concurrency(4);
        let report = serve(jobs(4, 11), &params, &opts, &perf, &LLEMMA_34B_SIM);
        for (job, served) in jobs(4, 11).into_iter().zip(&report.outcomes) {
            let mut lm = job.lm;
            let mut prm = job.prm;
            let mut policy = job.policy;
            let solo = crate::search::run_search(&mut lm, &mut prm, &mut policy, &params);
            assert_eq!(solo.answer, served.answer);
            assert_eq!(solo.total_kv_tokens(), served.total_kv_tokens());
            assert_eq!(solo.total_new_tokens(), served.total_new_tokens());
            assert_eq!(solo.steps.len(), served.steps.len());
        }
    }

    #[test]
    fn tight_capacity_preempts_but_cannot_change_results() {
        // Oversubscribe: a budget well below the uncapped working set but
        // comfortably above any single problem's peak. The scheduler must
        // keep every answer and every per-problem KV/token count identical
        // while visibly intervening (preempting / blocking admission /
        // deferring commits).
        let params = SearchParams { width: 16, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        let uncapped = serve(
            jobs(6, 42),
            &params,
            &ServeOptions::with_concurrency(6),
            &perf,
            &LLEMMA_34B_SIM,
        );
        let solo_peak = uncapped
            .outcomes
            .iter()
            .map(|o| o.peak_kv_tokens())
            .max()
            .unwrap() as usize;
        assert!(
            uncapped.peak_resident_kv_tokens > 2 * solo_peak + 4096,
            "precondition: co-scheduling must oversubscribe the tight budget \
             (shared peak {} vs solo peak {})",
            uncapped.peak_resident_kv_tokens,
            solo_peak
        );
        let tight = ServeOptions {
            concurrency: 6,
            capacity_tokens: 2 * solo_peak + 4096,
            block_size: 16,
        };
        let capped = serve(jobs(6, 42), &params, &tight, &perf, &LLEMMA_34B_SIM);
        assert_eq!(
            fingerprints(&uncapped),
            fingerprints(&capped),
            "memory pressure changed search results"
        );
        assert!(
            capped.kv_pressure_events() > 0,
            "a below-working-set budget must trigger interventions"
        );
        assert!(
            capped.peak_used_blocks <= capped.total_blocks,
            "hard budget violated: {} > {}",
            capped.peak_used_blocks,
            capped.total_blocks
        );
        assert!(
            capped.peak_resident_kv_tokens
                <= capped.total_blocks * tight.block_size,
            "resident tokens exceeded the block budget"
        );
        // preempted sessions recompute on resume; if any session was
        // preempted the recompute bill must be visible in the batches
        if capped.preemptions > 0 {
            assert!(capped.resumes > 0, "preempted sessions must resume");
            assert!(capped.recompute_tokens > 0);
            assert!(capped.batches.iter().any(|b| b.recompute_tokens > 0));
        }
        for o in &capped.outcomes {
            assert!(o.answer.is_some());
        }
    }

    #[test]
    #[should_panic(expected = "below a single problem's working set")]
    fn serve_panics_when_capacity_cannot_hold_one_problem() {
        let params = SearchParams { width: 8, max_steps: 16 };
        let perf = PerfModel::new(H100_NVL, true, 1);
        // 512 tokens barely covers the prompt (120) — the first real step
        // cannot commit and there is nothing to preempt
        let opts = ServeOptions {
            concurrency: 2,
            capacity_tokens: 512,
            block_size: 16,
        };
        let _ = serve(jobs(2, 3), &params, &opts, &perf, &LLEMMA_34B_SIM);
    }

    #[test]
    fn par_map_preserves_order_and_results() {
        let items: Vec<u64> = (0..100).collect();
        let out = par_map(items.clone(), 8, |_, x| x * x);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i as u64) * (i as u64));
        }
    }

    #[test]
    fn par_map_single_worker_fallback() {
        let out = par_map(vec![1, 2, 3], 1, |i, x| i + x);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<u32> = par_map(Vec::<u32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn par_map_uneven_work_balances() {
        // items with wildly different costs still all complete
        let items: Vec<u64> = (0..32).collect();
        let out = par_map(items, 4, |_, x| {
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i);
            }
            (x, acc).0
        });
        assert_eq!(out.len(), 32);
    }
}
