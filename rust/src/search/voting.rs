//! Final answer aggregation: weighted majority voting using each completed
//! trajectory's final PRM score as its weight (Beeching et al. '24 — the
//! aggregation the paper adopts).

use std::collections::HashMap;

/// A completed trajectory's (answer, final PRM score).
pub type Completion = (i64, f64);

/// Weighted majority vote. Returns `None` when nothing completed.
pub fn weighted_majority(completions: &[Completion]) -> Option<i64> {
    if completions.is_empty() {
        return None;
    }
    let mut mass: HashMap<i64, f64> = HashMap::new();
    for &(ans, w) in completions {
        *mass.entry(ans).or_insert(0.0) += w.max(0.0);
    }
    mass.into_iter()
        // deterministic tie-break on the answer value
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(b.0.cmp(&a.0)))
        .map(|(ans, _)| ans)
}

/// Unweighted majority (baseline aggregation).
pub fn majority(completions: &[Completion]) -> Option<i64> {
    weighted_majority(&completions.iter().map(|&(a, _)| (a, 1.0)).collect::<Vec<_>>())
}

/// Best-of-N: answer of the single highest-scoring trajectory.
pub fn best_of_n(completions: &[Completion]) -> Option<i64> {
    completions
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .map(|&(ans, _)| ans)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_returns_none() {
        assert_eq!(weighted_majority(&[]), None);
        assert_eq!(best_of_n(&[]), None);
    }

    #[test]
    fn weight_mass_beats_count() {
        // two votes for 1 with tiny weight, one vote for 2 with huge weight
        let c = vec![(1, 0.1), (1, 0.1), (2, 0.9)];
        assert_eq!(weighted_majority(&c), Some(2));
        assert_eq!(majority(&c), Some(1));
    }

    #[test]
    fn best_of_n_takes_argmax() {
        let c = vec![(1, 0.3), (2, 0.8), (3, 0.5)];
        assert_eq!(best_of_n(&c), Some(2));
    }

    #[test]
    fn deterministic_tie_break() {
        let c = vec![(5, 0.5), (9, 0.5)];
        let a = weighted_majority(&c);
        for _ in 0..10 {
            assert_eq!(weighted_majority(&c), a);
        }
    }
}
