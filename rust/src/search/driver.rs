//! The PRM-guided tree-search driver, built on the batched engine: a
//! [`SearchSession`] is one problem's resumable search state machine, and
//! [`run_search`] drives a single session to completion. The multi-problem
//! serving loop ([`crate::coordinator::serve`]) interleaves many sessions
//! through one [`BatchEngine`] instead.
//!
//! All KV numbers reported here are *views over the engine's
//! [`crate::kvcache::RadixCache`]* — the tree keeps no KV counters of its
//! own. In debug builds every step asserts that the cache-derived live KV
//! equals the sum of live tree step tokens (the accounting the seed kept by
//! hand, now provably consistent).
//!
//! A step is two phases so a scheduler can handle memory pressure between
//! them: [`SearchSession::prepare`] runs the generator (advancing the
//! per-problem RNG exactly once) without charging any KV, and
//! [`SearchSession::try_commit`] reserves the worst-case block need and
//! only then mutates the tree and cache — a commit that fails with
//! [`KvPressure`] leaves the prepared step stored and retryable, so
//! preemption can never change search results. [`SearchSession::suspend`] /
//! [`SearchSession::try_resume`] are the preemption hooks: suspend releases
//! every KV block (keeping the tree), resume recomputes the evicted prefix
//! through the radix cache.

use crate::engine::batch::{
    BatchEngine, ExpandRequest, ImportSource, KvLedger, ResumeStats, DEFAULT_KV_CAPACITY,
};
use crate::kvcache::KvPressure;
use crate::lm::{PendingBatch, StepGenerator};
use crate::reward::RewardModel;
use crate::search::policy::SearchPolicy;
use crate::search::voting::{weighted_majority, Completion};
use crate::tree::{NodeId, SearchTree, StepInfo};

/// Committed-telemetry snapshot of one session's search state, read at a
/// round barrier by the adaptive budget controller
/// ([`crate::coordinator::budget`]). Every field is derived purely from the
/// tree's committed frontier — nothing here depends on scheduling, shard
/// placement, or capacity, which is what makes controller decisions
/// byte-identical across serve configurations.
#[derive(Clone, Debug, PartialEq)]
pub struct DifficultySignals {
    /// Committed steps when the snapshot was taken.
    pub steps_taken: usize,
    /// Frontier size (live, non-terminal leaves).
    pub frontier: usize,
    /// Mean PRM reward over the frontier.
    pub reward_mean: f64,
    /// Max − min PRM reward over the frontier (contestedness).
    pub reward_spread: f64,
    /// Normalized softmax entropy of frontier rewards at the REBASE
    /// temperature (T = 0.2); in [0, 1], 0 for a single-leaf frontier.
    pub entropy: f64,
    /// Distinct semantic cluster ids over the frontier.
    pub sem_clusters: usize,
}

/// Per-search-step efficiency record.
#[derive(Clone, Debug, Default)]
pub struct StepMetrics {
    /// Live unique KV tokens during this step (radix-shared; the paper's
    /// per-step KV cache size), read from the engine's cache.
    pub live_kv_tokens: usize,
    /// KV tokens if every trajectory kept a private copy (no sharing).
    pub unshared_kv_tokens: usize,
    /// Tokens generated this step (FLOPs proxy).
    pub new_tokens: usize,
    /// Continuations sampled this step (model calls).
    pub model_calls: usize,
    /// Frontier size entering the step.
    pub frontier: usize,
    /// PRM scoring calls this step.
    pub prm_calls: usize,
}

/// Outcome of one problem's search.
#[derive(Clone, Debug)]
pub struct SearchOutcome {
    /// Weighted-majority answer (None if nothing completed — shouldn't
    /// happen within `max_steps`).
    pub answer: Option<i64>,
    pub completions: Vec<Completion>,
    pub steps: Vec<StepMetrics>,
    pub tree: SearchTree,
    /// Leaf node of every completed trajectory (for engine replay).
    pub completed_leaves: Vec<NodeId>,
    /// Tokens re-prefilled across every preemption/resume round trip this
    /// search went through (0 when it was never preempted). Kept out of
    /// [`StepMetrics`] on purpose: scheduling must not change the search's
    /// own KV/token accounting.
    pub recompute_tokens: u64,
}

impl SearchOutcome {
    /// Σ per-step live KV — the paper's "total KV cache size" metric.
    pub fn total_kv_tokens(&self) -> u64 {
        self.steps.iter().map(|s| s.live_kv_tokens as u64).sum()
    }

    pub fn total_unshared_kv_tokens(&self) -> u64 {
        self.steps.iter().map(|s| s.unshared_kv_tokens as u64).sum()
    }

    /// Total generated tokens (the FLOPs proxy used by prior work).
    pub fn total_new_tokens(&self) -> u64 {
        self.steps.iter().map(|s| s.new_tokens as u64).sum()
    }

    pub fn total_model_calls(&self) -> u64 {
        self.steps.iter().map(|s| s.model_calls as u64).sum()
    }

    /// Peak live KV across steps (memory high-water mark).
    pub fn peak_kv_tokens(&self) -> u64 {
        self.steps.iter().map(|s| s.live_kv_tokens as u64).max().unwrap_or(0)
    }
}

/// Search configuration for one run.
#[derive(Clone, Debug)]
pub struct SearchParams {
    /// Initial width N (continuations sampled at the root).
    pub width: usize,
    /// Safety cap on steps (>= dataset n_steps + slack).
    pub max_steps: usize,
}

impl Default for SearchParams {
    fn default() -> Self {
        Self { width: 16, max_steps: 24 }
    }
}

/// A generated-but-uncommitted step: the expansion results are held here
/// (per-problem RNG already advanced) until a commit reserves the KV.
struct PendingStep {
    requests: Vec<ExpandRequest>,
    expansions: Vec<Vec<StepInfo>>,
}

/// One problem's search as a resumable state machine, so a serving loop can
/// interleave steps from many concurrent searches through one engine.
///
/// Protocol per step: [`SearchSession::next_requests`] returns the policy's
/// allocation as an [`ExpandRequest`] batch (retiring pruned trajectories in
/// both the tree and the cache); [`SearchSession::prepare`] samples the
/// continuations; [`SearchSession::try_commit`] charges the new KV to the
/// engine (retryable under pressure). An empty request batch means the
/// search is over — call [`SearchSession::finish`].
pub struct SearchSession<G, R, P> {
    pub lm: G,
    pub prm: R,
    pub policy: P,
    params: SearchParams,
    tree: SearchTree,
    ledger: KvLedger,
    frontier: Vec<NodeId>,
    width: usize,
    steps_taken: usize,
    metrics: Vec<StepMetrics>,
    completions: Vec<Completion>,
    completed_leaves: Vec<NodeId>,
    started: bool,
    /// A decode batch submitted but not yet collected (phase 1a → 1b).
    in_flight: Option<(Vec<ExpandRequest>, PendingBatch)>,
    pending: Option<PendingStep>,
    suspended: bool,
    recompute_tokens: u64,
    /// Pending width reallocation from the adaptive budget controller:
    /// `(from_step, delta)` applies `delta` to the live width at the first
    /// allocation with `steps_taken >= from_step`. Stored as a *delta*
    /// against the base width so terminal-completion decrements that land
    /// between decision and application are preserved.
    width_override: Option<(usize, isize)>,
}

impl<G: StepGenerator, R: RewardModel, P: SearchPolicy> SearchSession<G, R, P> {
    pub fn new(engine: &mut BatchEngine, lm: G, prm: R, policy: P, params: &SearchParams) -> Self {
        let mut tree = SearchTree::new();
        let prompt_tokens = lm.prompt_tokens();
        tree.init_root(prompt_tokens);
        let ledger = match lm.prompt_token_ids() {
            Some(ids) if !ids.is_empty() => engine.register_with_prompt(ids),
            _ => engine.register(prompt_tokens),
        };
        Self {
            lm,
            prm,
            policy,
            params: params.clone(),
            tree,
            ledger,
            frontier: Vec::new(),
            width: params.width,
            steps_taken: 0,
            metrics: Vec::new(),
            completions: Vec::new(),
            completed_leaves: Vec::new(),
            started: false,
            in_flight: None,
            pending: None,
            suspended: false,
            recompute_tokens: 0,
            width_override: None,
        }
    }

    pub fn tree(&self) -> &SearchTree {
        &self.tree
    }

    pub fn ledger(&self) -> &KvLedger {
        &self.ledger
    }

    pub fn metrics(&self) -> &[StepMetrics] {
        &self.metrics
    }

    /// A prepared step is waiting for (re)commit.
    pub fn has_pending(&self) -> bool {
        self.pending.is_some()
    }

    /// Leaf-expansion requests in the prepared step (0 when none pending).
    pub fn pending_requests(&self) -> usize {
        self.pending.as_ref().map(|p| p.requests.len()).unwrap_or(0)
    }

    /// True between [`SearchSession::suspend`] and a successful
    /// [`SearchSession::try_resume`].
    pub fn is_suspended(&self) -> bool {
        self.suspended
    }

    /// Tokens re-prefilled across this session's preemption round trips.
    pub fn recompute_tokens(&self) -> u64 {
        self.recompute_tokens
    }

    /// Committed steps so far (round barrier coordinate).
    pub fn steps_taken(&self) -> usize {
        self.steps_taken
    }

    /// The configured initial width N (denominator of budget decisions).
    pub fn base_width(&self) -> usize {
        self.params.width
    }

    /// Live width right now (shrinks as trajectories complete).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Configured step cap for this search.
    pub fn max_steps(&self) -> usize {
        self.params.max_steps
    }

    /// Schedule a width reallocation: at the first allocation with
    /// `steps_taken >= from_step`, shift the live width by
    /// `target − base_width` (clamped to >= 1). Delta form, so terminal
    /// completions that retire width between the barrier decision and its
    /// application keep their decrement. Overwrites any earlier pending
    /// override (the controller issues at most one).
    pub fn set_width_override(&mut self, from_step: usize, target: usize) {
        let delta = target as isize - self.params.width as isize;
        self.width_override = Some((from_step, delta));
    }

    /// Snapshot the committed difficulty telemetry for the budget
    /// controller. `None` before the first commit or once the frontier is
    /// empty — there is nothing actionable to score. Pure function of the
    /// committed tree: reads only frontier rewards and semantic ids, in
    /// frontier order, so the same committed state yields bit-identical
    /// floats on every shard layout and schedule.
    pub fn difficulty_signals(&self) -> Option<DifficultySignals> {
        if self.steps_taken == 0 || self.frontier.is_empty() {
            return None;
        }
        let rewards: Vec<f64> =
            self.frontier.iter().map(|&n| self.tree.get(n).reward).collect();
        let n = rewards.len();
        let sum: f64 = rewards.iter().sum();
        let reward_mean = sum / n as f64;
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &r in &rewards {
            lo = lo.min(r);
            hi = hi.max(r);
        }
        let entropy = if n <= 1 {
            0.0
        } else {
            // Softmax at the REBASE temperature over frontier rewards,
            // max-subtracted for stability, normalized by ln(n).
            const TEMP: f64 = 0.2;
            let z: f64 = rewards.iter().map(|&r| ((r - hi) / TEMP).exp()).sum();
            let mut h = 0.0;
            for &r in &rewards {
                let p = ((r - hi) / TEMP).exp() / z;
                if p > 0.0 {
                    h -= p * p.ln();
                }
            }
            h / (n as f64).ln()
        };
        let mut sems: Vec<u64> =
            self.frontier.iter().map(|&n| self.tree.get(n).step.sem).collect();
        sems.sort_unstable();
        sems.dedup();
        Some(DifficultySignals {
            steps_taken: self.steps_taken,
            frontier: n,
            reward_mean,
            reward_spread: hi - lo,
            entropy,
            sem_clusters: sems.len(),
        })
    }

    /// The next step's expansion batch. Prunes retired trajectories (policy
    /// drops, prior completions) from the tree *and* releases their KV in
    /// the engine's cache. Empty when the search is over.
    pub fn next_requests(&mut self, engine: &mut BatchEngine) -> Vec<ExpandRequest> {
        debug_assert!(self.pending.is_none(), "next_requests with a step pending");
        debug_assert!(self.in_flight.is_none(), "next_requests with a batch in flight");
        debug_assert!(!self.suspended, "next_requests on a suspended session");
        if !self.started {
            self.started = true;
            return vec![ExpandRequest { leaf: self.tree.root(), n: self.width }];
        }
        if self.steps_taken >= self.params.max_steps
            || self.width == 0
            || self.frontier.is_empty()
        {
            return Vec::new();
        }
        // Apply a pending budget-controller reallocation. This runs in
        // session-step coordinates (`steps_taken >= from_step`), not wall
        // time: whether the allocation happens in a lockstep plan, a
        // speculative async plan, or after a deferred commit, the same
        // committed step count triggers the same width — which is what
        // keeps adaptive mode byte-identical across serve schedules.
        if let Some((from, delta)) = self.width_override {
            if self.steps_taken >= from {
                self.width_override = None;
                self.width = (self.width as isize + delta).max(1) as usize;
            }
        }
        let alloc = self.policy.allocate(&self.tree, &self.frontier, self.width);
        debug_assert!(!alloc.is_empty(), "policy returned empty allocation");
        // Prune everything outside the allocated paths (completed
        // trajectories' exclusive KV is released here too).
        let keep: Vec<NodeId> = alloc.iter().map(|&(c, _)| c).collect();
        self.tree.retain_paths(&keep);
        engine.retire(&mut self.ledger, &keep);
        alloc.into_iter().map(|(leaf, n)| ExpandRequest { leaf, n }).collect()
    }

    /// Phase 1 of a step ([`SearchSession::submit`] + immediate
    /// [`SearchSession::collect`]): run the allocation through the generator
    /// as one batched call and hold the results. Advances the per-problem
    /// RNG exactly once — committing later (or after a preemption round
    /// trip) cannot change what was sampled.
    pub fn prepare(&mut self, engine: &mut BatchEngine, requests: &[ExpandRequest]) {
        self.submit(engine, requests);
        self.collect(engine);
    }

    /// Phase 1a: dispatch the allocation to the generator without waiting
    /// for the results (two-phase decode). The per-problem RNG advances
    /// *here* — a sync backend resolves the batch inside the returned
    /// handle, a pipelined backend starts decoding — so the schedule of the
    /// matching [`SearchSession::collect`] cannot change what was sampled.
    pub fn submit(&mut self, engine: &mut BatchEngine, requests: &[ExpandRequest]) {
        debug_assert!(self.pending.is_none(), "submit with a step already pending");
        debug_assert!(self.in_flight.is_none(), "submit with a batch in flight");
        debug_assert!(!self.suspended, "submit on a suspended session");
        let batch = engine.submit(&mut self.lm, &self.tree, requests);
        self.in_flight = Some((requests.to_vec(), batch));
    }

    /// Phase 1b: wait for the submitted batch and store it as the prepared
    /// step, ready for [`SearchSession::try_commit`].
    pub fn collect(&mut self, engine: &mut BatchEngine) {
        let (requests, batch) = self.in_flight.take().expect("collect without submit");
        let expansions = engine.poll(&mut self.lm, batch);
        self.pending = Some(PendingStep { requests, expansions });
    }

    /// A submitted decode batch awaits [`SearchSession::collect`].
    pub fn has_in_flight(&self) -> bool {
        self.in_flight.is_some()
    }

    /// Phase 2: reserve the worst-case block need of the prepared step and,
    /// only if that succeeds, mutate the tree, charge the KV
    /// (insert-on-expand), score with the PRM, and retire completions.
    /// `Err(KvPressure)` keeps the prepared step stored for a later retry —
    /// the engine, tree, and RNG streams are untouched.
    pub fn try_commit(&mut self, engine: &mut BatchEngine) -> Result<StepMetrics, KvPressure> {
        debug_assert!(!self.suspended, "commit on a suspended session");
        let need: usize = {
            let pending = self.pending.as_ref().expect("try_commit without prepare");
            pending
                .expansions
                .iter()
                .flat_map(|steps| steps.iter())
                .map(|s| {
                    engine.blocks_for_insert(
                        &self.ledger,
                        s.tokens,
                        !s.token_ids.is_empty(),
                    )
                })
                .sum()
        };
        engine.try_reserve(need)?;
        let PendingStep { requests, expansions } =
            self.pending.take().expect("pending checked above");
        let mut m = StepMetrics {
            frontier: if self.steps_taken == 0 { 1 } else { self.frontier.len() },
            ..Default::default()
        };
        let mut new_nodes: Vec<NodeId> = Vec::new();
        for (req, steps) in requests.iter().zip(expansions) {
            m.model_calls += steps.len();
            for s in steps {
                m.new_tokens += s.tokens;
                new_nodes.push(self.tree.add_child(req.leaf, s, 0.0));
            }
        }
        engine.commit_admit(&mut self.ledger, &mut self.tree, &new_nodes, need);
        let rewards = self.prm.score(&self.tree, &new_nodes);
        m.prm_calls = new_nodes.len();
        for (&n, &r) in new_nodes.iter().zip(&rewards) {
            self.tree.set_reward(n, r);
        }
        if self.steps_taken == 0 {
            self.policy.on_root_children(&new_nodes);
        }
        m.live_kv_tokens = engine.live_kv(&self.ledger);
        m.unshared_kv_tokens = engine.unshared_kv(&self.ledger);
        #[cfg(debug_assertions)]
        self.assert_cache_matches_tree(engine, &m);
        self.frontier.clear();
        for n in new_nodes {
            let (terminal, answer, reward) = {
                let node = self.tree.get(n);
                (node.step.terminal, node.step.answer, node.reward)
            };
            if terminal {
                if let Some(ans) = answer {
                    self.completions.push((ans, reward));
                }
                // A terminal step with no parsed answer is dropped from
                // voting but still retires its trajectory slot.
                self.completed_leaves.push(n);
                self.width = self.width.saturating_sub(1);
            } else {
                self.frontier.push(n);
            }
        }
        self.steps_taken += 1;
        self.metrics.push(m.clone());
        Ok(m)
    }

    /// Execute one step's allocation end to end (prepare + commit). For
    /// callers with ample capacity; on pressure it LRU-evicts and retries,
    /// then panics — the scheduler path uses
    /// [`SearchSession::prepare`]/[`SearchSession::try_commit`] and handles
    /// pressure with preemption instead.
    pub fn step(&mut self, engine: &mut BatchEngine, requests: &[ExpandRequest]) -> StepMetrics {
        self.prepare(engine, requests);
        match self.try_commit(engine) {
            Ok(m) => m,
            Err(p) => {
                engine.relieve(&p);
                self.try_commit(engine).unwrap_or_else(|p| {
                    panic!("KV block budget below a single step's need: {p}")
                })
            }
        }
    }

    /// Preemption hook: release every KV block this session pins (prompt
    /// included), keeping the search tree and any prepared step. Returns
    /// tokens whose pins were dropped.
    pub fn suspend(&mut self, engine: &mut BatchEngine) -> usize {
        debug_assert!(!self.suspended, "double suspend");
        debug_assert!(
            self.in_flight.is_none(),
            "suspend with a decode batch in flight: collect first"
        );
        let freed = engine.suspend(&mut self.ledger);
        self.suspended = true;
        freed
    }

    /// Engine-independent token sequences of this (suspended) session's
    /// working set: compute once, then size several candidate engines via
    /// [`SearchSession::resume_need_blocks_with`] without rebuilding them.
    pub(crate) fn suspended_sequences(&self) -> Vec<Vec<u32>> {
        debug_assert!(self.suspended, "sequences of a resident session");
        BatchEngine::suspended_sequences(&self.ledger, &self.tree)
    }

    /// Worst-case blocks a resume of this (suspended) session would reserve
    /// on `engine`, given the working-set sequences precomputed by
    /// [`SearchSession::suspended_sequences`]. A suspended session holds no
    /// cache node indices, so the estimate is valid against *any* engine —
    /// the sharded coordinator sizes a cross-shard migration by probing
    /// every candidate target shard's engine before moving the session.
    pub(crate) fn resume_need_blocks_with(
        &self,
        engine: &BatchEngine,
        seqs: &[Vec<u32>],
    ) -> usize {
        debug_assert!(self.suspended, "resume sizing on a resident session");
        engine.resume_need_blocks_for(&self.ledger, &self.tree, seqs)
    }

    /// Resume hook: reserve and rebuild the working set, recomputing
    /// whatever was evicted while suspended. Returns the recomputed token
    /// count; `Err(KvPressure)` leaves the session suspended. The engine
    /// need not be the one the session was suspended from — resuming
    /// through a *different* shard's cache simply recomputes the full
    /// prefix there, which is what makes cross-shard migration correct by
    /// construction.
    pub fn try_resume(&mut self, engine: &mut BatchEngine) -> Result<usize, KvPressure> {
        self.try_resume_imported(engine, None).map(|s| s.recomputed_tokens)
    }

    /// [`SearchSession::try_resume`] with an optional cross-shard
    /// [`ImportSource`]: the returned [`ResumeStats`] additionally reports
    /// how much of the recomputed span a peer held (importable as a block
    /// transfer — the scheduler's `min(transfer, recompute)` input). The
    /// session's own `recompute_tokens` ledger always counts the *full*
    /// recompute, import or not: search-level accounting must not depend on
    /// how the fleet happened to bill the rebuild.
    pub fn try_resume_imported(
        &mut self,
        engine: &mut BatchEngine,
        import: Option<ImportSource<'_>>,
    ) -> Result<ResumeStats, KvPressure> {
        debug_assert!(self.suspended, "resume without suspend");
        let stats = engine.try_resume_with(&mut self.ledger, &self.tree, import)?;
        self.suspended = false;
        self.recompute_tokens += stats.recomputed_tokens as u64;
        Ok(stats)
    }

    /// Token ids of this problem's prompt — what the coordinator publishes
    /// to the prefix hub and what prompt-affinity routing matches against.
    pub fn prompt_ids(&self) -> &[u32] {
        self.ledger.prompt_ids()
    }

    /// Full token sequences of this session's committed step-span ends
    /// (pinned leaves while resident, suspended leaves otherwise) — what
    /// the coordinator fingerprints into the prefix hub as mid-tree step
    /// spans next to the prompt.
    pub(crate) fn step_span_sequences(&self) -> Vec<Vec<u32>> {
        self.ledger
            .span_leaves()
            .into_iter()
            .map(|leaf| BatchEngine::sequence(&self.ledger, &self.tree, leaf))
            .collect()
    }

    /// Step-level invariant (debug builds): when every token id was minted
    /// by the engine, the cache's live-KV view must equal the sum of live
    /// tree step tokens exactly — the two accountings cannot drift.
    #[cfg(debug_assertions)]
    fn assert_cache_matches_tree(&self, engine: &BatchEngine, m: &StepMetrics) {
        if let Err(e) = engine.check_invariants() {
            panic!("radix cache invariant broken: {e}");
        }
        if !self.ledger.exact_accounting() {
            return; // real surface ids may dedup beyond tree-level sharing
        }
        let tree_live: usize = (0..self.tree.len())
            .filter(|&i| self.tree.get(i).live)
            .map(|i| self.tree.get(i).step.tokens)
            .sum();
        assert_eq!(
            m.live_kv_tokens, tree_live,
            "cache live-KV accounting drifted from the tree at step {}",
            self.steps_taken
        );
    }

    /// Release every KV pin the session still holds and fold the outcome.
    /// Sessions with real surface token ids close *lazily*
    /// ([`BatchEngine::close_keep_cached`]): their prompt KV stays warm and
    /// evictable so a later request with the same prompt re-pins it for
    /// free — the cross-request prefix reuse the serve scheduler's hub
    /// advertises. Minted-id sessions release eagerly (globally unique ids
    /// can never be shared, so warm retention would be pure garbage).
    pub fn finish(mut self, engine: &mut BatchEngine) -> SearchOutcome {
        if self.ledger.exact_accounting() {
            engine.close(&mut self.ledger);
        } else {
            engine.close_keep_cached(&mut self.ledger);
        }
        SearchOutcome {
            answer: weighted_majority(&self.completions),
            completions: self.completions,
            steps: self.metrics,
            tree: self.tree,
            completed_leaves: self.completed_leaves,
            recompute_tokens: self.recompute_tokens,
        }
    }
}

/// Run PRM-guided tree search for one problem on a fresh engine.
///
/// The loop mirrors the paper's setup: sample `width` continuations at the
/// root, then at each step let the policy allocate the remaining width over
/// the frontier (pruning the rest), expand, score with the PRM, and retire
/// completed trajectories (the width shrinks as trajectories finish, as in
/// REBASE). The final answer is weighted-majority over completions.
pub fn run_search<G: StepGenerator, R: RewardModel, P: SearchPolicy>(
    lm: &mut G,
    prm: &mut R,
    policy: &mut P,
    params: &SearchParams,
) -> SearchOutcome {
    let mut engine = BatchEngine::new(DEFAULT_KV_CAPACITY);
    run_search_on(&mut engine, lm, prm, policy, params)
}

/// Run one problem's search on an existing (possibly shared) engine.
pub fn run_search_on<G: StepGenerator, R: RewardModel, P: SearchPolicy>(
    engine: &mut BatchEngine,
    lm: &mut G,
    prm: &mut R,
    policy: &mut P,
    params: &SearchParams,
) -> SearchOutcome {
    let mut session = SearchSession::new(engine, lm, prm, policy, params);
    loop {
        let requests = session.next_requests(engine);
        if requests.is_empty() {
            break;
        }
        session.step(engine, &requests);
    }
    session.finish(engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::HashEmbedder;
    use crate::lm::SynthLm;
    use crate::reward::OraclePrm;
    use crate::search::policy::{BeamPolicy, EtsPolicy, RebasePolicy};
    use crate::tree::StepInfo;
    use crate::workload::{ProblemSet, WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

    fn setup(seed: u64) -> (SynthLm, OraclePrm) {
        let spec = WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM);
        let p = ProblemSet::generate(&spec, 1, seed).problems.remove(0);
        let prm = OraclePrm::for_profile(&p.spec.model.clone(), seed);
        (SynthLm::new(p, seed), prm)
    }

    #[test]
    fn search_completes_and_votes() {
        let (mut lm, mut prm) = setup(11);
        let mut pol = RebasePolicy::default();
        let params = SearchParams { width: 16, max_steps: 16 };
        let out = run_search(&mut lm, &mut prm, &mut pol, &params);
        assert!(out.answer.is_some());
        assert!(!out.completions.is_empty());
        assert!(out.steps.len() >= lm.problem.spec.dataset.n_steps - 1);
        assert!(out.total_kv_tokens() > 0);
        assert!(out.total_new_tokens() > 0);
        // every completion at roughly the right depth
        for &leaf in &out.completed_leaves {
            assert!(out.tree.get(leaf).step.terminal);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let (mut lm, mut prm) = setup(5);
            let mut pol = RebasePolicy::default();
            let params = SearchParams { width: 8, max_steps: 16 };
            let out = run_search(&mut lm, &mut prm, &mut pol, &params);
            (out.answer, out.total_kv_tokens(), out.total_new_tokens())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn shared_engine_matches_fresh_engine() {
        // Running on a shared engine (serve path) must not perturb results:
        // KV accounting is per-ledger and token ids never collide.
        let fresh = {
            let (mut lm, mut prm) = setup(7);
            let mut pol = RebasePolicy::default();
            let params = SearchParams { width: 8, max_steps: 16 };
            let out = run_search(&mut lm, &mut prm, &mut pol, &params);
            (out.answer, out.total_kv_tokens(), out.total_new_tokens())
        };
        let mut engine = BatchEngine::new(DEFAULT_KV_CAPACITY);
        // occupy the engine with another problem first
        let (mut lm0, mut prm0) = setup(3);
        let mut pol0 = RebasePolicy::default();
        let params = SearchParams { width: 8, max_steps: 16 };
        let _ = run_search_on(&mut engine, &mut lm0, &mut prm0, &mut pol0, &params);
        let (mut lm, mut prm) = setup(7);
        let mut pol = RebasePolicy::default();
        let out = run_search_on(&mut engine, &mut lm, &mut prm, &mut pol, &params);
        assert_eq!(fresh, (out.answer, out.total_kv_tokens(), out.total_new_tokens()));
        assert_eq!(engine.live_tokens(), 0, "finished searches must release all KV");
    }

    #[test]
    fn suspend_resume_between_every_step_changes_nothing() {
        // The preemption acid test: a session that is suspended and resumed
        // between every single step (and with a prepared step pending) must
        // produce byte-identical results to an undisturbed run.
        let params = SearchParams { width: 8, max_steps: 16 };
        let undisturbed = {
            let (mut lm, mut prm) = setup(13);
            let mut pol = RebasePolicy::default();
            let out = run_search(&mut lm, &mut prm, &mut pol, &params);
            (out.answer, out.total_kv_tokens(), out.total_new_tokens(), out.steps.len())
        };
        let mut engine = BatchEngine::new(DEFAULT_KV_CAPACITY);
        let (lm, prm) = setup(13);
        let mut session =
            SearchSession::new(&mut engine, lm, prm, RebasePolicy::default(), &params);
        let mut flip = false;
        loop {
            let requests = session.next_requests(&mut engine);
            if requests.is_empty() {
                break;
            }
            session.prepare(&mut engine, &requests);
            // alternate: preempt before commit / after commit; evicting the
            // whole unpinned working set while suspended forces the resume
            // down the recompute path (a warm resume would be free)
            if flip {
                session.suspend(&mut engine);
                engine.relieve_pressure(usize::MAX);
                session.try_resume(&mut engine).unwrap();
                session.try_commit(&mut engine).unwrap();
            } else {
                session.try_commit(&mut engine).unwrap();
                session.suspend(&mut engine);
                engine.relieve_pressure(usize::MAX);
                session.try_resume(&mut engine).unwrap();
            }
            flip = !flip;
        }
        let out = session.finish(&mut engine);
        assert_eq!(
            undisturbed,
            (out.answer, out.total_kv_tokens(), out.total_new_tokens(), out.steps.len()),
            "preemption round trips changed search results"
        );
        assert!(out.recompute_tokens > 0, "resumes must have recomputed KV");
        assert_eq!(engine.live_tokens(), 0);
        engine.check_invariants().unwrap();
    }

    #[test]
    fn split_submit_collect_matches_prepare() {
        // Driving a session through the explicit two-phase decode surface
        // (submit … collect … commit) must be byte-identical to the fused
        // prepare path — the RNG advances at submit time in both.
        let params = SearchParams { width: 8, max_steps: 16 };
        let fused = {
            let (mut lm, mut prm) = setup(17);
            let mut pol = RebasePolicy::default();
            let out = run_search(&mut lm, &mut prm, &mut pol, &params);
            (out.answer, out.total_kv_tokens(), out.total_new_tokens(), out.steps.len())
        };
        let mut engine = BatchEngine::new(DEFAULT_KV_CAPACITY);
        let (lm, prm) = setup(17);
        let mut session =
            SearchSession::new(&mut engine, lm, prm, RebasePolicy::default(), &params);
        loop {
            let requests = session.next_requests(&mut engine);
            if requests.is_empty() {
                break;
            }
            session.submit(&mut engine, &requests);
            assert!(session.has_in_flight());
            assert!(!session.has_pending());
            session.collect(&mut engine);
            assert!(!session.has_in_flight());
            assert!(session.has_pending());
            session.try_commit(&mut engine).unwrap();
        }
        let out = session.finish(&mut engine);
        assert_eq!(
            fused,
            (out.answer, out.total_kv_tokens(), out.total_new_tokens(), out.steps.len()),
            "two-phase decode changed search results"
        );
        assert_eq!(engine.live_tokens(), 0);
    }

    #[test]
    fn deferred_commit_after_pressure_is_lossless() {
        // A commit that fails on a tiny budget must leave the prepared step
        // intact; retrying after relief commits the identical step.
        let params = SearchParams { width: 6, max_steps: 8 };
        let undisturbed = {
            let (mut lm, mut prm) = setup(21);
            let mut pol = RebasePolicy::default();
            let out = run_search(&mut lm, &mut prm, &mut pol, &params);
            (out.answer, out.total_kv_tokens(), out.total_new_tokens())
        };
        // budget: enough for one problem's working set (measured in the
        // undisturbed run: a few thousand tokens), not for hoarded garbage
        let mut engine = BatchEngine::with_block_size(1 << 22, 16);
        let (lm, prm) = setup(21);
        let mut session =
            SearchSession::new(&mut engine, lm, prm, RebasePolicy::default(), &params);
        loop {
            let requests = session.next_requests(&mut engine);
            if requests.is_empty() {
                break;
            }
            session.prepare(&mut engine, &requests);
            assert!(session.has_pending());
            // commit must succeed here (ample budget) — the pending-step
            // bookkeeping is what we exercise
            session.try_commit(&mut engine).unwrap();
            assert!(!session.has_pending());
        }
        let out = session.finish(&mut engine);
        assert_eq!(undisturbed, (out.answer, out.total_kv_tokens(), out.total_new_tokens()));
    }

    #[test]
    fn beam_shares_more_kv_than_rebase() {
        // Averaged over problems: beam retains few paths → more sharing →
        // lower total KV than REBASE at the same width.
        let mut beam_kv = 0u64;
        let mut rebase_kv = 0u64;
        for seed in 0..8 {
            let params = SearchParams { width: 32, max_steps: 16 };
            let (mut lm, mut prm) = setup(seed);
            let mut bp = BeamPolicy { keep: 4 };
            beam_kv += run_search(&mut lm, &mut prm, &mut bp, &params).total_kv_tokens();
            let (mut lm, mut prm) = setup(seed);
            let mut rp = RebasePolicy::default();
            rebase_kv += run_search(&mut lm, &mut prm, &mut rp, &params).total_kv_tokens();
        }
        assert!(
            beam_kv < rebase_kv,
            "beam total KV {beam_kv} should be below REBASE {rebase_kv}"
        );
    }

    #[test]
    fn ets_reduces_kv_vs_rebase() {
        let mut ets_kv = 0u64;
        let mut rebase_kv = 0u64;
        for seed in 0..8 {
            let params = SearchParams { width: 32, max_steps: 16 };
            let (mut lm, mut prm) = setup(seed);
            let mut ep = EtsPolicy::new(1.5, 1.0, HashEmbedder::default());
            ets_kv += run_search(&mut lm, &mut prm, &mut ep, &params).total_kv_tokens();
            let (mut lm, mut prm) = setup(seed);
            let mut rp = RebasePolicy::default();
            rebase_kv += run_search(&mut lm, &mut prm, &mut rp, &params).total_kv_tokens();
        }
        assert!(
            (ets_kv as f64) < 0.95 * rebase_kv as f64,
            "ETS total KV {ets_kv} should undercut REBASE {rebase_kv}"
        );
    }

    #[test]
    fn shared_kv_never_exceeds_unshared() {
        let (mut lm, mut prm) = setup(3);
        let mut pol = RebasePolicy::default();
        let params = SearchParams { width: 16, max_steps: 16 };
        let out = run_search(&mut lm, &mut prm, &mut pol, &params);
        for s in &out.steps {
            assert!(s.live_kv_tokens >= 1);
            // unshared counts only frontier paths; live includes them plus
            // shared ancestors — live <= unshared + prompt slack is the
            // meaningful direction once frontier is non-trivial
            if s.frontier > 1 {
                assert!(
                    s.live_kv_tokens <= s.unshared_kv_tokens + 1000,
                    "{s:?}"
                );
            }
        }
    }

    /// A generator that emits terminal steps with *no parsed answer*: the
    /// driver must drop them from voting instead of panicking (regression
    /// for the `answer.unwrap()` crash).
    struct NoAnswerLm {
        emitted: usize,
    }

    impl StepGenerator for NoAnswerLm {
        fn expand(&mut self, _tree: &SearchTree, _leaf: NodeId, n: usize) -> Vec<StepInfo> {
            (0..n)
                .map(|i| {
                    self.emitted += 1;
                    let parsed = self.emitted % 2 == 0;
                    StepInfo {
                        tokens: 5,
                        sem: i as u64,
                        paraphrase: self.emitted as u64,
                        terminal: true,
                        answer: if parsed { Some(42) } else { None },
                        path_id: self.emitted as u64,
                        alive: true,
                        ..Default::default()
                    }
                })
                .collect()
        }

        fn prompt_tokens(&self) -> usize {
            10
        }
    }

    #[test]
    fn unparsed_terminal_answers_are_dropped_not_fatal() {
        let mut lm = NoAnswerLm { emitted: 0 };
        let mut prm = OraclePrm::new(1.0, 0.1, 9);
        let mut pol = RebasePolicy::default();
        let params = SearchParams { width: 6, max_steps: 4 };
        let out = run_search(&mut lm, &mut prm, &mut pol, &params);
        assert_eq!(out.completed_leaves.len(), 6, "all trajectories completed");
        assert_eq!(out.completions.len(), 3, "only parsed answers vote");
        assert_eq!(out.answer, Some(42));
    }
}
