//! The PRM-guided tree-search driver: runs one problem to completion under a
//! policy, recording the efficiency metrics the paper's evaluation reports.

use crate::reward::RewardModel;
use crate::search::policy::SearchPolicy;
use crate::search::voting::{weighted_majority, Completion};
use crate::lm::StepGenerator;
use crate::tree::{NodeId, SearchTree};

/// Per-search-step efficiency record.
#[derive(Clone, Debug, Default)]
pub struct StepMetrics {
    /// Live unique KV tokens during this step (radix-shared; the paper's
    /// per-step KV cache size).
    pub live_kv_tokens: usize,
    /// KV tokens if every trajectory kept a private copy (no sharing).
    pub unshared_kv_tokens: usize,
    /// Tokens generated this step (FLOPs proxy).
    pub new_tokens: usize,
    /// Continuations sampled this step (model calls).
    pub model_calls: usize,
    /// Frontier size entering the step.
    pub frontier: usize,
    /// PRM scoring calls this step.
    pub prm_calls: usize,
}

/// Outcome of one problem's search.
#[derive(Clone, Debug)]
pub struct SearchOutcome {
    /// Weighted-majority answer (None if nothing completed — shouldn't
    /// happen within `max_steps`).
    pub answer: Option<i64>,
    pub completions: Vec<Completion>,
    pub steps: Vec<StepMetrics>,
    pub tree: SearchTree,
    /// Leaf node of every completed trajectory (for engine replay).
    pub completed_leaves: Vec<NodeId>,
}

impl SearchOutcome {
    /// Σ per-step live KV — the paper's "total KV cache size" metric.
    pub fn total_kv_tokens(&self) -> u64 {
        self.steps.iter().map(|s| s.live_kv_tokens as u64).sum()
    }

    pub fn total_unshared_kv_tokens(&self) -> u64 {
        self.steps.iter().map(|s| s.unshared_kv_tokens as u64).sum()
    }

    /// Total generated tokens (the FLOPs proxy used by prior work).
    pub fn total_new_tokens(&self) -> u64 {
        self.steps.iter().map(|s| s.new_tokens as u64).sum()
    }

    pub fn total_model_calls(&self) -> u64 {
        self.steps.iter().map(|s| s.model_calls as u64).sum()
    }

    /// Peak live KV across steps (memory high-water mark).
    pub fn peak_kv_tokens(&self) -> u64 {
        self.steps.iter().map(|s| s.live_kv_tokens as u64).max().unwrap_or(0)
    }
}

/// Search configuration for one run.
#[derive(Clone, Debug)]
pub struct SearchParams {
    /// Initial width N (continuations sampled at the root).
    pub width: usize,
    /// Safety cap on steps (>= dataset n_steps + slack).
    pub max_steps: usize,
}

impl Default for SearchParams {
    fn default() -> Self {
        Self { width: 16, max_steps: 24 }
    }
}

/// Run PRM-guided tree search for one problem.
///
/// The loop mirrors the paper's setup: sample `width` continuations at the
/// root, then at each step let the policy allocate the remaining width over
/// the frontier (pruning the rest), expand, score with the PRM, and retire
/// completed trajectories (the width shrinks as trajectories finish, as in
/// REBASE). The final answer is weighted-majority over completions.
pub fn run_search<G: StepGenerator, R: RewardModel, P: SearchPolicy>(
    lm: &mut G,
    prm: &mut R,
    policy: &mut P,
    params: &SearchParams,
) -> SearchOutcome {
    let mut tree = SearchTree::new();
    let root = tree.init_root(lm.prompt_tokens());
    let mut metrics: Vec<StepMetrics> = Vec::new();
    let mut completions: Vec<Completion> = Vec::new();
    let mut completed_leaves: Vec<NodeId> = Vec::new();
    let mut width = params.width;

    // ---- root expansion ----
    let mut frontier: Vec<NodeId> = Vec::new();
    {
        let steps = lm.expand(&tree, root, width);
        let mut m = StepMetrics { frontier: 1, model_calls: steps.len(), ..Default::default() };
        let mut new_nodes = Vec::with_capacity(steps.len());
        for s in steps {
            m.new_tokens += s.tokens;
            new_nodes.push(tree.add_child(root, s, 0.0));
        }
        let rewards = prm.score(&tree, &new_nodes);
        m.prm_calls = new_nodes.len();
        for (&n, &r) in new_nodes.iter().zip(&rewards) {
            tree.get_mut(n).reward = r;
        }
        policy.on_root_children(&new_nodes);
        m.live_kv_tokens = tree.live_kv_tokens();
        m.unshared_kv_tokens = tree.unshared_kv_tokens(&new_nodes);
        for n in new_nodes {
            let node = tree.get(n);
            if node.step.terminal {
                completions.push((node.step.answer.unwrap(), node.reward));
                completed_leaves.push(n);
                width = width.saturating_sub(1);
            } else {
                frontier.push(n);
            }
        }
        metrics.push(m);
    }

    // ---- search steps ----
    for _ in 1..params.max_steps {
        if width == 0 || frontier.is_empty() {
            break;
        }
        let alloc = policy.allocate(&tree, &frontier, width);
        debug_assert!(!alloc.is_empty(), "policy returned empty allocation");
        // Prune everything outside the allocated paths (completed
        // trajectories' exclusive KV is freed here too).
        let keep: Vec<NodeId> = alloc.iter().map(|&(c, _)| c).collect();
        tree.retain_paths(&keep);

        let mut m = StepMetrics { frontier: frontier.len(), ..Default::default() };
        let mut new_nodes: Vec<NodeId> = Vec::new();
        for &(leaf, n) in &alloc {
            let steps = lm.expand(&tree, leaf, n);
            m.model_calls += steps.len();
            for s in steps {
                m.new_tokens += s.tokens;
                new_nodes.push(tree.add_child(leaf, s, 0.0));
            }
        }
        let rewards = prm.score(&tree, &new_nodes);
        m.prm_calls = new_nodes.len();
        for (&n, &r) in new_nodes.iter().zip(&rewards) {
            tree.get_mut(n).reward = r;
        }
        m.live_kv_tokens = tree.live_kv_tokens();
        m.unshared_kv_tokens = tree.unshared_kv_tokens(&new_nodes);
        frontier.clear();
        for n in new_nodes {
            let node = tree.get(n);
            if node.step.terminal {
                completions.push((node.step.answer.unwrap(), node.reward));
                completed_leaves.push(n);
                width = width.saturating_sub(1);
            } else {
                frontier.push(n);
            }
        }
        metrics.push(m);
    }

    SearchOutcome {
        answer: weighted_majority(&completions),
        completions,
        steps: metrics,
        tree,
        completed_leaves,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::HashEmbedder;
    use crate::lm::SynthLm;
    use crate::reward::OraclePrm;
    use crate::search::policy::{BeamPolicy, EtsPolicy, RebasePolicy};
    use crate::workload::{ProblemSet, WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

    fn setup(seed: u64) -> (SynthLm, OraclePrm) {
        let spec = WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM);
        let p = ProblemSet::generate(&spec, 1, seed).problems.remove(0);
        let prm = OraclePrm::for_profile(&p.spec.model.clone(), seed);
        (SynthLm::new(p, seed), prm)
    }

    #[test]
    fn search_completes_and_votes() {
        let (mut lm, mut prm) = setup(11);
        let mut pol = RebasePolicy::default();
        let params = SearchParams { width: 16, max_steps: 16 };
        let out = run_search(&mut lm, &mut prm, &mut pol, &params);
        assert!(out.answer.is_some());
        assert!(!out.completions.is_empty());
        assert!(out.steps.len() >= lm.problem.spec.dataset.n_steps - 1);
        assert!(out.total_kv_tokens() > 0);
        assert!(out.total_new_tokens() > 0);
        // every completion at roughly the right depth
        for &leaf in &out.completed_leaves {
            assert!(out.tree.get(leaf).step.terminal);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let (mut lm, mut prm) = setup(5);
            let mut pol = RebasePolicy::default();
            let params = SearchParams { width: 8, max_steps: 16 };
            let out = run_search(&mut lm, &mut prm, &mut pol, &params);
            (out.answer, out.total_kv_tokens(), out.total_new_tokens())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn beam_shares_more_kv_than_rebase() {
        // Averaged over problems: beam retains few paths → more sharing →
        // lower total KV than REBASE at the same width.
        let mut beam_kv = 0u64;
        let mut rebase_kv = 0u64;
        for seed in 0..8 {
            let params = SearchParams { width: 32, max_steps: 16 };
            let (mut lm, mut prm) = setup(seed);
            let mut bp = BeamPolicy { keep: 4 };
            beam_kv += run_search(&mut lm, &mut prm, &mut bp, &params).total_kv_tokens();
            let (mut lm, mut prm) = setup(seed);
            let mut rp = RebasePolicy::default();
            rebase_kv += run_search(&mut lm, &mut prm, &mut rp, &params).total_kv_tokens();
        }
        assert!(
            beam_kv < rebase_kv,
            "beam total KV {beam_kv} should be below REBASE {rebase_kv}"
        );
    }

    #[test]
    fn ets_reduces_kv_vs_rebase() {
        let mut ets_kv = 0u64;
        let mut rebase_kv = 0u64;
        for seed in 0..8 {
            let params = SearchParams { width: 32, max_steps: 16 };
            let (mut lm, mut prm) = setup(seed);
            let mut ep = EtsPolicy::new(1.5, 1.0, HashEmbedder::default());
            ets_kv += run_search(&mut lm, &mut prm, &mut ep, &params).total_kv_tokens();
            let (mut lm, mut prm) = setup(seed);
            let mut rp = RebasePolicy::default();
            rebase_kv += run_search(&mut lm, &mut prm, &mut rp, &params).total_kv_tokens();
        }
        assert!(
            (ets_kv as f64) < 0.95 * rebase_kv as f64,
            "ETS total KV {ets_kv} should undercut REBASE {rebase_kv}"
        );
    }

    #[test]
    fn shared_kv_never_exceeds_unshared() {
        let (mut lm, mut prm) = setup(3);
        let mut pol = RebasePolicy::default();
        let params = SearchParams { width: 16, max_steps: 16 };
        let out = run_search(&mut lm, &mut prm, &mut pol, &params);
        for s in &out.steps {
            assert!(s.live_kv_tokens >= 1);
            // unshared counts only frontier paths; live includes them plus
            // shared ancestors — live <= unshared + prompt slack is the
            // meaningful direction once frontier is non-trivial
            if s.frontier > 1 {
                assert!(
                    s.live_kv_tokens <= s.unshared_kv_tokens + 1000,
                    "{s:?}"
                );
            }
        }
    }
}
