//! REBASE balanced sampling (paper Eq. 1 / Eq. 3) and allocation rounding.

use crate::util::stats::softmax;

/// Raw REBASE weights: `W_i = ceil(N * softmax(R / T_R)_i)` (Eq. 1).
pub fn rebase_weights_raw(rewards: &[f64], n: usize, temp: f64) -> Vec<usize> {
    assert!(temp > 0.0);
    let scaled: Vec<f64> = rewards.iter().map(|r| r / temp).collect();
    softmax(&scaled)
        .into_iter()
        .map(|p| (n as f64 * p).ceil().max(1.0) as usize)
        .collect()
}

/// REBASE allocation: Eq. 1 weights adjusted so the total equals `n`
/// (the open-source REBASE trims the ceil overshoot).
///
/// * `n >= k`: every candidate keeps >= 1 (balanced sampling); the overshoot
///   is trimmed from the most over-allocated (vs. its exact share `N*p_i`)
///   candidates, lowest reward first on ties.
/// * `n < k`: only the top-`n` candidates by reward get one continuation.
pub fn rebase_allocate(rewards: &[f64], n: usize, temp: f64) -> Vec<usize> {
    let k = rewards.len();
    if k == 0 || n == 0 {
        return vec![0; k];
    }
    // ascending-reward order (trim / drop victims first)
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| rewards[a].partial_cmp(&rewards[b]).unwrap());
    if n < k {
        let mut w = vec![0; k];
        for &c in order.iter().rev().take(n) {
            w[c] = 1;
        }
        return w;
    }
    let scaled: Vec<f64> = rewards.iter().map(|r| r / temp).collect();
    let p = softmax(&scaled);
    let mut w = rebase_weights_raw(rewards, n, temp);
    let mut total: usize = w.iter().sum();
    // Trim overshoot: victim = most over-allocated with w > 1 (exact share
    // as the reference), scanning ascending reward so ties hit low reward.
    while total > n {
        let mut victim = None;
        let mut worst = f64::NEG_INFINITY;
        for &c in &order {
            if w[c] > 1 {
                let over = w[c] as f64 - n as f64 * p[c];
                if over > worst + 1e-12 {
                    worst = over;
                    victim = Some(c);
                }
            }
        }
        match victim {
            Some(c) => {
                w[c] -= 1;
                total -= 1;
            }
            None => break, // all at 1 and still > n can't happen when n >= k
        }
    }
    // Top-up if ceil under-shot (can't happen, but keep the invariant).
    while total < n {
        let c = *order.last().unwrap();
        w[c] += 1;
        total += 1;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;

    #[test]
    fn weights_favor_high_reward() {
        let w = rebase_allocate(&[0.9, 0.5, 0.1], 16, 0.2);
        assert_eq!(w.iter().sum::<usize>(), 16);
        assert!(w[0] > w[1] && w[1] >= w[2], "{w:?}");
        assert!(w[2] >= 1, "balanced sampling keeps low-reward alive: {w:?}");
    }

    #[test]
    fn high_temp_is_nearly_uniform() {
        let w = rebase_allocate(&[0.9, 0.5, 0.1], 30, 100.0);
        assert_eq!(w.iter().sum::<usize>(), 30);
        let (mn, mx) = (w.iter().min().unwrap(), w.iter().max().unwrap());
        assert!(mx - mn <= 2, "{w:?}");
    }

    #[test]
    fn low_temp_concentrates() {
        let w = rebase_allocate(&[0.9, 0.5, 0.1], 30, 0.01);
        assert!(w[0] >= 28, "{w:?}");
    }

    #[test]
    fn budget_below_candidates_drops_lowest() {
        let w = rebase_allocate(&[0.9, 0.8, 0.2, 0.1], 2, 0.2);
        assert_eq!(w.iter().sum::<usize>(), 2);
        assert_eq!(w[3], 0);
    }

    #[test]
    fn single_candidate_gets_everything() {
        assert_eq!(rebase_allocate(&[0.5], 7, 0.2), vec![7]);
    }

    #[test]
    fn prop_allocation_sums_to_n_and_respects_order() {
        property(100, |rng: &mut Rng| {
            let k = 1 + rng.index(32);
            let n = 1 + rng.index(256);
            let rewards: Vec<f64> = (0..k).map(|_| rng.f64()).collect();
            let w = rebase_allocate(&rewards, n, 0.2);
            crate::prop_check!(w.iter().sum::<usize>() == n, "sum {w:?} != {n}");
            // monotone: higher reward never gets strictly fewer... allocation
            // ties can differ by 1 from trimming, so allow slack of 1.
            for a in 0..k {
                for b in 0..k {
                    if rewards[a] > rewards[b] {
                        crate::prop_check!(
                            w[a] + 1 >= w[b],
                            "non-monotone: r{}={} w={} vs r{}={} w={}",
                            a,
                            rewards[a],
                            w[a],
                            b,
                            rewards[b],
                            w[b]
                        );
                    }
                }
            }
            Ok(())
        });
    }
}
