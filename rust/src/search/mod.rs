//! PRM-guided tree search: the policies (beam / DVTS / REBASE / **ETS**),
//! the REBASE sampling math, the driver loop, and answer aggregation.

pub mod driver;
pub mod policy;
pub mod sampling;
pub mod voting;

pub use driver::{
    run_search, run_search_on, SearchOutcome, SearchParams, SearchSession, StepMetrics,
};
pub use policy::{Allocation, BeamPolicy, DvtsPolicy, EtsPolicy, RebasePolicy, SearchPolicy};
