//! Search policies: how many continuations each frontier leaf receives.
//!
//! A policy sees only the tree topology and PRM rewards (never workload
//! latents) and returns `(leaf, n_continuations)` allocations summing to the
//! current width. Leaves absent from the allocation are pruned (their
//! exclusive KV is freed).

use crate::cluster::agglomerative;
use crate::embed::Embedder;
use crate::ilp::select::{solve_tree, Candidate, SelectionProblem};
use crate::search::sampling::rebase_allocate;
use crate::tree::{NodeId, SearchTree};
use std::collections::HashMap;
use std::time::Duration;

/// Allocation decision for one search step.
pub type Allocation = Vec<(NodeId, usize)>;

pub trait SearchPolicy {
    /// Allocate `width` continuations across `candidates` (non-terminal
    /// frontier leaves, all live). Must return a non-empty allocation with
    /// positive counts summing to <= width (== width unless impossible).
    fn allocate(&mut self, tree: &SearchTree, candidates: &[NodeId], width: usize) -> Allocation;

    fn name(&self) -> String;

    /// DVTS-style policies need to tag root expansions with subtree ids.
    fn on_root_children(&mut self, _children: &[NodeId]) {}

    /// Fraction of the full `width`-trajectory frontier working set this
    /// policy is expected to keep resident per step — the *predicted KV
    /// footprint* unit the serve admission router balances across shards
    /// instead of raw resident-session counts (ETS policies shrink it, so
    /// footprint-aware placement cuts downstream migrations). A relative
    /// load estimate, not a reservation: it never gates capacity, only
    /// breaks routing ties, so a misestimate costs placement quality —
    /// never correctness. Default: 1.0 (REBASE keeps everything).
    ///
    /// This static heuristic is also the *seed* of the serve scheduler's
    /// online calibration
    /// ([`crate::coordinator::budget::RetentionCalibration`]): under
    /// `--adaptive-budget` the fleet replaces it with the observed
    /// retained-leaves/width ratio per policy name once committed
    /// telemetry exists, and routes admissions by the calibrated value.
    fn kv_retention(&self, _width: usize) -> f64 {
        1.0
    }
}

impl<P: SearchPolicy + ?Sized> SearchPolicy for &mut P {
    fn allocate(&mut self, tree: &SearchTree, candidates: &[NodeId], width: usize) -> Allocation {
        (**self).allocate(tree, candidates, width)
    }

    fn name(&self) -> String {
        (**self).name()
    }

    fn on_root_children(&mut self, children: &[NodeId]) {
        (**self).on_root_children(children)
    }

    fn kv_retention(&self, width: usize) -> f64 {
        (**self).kv_retention(width)
    }
}

/// Boxed policies — covers `Box<dyn SearchPolicy>` (heterogeneous eval
/// sweeps on the solo `run_search` path) and `Box<dyn SearchPolicy + Send>`
/// (the sharded serve path, where sessions and their policies move between
/// worker threads and migrate across shards).
impl<P: SearchPolicy + ?Sized> SearchPolicy for Box<P> {
    fn allocate(&mut self, tree: &SearchTree, candidates: &[NodeId], width: usize) -> Allocation {
        (**self).allocate(tree, candidates, width)
    }

    fn name(&self) -> String {
        (**self).name()
    }

    fn on_root_children(&mut self, children: &[NodeId]) {
        (**self).on_root_children(children)
    }

    fn kv_retention(&self, width: usize) -> f64 {
        (**self).kv_retention(width)
    }
}

fn rewards_of(tree: &SearchTree, candidates: &[NodeId]) -> Vec<f64> {
    candidates.iter().map(|&c| tree.get(c).reward).collect()
}

/// Top-k beam search: retain the `keep` best candidates, split the width
/// evenly among them (Snell et al. '24 setup).
pub struct BeamPolicy {
    pub keep: usize,
}

impl SearchPolicy for BeamPolicy {
    fn allocate(&mut self, tree: &SearchTree, candidates: &[NodeId], width: usize) -> Allocation {
        let rewards = rewards_of(tree, candidates);
        let mut order: Vec<usize> = (0..candidates.len()).collect();
        order.sort_by(|&a, &b| rewards[b].partial_cmp(&rewards[a]).unwrap());
        let keep = self.keep.max(1).min(candidates.len()).min(width.max(1));
        let base = width / keep;
        let extra = width % keep;
        order
            .into_iter()
            .take(keep)
            .enumerate()
            .map(|(rank, idx)| (candidates[idx], base + usize::from(rank < extra)))
            .filter(|&(_, n)| n > 0)
            .collect()
    }

    fn name(&self) -> String {
        format!("beam-{}", self.keep)
    }

    fn kv_retention(&self, width: usize) -> f64 {
        (self.keep.max(1) as f64 / width.max(1) as f64).min(1.0)
    }
}

/// Diverse Verifier Tree Search: the root expansion is segmented into
/// `subtrees` independent groups; within each group, beam search retains the
/// single best candidate per step (Beeching et al. '24: #subtrees ==
/// #trajectories retained per step).
pub struct DvtsPolicy {
    pub subtrees: usize,
    /// node -> subtree id, propagated to descendants lazily.
    assignment: HashMap<NodeId, usize>,
}

impl DvtsPolicy {
    pub fn new(subtrees: usize) -> Self {
        Self { subtrees: subtrees.max(1), assignment: HashMap::new() }
    }

    fn subtree_of(&mut self, tree: &SearchTree, node: NodeId) -> usize {
        if let Some(&s) = self.assignment.get(&node) {
            return s;
        }
        let parent = tree.get(node).parent.expect("unassigned root in DVTS");
        let s = self.subtree_of(tree, parent);
        self.assignment.insert(node, s);
        s
    }
}

impl SearchPolicy for DvtsPolicy {
    fn on_root_children(&mut self, children: &[NodeId]) {
        // Round-robin the initial continuations over subtrees.
        for (i, &c) in children.iter().enumerate() {
            self.assignment.insert(c, i % self.subtrees);
        }
    }

    fn allocate(&mut self, tree: &SearchTree, candidates: &[NodeId], width: usize) -> Allocation {
        // Group candidates by subtree; best candidate per subtree survives.
        let mut best: HashMap<usize, (NodeId, f64)> = HashMap::new();
        for &c in candidates {
            let s = self.subtree_of(tree, c);
            let r = tree.get(c).reward;
            match best.get(&s) {
                Some(&(_, br)) if br >= r => {}
                _ => {
                    best.insert(s, (c, r));
                }
            }
        }
        let mut winners: Vec<(usize, NodeId)> =
            best.into_iter().map(|(s, (c, _))| (s, c)).collect();
        winners.sort_unstable(); // deterministic order by subtree id
        let k = winners.len();
        let base = width / k;
        let extra = width % k;
        winners
            .into_iter()
            .enumerate()
            .map(|(rank, (_, c))| (c, base + usize::from(rank < extra)))
            .filter(|&(_, n)| n > 0)
            .collect()
    }

    fn name(&self) -> String {
        format!("dvts-{}", self.subtrees)
    }

    fn kv_retention(&self, width: usize) -> f64 {
        // one retained trajectory per subtree
        (self.subtrees as f64 / width.max(1) as f64).min(1.0)
    }
}

/// REBASE (Wu et al. '24): balanced softmax allocation over PRM rewards.
pub struct RebasePolicy {
    pub temp: f64,
}

impl Default for RebasePolicy {
    fn default() -> Self {
        Self { temp: 0.2 }
    }
}

impl SearchPolicy for RebasePolicy {
    fn allocate(&mut self, tree: &SearchTree, candidates: &[NodeId], width: usize) -> Allocation {
        let rewards = rewards_of(tree, candidates);
        let w = rebase_allocate(&rewards, width, self.temp);
        candidates
            .iter()
            .zip(w)
            .filter(|&(_, n)| n > 0)
            .map(|(&c, n)| (c, n))
            .collect()
    }

    fn name(&self) -> String {
        "rebase".into()
    }
}

/// ETS (this paper): REBASE weights, then the ILP cost model (Eq. 4) prunes
/// candidates to promote KV sharing while the coverage term preserves
/// semantically diverse trajectories; survivors are re-weighted (Eq. 3).
pub struct EtsPolicy<E: Embedder> {
    pub temp: f64,
    pub lambda_b: f64,
    pub lambda_d: f64,
    /// Cosine-distance threshold for the agglomerative clustering cut.
    pub cluster_threshold: f64,
    pub embedder: E,
    /// Wall-clock budget for the exact solver (incumbent returned on expiry).
    pub solver_budget: Duration,
    /// Telemetry: candidates pruned by the cost model so far.
    pub pruned_total: u64,
}

impl<E: Embedder> EtsPolicy<E> {
    pub fn new(lambda_b: f64, lambda_d: f64, embedder: E) -> Self {
        Self {
            temp: 0.2,
            lambda_b,
            lambda_d,
            cluster_threshold: 0.3,
            embedder,
            solver_budget: Duration::from_millis(10),
            pruned_total: 0,
        }
    }
}

impl<E: Embedder> SearchPolicy for EtsPolicy<E> {
    fn allocate(&mut self, tree: &SearchTree, candidates: &[NodeId], width: usize) -> Allocation {
        let rewards = rewards_of(tree, candidates);
        // Eq. 1 weights = the "value" of retaining each trajectory.
        let weights = rebase_allocate(&rewards, width, self.temp);
        // Candidates that REBASE itself would drop (n < k) are excluded.
        let active: Vec<usize> =
            (0..candidates.len()).filter(|&i| weights[i] > 0).collect();
        if active.len() <= 1 {
            return active.iter().map(|&i| (candidates[i], width)).collect();
        }
        // Cluster the latest steps of the active candidates.
        let nodes: Vec<NodeId> = active.iter().map(|&i| candidates[i]).collect();
        let (clusters, num_clusters) = if self.lambda_d > 0.0 {
            let embs = self.embedder.embed(tree, &nodes);
            let c = agglomerative(&embs, self.cluster_threshold);
            (c.assignment, c.num_clusters)
        } else {
            // ETS-KV ablation: coverage term disabled; one dummy cluster.
            (vec![0; nodes.len()], 1)
        };
        // Selection problem over the spanned live subtree. Node costs are
        // KV-token weighted (Eq. 2's |V_S|/|V_A| measured in tokens — the
        // actual KV footprint; identical to node counts for uniform steps).
        let (parents, leaf_idx, span_tokens) = tree.spanned_subtree(&nodes);
        let problem = SelectionProblem {
            candidates: nodes
                .iter()
                .enumerate()
                .map(|(j, _)| Candidate {
                    weight: weights[active[j]] as f64,
                    leaf_node: leaf_idx[j],
                    cluster: clusters[j],
                })
                .collect(),
            parents,
            node_weight: span_tokens.iter().map(|&t| t.max(1) as f64).collect(),
            num_clusters,
            lambda_b: self.lambda_b,
            lambda_d: self.lambda_d,
        };
        let selection = solve_tree(&problem, self.solver_budget);
        self.pruned_total += (nodes.len() - selection.chosen.len()) as u64;
        // Eq. 3: re-apply REBASE over the survivors only.
        let surv_nodes: Vec<NodeId> = selection.chosen.iter().map(|&j| nodes[j]).collect();
        let surv_rewards: Vec<f64> =
            selection.chosen.iter().map(|&j| rewards[active[j]]).collect();
        let w = rebase_allocate(&surv_rewards, width, self.temp);
        surv_nodes
            .into_iter()
            .zip(w)
            .filter(|&(_, n)| n > 0)
            .collect()
    }

    fn name(&self) -> String {
        if self.lambda_d == 0.0 {
            format!("ets-kv(b={})", self.lambda_b)
        } else {
            format!("ets(b={},d={})", self.lambda_b, self.lambda_d)
        }
    }

    fn kv_retention(&self, _width: usize) -> f64 {
        // The KV-budget term prunes harder as λ_b grows; at λ_b = 0 the
        // policy reduces to REBASE (retention 1). A calibration heuristic,
        // used only as the router's relative load unit.
        1.0 / (1.0 + self.lambda_b.max(0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::HashEmbedder;
    use crate::tree::StepInfo;

    /// Frontier of `n` children under the root with given rewards/groups.
    fn frontier(rewards: &[f64], groups: &[u64]) -> (SearchTree, Vec<NodeId>) {
        let mut t = SearchTree::new();
        let root = t.init_root(10);
        let ids = rewards
            .iter()
            .zip(groups)
            .enumerate()
            .map(|(i, (&r, &g))| {
                t.add_child(
                    root,
                    StepInfo {
                        tokens: 5,
                        sem: g,
                        paraphrase: i as u64,
                        path_id: crate::workload::extend_path_id(0, g),
                        ..Default::default()
                    },
                    r,
                )
            })
            .collect();
        (t, ids)
    }

    #[test]
    fn beam_keeps_top_k_and_splits_width() {
        let (t, ids) = frontier(&[0.9, 0.1, 0.8, 0.5], &[0, 1, 2, 3]);
        let mut p = BeamPolicy { keep: 2 };
        let alloc = p.allocate(&t, &ids, 16);
        assert_eq!(alloc.len(), 2);
        let total: usize = alloc.iter().map(|&(_, n)| n).sum();
        assert_eq!(total, 16);
        let chosen: Vec<NodeId> = alloc.iter().map(|&(c, _)| c).collect();
        assert!(chosen.contains(&ids[0]) && chosen.contains(&ids[2]));
    }

    #[test]
    fn rebase_allocates_to_all_candidates() {
        let (t, ids) = frontier(&[0.9, 0.1, 0.8, 0.5], &[0, 1, 2, 3]);
        let mut p = RebasePolicy::default();
        let alloc = p.allocate(&t, &ids, 16);
        assert_eq!(alloc.len(), 4, "balanced sampling keeps everyone");
        let total: usize = alloc.iter().map(|&(_, n)| n).sum();
        assert_eq!(total, 16);
        let n_of = |id: NodeId| alloc.iter().find(|&&(c, _)| c == id).unwrap().1;
        assert!(n_of(ids[0]) > n_of(ids[1]));
    }

    #[test]
    fn dvts_retains_one_per_subtree() {
        let (t, ids) = frontier(&[0.9, 0.1, 0.8, 0.5], &[0, 1, 2, 3]);
        let mut p = DvtsPolicy::new(2);
        p.on_root_children(&ids);
        // subtree 0: ids[0] (0.9), ids[2] (0.8); subtree 1: ids[1], ids[3]
        let alloc = p.allocate(&t, &ids, 8);
        assert_eq!(alloc.len(), 2);
        let chosen: Vec<NodeId> = alloc.iter().map(|&(c, _)| c).collect();
        assert!(chosen.contains(&ids[0]), "best of subtree 0");
        assert!(chosen.contains(&ids[3]), "best of subtree 1");
        assert_eq!(alloc.iter().map(|&(_, n)| n).sum::<usize>(), 8);
    }

    #[test]
    fn ets_prunes_redundant_same_cluster_leaves() {
        // 6 candidates: four paraphrases of group 0 (redundant), one each of
        // groups 1, 2. Similar rewards. ETS should prune within group 0 but
        // keep groups 1 and 2 covered.
        let (t, ids) = frontier(
            &[0.62, 0.60, 0.61, 0.59, 0.58, 0.57],
            &[0, 0, 0, 0, 1, 2],
        );
        let mut p = EtsPolicy::new(1.5, 1.0, HashEmbedder::default());
        let alloc = p.allocate(&t, &ids, 12);
        let chosen: Vec<NodeId> = alloc.iter().map(|&(c, _)| c).collect();
        assert!(chosen.len() < 6, "should prune: {alloc:?}");
        assert!(
            chosen.contains(&ids[4]) && chosen.contains(&ids[5]),
            "diverse groups must survive: {alloc:?}"
        );
        assert_eq!(alloc.iter().map(|&(_, n)| n).sum::<usize>(), 12);
        assert!(p.pruned_total > 0);
    }

    #[test]
    fn ets_kv_ablation_skips_embedding() {
        let (t, ids) = frontier(&[0.62, 0.60, 0.61], &[0, 1, 2]);
        let mut p = EtsPolicy::new(1.0, 0.0, HashEmbedder::default());
        let alloc = p.allocate(&t, &ids, 9);
        assert!(!alloc.is_empty());
        assert_eq!(alloc.iter().map(|&(_, n)| n).sum::<usize>(), 9);
        assert!(p.name().starts_with("ets-kv"));
    }

    #[test]
    fn lambda_zero_equals_rebase() {
        let (t, ids) = frontier(&[0.9, 0.3, 0.6, 0.2], &[0, 1, 2, 3]);
        let mut ets = EtsPolicy::new(0.0, 0.0, HashEmbedder::default());
        let mut reb = RebasePolicy::default();
        let a1: std::collections::HashMap<NodeId, usize> =
            ets.allocate(&t, &ids, 20).into_iter().collect();
        let a2: std::collections::HashMap<NodeId, usize> =
            reb.allocate(&t, &ids, 20).into_iter().collect();
        assert_eq!(a1, a2, "λ=0 must reduce to REBASE");
    }
}
