//! Synthetic math-reasoning workload (substitute for MATH500 / GSM8K).
//!
//! The search policies only see *reward scores, step token counts, semantic
//! groups, and final answers*; they never read natural language. The
//! generator therefore models exactly the statistics that drive tree search:
//!
//! * A problem is solved by a chain of `n_steps` correct reasoning steps.
//! * At each expansion the LM proposes a step drawn from a small set of
//!   **semantic groups** ("approaches"); paraphrases within a group are
//!   surface-level variants of the same idea.
//! * Whether a semantic group is *on-track* at a given tree node is a
//!   **deterministic function of (problem, node path, group)** — sampling the
//!   same group twice from the same parent yields the same fate (redundant!),
//!   while different groups are independent draws. This is the structure that
//!   makes semantic diversity genuinely valuable for exploration and
//!   redundancy genuinely prunable — the dynamic ETS exploits (paper §4.2).
//! * A trajectory that takes a wrong step is *doomed* (will emit a wrong
//!   final answer) but keeps generating plausible steps — as in real CoT.
//! * The oracle PRM observes the doomed/alive latent through noise
//!   (see [`crate::reward`]).
//!
//! Two dataset profiles (difficulty) × two model profiles (step accuracy)
//! reproduce the 2×2 structure of the paper's evaluation.

use crate::util::rng::Rng;

/// Dataset difficulty profile (synth-math500 ≈ MATH500, synth-gsm8k ≈ GSM8K).
#[derive(Clone, Debug)]
pub struct DatasetProfile {
    pub name: &'static str,
    /// Steps needed for a full solution.
    pub n_steps: usize,
    /// Distinct semantic approaches available at each node.
    pub n_groups: usize,
    /// Distinct wrong answers doomed trajectories can emit. Real wrong
    /// numeric answers scatter widely, which keeps weighted-majority voting
    /// from being drowned by doomed-trajectory mass.
    pub n_wrong_answers: usize,
    /// Per-problem difficulty mixture: (probability, p_correct lo, hi).
    /// Difficulty = per-step probability that a fresh semantic approach is
    /// on-track; drawn once per problem.
    pub difficulty_mix: [(f64, f64, f64); 3],
    /// Mean tokens per reasoning step.
    pub step_tokens_mean: f64,
    pub step_tokens_std: f64,
    /// Prompt length in tokens.
    pub prompt_tokens: usize,
}

/// Generator ("LM") capability profile.
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub name: &'static str,
    /// Probability that a *fresh semantic group* at an alive node is on-track.
    /// Per-dataset multiplier applied via [`WorkloadSpec::p_correct`].
    pub skill: f64,
    /// PRM observation noise (std of the fresh logit-space perturbation).
    pub prm_noise: f64,
    /// PRM logit margin between alive and doomed trajectories.
    pub prm_margin: f64,
    /// Std of the *persistent* per-path PRM bias innovation (AR(1) along the
    /// trajectory): models systematically deceptive reasoning paths that
    /// keep fooling the verifier — what exploitation-heavy beam search
    /// commits to and diverse search hedges against.
    pub prm_bias_sigma: f64,
    /// AR(1) decay of the inherited path bias.
    pub prm_bias_rho: f64,
    /// Weight bytes (for the serving perf model; f16).
    pub weight_bytes: u64,
    /// KV bytes per token (for the serving perf model).
    pub kv_bytes_per_token: u64,
}

pub const SYNTH_MATH500: DatasetProfile = DatasetProfile {
    name: "synth-math500",
    n_steps: 8,
    n_groups: 10,
    n_wrong_answers: 48,
    difficulty_mix: [(0.45, 0.88, 0.99), (0.13, 0.70, 0.88), (0.42, 0.25, 0.60)],
    step_tokens_mean: 55.0,
    step_tokens_std: 18.0,
    prompt_tokens: 120,
};

pub const SYNTH_GSM8K: DatasetProfile = DatasetProfile {
    name: "synth-gsm8k",
    n_steps: 5,
    n_groups: 8,
    n_wrong_answers: 24,
    difficulty_mix: [(0.78, 0.92, 0.995), (0.10, 0.75, 0.92), (0.12, 0.30, 0.70)],
    step_tokens_mean: 40.0,
    step_tokens_std: 12.0,
    prompt_tokens: 80,
};

/// Llemma-34B (Metamath) + Llemma-34B PRM analogue.
pub const LLEMMA_34B_SIM: ModelProfile = ModelProfile {
    name: "llemma-34b-sim",
    skill: 1.0,
    prm_noise: 0.45,
    prm_margin: 1.1,
    prm_bias_sigma: 0.55,
    prm_bias_rho: 0.80,
    weight_bytes: 68_000_000_000,
    kv_bytes_per_token: 1_966_080, // 48 layers * 8 kv heads * 128 dim * 2 * 2B (GQA)
};

/// Mistral-7B-SFT (Metamath) + Math-Shepherd PRM analogue.
pub const MISTRAL_7B_SIM: ModelProfile = ModelProfile {
    name: "mistral-7b-sim",
    skill: 0.93,
    prm_noise: 0.55,
    prm_margin: 1.0,
    prm_bias_sigma: 0.65,
    prm_bias_rho: 0.80,
    weight_bytes: 14_000_000_000,
    kv_bytes_per_token: 524_288, // 32 layers * 8 kv heads * 128 dim * 2 * 2B
};

pub fn dataset_by_name(name: &str) -> Option<&'static DatasetProfile> {
    match name {
        "synth-math500" => Some(&SYNTH_MATH500),
        "synth-gsm8k" => Some(&SYNTH_GSM8K),
        _ => None,
    }
}

pub fn model_by_name(name: &str) -> Option<&'static ModelProfile> {
    match name {
        "llemma-34b-sim" => Some(&LLEMMA_34B_SIM),
        "mistral-7b-sim" => Some(&MISTRAL_7B_SIM),
        _ => None,
    }
}

/// A dataset+model pairing with derived per-step solve probability.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub dataset: DatasetProfile,
    pub model: ModelProfile,
}

impl WorkloadSpec {
    pub fn new(dataset: &DatasetProfile, model: &ModelProfile) -> Self {
        Self { dataset: dataset.clone(), model: model.clone() }
    }

    /// Draw a per-problem difficulty (step-level on-track probability) from
    /// the dataset mixture, scaled by the model's skill.
    ///
    /// The mixture reproduces the benchmark structure: a mass of problems
    /// the model reliably solves, a band of marginal problems where search
    /// width/diversity decides the outcome, and a hard tail.
    pub fn sample_difficulty(&self, rng: &mut Rng) -> f64 {
        let mix = &self.dataset.difficulty_mix;
        let r = rng.f64();
        let (mut acc, mut band) = (0.0, &mix[0]);
        for b in mix {
            acc += b.0;
            if r < acc {
                band = b;
                break;
            }
            band = b;
        }
        let p = band.1 + rng.f64() * (band.2 - band.1);
        (p * self.model.skill).clamp(0.01, 0.995)
    }
}

/// One synthetic problem instance.
#[derive(Clone, Debug)]
pub struct Problem {
    pub id: u64,
    /// Root entropy: all latent step fates derive from this.
    pub seed: u64,
    pub spec: WorkloadSpec,
    /// Ground-truth final answer.
    pub answer: i64,
    /// Per-problem difficulty: step-level on-track probability.
    pub p_correct: f64,
}

/// A deterministic 64-bit mix (splitmix-style) for latent fate hashing.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Identity of a trajectory prefix = fold of chosen semantic groups.
pub fn extend_path_id(parent_path_id: u64, group: u64) -> u64 {
    mix(parent_path_id ^ mix(group.wrapping_add(0xABCD_EF01)))
}

impl Problem {
    /// Probability that a specific on-gate approach works (given the node's
    /// gate is open). High: when the model "sees" the continuation, most of
    /// its proposed approaches are fine.
    pub const P_GROUP: f64 = 0.92;

    /// Node-level gate: does the state at `path_id` admit a continuation the
    /// model can find? Fates are *correlated within a node* — if the model
    /// is lost at a state, every sample from that state fails together, so
    /// extra samples from one node barely help and independent trajectories
    /// (diversity) are the real hedge. This correlation is what makes beam
    /// search plateau with width and diverse search scale (paper Fig. 3).
    pub fn node_gate(&self, path_id: u64) -> bool {
        let h = mix(self.seed ^ mix(path_id ^ 0x6A7E));
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        u < self.p_correct
    }

    /// Is semantic `group`, taken at the node identified by `path_id`
    /// (with an alive prefix), on-track? Deterministic: same (path, group)
    /// → same fate; gated at the node level (see [`Self::node_gate`]).
    pub fn group_on_track(&self, path_id: u64, group: u64) -> bool {
        if !self.node_gate(path_id) {
            return false;
        }
        let h = mix(self.seed ^ extend_path_id(path_id, group));
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        u < Self::P_GROUP
    }

    /// Wrong answer a doomed trajectory emits (deterministic per path).
    pub fn wrong_answer(&self, path_id: u64) -> i64 {
        let k = self.spec.dataset.n_wrong_answers as u64;
        let h = mix(self.seed ^ mix(path_id ^ 0x5ADD));
        // offset by 1..=k so it never collides with the true answer
        self.answer + 1 + (h % k) as i64
    }

    /// Tokens for one generated step (deterministic per path).
    pub fn step_tokens(&self, path_id: u64) -> usize {
        let mut r = Rng::new(self.seed ^ mix(path_id ^ 0x70C5));
        let t = r.normal_ms(self.spec.dataset.step_tokens_mean, self.spec.dataset.step_tokens_std);
        t.max(4.0) as usize
    }
}

/// A reproducible problem set ("dataset").
pub struct ProblemSet {
    pub problems: Vec<Problem>,
}

impl ProblemSet {
    /// Generate `n` problems for a workload spec from a master seed.
    pub fn generate(spec: &WorkloadSpec, n: usize, master_seed: u64) -> Self {
        let mut rng = Rng::new(master_seed ^ mix(spec.dataset.name.len() as u64));
        let problems = (0..n)
            .map(|i| {
                let seed = rng.next_u64();
                let p_correct = spec.sample_difficulty(&mut rng);
                Problem {
                    id: i as u64,
                    seed,
                    spec: spec.clone(),
                    answer: rng.range_i64(0, 999),
                    p_correct,
                }
            })
            .collect();
        Self { problems }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> WorkloadSpec {
        WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM)
    }

    #[test]
    fn problems_are_reproducible() {
        let a = ProblemSet::generate(&spec(), 10, 1);
        let b = ProblemSet::generate(&spec(), 10, 1);
        for (x, y) in a.problems.iter().zip(&b.problems) {
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.answer, y.answer);
        }
        let c = ProblemSet::generate(&spec(), 10, 2);
        assert_ne!(a.problems[0].seed, c.problems[0].seed);
    }

    #[test]
    fn group_fate_is_deterministic_and_group_dependent() {
        let p = &ProblemSet::generate(&spec(), 1, 3).problems[0];
        let path = 12345u64;
        for g in 0..6u64 {
            assert_eq!(p.group_on_track(path, g), p.group_on_track(path, g));
        }
        // across many (path, group) draws the on-track frequency ≈
        // p_correct * P_GROUP (gate x approach coin)
        let mut on = 0;
        let total = 8000;
        for i in 0..total {
            if p.group_on_track(mix(i), i % 6) {
                on += 1;
            }
        }
        let f = on as f64 / total as f64;
        let target = p.p_correct * Problem::P_GROUP;
        assert!((f - target).abs() < 0.05, "freq {f} vs target {target}");
    }

    #[test]
    fn wrong_answers_never_equal_truth() {
        let p = &ProblemSet::generate(&spec(), 1, 4).problems[0];
        for i in 0..500u64 {
            assert_ne!(p.wrong_answer(mix(i)), p.answer);
        }
    }

    #[test]
    fn path_ids_distinguish_group_order() {
        let a = extend_path_id(extend_path_id(0, 1), 2);
        let b = extend_path_id(extend_path_id(0, 2), 1);
        assert_ne!(a, b);
    }

    #[test]
    fn step_tokens_positive_and_deterministic() {
        let p = &ProblemSet::generate(&spec(), 1, 5).problems[0];
        for i in 0..100u64 {
            let t = p.step_tokens(mix(i));
            assert!(t >= 4);
            assert_eq!(t, p.step_tokens(mix(i)));
        }
    }

    #[test]
    fn gsm_easier_than_math() {
        let m = WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM);
        let g = WorkloadSpec::new(&SYNTH_GSM8K, &LLEMMA_34B_SIM);
        let mut rng = Rng::new(0);
        let avg = |s: &WorkloadSpec, rng: &mut Rng| -> f64 {
            (0..2000).map(|_| s.sample_difficulty(rng)).sum::<f64>() / 2000.0
        };
        let pm = avg(&m, &mut rng);
        let pg = avg(&g, &mut rng);
        assert!(pg > pm + 0.15, "gsm mean {pg} vs math mean {pm}");
    }

    #[test]
    fn difficulty_mixture_within_bounds() {
        let m = WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM);
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let p = m.sample_difficulty(&mut rng);
            assert!((0.01..=0.995).contains(&p));
        }
    }
}
