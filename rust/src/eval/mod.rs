//! Evaluation harness: run a policy over a problem set and report the
//! paper's metrics (accuracy, total KV, FLOPs proxy, model calls).
//!
//! Used by the CLI (`ets eval`), the examples, and every bench that
//! regenerates a paper table/figure.

use crate::coordinator::{ServeJob, ServeOptions, ServeReport};
use crate::embed::HashEmbedder;
use crate::engine::{PerfModel, DEFAULT_KV_CAPACITY, H100_NVL};
use crate::lm::{AsyncLm, SynthLm};
use crate::reward::OraclePrm;
use crate::search::policy::{BeamPolicy, DvtsPolicy, EtsPolicy, RebasePolicy, SearchPolicy};
use crate::search::{SearchOutcome, SearchParams};
use crate::workload::{ProblemSet, WorkloadSpec};

/// Which search policy to instantiate (fresh per problem — policies carry
/// per-tree state like DVTS subtree maps).
#[derive(Clone, Debug, PartialEq)]
pub enum PolicySpec {
    /// Beam search retaining `keep` trajectories per step.
    Beam { keep: usize },
    /// Beam search retaining sqrt(width).
    BeamSqrt,
    /// DVTS with `subtrees` independent subtrees (1 retained per subtree).
    Dvts { subtrees: usize },
    /// DVTS with sqrt(width) subtrees.
    DvtsSqrt,
    /// REBASE balanced sampling (T_R = 0.2).
    Rebase,
    /// ETS with the KV-budget and coverage terms (λ_d = 1 per the paper).
    Ets { lambda_b: f64, lambda_d: f64 },
    /// ETS-KV ablation (coverage term disabled).
    EtsKv { lambda_b: f64 },
}

impl PolicySpec {
    pub fn name(&self, width: usize) -> String {
        match self {
            PolicySpec::Beam { keep } => format!("beam-{keep}"),
            PolicySpec::BeamSqrt => format!("beam-sqrt({})", isqrt(width)),
            PolicySpec::Dvts { subtrees } => format!("dvts-{subtrees}"),
            PolicySpec::DvtsSqrt => format!("dvts-sqrt({})", isqrt(width)),
            PolicySpec::Rebase => "rebase".into(),
            PolicySpec::Ets { lambda_b, lambda_d } => {
                format!("ets(λb={lambda_b},λd={lambda_d})")
            }
            PolicySpec::EtsKv { lambda_b } => format!("ets-kv(λb={lambda_b})"),
        }
    }

    /// Parse "beam-4", "beam-sqrt", "dvts-4", "dvts-sqrt", "rebase",
    /// "ets", "ets:1.5", "ets-kv:1.0".
    pub fn parse(s: &str) -> Result<Self, String> {
        if let Some(rest) = s.strip_prefix("ets-kv") {
            let lb = rest.strip_prefix(':').map(|x| x.parse::<f64>()).transpose()
                .map_err(|e| format!("{s}: {e}"))?;
            return Ok(PolicySpec::EtsKv { lambda_b: lb.unwrap_or(1.0) });
        }
        if let Some(rest) = s.strip_prefix("ets") {
            let lb = rest.strip_prefix(':').map(|x| x.parse::<f64>()).transpose()
                .map_err(|e| format!("{s}: {e}"))?;
            return Ok(PolicySpec::Ets { lambda_b: lb.unwrap_or(1.5), lambda_d: 1.0 });
        }
        match s {
            "rebase" => Ok(PolicySpec::Rebase),
            "beam-sqrt" => Ok(PolicySpec::BeamSqrt),
            "dvts-sqrt" => Ok(PolicySpec::DvtsSqrt),
            _ => {
                if let Some(k) = s.strip_prefix("beam-") {
                    Ok(PolicySpec::Beam { keep: k.parse().map_err(|e| format!("{s}: {e}"))? })
                } else if let Some(k) = s.strip_prefix("dvts-") {
                    Ok(PolicySpec::Dvts {
                        subtrees: k.parse().map_err(|e| format!("{s}: {e}"))?,
                    })
                } else {
                    Err(format!("unknown policy '{s}'"))
                }
            }
        }
    }
}

pub fn isqrt(n: usize) -> usize {
    (n as f64).sqrt().round() as usize
}

/// Aggregated evaluation metrics over a problem set.
#[derive(Clone, Debug, Default)]
pub struct EvalReport {
    pub policy: String,
    pub dataset: String,
    pub model: String,
    pub width: usize,
    pub n_problems: usize,
    pub n_correct: usize,
    /// Mean per-problem Σ-over-steps live KV tokens (paper's KV size metric).
    pub mean_kv_tokens: f64,
    /// Mean per-problem Σ KV without sharing.
    pub mean_unshared_kv_tokens: f64,
    /// Mean per-problem peak live KV tokens.
    pub mean_peak_kv_tokens: f64,
    /// Mean per-problem generated tokens (FLOPs proxy).
    pub mean_new_tokens: f64,
    /// Mean per-problem model calls.
    pub mean_model_calls: f64,
    /// Per-problem outcomes for downstream analysis (correct, kv, tokens).
    pub per_problem: Vec<(bool, u64, u64)>,
}

impl EvalReport {
    pub fn accuracy(&self) -> f64 {
        if self.n_problems == 0 {
            0.0
        } else {
            self.n_correct as f64 / self.n_problems as f64
        }
    }
}

/// Evaluation configuration.
#[derive(Clone, Debug)]
pub struct EvalConfig {
    pub spec: WorkloadSpec,
    pub policy: PolicySpec,
    pub width: usize,
    pub n_problems: usize,
    pub seed: u64,
    pub max_steps: usize,
}

/// Instantiate a policy behind a `Send` trait object: the sharded serve
/// scheduler moves sessions (and their policies) between worker threads and,
/// under sustained memory pressure, migrates them across shards.
fn make_policy(spec: &PolicySpec, width: usize) -> Box<dyn SearchPolicy + Send> {
    match spec {
        PolicySpec::Beam { keep } => Box::new(BeamPolicy { keep: *keep }),
        PolicySpec::BeamSqrt => Box::new(BeamPolicy { keep: isqrt(width) }),
        PolicySpec::Dvts { subtrees } => Box::new(DvtsPolicy::new(*subtrees)),
        PolicySpec::DvtsSqrt => Box::new(DvtsPolicy::new(isqrt(width))),
        PolicySpec::Rebase => Box::new(RebasePolicy::default()),
        PolicySpec::Ets { lambda_b, lambda_d } => {
            Box::new(EtsPolicy::new(*lambda_b, *lambda_d, HashEmbedder::default()))
        }
        PolicySpec::EtsKv { lambda_b } => {
            Box::new(EtsPolicy::new(*lambda_b, 0.0, HashEmbedder::default()))
        }
    }
}

/// The per-problem summary both eval paths fold: (correct, total KV, total
/// unshared KV, peak KV, new tokens, model calls).
type ProblemSummary = (bool, u64, u64, u64, u64, u64);

fn summarize(out: &SearchOutcome, truth: i64) -> ProblemSummary {
    (
        out.answer == Some(truth),
        out.total_kv_tokens(),
        out.total_unshared_kv_tokens(),
        out.peak_kv_tokens(),
        out.total_new_tokens(),
        out.total_model_calls(),
    )
}

/// Fold per-problem summaries into an [`EvalReport`]. Every eval shape
/// (worker sweep, serve concurrency sweep, capacity sweep, shard sweep)
/// folds through here so reports compare field-for-field.
fn fold_report(cfg: &EvalConfig, results: Vec<ProblemSummary>) -> EvalReport {
    let mut report = EvalReport {
        policy: cfg.policy.name(cfg.width),
        dataset: cfg.spec.dataset.name.to_string(),
        model: cfg.spec.model.name.to_string(),
        width: cfg.width,
        n_problems: cfg.n_problems,
        ..Default::default()
    };
    let (mut kv, mut unshared, mut peak, mut toks, mut calls) =
        (0u64, 0u64, 0u64, 0u64, 0u64);
    for (correct, okv, ouns, opeak, otoks, ocalls) in results {
        if correct {
            report.n_correct += 1;
        }
        kv += okv;
        unshared += ouns;
        peak += opeak;
        toks += otoks;
        calls += ocalls;
        report.per_problem.push((correct, okv, otoks));
    }
    let n = cfg.n_problems.max(1) as f64;
    report.mean_kv_tokens = kv as f64 / n;
    report.mean_unshared_kv_tokens = unshared as f64 / n;
    report.mean_peak_kv_tokens = peak as f64 / n;
    report.mean_new_tokens = toks as f64 / n;
    report.mean_model_calls = calls as f64 / n;
    report
}

/// Run the evaluation in parallel over `workers` threads.
///
/// Rebased onto the sharded [`crate::coordinator::serve`] engine: `workers`
/// shards with one resident job per shard (`concurrency == shards`, routed
/// by the deterministic least-loaded admission), each shard holding the
/// default ample per-shard KV capacity and stepped by `serve`'s persistent
/// worker pool (spawned once per call, not per round). This replaces the
/// old `par_map`-over-fresh-engines path so eval and serving share a single
/// execution engine — the plan → decode → commit round pipeline included;
/// because sessions are schedule-invariant, the folded report is identical
/// for any worker count (and identical to what the old path produced —
/// `tests/serve_determinism.rs` pins this).
pub fn evaluate_with_workers(cfg: &EvalConfig, workers: usize) -> EvalReport {
    let workers = workers.max(1).min(cfg.n_problems.max(1));
    let opts = ServeOptions {
        concurrency: workers,
        // one full default-sized engine per shard, like the old per-worker
        // fresh engines (the global budget is partitioned across shards)
        capacity_tokens: DEFAULT_KV_CAPACITY.saturating_mul(workers),
        shards: workers,
        ..Default::default()
    };
    let perf = PerfModel::new(H100_NVL, true, workers);
    evaluate_serve_with(cfg, &opts, &perf).report
}

/// Run the evaluation using all available cores.
pub fn evaluate(cfg: &EvalConfig) -> EvalReport {
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    evaluate_with_workers(cfg, workers)
}

/// Eval result of the batched serve path: the standard report plus the
/// serving telemetry (per-batch latency, modeled throughput, cache
/// high-water mark).
pub struct ServeEvalReport {
    pub report: EvalReport,
    pub serve: ServeReport,
}

/// Run the evaluation through [`crate::coordinator::serve`] at the default
/// (ample) KV capacity: same problems, same seeds, but up to `concurrency`
/// searches interleaved through one batched engine, with `perf` costing
/// every merged batch. The folded [`EvalReport`] is identical to
/// [`evaluate_with_workers`]'s for any worker count / concurrency — the
/// determinism tests pin this.
pub fn evaluate_serve(cfg: &EvalConfig, concurrency: usize, perf: &PerfModel) -> ServeEvalReport {
    evaluate_serve_with(cfg, &ServeOptions::with_concurrency(concurrency), perf)
}

/// Run the evaluation through the full memory-pressure-aware scheduler:
/// `opts` carries the concurrency *and* the hard KV block budget, so this
/// is the entry point for oversubscription experiments (capacity sweeps in
/// `benches/table2_throughput.rs`, `ets serve --capacity`). Scheduling
/// (admission gating, preemption, resume-with-recompute) shows up in
/// `serve` telemetry only — the folded [`EvalReport`] stays identical to
/// the uncapped run at the same seed.
pub fn evaluate_serve_with(
    cfg: &EvalConfig,
    opts: &ServeOptions,
    perf: &PerfModel,
) -> ServeEvalReport {
    serve_problem_set(cfg, opts, perf, None)
}

/// Deterministic real token ids for pool prompt `g` of a duplicate-heavy
/// workload: `prompt_tokens` ids per prompt, disjoint across pool entries
/// (distinct prompts differ at token 0, so their radix paths never
/// partially overlap). Problems sharing a pool prompt share their prompt KV
/// honestly through the radix cache — the sharing the cross-shard prefix
/// hub recovers at fleet scale.
pub fn pool_prompt_ids(spec: &WorkloadSpec, g: usize) -> Vec<u32> {
    let n = spec.dataset.prompt_tokens;
    (0..n).map(|t| 0x4000_0000 + (g * n + t) as u32).collect()
}

/// [`evaluate_serve_with`] over a **duplicate-heavy prompt workload**:
/// problem `i` is given the real prompt ids of pool entry
/// `i % distinct_prompts`, so `distinct_prompts < n_problems` makes
/// identical prompts recur — the workload where `--prefix-share`'s
/// prompt-affinity routing and cross-shard imports pay. Sampling (and so
/// every per-problem outcome) is identical to the plain minted-id run; only
/// KV placement and sharing telemetry change.
pub fn evaluate_serve_duplicate_prompts(
    cfg: &EvalConfig,
    opts: &ServeOptions,
    perf: &PerfModel,
    distinct_prompts: usize,
) -> ServeEvalReport {
    serve_problem_set(cfg, opts, perf, Some(distinct_prompts.max(1)))
}

/// [`evaluate_serve_with`] over a **mixed-difficulty workload**: the
/// problems of `cfg` (its dataset is the *hard* profile) interleaved with
/// `n_easy` problems drawn from the `easy` dataset profile under the same
/// model, all served through one coordinator call at one global KV budget.
/// This is the workload where `--adaptive-budget` pays: easy sessions are
/// recognized early and donate width/KV blocks to the hard tail. Per-problem
/// sampling is independent of the serve configuration, so the folded report
/// is identical across shard counts / capacities at a fixed seed (the
/// adaptive determinism tests pin this).
///
/// `cfg.max_steps` must cover the deeper of the two datasets.
pub fn evaluate_serve_mixed(
    cfg: &EvalConfig,
    easy: &WorkloadSpec,
    n_easy: usize,
    opts: &ServeOptions,
    perf: &PerfModel,
) -> ServeEvalReport {
    let hard = ProblemSet::generate(&cfg.spec, cfg.n_problems, cfg.seed).problems;
    let mut soft = ProblemSet::generate(easy, n_easy, cfg.seed ^ 0x517E_AD00).problems;
    for (i, p) in soft.iter_mut().enumerate() {
        // re-key so per-job seeds (lm/prm xor cfg.seed with the id) never
        // collide with the hard set's
        p.id = (cfg.n_problems + i) as u64;
    }
    // deterministic interleave: hard/easy alternate in admission order
    let mut problems = Vec::with_capacity(hard.len() + soft.len());
    let (mut h, mut s) = (hard.into_iter(), soft.into_iter());
    loop {
        match (h.next(), s.next()) {
            (None, None) => break,
            (a, b) => {
                problems.extend(a);
                problems.extend(b);
            }
        }
    }
    let params = SearchParams { width: cfg.width, max_steps: cfg.max_steps };
    let mut truths = Vec::with_capacity(problems.len());
    let parts: Vec<(SynthLm, OraclePrm, Box<dyn SearchPolicy + Send>)> = problems
        .into_iter()
        .map(|p| {
            truths.push(p.answer);
            let id = p.id;
            let prm = OraclePrm::for_profile(&cfg.spec.model, cfg.seed ^ 0xBEEF ^ id);
            let lm = SynthLm::new(p, cfg.seed ^ id);
            (lm, prm, make_policy(&cfg.policy, cfg.width))
        })
        .collect();
    let serve = if opts.async_decode {
        let jobs: Vec<_> = parts
            .into_iter()
            .map(|(lm, prm, policy)| ServeJob { lm: AsyncLm::new(lm), prm, policy })
            .collect();
        crate::coordinator::serve(jobs, &params, opts, perf, &cfg.spec.model)
    } else {
        let jobs: Vec<_> = parts
            .into_iter()
            .map(|(lm, prm, policy)| ServeJob { lm, prm, policy })
            .collect();
        crate::coordinator::serve(jobs, &params, opts, perf, &cfg.spec.model)
    };
    let results = serve
        .outcomes
        .iter()
        .zip(&truths)
        .map(|(out, &truth)| summarize(out, truth))
        .collect();
    let mut total_cfg = cfg.clone();
    total_cfg.n_problems = cfg.n_problems + n_easy;
    let mut report = fold_report(&total_cfg, results);
    report.dataset = format!("mixed({}+{})", cfg.spec.dataset.name, easy.dataset.name);
    ServeEvalReport { report, serve }
}

fn serve_problem_set(
    cfg: &EvalConfig,
    opts: &ServeOptions,
    perf: &PerfModel,
    distinct_prompts: Option<usize>,
) -> ServeEvalReport {
    let problems = ProblemSet::generate(&cfg.spec, cfg.n_problems, cfg.seed);
    let params = SearchParams { width: cfg.width, max_steps: cfg.max_steps };
    let mut truths = Vec::with_capacity(problems.problems.len());
    let parts: Vec<(SynthLm, OraclePrm, Box<dyn SearchPolicy + Send>)> = problems
        .problems
        .into_iter()
        .enumerate()
        .map(|(i, p)| {
            truths.push(p.answer);
            let id = p.id;
            let prm = OraclePrm::for_profile(&cfg.spec.model, cfg.seed ^ 0xBEEF ^ id);
            let mut lm = SynthLm::new(p, cfg.seed ^ id);
            if let Some(k) = distinct_prompts {
                lm = lm.with_prompt_ids(pool_prompt_ids(&cfg.spec, i % k));
            }
            (lm, prm, make_policy(&cfg.policy, cfg.width))
        })
        .collect();
    // The async data plane swaps only the generator type: each job's
    // decodes are served on its own completion worker ([`AsyncLm`]).
    // Sampling streams are untouched, so per-problem results stay
    // byte-identical (pinned by `tests/serve_determinism.rs`).
    let serve = if opts.async_decode {
        let jobs: Vec<_> = parts
            .into_iter()
            .map(|(lm, prm, policy)| ServeJob { lm: AsyncLm::new(lm), prm, policy })
            .collect();
        crate::coordinator::serve(jobs, &params, opts, perf, &cfg.spec.model)
    } else {
        let jobs: Vec<_> = parts
            .into_iter()
            .map(|(lm, prm, policy)| ServeJob { lm, prm, policy })
            .collect();
        crate::coordinator::serve(jobs, &params, opts, perf, &cfg.spec.model)
    };
    let results = serve
        .outcomes
        .iter()
        .zip(&truths)
        .map(|(out, &truth)| summarize(out, truth))
        .collect();
    ServeEvalReport { report: fold_report(cfg, results), serve }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{LLEMMA_34B_SIM, SYNTH_MATH500};

    #[test]
    fn policy_spec_parsing() {
        assert_eq!(PolicySpec::parse("rebase").unwrap(), PolicySpec::Rebase);
        assert_eq!(PolicySpec::parse("beam-4").unwrap(), PolicySpec::Beam { keep: 4 });
        assert_eq!(PolicySpec::parse("dvts-sqrt").unwrap(), PolicySpec::DvtsSqrt);
        assert_eq!(
            PolicySpec::parse("ets:1.5").unwrap(),
            PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 }
        );
        assert_eq!(
            PolicySpec::parse("ets-kv:0.75").unwrap(),
            PolicySpec::EtsKv { lambda_b: 0.75 }
        );
        assert!(PolicySpec::parse("nope").is_err());
    }

    #[test]
    fn evaluate_reports_consistent_counts() {
        let cfg = EvalConfig {
            spec: WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM),
            policy: PolicySpec::Rebase,
            width: 8,
            n_problems: 6,
            seed: 42,
            max_steps: 16,
        };
        let r = evaluate(&cfg);
        assert_eq!(r.per_problem.len(), 6);
        assert!(r.n_correct <= 6);
        assert!(r.mean_kv_tokens > 0.0);
        assert!(r.mean_model_calls > 0.0);
        // deterministic
        let r2 = evaluate(&cfg);
        assert_eq!(r.n_correct, r2.n_correct);
        assert_eq!(r.mean_kv_tokens, r2.mean_kv_tokens);
    }
}
