//! Process reward models.
//!
//! * [`OraclePrm`] — noisy observation of the workload latent, used by the
//!   accuracy experiments. The PRM sees whether a partial trajectory is still
//!   on a correct path only through logit-space noise, which reproduces the
//!   imperfect-verifier dynamics that make search width / diversity matter.
//! * [`crate::engine::pjrt_lm::PjrtPrm`] — the trained-head scorer executed
//!   via the AOT artifacts (throughput path).

use crate::tree::{NodeId, SearchTree};
use crate::util::rng::Rng;

/// Scores partial trajectories (the paper uses the final per-step PRM score
/// as the step reward).
pub trait RewardModel {
    /// Score the trajectories ending at `nodes`; values in [0, 1].
    fn score(&mut self, tree: &SearchTree, nodes: &[NodeId]) -> Vec<f64>;
}

impl<R: RewardModel + ?Sized> RewardModel for &mut R {
    fn score(&mut self, tree: &SearchTree, nodes: &[NodeId]) -> Vec<f64> {
        (**self).score(tree, nodes)
    }
}

/// Boxed reward models (covers the `+ Send` trait objects the sharded
/// coordinator moves between worker threads).
impl<R: RewardModel + ?Sized> RewardModel for Box<R> {
    fn score(&mut self, tree: &SearchTree, nodes: &[NodeId]) -> Vec<f64> {
        (**self).score(tree, nodes)
    }
}

/// Noisy oracle: `sigmoid(margin * (alive ? 1 : -1) + path_bias + noise)`.
///
/// Two noise components, both *deterministic per node path* (hash-seeded),
/// so re-scoring the same trajectory gives the same reward — like a real
/// PRM, and required for reproducibility across policies:
///
/// * `noise` — fresh per-step observation noise;
/// * `path_bias` — an AR(1) process along the trajectory
///   (`bias = ρ·parent_bias + σ_b·η(path)`): *persistently deceptive* (or
///   persistently under-rated) reasoning paths. This is what makes pure
///   exploitation (beam search) commit to wrong trajectories and gives
///   diverse search its accuracy edge — the dynamic the paper's Figure 3
///   turns on.
pub struct OraclePrm {
    /// Mean separation between alive and doomed scores (logit space).
    pub margin: f64,
    /// Std of the fresh logit-space noise.
    pub noise: f64,
    /// Std of the per-step bias innovation.
    pub bias_sigma: f64,
    /// AR(1) decay of the inherited bias.
    pub bias_rho: f64,
    /// Steps until the PRM reaches full discrimination. Real PRMs can barely
    /// judge a trajectory's promise from its first steps; the margin ramps
    /// as `(depth / ramp)^0.7` up to 1. This is what makes beam search's
    /// early hard pruning costly and REBASE's early balance valuable.
    pub margin_ramp: f64,
    /// Margin multiplier for *completed* trajectories: verifying a full
    /// solution is much easier than judging a partial one, which is what
    /// makes weighted-majority voting robust to doomed completions.
    pub terminal_boost: f64,
    seed: u64,
}

impl OraclePrm {
    pub fn new(margin: f64, noise: f64, seed: u64) -> Self {
        Self { margin, noise, bias_sigma: 0.0, bias_rho: 0.0, margin_ramp: 1.0, terminal_boost: 2.0, seed }
    }

    /// Construct from a model profile.
    pub fn for_profile(profile: &crate::workload::ModelProfile, seed: u64) -> Self {
        Self {
            margin: profile.prm_margin,
            noise: profile.prm_noise,
            bias_sigma: profile.prm_bias_sigma,
            bias_rho: profile.prm_bias_rho,
            margin_ramp: 6.0,
            terminal_boost: 2.0,
            seed,
        }
    }

    /// AR(1) path bias: fold the per-ancestor innovations from the root.
    fn path_bias(&self, tree: &SearchTree, id: NodeId) -> f64 {
        if self.bias_sigma == 0.0 {
            return 0.0;
        }
        let mut bias = 0.0;
        for n in tree.path(id) {
            let pid = tree.get(n).step.path_id;
            if pid == 0 {
                continue; // root (prompt) carries no step bias
            }
            let mut r = Rng::new(self.seed ^ pid.wrapping_mul(0xA076_1D64_78BD_642F));
            bias = self.bias_rho * bias + self.bias_sigma * r.normal();
        }
        bias
    }
}

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

impl RewardModel for OraclePrm {
    fn score(&mut self, tree: &SearchTree, nodes: &[NodeId]) -> Vec<f64> {
        nodes
            .iter()
            .map(|&id| {
                let n = tree.get(id);
                // fresh noise keyed on path AND surface form: paraphrase
                // clones score similarly but not identically
                let key = n.step.path_id ^ n.step.paraphrase.wrapping_mul(0x94D0_49BB_1331_11EB);
                let mut r = Rng::new(self.seed ^ key.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let depth = tree.depth(id) as f64;
                let ramp = (depth / self.margin_ramp).min(1.0).powf(0.7);
                let m = if n.step.terminal {
                    self.margin * self.terminal_boost
                } else {
                    self.margin * ramp
                };
                let logit = if n.step.alive { m } else { -m };
                sigmoid(logit + self.path_bias(tree, id) + r.normal() * self.noise)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::StepInfo;

    fn tree_with(alive: &[bool]) -> (SearchTree, Vec<NodeId>) {
        let mut t = SearchTree::new();
        let root = t.init_root(10);
        let ids = alive
            .iter()
            .enumerate()
            .map(|(i, &a)| {
                t.add_child(
                    root,
                    StepInfo { tokens: 5, alive: a, path_id: i as u64 + 1, ..Default::default() },
                    0.0,
                )
            })
            .collect();
        (t, ids)
    }

    #[test]
    fn scores_in_unit_interval_and_deterministic() {
        let (t, ids) = tree_with(&[true, false, true, false]);
        let mut prm = OraclePrm::new(1.0, 0.5, 42);
        let s1 = prm.score(&t, &ids);
        let s2 = prm.score(&t, &ids);
        assert_eq!(s1, s2);
        for s in &s1 {
            assert!((0.0..=1.0).contains(s));
        }
    }

    #[test]
    fn alive_scores_higher_on_average() {
        let alive: Vec<bool> = (0..400).map(|i| i % 2 == 0).collect();
        let (t, ids) = tree_with(&alive);
        let mut prm = OraclePrm::new(1.0, 0.5, 7);
        let s = prm.score(&t, &ids);
        let (mut sa, mut na, mut sd, mut nd) = (0.0, 0, 0.0, 0);
        for (i, &a) in alive.iter().enumerate() {
            if a {
                sa += s[i];
                na += 1;
            } else {
                sd += s[i];
                nd += 1;
            }
        }
        let (ma, md) = (sa / na as f64, sd / nd as f64);
        assert!(ma > md + 0.2, "alive mean {ma} vs doomed mean {md}");
    }

    #[test]
    fn zero_noise_is_perfectly_separable() {
        let (t, ids) = tree_with(&[true, false]);
        let mut prm = OraclePrm::new(2.0, 0.0, 1);
        let s = prm.score(&t, &ids);
        assert!(s[0] > 0.8 && s[1] < 0.2);
    }

    #[test]
    fn more_noise_means_more_confusable() {
        // With huge noise, ordering flips often: count inversions.
        let alive: Vec<bool> = (0..300).map(|i| i % 2 == 0).collect();
        let (t, ids) = tree_with(&alive);
        let count_inversions = |noise: f64| {
            let mut prm = OraclePrm::new(1.0, noise, 3);
            let s = prm.score(&t, &ids);
            let mut inv = 0;
            for i in (0..300).step_by(2) {
                if s[i] < s[i + 1] {
                    inv += 1; // doomed outranked alive
                }
            }
            inv
        };
        assert!(count_inversions(3.0) > count_inversions(0.3));
    }
}
