//! Hierarchical agglomerative clustering (average linkage, cosine distance).
//!
//! In-repo replacement for `scipy.cluster.hierarchy`: ETS embeds the latest
//! step of each candidate trajectory and clusters the embeddings with a fixed
//! distance threshold; cluster ids feed the coverage term of the cost model
//! (paper §4.2). Average linkage over cosine distance `1 − cos(a, b)`,
//! threshold cut, exactly as the paper configures scipy.

use crate::util::simd;
use crate::util::stats::cosine;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Assignment of each input vector to a cluster id `0..num_clusters`.
#[derive(Clone, Debug, PartialEq)]
pub struct Clustering {
    pub assignment: Vec<usize>,
    pub num_clusters: usize,
}

/// One candidate merge in the lazy min-heap: the average-linkage distance
/// between clusters `a < b` recorded at versions (`va`, `vb`). Entries are
/// never updated in place — a merge bumps the surviving cluster's version
/// (and kills the absorbed one), which invalidates every older entry
/// lazily; stale entries are skipped on pop. Ordered as a *min*-heap on
/// `(d, a, b)` so ties break exactly like a row-major best-pair scan (first
/// pair wins), keeping results identical to the previous O(n³)
/// implementation.
struct PairEntry {
    d: f64,
    a: usize,
    b: usize,
    va: u32,
    vb: u32,
}

impl PartialEq for PairEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for PairEntry {}

impl PartialOrd for PairEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for PairEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: reverse (d, a, b) for min-heap behavior.
        // `total_cmp` gives a total order (cosine distances are never NaN,
        // but the heap must not care either way).
        other
            .d
            .total_cmp(&self.d)
            .then_with(|| other.a.cmp(&self.a))
            .then_with(|| other.b.cmp(&self.b))
    }
}

/// Cluster `embeddings` with average-linkage agglomerative clustering,
/// merging while the closest pair of clusters is below `distance_threshold`
/// (cosine distance).
///
/// UPGMA via Lance–Williams updates over a lazy pair min-heap: on merging
/// `b` into `a`, every row entry is updated as
///   `d(a∪b, k) = (n_a d(a,k) + n_b d(b,k)) / (n_a + n_b)`
/// and the fresh `(a, k)` pairs are pushed; superseded entries die lazily
/// via version stamps. O(n²) heap entries total → O(n² log n) for a full
/// merge cascade, replacing the previous O(n³) full-matrix rescan per
/// merge. (Measured by the agglomerative-clustering threshold-sweep cases
/// in `benches/micro_substrates.rs`.)
pub fn agglomerative(embeddings: &[Vec<f32>], distance_threshold: f64) -> Clustering {
    let n = embeddings.len();
    if n == 0 {
        return Clustering { assignment: vec![], num_clusters: 0 };
    }
    // Pairwise cosine distances + the initial heap of candidate merges.
    // The matrix is one flat row-major allocation (row k at `k*n..k*n+n`)
    // so the Lance–Williams row merges below stream two contiguous rows
    // instead of chasing per-row heap pointers.
    let mut dist = vec![0.0f64; n * n];
    let mut heap: BinaryHeap<PairEntry> =
        BinaryHeap::with_capacity(n * n.saturating_sub(1) / 2);
    for i in 0..n {
        for j in (i + 1)..n {
            let d = 1.0 - cosine(&embeddings[i], &embeddings[j]);
            dist[i * n + j] = d;
            dist[j * n + i] = d;
            heap.push(PairEntry { d, a: i, b: j, va: 0, vb: 0 });
        }
    }
    let mut clusters: Vec<Vec<usize>> = (0..n).map(|i| vec![i]).collect();
    let mut alive: Vec<bool> = vec![true; n];
    let mut version: Vec<u32> = vec![0; n];
    while let Some(PairEntry { d, a, b, va, vb }) = heap.pop() {
        if !alive[a] || !alive[b] || version[a] != va || version[b] != vb {
            continue; // stale: a side was merged since this entry was pushed
        }
        if d >= distance_threshold {
            break; // the closest live pair is already too far apart
        }
        // Lance–Williams average-linkage update, arithmetic identical to
        // the former rescan implementation (merge order and distances must
        // match exactly). Invariant: after every merge the slots touched
        // get fresh-version entries pushed for all live partners, so each
        // live pair always has exactly one valid entry in the heap.
        let (na, nb) = (clusters[a].len() as f64, clusters[b].len() as f64);
        alive[b] = false;
        version[a] += 1;
        // Vectorized over the *whole* row a (dead slots and the diagonal
        // included — they are never read again: merges only ever read
        // `dist[live][live≠diag]` entries). The per-element arithmetic is
        // exactly the former `(na·d_ak + nb·d_bk) / (na+nb)` expression, so
        // merge order and distances are unchanged.
        {
            let (row_a, row_b) = if a < b {
                let (lo, hi) = dist.split_at_mut(b * n);
                (&mut lo[a * n..a * n + n], &hi[..n])
            } else {
                let (lo, hi) = dist.split_at_mut(a * n);
                (&mut hi[..n], &lo[b * n..b * n + n])
            };
            simd::lw_merge(row_a, row_b, na, nb);
        }
        for k in 0..n {
            if alive[k] && k != a {
                let dk = dist[a * n + k];
                dist[k * n + a] = dk;
                let (x, y) = if a < k { (a, k) } else { (k, a) };
                heap.push(PairEntry { d: dk, a: x, b: y, va: version[x], vb: version[y] });
            }
        }
        let merged = std::mem::take(&mut clusters[b]);
        clusters[a].extend(merged);
    }
    let mut assignment = vec![0usize; n];
    let mut num_clusters = 0;
    for (slot, members) in clusters.iter().enumerate() {
        if alive[slot] {
            for &m in members {
                assignment[m] = num_clusters;
            }
            num_clusters += 1;
        }
    }
    Clustering { assignment, num_clusters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;

    fn unit(angle: f64) -> Vec<f32> {
        vec![angle.cos() as f32, angle.sin() as f32]
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(agglomerative(&[], 0.5).num_clusters, 0);
        let c = agglomerative(&[vec![1.0, 0.0]], 0.5);
        assert_eq!(c.num_clusters, 1);
        assert_eq!(c.assignment, vec![0]);
    }

    #[test]
    fn two_tight_groups_split() {
        // Group A near angle 0, group B near angle pi/2.
        let pts = vec![unit(0.0), unit(0.05), unit(1.5), unit(1.55)];
        let c = agglomerative(&pts, 0.3);
        assert_eq!(c.num_clusters, 2);
        assert_eq!(c.assignment[0], c.assignment[1]);
        assert_eq!(c.assignment[2], c.assignment[3]);
        assert_ne!(c.assignment[0], c.assignment[2]);
    }

    #[test]
    fn threshold_zero_keeps_all_separate() {
        let pts = vec![unit(0.0), unit(0.5), unit(1.0)];
        let c = agglomerative(&pts, 0.0);
        assert_eq!(c.num_clusters, 3);
    }

    #[test]
    fn huge_threshold_merges_all() {
        let pts = vec![unit(0.0), unit(0.7), unit(1.4), unit(2.0)];
        let c = agglomerative(&pts, 10.0);
        assert_eq!(c.num_clusters, 1);
    }

    #[test]
    fn identical_points_always_merge() {
        let pts = vec![vec![0.3, 0.7], vec![0.3, 0.7], vec![-0.5, 0.2]];
        let c = agglomerative(&pts, 1e-6);
        assert_eq!(c.assignment[0], c.assignment[1]);
        assert_ne!(c.assignment[0], c.assignment[2]);
    }

    #[test]
    fn prop_assignment_is_valid_partition() {
        property(60, |rng: &mut Rng| {
            let n = rng.index(20);
            let d = 2 + rng.index(6);
            let pts: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
                .collect();
            let c = agglomerative(&pts, rng.f64());
            crate::prop_check!(c.assignment.len() == n);
            if n > 0 {
                crate::prop_check!(c.num_clusters >= 1 && c.num_clusters <= n);
                for &a in &c.assignment {
                    crate::prop_check!(a < c.num_clusters, "cid {a}");
                }
                // every cluster id used
                for cid in 0..c.num_clusters {
                    crate::prop_check!(
                        c.assignment.iter().any(|&a| a == cid),
                        "unused cluster {cid}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_monotone_in_threshold() {
        // A larger threshold can only produce fewer-or-equal clusters.
        property(40, |rng: &mut Rng| {
            let n = 2 + rng.index(12);
            let pts: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..3).map(|_| rng.normal() as f32).collect())
                .collect();
            let t1 = rng.f64() * 0.8;
            let t2 = t1 + rng.f64() * 0.8;
            let c1 = agglomerative(&pts, t1);
            let c2 = agglomerative(&pts, t2);
            crate::prop_check!(
                c2.num_clusters <= c1.num_clusters,
                "t1={t1} k={} t2={t2} k={}",
                c1.num_clusters,
                c2.num_clusters
            );
            Ok(())
        });
    }
}
