//! Minimal error plumbing (the `anyhow` crate is unavailable offline).
//!
//! [`Error`] is a message-carrying error; [`Context`] mirrors the
//! `anyhow::Context` ergonomics for `Result` and `Option`, and the
//! [`crate::bail!`] / [`crate::err!`] macros cover the common construction
//! patterns. Anything implementing `std::error::Error` converts via `?`.

use std::fmt;

/// A human-readable error message.
pub struct Error(pub String);

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Build an error from anything displayable (e.g. the `String` errors
    /// returned by the util parsers).
    pub fn msg(m: impl fmt::Display) -> Self {
        Error(m.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error(e.to_string())
    }
}

/// Attach context to a failure, `anyhow::Context`-style.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error(format!("{msg}: {e}")))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error(msg.to_string()))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! err {
    ($($fmt:tt)+) => {
        $crate::util::error::Error(format!($($fmt)+))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($fmt:tt)+) => {
        return Err($crate::err!($($fmt)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn std_errors_convert() {
        let e = io_fail().unwrap_err();
        assert!(!e.0.is_empty());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), String> = Err("boom".into());
        let e = r.context("stage").unwrap_err();
        assert_eq!(e.0, "stage: boom");
        let o: Option<u32> = None;
        let e = o.with_context(|| "missing key".to_string()).unwrap_err();
        assert_eq!(e.0, "missing key");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn macros_format() {
        let e = err!("bad {}", 7);
        assert_eq!(e.0, "bad 7");
        fn f() -> Result<()> {
            bail!("nope {}", "really");
        }
        assert_eq!(f().unwrap_err().0, "nope really");
    }
}
