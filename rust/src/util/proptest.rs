//! Mini property-testing harness (the `proptest` crate is unavailable).
//!
//! Usage:
//! ```ignore
//! property(256, |rng| {
//!     let n = rng.index(20) + 1;
//!     let xs: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
//!     let p = softmax(&xs);
//!     check!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9, "sum {p:?}");
//!     Ok(())
//! });
//! ```
//!
//! Each case gets an independently seeded [`Rng`]; on failure the harness
//! reports the failing case's seed so it can be replayed deterministically
//! with [`replay`]. (No shrinking — cases should be generated small.)

use super::rng::Rng;

/// Result of one property case. `Err(msg)` fails the property.
pub type CaseResult = Result<(), String>;

/// Run `cases` random cases of property `f`. Panics (test failure) on the
/// first failing case, printing its seed.
pub fn property<F: FnMut(&mut Rng) -> CaseResult>(cases: u64, mut f: F) {
    // Fixed master seed keeps CI deterministic; change locally to explore.
    let master = 0xE75_5EED_u64;
    for case in 0..cases {
        let seed = master.wrapping_add(case.wrapping_mul(0x9E37_79B9));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property failed at case {case} (replay seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing case by seed.
pub fn replay<F: FnMut(&mut Rng) -> CaseResult>(seed: u64, mut f: F) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("replay seed {seed:#x}: {msg}");
    }
}

/// Assert inside a property body, returning a `CaseResult`-compatible error.
#[macro_export]
macro_rules! prop_check {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("check failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        property(50, |rng| {
            count += 1;
            let x = rng.f64();
            prop_check!((0.0..1.0).contains(&x));
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        property(50, |rng| {
            let x = rng.f64();
            prop_check!(x < 0.5, "x = {x}");
            Ok(())
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut first = None;
        replay(1234, |rng| {
            first = Some(rng.next_u64());
            Ok(())
        });
        let mut second = None;
        replay(1234, |rng| {
            second = Some(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
