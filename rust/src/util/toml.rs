//! TOML-subset parser for config files (the `toml` crate is unavailable).
//!
//! Supported grammar — enough for launcher configs:
//!   * `[section]` and `[section.sub]` headers
//!   * `key = "string" | 123 | 1.5 | true | false | [1, 2, 3] | ["a", "b"]`
//!   * `#` comments, blank lines
//!
//! Values land in a flat `section.key → Value` map.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
}

/// Parsed document: dotted-path key → value.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    map: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, String> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                let name = body
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                section = name.to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = parse_value(v.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            map.insert(key, val);
        }
        Ok(Doc { map })
    }

    pub fn get(&self, path: &str) -> Option<&Value> {
        self.map.get(path)
    }

    pub fn str_or(&self, path: &str, default: &str) -> String {
        self.get(path)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, path: &str, default: usize) -> usize {
        self.get(path).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if let Some(body) = s.strip_prefix('"') {
        let inner = body
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string: {s}"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let inner = body
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array: {s}"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            split_top_level(inner).iter().map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Arr(items?));
    }
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("cannot parse value: {s}"))
}

/// Split on commas that are not inside quotes (arrays of strings).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = vec![];
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# launcher config
name = "ets-serve"

[search]
method = "ets"        # policy
width = 256
lambda_b = 1.5
lambda_d = 1.0
widths = [16, 64, 256]

[engine]
real_pjrt = false
datasets = ["synth-math500", "synth-gsm8k"]
"#;

    #[test]
    fn parses_sections_and_types() {
        let d = Doc::parse(SAMPLE).unwrap();
        assert_eq!(d.str_or("name", ""), "ets-serve");
        assert_eq!(d.str_or("search.method", ""), "ets");
        assert_eq!(d.usize_or("search.width", 0), 256);
        assert_eq!(d.f64_or("search.lambda_b", 0.0), 1.5);
        assert!(!d.bool_or("engine.real_pjrt", true));
        let widths = d.get("search.widths").unwrap();
        assert_eq!(
            widths,
            &Value::Arr(vec![Value::Num(16.0), Value::Num(64.0), Value::Num(256.0)])
        );
        let ds = d.get("engine.datasets").unwrap();
        assert_eq!(
            ds,
            &Value::Arr(vec![
                Value::Str("synth-math500".into()),
                Value::Str("synth-gsm8k".into())
            ])
        );
    }

    #[test]
    fn defaults_for_missing_keys() {
        let d = Doc::parse("").unwrap();
        assert_eq!(d.usize_or("nope", 7), 7);
        assert_eq!(d.str_or("nope", "x"), "x");
    }

    #[test]
    fn comment_inside_string_kept() {
        let d = Doc::parse("k = \"a#b\"").unwrap();
        assert_eq!(d.str_or("k", ""), "a#b");
    }

    #[test]
    fn errors_are_reported_with_line() {
        let err = Doc::parse("x = ").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        assert!(Doc::parse("[unclosed").is_err());
        assert!(Doc::parse("novalue").is_err());
    }
}
