//! Minimal JSON value model, writer, and parser.
//!
//! `serde` is not available in the offline build environment, so results
//! dumping (benches, EXPERIMENTS.md tables) and artifact metadata parsing use
//! this small implementation. It supports the full JSON grammar except
//! `\uXXXX` surrogate pairs are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let start = self.pos;
            while self.pos < self.bytes.len()
                && self.bytes[self.pos] != b'"'
                && self.bytes[self.pos] != b'\\'
            {
                self.pos += 1;
            }
            s.push_str(
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|e| e.to_string())?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or("eof in escape")?;
                    self.pos += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err("eof in \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|e| e.to_string())?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                }
                _ => return Err("eof in string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{text}': {e}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = vec![];
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", Json::str("ets")),
            ("width", Json::num(256)),
            ("ratio", Json::num(1.8)),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            ("xs", Json::arr(vec![Json::num(1), Json::num(2)])),
        ]);
        let text = j.to_string_compact();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parses_whitespace_and_nesting() {
        let v = Json::parse(" { \"a\" : [ 1 , { \"b\" : \"x\\ny\" } ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0].as_f64(), Some(1.0));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1].get("b").unwrap().as_str(),
            Some("x\ny")
        );
    }

    #[test]
    fn parses_negative_and_exponent() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::str("quote\" slash\\ tab\t nl\n ctrl\u{1}");
        let back = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u00e9\"").unwrap().as_str(), Some("é"));
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::num(3).to_string_compact(), "3");
        assert_eq!(Json::num(3.5).to_string_compact(), "3.5");
    }
}
