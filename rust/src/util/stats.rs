//! Small statistics helpers used by metrics reporting and the bench harness.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Percentile via linear interpolation on the sorted copy; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Numerically-stable softmax.
pub fn softmax(xs: &[f64]) -> Vec<f64> {
    if xs.is_empty() {
        return vec![];
    }
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = xs.iter().map(|x| (x - m).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.iter().map(|e| e / z).collect()
}

/// Cosine similarity between two equal-length vectors; 0.0 if either is zero.
/// One pass through the blocked-reduction kernel (same bytes with SIMD on
/// or off — see [`crate::util::simd`]).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let (dot, na, nb) = crate::util::simd::dot_norms(a, b);
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na.sqrt() * nb.sqrt())
    }
}

/// Running aggregator: count / mean / min / max / sum without storing samples.
#[derive(Clone, Debug, Default)]
pub struct Running {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self { n: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn merge(&mut self, other: &Running) {
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_stddev() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        assert_eq!(median(&xs), 3.0);
        assert!((stddev(&xs) - 1.5811388).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let p = softmax(&[1000.0, 1001.0, 1002.0]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-12);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn running_aggregates() {
        let mut r = Running::new();
        for x in [3.0, 1.0, 2.0] {
            r.push(x);
        }
        assert_eq!(r.n, 3);
        assert_eq!(r.min, 1.0);
        assert_eq!(r.max, 3.0);
        assert_eq!(r.mean(), 2.0);
        let mut s = Running::new();
        s.push(10.0);
        r.merge(&s);
        assert_eq!(r.n, 4);
        assert_eq!(r.max, 10.0);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert!(softmax(&[]).is_empty());
    }
}
