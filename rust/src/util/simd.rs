//! Fixed-width vectorized kernels for the host-side hot loops (embed
//! cosine/distance, Lance–Williams cluster merges, simplex pivots).
//!
//! Every kernel has arch-dispatched vector implementations — an AVX path
//! (`std::arch` intrinsics behind `is_x86_feature_detected!`), a NEON path
//! on aarch64 (ASIMD is architecturally mandatory there, so no runtime
//! probe) — and a scalar fallback, all **bit-identical by construction**:
//!
//! * Reductions use a fixed 8-lane blocked accumulation: element `i` always
//!   lands in lane `i % 8` (two 4-lane f64 registers on AVX, four 2-lane
//!   registers on NEON — the lane *indexing* is identical), and the lanes
//!   collapse through the same pairwise tree (`l[i] + l[i+4]`, then `+2`,
//!   then `+1`) in every path. f64 adds are deterministic for a fixed
//!   association order, so SIMD-on and SIMD-off produce the same bytes. No
//!   FMA anywhere: the scalar path's separate mul-then-add rounding must
//!   match `_mm256_mul_pd` + `_mm256_add_pd` (and `vmulq_f64` +
//!   `vaddq_f64`).
//! * Element-wise kernels (merge arithmetic, pivot row updates) perform the
//!   identical per-element operation sequence; lane width cannot reassociate
//!   anything.
//!
//! The `ETS_NO_SIMD=1` environment variable (or [`force_scalar`], for
//! in-process tests) pins every kernel to the scalar path; the determinism
//! suite asserts byte-identical serve output across the two modes.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);
static ENV_INIT: OnceLock<()> = OnceLock::new();

fn env_init() {
    ENV_INIT.get_or_init(|| {
        let off = std::env::var("ETS_NO_SIMD")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        if off {
            FORCE_SCALAR.store(true, Ordering::Relaxed);
        }
    });
}

/// Pin every kernel to the scalar path (equivalent to `ETS_NO_SIMD=1`),
/// or release the pin again. For tests that compare both modes in-process.
pub fn force_scalar(on: bool) {
    env_init();
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

#[cfg(target_arch = "x86_64")]
fn have_avx() -> bool {
    static DETECTED: OnceLock<bool> = OnceLock::new();
    *DETECTED.get_or_init(|| is_x86_feature_detected!("avx"))
}

/// Whether the vectorized paths are active (AVX / NEON present and not
/// killed by `ETS_NO_SIMD` / [`force_scalar`]).
pub fn simd_active() -> bool {
    env_init();
    if FORCE_SCALAR.load(Ordering::Relaxed) {
        return false;
    }
    #[cfg(target_arch = "x86_64")]
    {
        have_avx()
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON (ASIMD) is architecturally mandatory on aarch64 — no probe.
        true
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        false
    }
}

/// Collapse the 8 accumulator lanes through the fixed pairwise tree. Shared
/// verbatim by both paths — the reduction order *is* the determinism
/// contract of this module.
#[inline]
fn reduce8(l: [f64; 8]) -> f64 {
    let q = [l[0] + l[4], l[1] + l[5], l[2] + l[6], l[3] + l[7]];
    let p = [q[0] + q[2], q[1] + q[3]];
    p[0] + p[1]
}

// ---------------------------------------------------------------------------
// Blocked reductions over f32 slices (f64 accumulation)
// ---------------------------------------------------------------------------

/// `(a·b, a·a, b·b)` in one pass — the cosine kernel. Panics on length
/// mismatch.
pub fn dot_norms(a: &[f32], b: &[f32]) -> (f64, f64, f64) {
    assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: AVX availability checked by `simd_active`.
        return unsafe { avx::dot_norms(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if simd_active() {
        // SAFETY: NEON is baseline on aarch64; the gate is only the kill
        // switch (`ETS_NO_SIMD` / `force_scalar`).
        return unsafe { neon::dot_norms(a, b) };
    }
    dot_norms_scalar(a, b)
}

fn dot_norms_scalar(a: &[f32], b: &[f32]) -> (f64, f64, f64) {
    let mut dot = [0.0f64; 8];
    let mut na = [0.0f64; 8];
    let mut nb = [0.0f64; 8];
    let full = a.len() / 8 * 8;
    let mut i = 0;
    while i < full {
        for l in 0..8 {
            let x = a[i + l] as f64;
            let y = b[i + l] as f64;
            dot[l] += x * y;
            na[l] += x * x;
            nb[l] += y * y;
        }
        i += 8;
    }
    for l in 0..a.len() - full {
        let x = a[full + l] as f64;
        let y = b[full + l] as f64;
        dot[l] += x * y;
        na[l] += x * x;
        nb[l] += y * y;
    }
    (reduce8(dot), reduce8(na), reduce8(nb))
}

/// Σ x², accumulated in f64 — the embed normalization kernel.
pub fn sum_sq(a: &[f32]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: AVX availability checked by `simd_active`.
        return unsafe { avx::sum_sq(a) };
    }
    #[cfg(target_arch = "aarch64")]
    if simd_active() {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { neon::sum_sq(a) };
    }
    sum_sq_scalar(a)
}

fn sum_sq_scalar(a: &[f32]) -> f64 {
    let mut acc = [0.0f64; 8];
    let full = a.len() / 8 * 8;
    let mut i = 0;
    while i < full {
        for l in 0..8 {
            let x = a[i + l] as f64;
            acc[l] += x * x;
        }
        i += 8;
    }
    for l in 0..a.len() - full {
        let x = a[full + l] as f64;
        acc[l] += x * x;
    }
    reduce8(acc)
}

// ---------------------------------------------------------------------------
// Element-wise kernels (trivially order-preserving)
// ---------------------------------------------------------------------------

/// `xs[i] /= d` — embed unit normalization (division kept: `* (1/d)` would
/// round differently).
pub fn div_scalar_f32(xs: &mut [f32], d: f32) {
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: AVX availability checked by `simd_active`.
        unsafe { avx::div_scalar_f32(xs, d) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if simd_active() {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { neon::div_scalar_f32(xs, d) };
        return;
    }
    for x in xs.iter_mut() {
        *x /= d;
    }
}

/// Lance–Williams average-linkage row merge:
/// `acc[k] = (na * acc[k] + nb * other[k]) / (na + nb)`.
pub fn lw_merge(acc: &mut [f64], other: &[f64], na: f64, nb: f64) {
    assert_eq!(acc.len(), other.len());
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: AVX availability checked by `simd_active`.
        unsafe { avx::lw_merge(acc, other, na, nb) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if simd_active() {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { neon::lw_merge(acc, other, na, nb) };
        return;
    }
    let den = na + nb;
    for (x, &o) in acc.iter_mut().zip(other) {
        *x = (na * *x + nb * o) / den;
    }
}

/// `xs[i] *= factor` — pivot-row scaling.
pub fn scale(xs: &mut [f64], factor: f64) {
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: AVX availability checked by `simd_active`.
        unsafe { avx::scale(xs, factor) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if simd_active() {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { neon::scale(xs, factor) };
        return;
    }
    for x in xs.iter_mut() {
        *x *= factor;
    }
}

/// `dst[i] -= factor * src[i]` — the tableau row elimination (axpy).
pub fn sub_scaled(dst: &mut [f64], src: &[f64], factor: f64) {
    assert_eq!(dst.len(), src.len());
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: AVX availability checked by `simd_active`.
        unsafe { avx::sub_scaled(dst, src, factor) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if simd_active() {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { neon::sub_scaled(dst, src, factor) };
        return;
    }
    for (d, &s) in dst.iter_mut().zip(src) {
        *d -= factor * s;
    }
}

/// `dst[i] += src[i]` — phase-1 pricing of artificial basics.
pub fn add_assign(dst: &mut [f64], src: &[f64]) {
    assert_eq!(dst.len(), src.len());
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: AVX availability checked by `simd_active`.
        unsafe { avx::add_assign(dst, src) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if simd_active() {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { neon::add_assign(dst, src) };
        return;
    }
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

// ---------------------------------------------------------------------------
// AVX implementations
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx {
    use super::reduce8;
    use std::arch::x86_64::*;

    /// Widen 8 f32 lanes to two f64 quads (lanes 0..4, 4..8).
    #[inline]
    unsafe fn widen(v: __m256) -> (__m256d, __m256d) {
        let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(v));
        let hi = _mm256_cvtps_pd(_mm256_extractf128_ps(v, 1));
        (lo, hi)
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn dot_norms(a: &[f32], b: &[f32]) -> (f64, f64, f64) {
        let mut dot_lo = _mm256_setzero_pd();
        let mut dot_hi = _mm256_setzero_pd();
        let mut na_lo = _mm256_setzero_pd();
        let mut na_hi = _mm256_setzero_pd();
        let mut nb_lo = _mm256_setzero_pd();
        let mut nb_hi = _mm256_setzero_pd();
        let full = a.len() / 8 * 8;
        let mut i = 0;
        while i < full {
            let (a_lo, a_hi) = widen(_mm256_loadu_ps(a.as_ptr().add(i)));
            let (b_lo, b_hi) = widen(_mm256_loadu_ps(b.as_ptr().add(i)));
            dot_lo = _mm256_add_pd(dot_lo, _mm256_mul_pd(a_lo, b_lo));
            dot_hi = _mm256_add_pd(dot_hi, _mm256_mul_pd(a_hi, b_hi));
            na_lo = _mm256_add_pd(na_lo, _mm256_mul_pd(a_lo, a_lo));
            na_hi = _mm256_add_pd(na_hi, _mm256_mul_pd(a_hi, a_hi));
            nb_lo = _mm256_add_pd(nb_lo, _mm256_mul_pd(b_lo, b_lo));
            nb_hi = _mm256_add_pd(nb_hi, _mm256_mul_pd(b_hi, b_hi));
            i += 8;
        }
        let mut dot = [0.0f64; 8];
        let mut na = [0.0f64; 8];
        let mut nb = [0.0f64; 8];
        _mm256_storeu_pd(dot.as_mut_ptr(), dot_lo);
        _mm256_storeu_pd(dot.as_mut_ptr().add(4), dot_hi);
        _mm256_storeu_pd(na.as_mut_ptr(), na_lo);
        _mm256_storeu_pd(na.as_mut_ptr().add(4), na_hi);
        _mm256_storeu_pd(nb.as_mut_ptr(), nb_lo);
        _mm256_storeu_pd(nb.as_mut_ptr().add(4), nb_hi);
        // tail elements land in lanes 0..rem, exactly as in the scalar path
        for l in 0..a.len() - full {
            let x = a[full + l] as f64;
            let y = b[full + l] as f64;
            dot[l] += x * y;
            na[l] += x * x;
            nb[l] += y * y;
        }
        (reduce8(dot), reduce8(na), reduce8(nb))
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn sum_sq(a: &[f32]) -> f64 {
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        let full = a.len() / 8 * 8;
        let mut i = 0;
        while i < full {
            let (a_lo, a_hi) = widen(_mm256_loadu_ps(a.as_ptr().add(i)));
            acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(a_lo, a_lo));
            acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(a_hi, a_hi));
            i += 8;
        }
        let mut acc = [0.0f64; 8];
        _mm256_storeu_pd(acc.as_mut_ptr(), acc_lo);
        _mm256_storeu_pd(acc.as_mut_ptr().add(4), acc_hi);
        for l in 0..a.len() - full {
            let x = a[full + l] as f64;
            acc[l] += x * x;
        }
        reduce8(acc)
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn div_scalar_f32(xs: &mut [f32], d: f32) {
        let dv = _mm256_set1_ps(d);
        let full = xs.len() / 8 * 8;
        let mut i = 0;
        while i < full {
            let v = _mm256_loadu_ps(xs.as_ptr().add(i));
            _mm256_storeu_ps(xs.as_mut_ptr().add(i), _mm256_div_ps(v, dv));
            i += 8;
        }
        for x in &mut xs[full..] {
            *x /= d;
        }
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn lw_merge(acc: &mut [f64], other: &[f64], na: f64, nb: f64) {
        let vna = _mm256_set1_pd(na);
        let vnb = _mm256_set1_pd(nb);
        let vden = _mm256_set1_pd(na + nb);
        let full = acc.len() / 4 * 4;
        let mut i = 0;
        while i < full {
            let x = _mm256_loadu_pd(acc.as_ptr().add(i));
            let o = _mm256_loadu_pd(other.as_ptr().add(i));
            let num = _mm256_add_pd(_mm256_mul_pd(vna, x), _mm256_mul_pd(vnb, o));
            _mm256_storeu_pd(acc.as_mut_ptr().add(i), _mm256_div_pd(num, vden));
            i += 4;
        }
        let den = na + nb;
        for l in full..acc.len() {
            acc[l] = (na * acc[l] + nb * other[l]) / den;
        }
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn scale(xs: &mut [f64], factor: f64) {
        let f = _mm256_set1_pd(factor);
        let full = xs.len() / 4 * 4;
        let mut i = 0;
        while i < full {
            let v = _mm256_loadu_pd(xs.as_ptr().add(i));
            _mm256_storeu_pd(xs.as_mut_ptr().add(i), _mm256_mul_pd(v, f));
            i += 4;
        }
        for x in &mut xs[full..] {
            *x *= factor;
        }
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn sub_scaled(dst: &mut [f64], src: &[f64], factor: f64) {
        let f = _mm256_set1_pd(factor);
        let full = dst.len() / 4 * 4;
        let mut i = 0;
        while i < full {
            let d = _mm256_loadu_pd(dst.as_ptr().add(i));
            let s = _mm256_loadu_pd(src.as_ptr().add(i));
            _mm256_storeu_pd(
                dst.as_mut_ptr().add(i),
                _mm256_sub_pd(d, _mm256_mul_pd(f, s)),
            );
            i += 4;
        }
        for l in full..dst.len() {
            dst[l] -= factor * src[l];
        }
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn add_assign(dst: &mut [f64], src: &[f64]) {
        let full = dst.len() / 4 * 4;
        let mut i = 0;
        while i < full {
            let d = _mm256_loadu_pd(dst.as_ptr().add(i));
            let s = _mm256_loadu_pd(src.as_ptr().add(i));
            _mm256_storeu_pd(dst.as_mut_ptr().add(i), _mm256_add_pd(d, s));
            i += 4;
        }
        for l in full..dst.len() {
            dst[l] += src[l];
        }
    }
}

// ---------------------------------------------------------------------------
// NEON implementations (aarch64)
// ---------------------------------------------------------------------------
//
// Same lane discipline as the AVX module, different register geometry: the
// 8-lane f64 accumulator block is four 2-lane `float64x2_t` registers, with
// register `j` holding lanes `2j` and `2j+1`. Element `i` therefore still
// lands in lane `i % 8`, the arrays spill in lane order, and `reduce8`
// collapses them through the shared pairwise tree — bit-identical to both
// the scalar and the AVX paths. `vmulq_f64` + `vaddq_f64` are separate
// rounding steps (no `vfmaq_f64` anywhere), matching the scalar
// mul-then-add.

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::reduce8;
    use std::arch::aarch64::*;

    /// Widen 4 f32 lanes to two f64 pairs (lanes 0..2, 2..4).
    #[inline]
    unsafe fn widen(v: float32x4_t) -> (float64x2_t, float64x2_t) {
        (vcvt_f64_f32(vget_low_f32(v)), vcvt_f64_f32(vget_high_f32(v)))
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dot_norms(a: &[f32], b: &[f32]) -> (f64, f64, f64) {
        let zero = vdupq_n_f64(0.0);
        let mut dotv = [zero; 4];
        let mut nav = [zero; 4];
        let mut nbv = [zero; 4];
        let full = a.len() / 8 * 8;
        let mut i = 0;
        while i < full {
            let (a01, a23) = widen(vld1q_f32(a.as_ptr().add(i)));
            let (a45, a67) = widen(vld1q_f32(a.as_ptr().add(i + 4)));
            let (b01, b23) = widen(vld1q_f32(b.as_ptr().add(i)));
            let (b45, b67) = widen(vld1q_f32(b.as_ptr().add(i + 4)));
            let av = [a01, a23, a45, a67];
            let bv = [b01, b23, b45, b67];
            for j in 0..4 {
                dotv[j] = vaddq_f64(dotv[j], vmulq_f64(av[j], bv[j]));
                nav[j] = vaddq_f64(nav[j], vmulq_f64(av[j], av[j]));
                nbv[j] = vaddq_f64(nbv[j], vmulq_f64(bv[j], bv[j]));
            }
            i += 8;
        }
        let mut dot = [0.0f64; 8];
        let mut na = [0.0f64; 8];
        let mut nb = [0.0f64; 8];
        for j in 0..4 {
            vst1q_f64(dot.as_mut_ptr().add(2 * j), dotv[j]);
            vst1q_f64(na.as_mut_ptr().add(2 * j), nav[j]);
            vst1q_f64(nb.as_mut_ptr().add(2 * j), nbv[j]);
        }
        // tail elements land in lanes 0..rem, exactly as in the scalar path
        for l in 0..a.len() - full {
            let x = a[full + l] as f64;
            let y = b[full + l] as f64;
            dot[l] += x * y;
            na[l] += x * x;
            nb[l] += y * y;
        }
        (reduce8(dot), reduce8(na), reduce8(nb))
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn sum_sq(a: &[f32]) -> f64 {
        let zero = vdupq_n_f64(0.0);
        let mut accv = [zero; 4];
        let full = a.len() / 8 * 8;
        let mut i = 0;
        while i < full {
            let (a01, a23) = widen(vld1q_f32(a.as_ptr().add(i)));
            let (a45, a67) = widen(vld1q_f32(a.as_ptr().add(i + 4)));
            let av = [a01, a23, a45, a67];
            for j in 0..4 {
                accv[j] = vaddq_f64(accv[j], vmulq_f64(av[j], av[j]));
            }
            i += 8;
        }
        let mut acc = [0.0f64; 8];
        for j in 0..4 {
            vst1q_f64(acc.as_mut_ptr().add(2 * j), accv[j]);
        }
        for l in 0..a.len() - full {
            let x = a[full + l] as f64;
            acc[l] += x * x;
        }
        reduce8(acc)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn div_scalar_f32(xs: &mut [f32], d: f32) {
        let dv = vdupq_n_f32(d);
        let full = xs.len() / 4 * 4;
        let mut i = 0;
        while i < full {
            let v = vld1q_f32(xs.as_ptr().add(i));
            vst1q_f32(xs.as_mut_ptr().add(i), vdivq_f32(v, dv));
            i += 4;
        }
        for x in &mut xs[full..] {
            *x /= d;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn lw_merge(acc: &mut [f64], other: &[f64], na: f64, nb: f64) {
        let vna = vdupq_n_f64(na);
        let vnb = vdupq_n_f64(nb);
        let vden = vdupq_n_f64(na + nb);
        let full = acc.len() / 2 * 2;
        let mut i = 0;
        while i < full {
            let x = vld1q_f64(acc.as_ptr().add(i));
            let o = vld1q_f64(other.as_ptr().add(i));
            let num = vaddq_f64(vmulq_f64(vna, x), vmulq_f64(vnb, o));
            vst1q_f64(acc.as_mut_ptr().add(i), vdivq_f64(num, vden));
            i += 2;
        }
        let den = na + nb;
        for l in full..acc.len() {
            acc[l] = (na * acc[l] + nb * other[l]) / den;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn scale(xs: &mut [f64], factor: f64) {
        let f = vdupq_n_f64(factor);
        let full = xs.len() / 2 * 2;
        let mut i = 0;
        while i < full {
            let v = vld1q_f64(xs.as_ptr().add(i));
            vst1q_f64(xs.as_mut_ptr().add(i), vmulq_f64(v, f));
            i += 2;
        }
        for x in &mut xs[full..] {
            *x *= factor;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn sub_scaled(dst: &mut [f64], src: &[f64], factor: f64) {
        let f = vdupq_n_f64(factor);
        let full = dst.len() / 2 * 2;
        let mut i = 0;
        while i < full {
            let d = vld1q_f64(dst.as_ptr().add(i));
            let s = vld1q_f64(src.as_ptr().add(i));
            vst1q_f64(dst.as_mut_ptr().add(i), vsubq_f64(d, vmulq_f64(f, s)));
            i += 2;
        }
        for l in full..dst.len() {
            dst[l] -= factor * src[l];
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn add_assign(dst: &mut [f64], src: &[f64]) {
        let full = dst.len() / 2 * 2;
        let mut i = 0;
        while i < full {
            let d = vld1q_f64(dst.as_ptr().add(i));
            let s = vld1q_f64(src.as_ptr().add(i));
            vst1q_f64(dst.as_mut_ptr().add(i), vaddq_f64(d, s));
            i += 2;
        }
        for l in full..dst.len() {
            dst[l] += src[l];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn vec_f32(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn vec_f64(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Run `f` once with SIMD allowed and once forced scalar; restore state.
    fn both_modes<T>(f: impl Fn() -> T) -> (T, T) {
        force_scalar(false);
        let fast = f();
        force_scalar(true);
        let slow = f();
        force_scalar(false);
        (fast, slow)
    }

    #[test]
    fn reductions_bit_identical_across_modes() {
        let mut rng = Rng::new(0xD07);
        for n in [0, 1, 3, 7, 8, 9, 15, 16, 31, 32, 33, 100, 257] {
            let a = vec_f32(&mut rng, n);
            let b = vec_f32(&mut rng, n);
            let (fast, slow) = both_modes(|| dot_norms(&a, &b));
            assert_eq!(fast.0.to_bits(), slow.0.to_bits(), "dot n={n}");
            assert_eq!(fast.1.to_bits(), slow.1.to_bits(), "na n={n}");
            assert_eq!(fast.2.to_bits(), slow.2.to_bits(), "nb n={n}");
            let (fast, slow) = both_modes(|| sum_sq(&a));
            assert_eq!(fast.to_bits(), slow.to_bits(), "sum_sq n={n}");
        }
    }

    #[test]
    fn elementwise_bit_identical_across_modes() {
        let mut rng = Rng::new(0xE1E);
        for n in [0, 1, 2, 3, 4, 5, 7, 8, 13, 64, 101] {
            let base = vec_f64(&mut rng, n);
            let other = vec_f64(&mut rng, n);
            let basef: Vec<f32> = base.iter().map(|&x| x as f32).collect();
            let (na, nb) = (1.0 + rng.f64() * 5.0, 1.0 + rng.f64() * 5.0);
            let factor = rng.normal();

            let (fast, slow) = both_modes(|| {
                let mut v = base.clone();
                lw_merge(&mut v, &other, na, nb);
                v
            });
            assert!(fast.iter().zip(&slow).all(|(a, b)| a.to_bits() == b.to_bits()));

            let (fast, slow) = both_modes(|| {
                let mut v = base.clone();
                scale(&mut v, factor);
                v
            });
            assert!(fast.iter().zip(&slow).all(|(a, b)| a.to_bits() == b.to_bits()));

            let (fast, slow) = both_modes(|| {
                let mut v = base.clone();
                sub_scaled(&mut v, &other, factor);
                v
            });
            assert!(fast.iter().zip(&slow).all(|(a, b)| a.to_bits() == b.to_bits()));

            let (fast, slow) = both_modes(|| {
                let mut v = base.clone();
                add_assign(&mut v, &other);
                v
            });
            assert!(fast.iter().zip(&slow).all(|(a, b)| a.to_bits() == b.to_bits()));

            let (fast, slow) = both_modes(|| {
                let mut v = basef.clone();
                div_scalar_f32(&mut v, 3.7);
                v
            });
            assert!(fast.iter().zip(&slow).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn dot_norms_matches_plain_math() {
        // The lane-tree result equals a plain sum within fp tolerance.
        let mut rng = Rng::new(0x5EED);
        let a = vec_f32(&mut rng, 67);
        let b = vec_f32(&mut rng, 67);
        let (dot, na, nb) = dot_norms(&a, &b);
        let refdot: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        let refna: f64 = a.iter().map(|&x| (x as f64) * (x as f64)).sum();
        let refnb: f64 = b.iter().map(|&y| (y as f64) * (y as f64)).sum();
        assert!((dot - refdot).abs() < 1e-9);
        assert!((na - refna).abs() < 1e-9);
        assert!((nb - refnb).abs() < 1e-9);
    }
}
