//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so we implement a
//! small, well-tested generator in-repo. We use **SplitMix64** for seeding
//! and **xoshiro256++** for the stream — the same construction the reference
//! `rand_xoshiro` crate uses — which is more than adequate for simulation
//! workloads (not cryptographic).

/// xoshiro256++ PRNG seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    /// Derive an independent child stream (for per-problem / per-worker rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let a = self.next_u64();
        Rng::new(a ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → uniform double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection method (unbiased).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean / std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Sample an index from unnormalized non-negative weights.
    /// Panics if all weights are zero/negative.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().map(|w| w.max(0.0)).sum();
        assert!(total > 0.0, "weighted(): all weights zero");
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            let w = w.max(0.0);
            if t < w {
                return i;
            }
            t -= w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(5);
        let w = [0.0, 1.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[2] > counts[1] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let s = r.sample_indices(20, 10);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 10);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(100);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
