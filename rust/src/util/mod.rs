//! Shared infrastructure substrates: RNG, stats, JSON, CLI args, TOML config,
//! and a mini property-testing harness. These replace external crates that
//! are unreachable in the offline build environment (rand, serde, clap, toml,
//! proptest).

pub mod argparse;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod toml;
