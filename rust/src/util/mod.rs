//! Shared infrastructure substrates: RNG, stats, JSON, CLI args, TOML config,
//! error plumbing, and a mini property-testing harness. These replace
//! external crates that are unreachable in the offline build environment
//! (rand, serde, clap, toml, proptest, anyhow).

pub mod affinity;
pub mod argparse;
pub mod error;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod toml;
