//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! subcommands (first positional). Typed getters parse on access and report
//! readable errors.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, options, flags, positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Binary name (argv[0]).
    pub program: String,
    /// Key → value for `--key value` / `--key=value`.
    opts: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    flags: Vec<String>,
    /// Positional arguments in order (subcommand not included).
    pub positional: Vec<String>,
}

/// Option keys that take a value; everything else starting with `--` is a flag.
pub struct Spec {
    value_keys: Vec<&'static str>,
}

impl Spec {
    pub fn new(value_keys: &[&'static str]) -> Self {
        Self { value_keys: value_keys.to_vec() }
    }

    /// Parse a raw argv (excluding nothing; pass `std::env::args()`).
    pub fn parse<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args, String> {
        let mut it = argv.into_iter();
        let program = it.next().unwrap_or_default();
        let mut args = Args { program, ..Default::default() };
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if self.value_keys.contains(&body) {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("--{body} expects a value"))?;
                    args.opts.insert(body.to_string(), v);
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }
}

impl Args {
    /// First positional (conventionally the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}={v}: {e}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}={v}: {e}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}={v}: {e}")),
        }
    }

    /// Comma-separated list of usize, e.g. `--widths 16,64,256`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>, String> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse().map_err(|e| format!("--{key}={v}: {e}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        std::iter::once("prog".to_string())
            .chain(s.split_whitespace().map(|t| t.to_string()))
            .collect()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let spec = Spec::new(&["width", "dataset"]);
        let a = spec
            .parse(argv("search --width 64 --dataset=synth-math500 --verbose extra"))
            .unwrap();
        assert_eq!(a.subcommand(), Some("search"));
        assert_eq!(a.get("width"), Some("64"));
        assert_eq!(a.get("dataset"), Some("synth-math500"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["search", "extra"]);
    }

    #[test]
    fn typed_getters() {
        let spec = Spec::new(&["n", "x", "widths"]);
        let a = spec.parse(argv("--n 5 --x 1.5 --widths 16,64,256")).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 5);
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 1.5);
        assert_eq!(a.get_usize_list("widths", &[]).unwrap(), vec![16, 64, 256]);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn missing_value_errors() {
        let spec = Spec::new(&["width"]);
        assert!(spec.parse(argv("--width")).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let spec = Spec::new(&["n"]);
        let a = spec.parse(argv("--n abc")).unwrap();
        assert!(a.get_usize("n", 0).is_err());
    }
}
