//! Zero-dependency CPU-affinity shim.
//!
//! Linux: raw `sched_setaffinity(2)` against the libc that std already
//! links — no `libc` crate. Everywhere else: a no-op that reports failure,
//! so callers degrade to OS placement. Pinning is purely a *placement*
//! knob: it changes where work runs, never what it computes, so
//! `--pin-cores` on/off must (and does, per the determinism suite) produce
//! identical serve results.

/// Worker threads available to this process (fallback 1).
pub fn num_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Pin the *calling thread* to `core` (wrapped into the available range by
/// the caller if desired). Returns `true` when the kernel accepted the
/// mask; `false` on failure or on non-Linux targets.
#[cfg(target_os = "linux")]
pub fn pin_to_core(core: usize) -> bool {
    // Matches glibc/musl: cpu_set_t is a 1024-bit mask; pid 0 = this thread
    // (the raw syscall semantics sched_setaffinity forwards to).
    const SET_WORDS: usize = 1024 / 64;
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    if core >= 1024 {
        return false;
    }
    let mut mask = [0u64; SET_WORDS];
    mask[core / 64] = 1u64 << (core % 64);
    // SAFETY: the mask buffer outlives the call and its size is passed.
    unsafe { sched_setaffinity(0, SET_WORDS * 8, mask.as_ptr()) == 0 }
}

#[cfg(not(target_os = "linux"))]
pub fn pin_to_core(_core: usize) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_cores_positive() {
        assert!(num_cores() >= 1);
    }

    #[test]
    fn pin_to_core_zero_is_accepted_on_linux() {
        let ok = pin_to_core(0);
        if cfg!(target_os = "linux") {
            // core 0 exists on any Linux box this test runs on; do not
            // leave the test thread pinned afterwards
            assert!(ok, "sched_setaffinity(0) failed");
            let all: Vec<bool> = (0..num_cores()).map(pin_to_core).collect();
            assert!(all.iter().any(|&b| b));
        } else {
            assert!(!ok);
        }
    }

    #[test]
    fn out_of_range_core_is_rejected() {
        assert!(!pin_to_core(1 << 20));
    }
}
