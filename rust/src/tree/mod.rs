//! Search-tree representation shared by all search policies.
//!
//! A [`SearchTree`] holds the partial-trajectory tree for one problem: every
//! node is one reasoning *step* (a span of generated tokens), children extend
//! their parent, and the KV cache for a node's tokens is shared by all
//! descendants. Node bookkeeping (token counts, live/pruned state) feeds the
//! ETS cost model (`|V_S|`, `|V_A|`).
//!
//! Storage is struct-of-arrays: parents, rewards, live flags, and per-step
//! token counts live in parallel `Vec`s so the hot sweeps — `retain_paths`,
//! `spanned_subtree`, frontier scans — stream linearly over dense arrays
//! instead of hopping between per-node structs. Reads go through the
//! [`NodeRef`] view (`tree.get(id).step / .parent / .reward / .live /
//! .children`), writes through targeted setters.
//!
//! KV accounting does *not* live here: the serving KV numbers (live /
//! unshared footprints) are views over the shared
//! [`crate::kvcache::RadixCache`], maintained by
//! [`crate::engine::BatchEngine`] as trajectories are expanded, pruned, and
//! completed. The tree only knows per-step token counts.

/// Node id within a [`SearchTree`].
pub type NodeId = usize;

/// Payload of one generated step, supplied by a [`crate::lm::StepGenerator`].
#[derive(Clone, Debug, Default)]
pub struct StepInfo {
    /// Number of tokens this step appended (its share of KV cache).
    pub tokens: usize,
    /// Semantic group of the step ("approach"); drives paraphrase-aware
    /// embeddings. PJRT LMs derive it from content hashes.
    pub sem: u64,
    /// Paraphrase variant within the semantic group.
    pub paraphrase: u64,
    /// Surface token ids (PJRT path; empty for pure simulation).
    pub token_ids: Vec<u32>,
    /// Whether the trajectory ending here is complete (answer emitted).
    pub terminal: bool,
    /// Final answer value when `terminal`.
    pub answer: Option<i64>,
    /// WORKLOAD LATENT — never read by search policies: trajectory-prefix
    /// identity in the synthetic fate space.
    pub path_id: u64,
    /// WORKLOAD LATENT — never read by search policies: whether the prefix
    /// is still on a correct solution path.
    pub alive: bool,
}

/// Read view of one step of a partial trajectory (the column slice of the
/// struct-of-arrays store at one node id).
#[derive(Clone, Copy, Debug)]
pub struct NodeRef<'a> {
    pub parent: Option<NodeId>,
    pub children: &'a [NodeId],
    /// Step payload.
    pub step: &'a StepInfo,
    /// PRM reward of the trajectory prefix ending at this node.
    pub reward: f64,
    /// True while the node is part of a live (unpruned) trajectory path.
    pub live: bool,
}

/// Partial-trajectory tree for one search problem (struct-of-arrays).
#[derive(Clone, Debug, Default)]
pub struct SearchTree {
    parents: Vec<Option<NodeId>>,
    children: Vec<Vec<NodeId>>,
    /// PRM reward of the trajectory prefix ending at each node.
    rewards: Vec<f64>,
    /// Live (unpruned) flag per node — the `retain_paths` sweep column.
    live: Vec<bool>,
    /// Hot mirror of `steps[i].tokens` so token sweeps stay in one dense
    /// array (`StepInfo.tokens` is set at creation and never mutated).
    step_tokens: Vec<usize>,
    steps: Vec<StepInfo>,
    root: Option<NodeId>,
}

impl SearchTree {
    pub fn new() -> Self {
        Self::default()
    }

    fn push_node(&mut self, parent: Option<NodeId>, step: StepInfo, reward: f64) -> NodeId {
        let id = self.steps.len();
        self.parents.push(parent);
        self.children.push(vec![]);
        self.rewards.push(reward);
        self.live.push(true);
        self.step_tokens.push(step.tokens);
        self.steps.push(step);
        id
    }

    /// Create the root (the problem prompt), with `tokens` prompt tokens.
    pub fn init_root(&mut self, tokens: usize) -> NodeId {
        assert!(self.root.is_none(), "root already set");
        let id = self.push_node(None, StepInfo { tokens, alive: true, ..Default::default() }, 0.0);
        self.root = Some(id);
        id
    }

    pub fn root(&self) -> NodeId {
        self.root.expect("tree has no root")
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    pub fn get(&self, id: NodeId) -> NodeRef<'_> {
        NodeRef {
            parent: self.parents[id],
            children: &self.children[id],
            step: &self.steps[id],
            reward: self.rewards[id],
            live: self.live[id],
        }
    }

    /// Overwrite the PRM reward of `id` (reward-model rescoring).
    pub fn set_reward(&mut self, id: NodeId, reward: f64) {
        self.rewards[id] = reward;
    }

    /// Attach minted surface token ids to `id` (PJRT commit path).
    pub fn set_token_ids(&mut self, id: NodeId, token_ids: Vec<u32>) {
        self.steps[id].token_ids = token_ids;
    }

    /// Append a child step under `parent`.
    pub fn add_child(&mut self, parent: NodeId, step: StepInfo, reward: f64) -> NodeId {
        let id = self.push_node(Some(parent), step, reward);
        self.children[parent].push(id);
        id
    }

    /// Path from root to `id`, inclusive.
    pub fn path(&self, id: NodeId) -> Vec<NodeId> {
        let mut p = vec![id];
        let mut cur = id;
        while let Some(parent) = self.parents[cur] {
            p.push(parent);
            cur = parent;
        }
        p.reverse();
        p
    }

    /// Depth of `id` (root = 0).
    pub fn depth(&self, id: NodeId) -> usize {
        self.path(id).len() - 1
    }

    /// Total tokens along the path root..=id (the sequence length at `id`).
    pub fn seq_len(&self, id: NodeId) -> usize {
        let mut total = self.step_tokens[id];
        let mut cur = id;
        while let Some(parent) = self.parents[cur] {
            total += self.step_tokens[parent];
            cur = parent;
        }
        total
    }

    /// Mark ancestors of each of `leaves` in `mark`, stopping each upward
    /// walk at the first already-marked node (shared prefixes walked once).
    fn mark_paths(&self, leaves: &[NodeId], mark: &mut [bool]) {
        for &leaf in leaves {
            let mut cur = leaf;
            while !mark[cur] {
                mark[cur] = true;
                match self.parents[cur] {
                    Some(p) => cur = p,
                    None => break,
                }
            }
        }
    }

    /// Mark the paths of `keep` live and prune every other previously-live
    /// leaf path. Returns the number of nodes that transitioned live→pruned.
    pub fn retain_paths(&mut self, keep: &[NodeId]) -> usize {
        let mut mark = vec![false; self.steps.len()];
        self.mark_paths(keep, &mut mark);
        let mut pruned = 0;
        // linear sweep over the dense live column
        for (id, &keep_it) in mark.iter().enumerate() {
            if self.live[id] && !keep_it {
                self.live[id] = false;
                pruned += 1;
            }
        }
        pruned
    }

    /// Unique live nodes (`|V|` over the live tree).
    pub fn live_nodes(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }

    /// Build the ETS selection sub-problem over `candidates` (current
    /// frontier leaves): the spanned subtree with dense renumbering.
    ///
    /// Returns (parents vector, leaf-node index per candidate, tokens per
    /// spanned node).
    pub fn spanned_subtree(
        &self,
        candidates: &[NodeId],
    ) -> (Vec<Option<usize>>, Vec<usize>, Vec<usize>) {
        // Mark spanned nodes, then renumber by one linear id scan: ids are
        // allocation-ordered, so the scan yields the same parent-precedes-
        // child dense order the old sort-based implementation produced.
        let n = self.steps.len();
        let mut in_span = vec![false; n];
        self.mark_paths(candidates, &mut in_span);
        let mut dense = vec![usize::MAX; n];
        let mut parents: Vec<Option<usize>> = Vec::new();
        let mut tokens: Vec<usize> = Vec::new();
        for id in 0..n {
            if !in_span[id] {
                continue;
            }
            dense[id] = parents.len();
            parents.push(self.parents[id].filter(|&p| in_span[p]).map(|p| dense[p]));
            tokens.push(self.step_tokens[id]);
        }
        let leaf_idx: Vec<usize> = candidates.iter().map(|&c| dense[c]).collect();
        (parents, leaf_idx, tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;

    fn chain(tree: &mut SearchTree, from: NodeId, steps: usize, tokens: usize) -> NodeId {
        let mut cur = from;
        for _ in 0..steps {
            cur = tree.add_child(cur, StepInfo { tokens, ..Default::default() }, 0.5);
        }
        cur
    }

    /// Σ step tokens over live nodes (what the engine's cache accounting
    /// must reproduce; computed here from first principles).
    fn live_step_tokens(t: &SearchTree) -> usize {
        (0..t.len()).filter(|&i| t.get(i).live).map(|i| t.get(i).step.tokens).sum()
    }

    #[test]
    fn path_and_depth() {
        let mut t = SearchTree::new();
        let root = t.init_root(10);
        let leaf = chain(&mut t, root, 3, 5);
        assert_eq!(t.depth(leaf), 3);
        assert_eq!(t.path(leaf).len(), 4);
        assert_eq!(t.seq_len(leaf), 10 + 15);
    }

    #[test]
    fn retain_paths_prunes_others() {
        let mut t = SearchTree::new();
        let root = t.init_root(4);
        let a = chain(&mut t, root, 2, 3);
        let b = chain(&mut t, root, 2, 3);
        assert_eq!(t.live_nodes(), 5);
        let pruned = t.retain_paths(&[a]);
        assert_eq!(pruned, 2);
        assert_eq!(t.live_nodes(), 3);
        assert!(!t.get(b).live);
        assert_eq!(live_step_tokens(&t), 4 + 6);
    }

    #[test]
    fn setters_update_the_read_view() {
        let mut t = SearchTree::new();
        let root = t.init_root(1);
        let a = t.add_child(root, StepInfo { tokens: 2, ..Default::default() }, 0.25);
        t.set_reward(a, 0.75);
        t.set_token_ids(a, vec![7, 8, 9]);
        assert_eq!(t.get(a).reward, 0.75);
        assert_eq!(t.get(a).step.token_ids, vec![7, 8, 9]);
        assert_eq!(t.get(a).step.tokens, 2, "token count untouched by setters");
        assert_eq!(t.seq_len(a), 3);
    }

    #[test]
    fn seq_len_charges_the_full_path() {
        let mut t = SearchTree::new();
        let root = t.init_root(100);
        // two leaves sharing the 100-token prompt + a 10-token step
        let mid = t.add_child(root, StepInfo { tokens: 10, ..Default::default() }, 0.5);
        let l1 = t.add_child(mid, StepInfo { tokens: 10, ..Default::default() }, 0.5);
        let l2 = t.add_child(mid, StepInfo { tokens: 10, ..Default::default() }, 0.5);
        assert_eq!(t.seq_len(l1), 120);
        assert_eq!(t.seq_len(l2), 120);
        // each node counted once when walking the union of paths
        assert_eq!(live_step_tokens(&t), 130);
    }

    #[test]
    fn spanned_subtree_renumbers_consistently() {
        let mut t = SearchTree::new();
        let root = t.init_root(1);
        let a1 = t.add_child(root, StepInfo { tokens: 1, ..Default::default() }, 0.5);
        let _dead = chain(&mut t, root, 3, 1); // not part of candidates
        let a2 = t.add_child(a1, StepInfo { tokens: 1, ..Default::default() }, 0.5);
        let b = t.add_child(root, StepInfo { tokens: 1, ..Default::default() }, 0.5);
        let (parents, leaf_idx, tokens) = t.spanned_subtree(&[a2, b]);
        assert_eq!(parents.len(), 4); // root, a1, a2, b
        assert_eq!(tokens.len(), 4);
        // exactly one root in the span
        assert_eq!(parents.iter().filter(|p| p.is_none()).count(), 1);
        // each candidate's leaf index valid and parents chain to the root
        for &li in &leaf_idx {
            let mut v = li;
            let mut hops = 0;
            while let Some(p) = parents[v] {
                v = p;
                hops += 1;
                assert!(hops <= parents.len());
            }
        }
    }

    #[test]
    fn prop_live_kv_never_exceeds_unshared() {
        property(100, |rng: &mut Rng| {
            let mut t = SearchTree::new();
            let root = t.init_root(1 + rng.index(50));
            let mut leaves = vec![root];
            for _ in 0..rng.index(40) {
                let parent = leaves[rng.index(leaves.len())];
                let leaf = t.add_child(
                    parent,
                    StepInfo { tokens: 1 + rng.index(20), ..Default::default() },
                    rng.f64(),
                );
                leaves.push(leaf);
            }
            let frontier: Vec<NodeId> = leaves
                .iter()
                .copied()
                .filter(|&l| t.get(l).children.is_empty())
                .collect();
            let shared = live_step_tokens(&t);
            let unshared: usize = frontier.iter().map(|&l| t.seq_len(l)).sum();
            crate::prop_check!(
                shared <= unshared || frontier.is_empty(),
                "shared {shared} > unshared {unshared}"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_retain_then_live_matches_kept_union() {
        property(100, |rng: &mut Rng| {
            let mut t = SearchTree::new();
            let root = t.init_root(1);
            let mut all = vec![root];
            for _ in 0..(1 + rng.index(30)) {
                let parent = all[rng.index(all.len())];
                all.push(t.add_child(parent, StepInfo { tokens: 1, ..Default::default() }, 0.5));
            }
            let k = 1 + rng.index(all.len());
            let keep: Vec<NodeId> = rng.sample_indices(all.len(), k);
            t.retain_paths(&keep);
            let mut expect: std::collections::HashSet<NodeId> =
                std::collections::HashSet::new();
            for &l in &keep {
                for n in t.path(l) {
                    expect.insert(n);
                }
            }
            crate::prop_check!(t.live_nodes() == expect.len());
            Ok(())
        });
    }

    #[test]
    fn prop_spanned_subtree_matches_reference() {
        // The bitmap + linear-scan renumbering must equal the reference
        // HashSet + sort implementation node for node.
        property(80, |rng: &mut Rng| {
            let mut t = SearchTree::new();
            let root = t.init_root(1);
            let mut all = vec![root];
            for _ in 0..rng.index(40) {
                let parent = all[rng.index(all.len())];
                all.push(t.add_child(
                    parent,
                    StepInfo { tokens: 1 + rng.index(9), ..Default::default() },
                    0.5,
                ));
            }
            let k = 1 + rng.index(all.len());
            let cands: Vec<NodeId> = rng.sample_indices(all.len(), k);
            let (parents, leaf_idx, tokens) = t.spanned_subtree(&cands);
            // reference: HashSet + sorted ids + binary-search renumbering
            let mut in_span: std::collections::HashSet<NodeId> =
                std::collections::HashSet::new();
            for &leaf in &cands {
                for n in t.path(leaf) {
                    in_span.insert(n);
                }
            }
            let mut span: Vec<NodeId> = in_span.iter().copied().collect();
            span.sort_unstable();
            let index_of = |id: NodeId| span.binary_search(&id).unwrap();
            let ref_parents: Vec<Option<usize>> = span
                .iter()
                .map(|&id| t.get(id).parent.filter(|p| in_span.contains(p)).map(index_of))
                .collect();
            let ref_leaf: Vec<usize> = cands.iter().map(|&c| index_of(c)).collect();
            let ref_tokens: Vec<usize> =
                span.iter().map(|&id| t.get(id).step.tokens).collect();
            crate::prop_check!(parents == ref_parents, "parents mismatch");
            crate::prop_check!(leaf_idx == ref_leaf, "leaf indices mismatch");
            crate::prop_check!(tokens == ref_tokens, "tokens mismatch");
            Ok(())
        });
    }
}
