//! Search-tree representation shared by all search policies.
//!
//! A [`SearchTree`] holds the partial-trajectory tree for one problem: every
//! node is one reasoning *step* (a span of generated tokens), children extend
//! their parent, and the KV cache for a node's tokens is shared by all
//! descendants. Node bookkeeping (token counts, live/pruned state) feeds the
//! ETS cost model (`|V_S|`, `|V_A|`).
//!
//! KV accounting does *not* live here: the serving KV numbers (live /
//! unshared footprints) are views over the shared
//! [`crate::kvcache::RadixCache`], maintained by
//! [`crate::engine::BatchEngine`] as trajectories are expanded, pruned, and
//! completed. The tree only knows per-step token counts.

use std::collections::HashSet;

/// Node id within a [`SearchTree`].
pub type NodeId = usize;

/// Payload of one generated step, supplied by a [`crate::lm::StepGenerator`].
#[derive(Clone, Debug, Default)]
pub struct StepInfo {
    /// Number of tokens this step appended (its share of KV cache).
    pub tokens: usize,
    /// Semantic group of the step ("approach"); drives paraphrase-aware
    /// embeddings. PJRT LMs derive it from content hashes.
    pub sem: u64,
    /// Paraphrase variant within the semantic group.
    pub paraphrase: u64,
    /// Surface token ids (PJRT path; empty for pure simulation).
    pub token_ids: Vec<u32>,
    /// Whether the trajectory ending here is complete (answer emitted).
    pub terminal: bool,
    /// Final answer value when `terminal`.
    pub answer: Option<i64>,
    /// WORKLOAD LATENT — never read by search policies: trajectory-prefix
    /// identity in the synthetic fate space.
    pub path_id: u64,
    /// WORKLOAD LATENT — never read by search policies: whether the prefix
    /// is still on a correct solution path.
    pub alive: bool,
}

/// One step of a partial trajectory.
#[derive(Clone, Debug)]
pub struct Node {
    pub parent: Option<NodeId>,
    pub children: Vec<NodeId>,
    /// Step payload.
    pub step: StepInfo,
    /// PRM reward of the trajectory prefix ending at this node.
    pub reward: f64,
    /// True while the node is part of a live (unpruned) trajectory path.
    pub live: bool,
}

/// Partial-trajectory tree for one search problem.
#[derive(Clone, Debug, Default)]
pub struct SearchTree {
    nodes: Vec<Node>,
    root: Option<NodeId>,
}

impl SearchTree {
    pub fn new() -> Self {
        Self::default()
    }

    /// Create the root (the problem prompt), with `tokens` prompt tokens.
    pub fn init_root(&mut self, tokens: usize) -> NodeId {
        assert!(self.root.is_none(), "root already set");
        self.nodes.push(Node {
            parent: None,
            children: vec![],
            step: StepInfo { tokens, alive: true, ..Default::default() },
            reward: 0.0,
            live: true,
        });
        self.root = Some(0);
        0
    }

    pub fn root(&self) -> NodeId {
        self.root.expect("tree has no root")
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn get(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn get_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id]
    }

    /// Append a child step under `parent`.
    pub fn add_child(&mut self, parent: NodeId, step: StepInfo, reward: f64) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node {
            parent: Some(parent),
            children: vec![],
            step,
            reward,
            live: true,
        });
        self.nodes[parent].children.push(id);
        id
    }

    /// Path from root to `id`, inclusive.
    pub fn path(&self, id: NodeId) -> Vec<NodeId> {
        let mut p = vec![id];
        let mut cur = id;
        while let Some(parent) = self.nodes[cur].parent {
            p.push(parent);
            cur = parent;
        }
        p.reverse();
        p
    }

    /// Depth of `id` (root = 0).
    pub fn depth(&self, id: NodeId) -> usize {
        self.path(id).len() - 1
    }

    /// Total tokens along the path root..=id (the sequence length at `id`).
    pub fn seq_len(&self, id: NodeId) -> usize {
        self.path(id).iter().map(|&n| self.nodes[n].step.tokens).sum()
    }

    /// Mark the paths of `keep` live and prune every other previously-live
    /// leaf path. Returns the number of nodes that transitioned live→pruned.
    pub fn retain_paths(&mut self, keep: &[NodeId]) -> usize {
        let mut keep_set: HashSet<NodeId> = HashSet::new();
        for &leaf in keep {
            for n in self.path(leaf) {
                keep_set.insert(n);
            }
        }
        let mut pruned = 0;
        for id in 0..self.nodes.len() {
            if self.nodes[id].live && !keep_set.contains(&id) {
                self.nodes[id].live = false;
                pruned += 1;
            }
        }
        pruned
    }

    /// Unique live nodes (`|V|` over the live tree).
    pub fn live_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| n.live).count()
    }

    /// Build the ETS selection sub-problem over `candidates` (current
    /// frontier leaves): the spanned subtree with dense renumbering.
    ///
    /// Returns (parents vector, leaf-node index per candidate, tokens per
    /// spanned node).
    pub fn spanned_subtree(
        &self,
        candidates: &[NodeId],
    ) -> (Vec<Option<usize>>, Vec<usize>, Vec<usize>) {
        // Collect spanned nodes (dedup), keep stable order by node id so the
        // parent always precedes the child (ids are allocation-ordered).
        let mut in_span: HashSet<NodeId> = HashSet::new();
        for &leaf in candidates {
            for n in self.path(leaf) {
                in_span.insert(n);
            }
        }
        let mut span: Vec<NodeId> = in_span.iter().copied().collect();
        span.sort_unstable();
        let index_of = |id: NodeId| span.binary_search(&id).unwrap();
        let parents: Vec<Option<usize>> = span
            .iter()
            .map(|&id| {
                self.nodes[id]
                    .parent
                    .filter(|p| in_span.contains(p))
                    .map(index_of)
            })
            .collect();
        let leaf_idx: Vec<usize> = candidates.iter().map(|&c| index_of(c)).collect();
        let tokens: Vec<usize> = span.iter().map(|&id| self.nodes[id].step.tokens).collect();
        (parents, leaf_idx, tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;

    fn chain(tree: &mut SearchTree, from: NodeId, steps: usize, tokens: usize) -> NodeId {
        let mut cur = from;
        for _ in 0..steps {
            cur = tree.add_child(cur, StepInfo { tokens, ..Default::default() }, 0.5);
        }
        cur
    }

    /// Σ step tokens over live nodes (what the engine's cache accounting
    /// must reproduce; computed here from first principles).
    fn live_step_tokens(t: &SearchTree) -> usize {
        (0..t.len()).filter(|&i| t.get(i).live).map(|i| t.get(i).step.tokens).sum()
    }

    #[test]
    fn path_and_depth() {
        let mut t = SearchTree::new();
        let root = t.init_root(10);
        let leaf = chain(&mut t, root, 3, 5);
        assert_eq!(t.depth(leaf), 3);
        assert_eq!(t.path(leaf).len(), 4);
        assert_eq!(t.seq_len(leaf), 10 + 15);
    }

    #[test]
    fn retain_paths_prunes_others() {
        let mut t = SearchTree::new();
        let root = t.init_root(4);
        let a = chain(&mut t, root, 2, 3);
        let b = chain(&mut t, root, 2, 3);
        assert_eq!(t.live_nodes(), 5);
        let pruned = t.retain_paths(&[a]);
        assert_eq!(pruned, 2);
        assert_eq!(t.live_nodes(), 3);
        assert!(!t.get(b).live);
        assert_eq!(live_step_tokens(&t), 4 + 6);
    }

    #[test]
    fn seq_len_charges_the_full_path() {
        let mut t = SearchTree::new();
        let root = t.init_root(100);
        // two leaves sharing the 100-token prompt + a 10-token step
        let mid = t.add_child(root, StepInfo { tokens: 10, ..Default::default() }, 0.5);
        let l1 = t.add_child(mid, StepInfo { tokens: 10, ..Default::default() }, 0.5);
        let l2 = t.add_child(mid, StepInfo { tokens: 10, ..Default::default() }, 0.5);
        assert_eq!(t.seq_len(l1), 120);
        assert_eq!(t.seq_len(l2), 120);
        // each node counted once when walking the union of paths
        assert_eq!(live_step_tokens(&t), 130);
    }

    #[test]
    fn spanned_subtree_renumbers_consistently() {
        let mut t = SearchTree::new();
        let root = t.init_root(1);
        let a1 = t.add_child(root, StepInfo { tokens: 1, ..Default::default() }, 0.5);
        let _dead = chain(&mut t, root, 3, 1); // not part of candidates
        let a2 = t.add_child(a1, StepInfo { tokens: 1, ..Default::default() }, 0.5);
        let b = t.add_child(root, StepInfo { tokens: 1, ..Default::default() }, 0.5);
        let (parents, leaf_idx, tokens) = t.spanned_subtree(&[a2, b]);
        assert_eq!(parents.len(), 4); // root, a1, a2, b
        assert_eq!(tokens.len(), 4);
        // exactly one root in the span
        assert_eq!(parents.iter().filter(|p| p.is_none()).count(), 1);
        // each candidate's leaf index valid and parents chain to the root
        for &li in &leaf_idx {
            let mut v = li;
            let mut hops = 0;
            while let Some(p) = parents[v] {
                v = p;
                hops += 1;
                assert!(hops <= parents.len());
            }
        }
    }

    #[test]
    fn prop_live_kv_never_exceeds_unshared() {
        property(100, |rng: &mut Rng| {
            let mut t = SearchTree::new();
            let root = t.init_root(1 + rng.index(50));
            let mut leaves = vec![root];
            for _ in 0..rng.index(40) {
                let parent = leaves[rng.index(leaves.len())];
                let leaf = t.add_child(
                    parent,
                    StepInfo { tokens: 1 + rng.index(20), ..Default::default() },
                    rng.f64(),
                );
                leaves.push(leaf);
            }
            let frontier: Vec<NodeId> = leaves
                .iter()
                .copied()
                .filter(|&l| t.get(l).children.is_empty())
                .collect();
            let shared = live_step_tokens(&t);
            let unshared: usize = frontier.iter().map(|&l| t.seq_len(l)).sum();
            crate::prop_check!(
                shared <= unshared || frontier.is_empty(),
                "shared {shared} > unshared {unshared}"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_retain_then_live_matches_kept_union() {
        property(100, |rng: &mut Rng| {
            let mut t = SearchTree::new();
            let root = t.init_root(1);
            let mut all = vec![root];
            for _ in 0..(1 + rng.index(30)) {
                let parent = all[rng.index(all.len())];
                all.push(t.add_child(parent, StepInfo { tokens: 1, ..Default::default() }, 0.5));
            }
            let k = 1 + rng.index(all.len());
            let keep: Vec<NodeId> = rng.sample_indices(all.len(), k);
            t.retain_paths(&keep);
            let mut expect: std::collections::HashSet<NodeId> =
                std::collections::HashSet::new();
            for &l in &keep {
                for n in t.path(l) {
                    expect.insert(n);
                }
            }
            crate::prop_check!(t.live_nodes() == expect.len());
            Ok(())
        });
    }
}
