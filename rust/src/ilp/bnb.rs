//! 0/1 integer linear programming by branch-and-bound with LP bounds.
//!
//! Generic exact solver over the [`super::simplex`] LP engine — the in-repo
//! replacement for the paper's PuLP + CBC. Variables may be declared binary
//! or continuous-[0,1]; branching is on the most fractional binary variable,
//! depth-first with best-bound pruning.

use super::simplex::{solve, Lp, LpOutcome};

/// A 0/1 ILP: the embedded LP plus which variables are integral.
#[derive(Clone, Debug)]
pub struct Ilp {
    pub lp: Lp,
    /// `true` → variable must be 0 or 1 at the optimum.
    pub binary: Vec<bool>,
}

/// Result of an ILP solve.
#[derive(Clone, Debug, PartialEq)]
pub enum IlpOutcome {
    Optimal { objective: f64, x: Vec<f64> },
    Infeasible,
}

impl Ilp {
    /// All variables binary.
    pub fn all_binary(mut lp: Lp) -> Self {
        let n = lp.num_vars();
        for u in lp.ub.iter_mut() {
            *u = u.min(1.0);
        }
        Self { lp, binary: vec![true; n] }
    }
}

const INT_EPS: f64 = 1e-6;

struct Node {
    /// (var, value) fixings along this branch.
    fixings: Vec<(usize, f64)>,
    /// LP bound inherited from the parent (for pruning before re-solve).
    bound: f64,
}

/// Solve the ILP exactly. `time_limit` bounds wall time; on hitting it the
/// best incumbent found so far is returned (with `objective` still exact for
/// that incumbent). Returns `Infeasible` when no integral point exists.
pub fn solve_ilp(ilp: &Ilp, time_limit: std::time::Duration) -> IlpOutcome {
    let start = std::time::Instant::now();
    let n = ilp.lp.num_vars();
    let mut best_obj = f64::NEG_INFINITY;
    let mut best_x: Option<Vec<f64>> = None;

    let mut stack = vec![Node { fixings: vec![], bound: f64::INFINITY }];
    while let Some(node) = stack.pop() {
        if node.bound <= best_obj + 1e-9 {
            continue; // parent bound already dominated
        }
        if start.elapsed() > time_limit && best_x.is_some() {
            break;
        }
        // Build the LP with this node's fixings applied as bounds.
        let mut lp = ilp.lp.clone();
        let mut lo = vec![0.0f64; n];
        for &(var, val) in &node.fixings {
            if val >= 0.5 {
                lo[var] = 1.0; // x_var >= 1
                lp.geq(unit_row(n, var), 1.0);
            } else {
                lp.ub[var] = 0.0;
            }
        }
        let (obj, x) = match solve(&lp) {
            LpOutcome::Optimal { objective, x } => (objective, x),
            LpOutcome::Infeasible => continue,
            LpOutcome::Unbounded => {
                // Binary + bounded-vars problems can't be unbounded unless a
                // continuous var has infinite ub; treat as model error.
                panic!("ILP relaxation unbounded: add upper bounds");
            }
        };
        if obj <= best_obj + 1e-9 {
            continue;
        }
        // Most fractional binary variable.
        let mut branch_var = None;
        let mut best_frac = INT_EPS;
        for j in 0..n {
            if ilp.binary[j] {
                let f = (x[j] - x[j].round()).abs();
                if f > best_frac {
                    best_frac = f;
                    branch_var = Some(j);
                }
            }
        }
        match branch_var {
            None => {
                // Integral: new incumbent.
                if obj > best_obj {
                    best_obj = obj;
                    let mut xr = x;
                    for (j, v) in xr.iter_mut().enumerate() {
                        if ilp.binary[j] {
                            *v = v.round();
                        }
                    }
                    let _ = lo;
                    best_x = Some(xr);
                }
            }
            Some(j) => {
                // Branch: explore x_j = 1 first (reward-greedy for our use).
                let mut f1 = node.fixings.clone();
                f1.push((j, 0.0));
                stack.push(Node { fixings: f1, bound: obj });
                let mut f2 = node.fixings;
                f2.push((j, 1.0));
                stack.push(Node { fixings: f2, bound: obj });
            }
        }
    }
    match best_x {
        Some(x) => IlpOutcome::Optimal { objective: best_obj, x },
        None => IlpOutcome::Infeasible,
    }
}

fn unit_row(n: usize, j: usize) -> Vec<f64> {
    let mut r = vec![0.0; n];
    r[j] = 1.0;
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;
    use std::time::Duration;

    const LIMIT: Duration = Duration::from_secs(10);

    fn optimal(out: IlpOutcome) -> (f64, Vec<f64>) {
        match out {
            IlpOutcome::Optimal { objective, x } => (objective, x),
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn knapsack() {
        // max 10a + 6b + 4c s.t. a + b + c <= 2 (binary) → a + b = 16
        let mut lp = Lp::new(3);
        lp.c = vec![10.0, 6.0, 4.0];
        lp.leq(vec![1.0, 1.0, 1.0], 2.0);
        let (z, x) = optimal(solve_ilp(&Ilp::all_binary(lp), LIMIT));
        assert!((z - 16.0).abs() < 1e-6);
        assert_eq!(x, vec![1.0, 1.0, 0.0]);
    }

    #[test]
    fn fractional_lp_integral_ilp() {
        // LP relaxation fractional: max x+y s.t. 2x+2y <= 3 → LP 1.5, ILP 1.
        let mut lp = Lp::new(2);
        lp.c = vec![1.0, 1.0];
        lp.leq(vec![2.0, 2.0], 3.0);
        let (z, x) = optimal(solve_ilp(&Ilp::all_binary(lp), LIMIT));
        assert!((z - 1.0).abs() < 1e-6, "z = {z} x = {x:?}");
    }

    #[test]
    fn infeasible() {
        let mut lp = Lp::new(2);
        lp.c = vec![1.0, 1.0];
        lp.geq(vec![1.0, 1.0], 3.0); // needs sum >= 3 with two binaries
        assert_eq!(solve_ilp(&Ilp::all_binary(lp), LIMIT), IlpOutcome::Infeasible);
    }

    #[test]
    fn equality_via_pair() {
        // exactly one of three: max 3a+2b+c, a+b+c == 1
        let mut lp = Lp::new(3);
        lp.c = vec![3.0, 2.0, 1.0];
        lp.leq(vec![1.0, 1.0, 1.0], 1.0);
        lp.geq(vec![1.0, 1.0, 1.0], 1.0);
        let (z, x) = optimal(solve_ilp(&Ilp::all_binary(lp), LIMIT));
        assert!((z - 3.0).abs() < 1e-6);
        assert_eq!(x, vec![1.0, 0.0, 0.0]);
    }

    /// Exhaustive reference: enumerate all 2^n binary points.
    fn brute_force(lp: &Lp) -> Option<(f64, Vec<f64>)> {
        let n = lp.num_vars();
        let mut best: Option<(f64, Vec<f64>)> = None;
        for mask in 0..(1u32 << n) {
            let x: Vec<f64> =
                (0..n).map(|j| if mask >> j & 1 == 1 { 1.0 } else { 0.0 }).collect();
            if x.iter().zip(&lp.ub).any(|(xi, ubi)| xi > ubi) {
                continue;
            }
            let feasible = lp
                .a
                .iter()
                .zip(&lp.b)
                .all(|(row, &b)| row.iter().zip(&x).map(|(a, v)| a * v).sum::<f64>() <= b + 1e-9);
            if !feasible {
                continue;
            }
            let z: f64 = lp.c.iter().zip(&x).map(|(c, v)| c * v).sum();
            if best.as_ref().map(|(bz, _)| z > *bz).unwrap_or(true) {
                best = Some((z, x));
            }
        }
        best
    }

    #[test]
    fn matches_brute_force_on_random_instances() {
        property(60, |rng: &mut Rng| {
            let n = 3 + rng.index(6); // 3..8 vars
            let m = 1 + rng.index(4); // 1..4 constraints
            let mut lp = Lp::new(n);
            lp.c = (0..n).map(|_| rng.normal_ms(0.0, 2.0)).collect();
            for _ in 0..m {
                let row: Vec<f64> = (0..n).map(|_| rng.range_i64(-2, 3) as f64).collect();
                let rhs = rng.range_i64(0, n as i64) as f64;
                lp.leq(row, rhs);
            }
            let expect = brute_force(&lp);
            let got = solve_ilp(&Ilp::all_binary(lp), LIMIT);
            match (expect, got) {
                (None, IlpOutcome::Infeasible) => Ok(()),
                (Some((bz, _)), IlpOutcome::Optimal { objective, .. }) => {
                    crate::prop_check!(
                        (bz - objective).abs() < 1e-6,
                        "brute {bz} vs bnb {objective}"
                    );
                    Ok(())
                }
                (e, g) => Err(format!("mismatch: brute {e:?} vs bnb {g:?}")),
            }
        });
    }
}
