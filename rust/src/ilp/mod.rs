//! Integer linear programming substrate (in-repo replacement for PuLP+CBC).
//!
//! * [`simplex`] — dense two-phase primal simplex LP solver.
//! * [`bnb`] — generic exact 0/1 branch-and-bound over LP relaxations.
//! * [`select`] — the ETS trajectory-selection problem (paper Eq. 2/4) with a
//!   paper-faithful ILP formulation and an exact tree-DP fast path.

pub mod bnb;
pub mod select;
pub mod simplex;
