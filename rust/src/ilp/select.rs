//! The ETS trajectory-selection problem (paper Eq. 2 / Eq. 4) and its solvers.
//!
//! At each search step we must choose a subset `S` of candidate leaves
//! maximizing
//!
//! ```text
//!   Σ_{i∈S} W_i / Σ_{i∈A} W_i   −   λ_b · |V_S|/|V_A|   +   λ_d · |C_S|/|C_A|
//! ```
//!
//! subject to `|S| ≥ 1`, where `V_S` is the set of tree nodes on the paths of
//! the selected leaves (the KV-cache footprint) and `C_S` the set of semantic
//! clusters covered.
//!
//! Three solvers, all exact, cross-checked against each other in tests:
//!
//! * [`solve_brute`] — exhaustive, for n ≤ ~20 (testing oracle).
//! * [`solve_ilp`] — the paper-faithful formulation (binary `x_i` per leaf,
//!   continuous node indicators `y_v` and cluster indicators `z_c`, per-edge
//!   constraints) solved by the in-repo branch-and-bound over simplex.
//! * [`solve_tree`] — production fast path: branch-and-bound over leaves with
//!   an upper bound from a dynamic program on the tree (exact because node
//!   costs decompose along tree edges; the cluster bonus is over-counted in
//!   the bound, making it a valid UB, and is exact in every incumbent).

use super::bnb::{solve_ilp as bnb_solve, Ilp, IlpOutcome};
use super::simplex::Lp;
use std::collections::HashSet;
use std::time::Duration;

/// One candidate leaf trajectory at the current search step.
#[derive(Clone, Debug)]
pub struct Candidate {
    /// REBASE weight `W_i` (unnormalized; Eq. 1).
    pub weight: f64,
    /// Tree node holding this leaf's newest step KV.
    pub leaf_node: usize,
    /// Semantic cluster id in `0..num_clusters`.
    pub cluster: usize,
}

/// The selection problem over the subtree spanned by the candidates.
///
/// Nodes are densely numbered `0..num_nodes`; `parents[v]` is `None` for the
/// root(s). Every node must lie on some candidate's path (callers build the
/// spanned subtree — `|V_A| = num_nodes`).
#[derive(Clone, Debug)]
pub struct SelectionProblem {
    pub candidates: Vec<Candidate>,
    pub parents: Vec<Option<usize>>,
    /// Per-node retention cost weight. Uniform weights give the paper's
    /// exact `|V_S|/|V_A|` term (Eq. 2); the serving engine uses KV *token*
    /// counts per node, which measures the same quantity in bytes and
    /// avoids quantization cliffs when all steps cost the same.
    pub node_weight: Vec<f64>,
    pub num_clusters: usize,
    pub lambda_b: f64,
    pub lambda_d: f64,
}

/// Result: chosen candidate indices (non-empty) and the objective value.
#[derive(Clone, Debug, PartialEq)]
pub struct Selection {
    pub chosen: Vec<usize>,
    pub objective: f64,
}

impl SelectionProblem {
    pub fn num_nodes(&self) -> usize {
        self.parents.len()
    }

    /// Uniform node costs (paper Eq. 2 exactly).
    pub fn uniform_node_weight(n: usize) -> Vec<f64> {
        vec![1.0; n]
    }

    fn total_weight(&self) -> f64 {
        self.candidates.iter().map(|c| c.weight).sum()
    }

    fn total_node_weight(&self) -> f64 {
        self.node_weight.iter().sum()
    }

    /// Path from a leaf node to the root (inclusive).
    fn path(&self, mut v: usize) -> Vec<usize> {
        let mut p = vec![v];
        while let Some(u) = self.parents[v] {
            p.push(u);
            v = u;
        }
        p
    }

    /// Exact objective of a subset (empty subset → -inf, it's infeasible).
    pub fn objective(&self, subset: &[usize]) -> f64 {
        if subset.is_empty() {
            return f64::NEG_INFINITY;
        }
        let wsum = self.total_weight();
        let vsum = self.total_node_weight();
        let mut nodes: HashSet<usize> = HashSet::new();
        let mut clusters: HashSet<usize> = HashSet::new();
        let mut reward = 0.0;
        for &i in subset {
            let c = &self.candidates[i];
            reward += c.weight;
            clusters.insert(c.cluster);
            for v in self.path(c.leaf_node) {
                nodes.insert(v);
            }
        }
        let node_cost: f64 = nodes.iter().map(|&v| self.node_weight[v]).sum();
        reward / wsum - self.lambda_b * node_cost / vsum
            + self.lambda_d * clusters.len() as f64 / self.num_clusters.max(1) as f64
    }

    /// Sanity-check the instance (used by tests and debug builds).
    pub fn validate(&self) -> Result<(), String> {
        if self.candidates.is_empty() {
            return Err("no candidates".into());
        }
        if self.node_weight.len() != self.parents.len() {
            return Err("node_weight length mismatch".into());
        }
        if self.node_weight.iter().any(|&w| !(w > 0.0)) {
            return Err("non-positive node weight".into());
        }
        for c in &self.candidates {
            if c.leaf_node >= self.num_nodes() {
                return Err(format!("leaf_node {} out of range", c.leaf_node));
            }
            if c.cluster >= self.num_clusters {
                return Err(format!("cluster {} out of range", c.cluster));
            }
            if !(c.weight > 0.0) {
                return Err(format!("non-positive weight {}", c.weight));
            }
        }
        // acyclicity: path() must terminate
        for c in &self.candidates {
            let p = self.path(c.leaf_node);
            if p.len() > self.num_nodes() {
                return Err("cycle in parents".into());
            }
        }
        Ok(())
    }
}

/// Exhaustive testing oracle (n ≤ 25 or panics).
pub fn solve_brute(p: &SelectionProblem) -> Selection {
    let n = p.candidates.len();
    assert!(n <= 25, "brute force capped at 25 candidates");
    let mut best = Selection { chosen: vec![], objective: f64::NEG_INFINITY };
    for mask in 1u32..(1 << n) {
        let subset: Vec<usize> = (0..n).filter(|i| mask >> i & 1 == 1).collect();
        let obj = p.objective(&subset);
        if obj > best.objective + 1e-12 {
            best = Selection { chosen: subset, objective: obj };
        }
    }
    best
}

/// Paper-faithful ILP formulation solved with the generic B&B.
///
/// Variables: `x_i` (binary, per leaf), `y_v` (continuous [0,1], per node),
/// `z_c` (continuous [0,1], per cluster). Constraints per tree edge
/// `y_child ≤ y_parent`, per leaf `x_i ≤ y_{leaf_node(i)}`, per cluster
/// `z_c ≤ Σ_{i∈c} x_i`, and `Σ x_i ≥ 1`. With binary `x`, optimal `y`/`z`
/// equal the node/cluster indicators, so the LP objective matches Eq. 4.
pub fn solve_ilp(p: &SelectionProblem, limit: Duration) -> Selection {
    let n = p.candidates.len();
    let nv = p.num_nodes();
    let nc = p.num_clusters;
    let total = n + nv + nc;
    let wsum = p.total_weight();

    let mut lp = Lp::new(total);
    for (i, c) in p.candidates.iter().enumerate() {
        lp.c[i] = c.weight / wsum;
    }
    let vsum: f64 = p.node_weight.iter().sum();
    for v in 0..nv {
        lp.c[n + v] = -p.lambda_b * p.node_weight[v] / vsum;
    }
    for c in 0..nc {
        lp.c[n + nv + c] = p.lambda_d / nc.max(1) as f64;
    }
    lp.ub = vec![1.0; total];

    // x_i <= y_leaf
    for (i, c) in p.candidates.iter().enumerate() {
        let mut row = vec![0.0; total];
        row[i] = 1.0;
        row[n + c.leaf_node] = -1.0;
        lp.leq(row, 0.0);
    }
    // y_child <= y_parent per edge
    for (v, parent) in p.parents.iter().enumerate() {
        if let Some(u) = parent {
            let mut row = vec![0.0; total];
            row[n + v] = 1.0;
            row[n + u] = -1.0;
            lp.leq(row, 0.0);
        }
    }
    // z_c <= sum x_i in cluster c
    for cid in 0..nc {
        let mut row = vec![0.0; total];
        row[n + nv + cid] = 1.0;
        for (i, c) in p.candidates.iter().enumerate() {
            if c.cluster == cid {
                row[i] = -1.0;
            }
        }
        lp.leq(row, 0.0);
    }
    // at least one leaf
    let mut row = vec![0.0; total];
    for r in row.iter_mut().take(n) {
        *r = 1.0;
    }
    lp.geq(row, 1.0);

    let mut binary = vec![false; total];
    for b in binary.iter_mut().take(n) {
        *b = true;
    }
    match bnb_solve(&Ilp { lp, binary }, limit) {
        IlpOutcome::Optimal { x, .. } => {
            let chosen: Vec<usize> = (0..n).filter(|&i| x[i] > 0.5).collect();
            let objective = p.objective(&chosen);
            Selection { chosen, objective }
        }
        IlpOutcome::Infeasible => unreachable!("Σx≥1 with n≥1 is always feasible"),
    }
}

// ---------------------------------------------------------------------------
// Production fast path: branch & bound over leaves with a tree-DP bound.
// ---------------------------------------------------------------------------

struct TreeCtx {
    /// children[v] = internal child nodes of v.
    children: Vec<Vec<usize>>,
    /// candidate leaves attached to node v (leaf_node == v).
    leaves_at: Vec<Vec<usize>>,
    roots: Vec<usize>,
    /// topological order, children before parents.
    topo: Vec<usize>,
}

fn build_ctx(p: &SelectionProblem) -> TreeCtx {
    let nv = p.num_nodes();
    let mut children = vec![Vec::new(); nv];
    let mut roots = vec![];
    for (v, parent) in p.parents.iter().enumerate() {
        match parent {
            Some(u) => children[*u].push(v),
            None => roots.push(v),
        }
    }
    let mut leaves_at = vec![Vec::new(); nv];
    for (i, c) in p.candidates.iter().enumerate() {
        leaves_at[c.leaf_node].push(i);
    }
    // iterative post-order
    let mut topo = Vec::with_capacity(nv);
    let mut stack: Vec<(usize, bool)> = roots.iter().map(|&r| (r, false)).collect();
    while let Some((v, expanded)) = stack.pop() {
        if expanded {
            topo.push(v);
        } else {
            stack.push((v, true));
            for &c in &children[v] {
                stack.push((c, false));
            }
        }
    }
    TreeCtx { children, leaves_at, roots, topo }
}

/// State during B&B: per-candidate fixing (0 = excluded, 1 = forced, 2 = free).
const FIX_OUT: u8 = 0;
const FIX_IN: u8 = 1;
const FREE: u8 = 2;

struct TreeSolver<'a> {
    p: &'a SelectionProblem,
    ctx: TreeCtx,
    /// λ_b-scaled retention cost per node.
    node_cost: Vec<f64>,
    cluster_bonus: f64,
    wsum: f64,
    best: Selection,
    deadline: std::time::Instant,
    nodes_explored: usize,
    /// Sticky abort: set on deadline/cap, stops the whole recursion.
    expired: bool,
    node_cap: usize,
    /// Absolute optimality-gap tolerance (objective is O(1)-scaled; 1e-4
    /// trades exactness for a large cut in proven-optimal search time).
    gap_tol: f64,
}

impl<'a> TreeSolver<'a> {
    /// Fused DP: one tree pass computes BOTH bounds —
    /// `ub_leaf` (per-leaf cluster bonus, over-counted ⇒ valid UB, exact for
    /// λ_d = 0) and `ub_plain + λ_d·coverable` (global coverage credit) —
    /// plus the greedy incumbent selection. Returns
    /// (min of the two bounds, dp-selected free leaves).
    fn dp_fused(&self, fix: &[u8]) -> (f64, Vec<usize>) {
        let p = self.p;
        let nv = p.num_nodes();
        let mut paid = vec![false; nv];
        let mut covered = vec![false; p.num_clusters.max(1)];
        let mut base = 0.0;
        for (i, c) in p.candidates.iter().enumerate() {
            if fix[i] == FIX_IN {
                base += c.weight / self.wsum;
                if !covered[c.cluster] {
                    covered[c.cluster] = true;
                    base += self.cluster_bonus;
                }
                let mut v = c.leaf_node;
                loop {
                    if !paid[v] {
                        paid[v] = true;
                        base -= self.node_cost[v];
                    }
                    match p.parents[v] {
                        Some(u) => v = u,
                        None => break,
                    }
                }
            }
        }
        // coverable clusters for the global-credit bound
        let mut coverable_bonus = 0.0;
        if self.cluster_bonus > 0.0 {
            let mut seen = vec![false; p.num_clusters.max(1)];
            for (i, c) in p.candidates.iter().enumerate() {
                if fix[i] == FREE && !covered[c.cluster] && !seen[c.cluster] {
                    seen[c.cluster] = true;
                    coverable_bonus += self.cluster_bonus;
                }
            }
        }
        let mut gain_b = vec![0.0f64; nv]; // with per-leaf bonus
        let mut gain_p = vec![0.0f64; nv]; // plain
        for &v in &self.ctx.topo {
            let (mut gb, mut gp) = (0.0, 0.0);
            for &i in &self.ctx.leaves_at[v] {
                if fix[i] == FREE {
                    let c = &p.candidates[i];
                    let w = c.weight / self.wsum;
                    let bonus =
                        if covered[c.cluster] { 0.0 } else { self.cluster_bonus };
                    if w + bonus > 0.0 {
                        gb += w + bonus;
                    }
                    if w > 0.0 {
                        gp += w;
                    }
                }
            }
            for &ch in &self.ctx.children[v] {
                if gain_b[ch] > 0.0 {
                    gb += gain_b[ch];
                }
                if gain_p[ch] > 0.0 {
                    gp += gain_p[ch];
                }
            }
            if !paid[v] {
                gb -= self.node_cost[v];
                gp -= self.node_cost[v];
            }
            gain_b[v] = gb;
            gain_p[v] = gp;
        }
        let (mut ub_leaf, mut ub_plain) = (base, base);
        for &r in &self.ctx.roots {
            ub_leaf += gain_b[r].max(0.0);
            ub_plain += gain_p[r].max(0.0);
        }
        let ub = if self.cluster_bonus > 0.0 {
            ub_leaf.min(ub_plain + coverable_bonus)
        } else {
            ub_leaf
        };
        // Reconstruct the bonus-DP's selected free leaves.
        let mut sel = vec![];
        let mut stack: Vec<usize> = self
            .ctx
            .roots
            .iter()
            .copied()
            .filter(|&r| gain_b[r] > 0.0 || paid[r])
            .collect();
        while let Some(v) = stack.pop() {
            for &i in &self.ctx.leaves_at[v] {
                if fix[i] == FREE {
                    let c = &p.candidates[i];
                    let bonus =
                        if covered[c.cluster] { 0.0 } else { self.cluster_bonus };
                    if c.weight / self.wsum + bonus > 0.0 {
                        sel.push(i);
                    }
                }
            }
            for &ch in &self.ctx.children[v] {
                if gain_b[ch] > 0.0 {
                    stack.push(ch);
                }
            }
        }
        (ub, sel)
    }

    /// (kept for cross-checking in tests) single-bound DP.
    #[allow(dead_code)]
    fn dp(&self, fix: &[u8], with_bonus: bool) -> (f64, Vec<usize>) {
        let p = self.p;
        let nv = p.num_nodes();
        // paid[v]: node already paid for by a forced-in leaf's path.
        let mut paid = vec![false; nv];
        let mut covered = vec![false; p.num_clusters.max(1)];
        let mut base = 0.0;
        for (i, c) in p.candidates.iter().enumerate() {
            if fix[i] == FIX_IN {
                base += c.weight / self.wsum;
                if !covered[c.cluster] {
                    covered[c.cluster] = true;
                    base += self.cluster_bonus;
                }
                let mut v = c.leaf_node;
                loop {
                    if !paid[v] {
                        paid[v] = true;
                        base -= self.node_cost[v];
                    }
                    match p.parents[v] {
                        Some(u) => v = u,
                        None => break,
                    }
                }
            }
        }
        // DP over tree: gain[v] = best extra objective from free leaves in
        // v's subtree, given v's path to the root is paid.
        let mut gain = vec![0.0f64; nv];
        // track which free leaves the DP keeps: keep[v] bool gates subtree
        let mut keep_subtree = vec![false; nv];
        for &v in &self.ctx.topo {
            let mut g = 0.0;
            for &i in &self.ctx.leaves_at[v] {
                if fix[i] == FREE {
                    let c = &p.candidates[i];
                    let bonus = if with_bonus && !covered[c.cluster] {
                        self.cluster_bonus
                    } else {
                        0.0
                    };
                    let val = c.weight / self.wsum + bonus;
                    if val > 0.0 {
                        g += val;
                    }
                }
            }
            for &ch in &self.ctx.children[v] {
                if gain[ch] > 0.0 {
                    // child subtree worth keeping
                    g += gain[ch];
                }
            }
            if !paid[v] {
                g -= self.node_cost[v];
            }
            gain[v] = g;
        }
        let mut ub = base;
        for &r in &self.ctx.roots {
            if gain[r] > 0.0 {
                ub += gain[r];
                keep_subtree[r] = true;
            } else if paid[r] {
                // forced path through this root: subtree decisions below may
                // still be positive locally; gain[r] already accounts paid.
                if gain[r] > 0.0 {
                    keep_subtree[r] = true;
                }
                ub += gain[r].max(0.0);
            }
        }
        // Reconstruct the DP's selected free leaves (pre-order walk keeping
        // positive-gain subtrees).
        let mut sel = vec![];
        let mut stack: Vec<usize> =
            self.ctx.roots.iter().copied().filter(|&r| gain[r] > 0.0 || paid[r]).collect();
        while let Some(v) = stack.pop() {
            // Inside a kept subtree, keep each free leaf with positive value
            // and each child subtree with positive gain.
            for &i in &self.ctx.leaves_at[v] {
                if fix[i] == FREE {
                    let c = &p.candidates[i];
                    let bonus = if with_bonus && !covered[c.cluster] {
                        self.cluster_bonus
                    } else {
                        0.0
                    };
                    if c.weight / self.wsum + bonus > 0.0 {
                        sel.push(i);
                    }
                }
            }
            for &ch in &self.ctx.children[v] {
                if gain[ch] > 0.0 {
                    stack.push(ch);
                }
            }
        }
        (ub, sel)
    }

    /// Evaluate a concrete completion and update the incumbent.
    fn try_incumbent(&mut self, fix: &[u8], dp_sel: &[usize]) {
        let mut subset: Vec<usize> = (0..fix.len()).filter(|&i| fix[i] == FIX_IN).collect();
        subset.extend_from_slice(dp_sel);
        if subset.is_empty() {
            // |S| >= 1: fall back to the single best candidate.
            let best_single = (0..self.p.candidates.len())
                .filter(|&i| fix[i] != FIX_OUT)
                .max_by(|&a, &b| {
                    self.p.candidates[a]
                        .weight
                        .partial_cmp(&self.p.candidates[b].weight)
                        .unwrap()
                });
            match best_single {
                Some(i) => subset.push(i),
                None => return,
            }
        }
        subset.sort_unstable();
        subset.dedup();
        let obj = self.p.objective(&subset);
        if obj > self.best.objective + 1e-12 {
            self.best = Selection { chosen: subset, objective: obj };
        }
    }

    fn search(&mut self, fix: &mut Vec<u8>, order: &[usize], depth: usize) {
        if self.expired {
            return;
        }
        self.nodes_explored += 1;
        if self.nodes_explored >= self.node_cap
            || (self.nodes_explored % 64 == 0 && std::time::Instant::now() > self.deadline)
        {
            // Budget exhausted: abort the whole search, keep the incumbent
            // (always a feasible selection — solve_tree seeds one up front).
            self.expired = true;
            return;
        }
        let (ub, dp_sel) = self.dp_fused(fix);
        self.try_incumbent(fix, &dp_sel);
        if ub <= self.best.objective + self.gap_tol {
            return; // pruned: bound can't beat incumbent (within tolerance)
        }
        // Next free variable in branching order.
        let Some(&var) = order[depth..].iter().find(|&&i| fix[i] == FREE) else {
            return; // fully fixed; incumbent already evaluated
        };
        // Branch var = 1 first (reward-greedy).
        fix[var] = FIX_IN;
        self.search(fix, order, depth + 1);
        fix[var] = FIX_OUT;
        self.search(fix, order, depth + 1);
        fix[var] = FREE;
    }
}

/// Exact production solver: B&B over leaves with tree-DP bounds.
///
/// When `lambda_d == 0` the DP bound is exact and the root call returns
/// immediately. With the coverage term the bound over-counts shared-cluster
/// bonuses, so a few levels of branching resolve the ties. `limit` bounds
/// wall time; the incumbent (always a feasible selection) is returned on
/// expiry.
pub fn solve_tree(p: &SelectionProblem, limit: Duration) -> Selection {
    debug_assert!(p.validate().is_ok(), "{:?}", p.validate());
    let ctx = build_ctx(p);
    let wsum = p.total_weight();
    let vsum = p.total_node_weight();
    let node_cost: Vec<f64> =
        p.node_weight.iter().map(|w| p.lambda_b * w / vsum).collect();
    let cluster_bonus = p.lambda_d / p.num_clusters.max(1) as f64;
    let mut order: Vec<usize> = (0..p.candidates.len()).collect();
    order.sort_by(|&a, &b| {
        p.candidates[b].weight.partial_cmp(&p.candidates[a].weight).unwrap()
    });
    let mut solver = TreeSolver {
        p,
        ctx,
        node_cost,
        cluster_bonus,
        wsum,
        best: Selection { chosen: vec![], objective: f64::NEG_INFINITY },
        deadline: std::time::Instant::now() + limit,
        nodes_explored: 0,
        expired: false,
        node_cap: 500_000,
        gap_tol: 1e-4,
    };
    let mut fix = vec![FREE; p.candidates.len()];
    solver.search(&mut fix, &order, 0);
    debug_assert!(!solver.best.chosen.is_empty());
    solver.best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;

    const LIMIT: Duration = Duration::from_secs(20);

    /// Random selection instance over a random tree.
    pub(crate) fn random_problem(rng: &mut Rng, max_leaves: usize) -> SelectionProblem {
        let n_internal = 1 + rng.index(8);
        let mut parents: Vec<Option<usize>> = vec![None];
        for v in 1..n_internal {
            parents.push(Some(rng.index(v)));
        }
        let n_leaves = 1 + rng.index(max_leaves);
        let num_clusters = 1 + rng.index(n_leaves);
        let mut candidates = vec![];
        for _ in 0..n_leaves {
            // each candidate gets its own fresh leaf node under a random
            // existing node (mirrors "newly sampled continuation")
            let attach = rng.index(parents.len());
            parents.push(Some(attach));
            candidates.push(Candidate {
                weight: 1.0 + rng.index(10) as f64,
                leaf_node: parents.len() - 1,
                cluster: rng.index(num_clusters),
            });
        }
        let node_weight: Vec<f64> = if rng.chance(0.5) {
            SelectionProblem::uniform_node_weight(parents.len())
        } else {
            (0..parents.len()).map(|_| 1.0 + rng.index(60) as f64).collect()
        };
        SelectionProblem {
            candidates,
            parents,
            node_weight,
            num_clusters,
            lambda_b: rng.f64() * 2.0,
            lambda_d: if rng.chance(0.3) { 0.0 } else { rng.f64() * 1.5 },
        }
    }

    #[test]
    fn single_candidate_always_selected() {
        let p = SelectionProblem {
            candidates: vec![Candidate { weight: 1.0, leaf_node: 1, cluster: 0 }],
            parents: vec![None, Some(0)],
            node_weight: vec![1.0, 1.0],
            num_clusters: 1,
            lambda_b: 5.0, // even with a huge budget penalty
            lambda_d: 1.0,
        };
        let s = solve_tree(&p, LIMIT);
        assert_eq!(s.chosen, vec![0]);
        let s2 = solve_ilp(&p, LIMIT);
        assert_eq!(s2.chosen, vec![0]);
    }

    #[test]
    fn kv_penalty_prefers_shared_paths() {
        // Two pairs of leaves: (0,1) share a deep path; (2) hangs off its own
        // long divergent path. Equal weights, no diversity term: with a high
        // enough λ_b the divergent leaf is pruned.
        // nodes: 0 root; 1 shared; 2,3 leaves under 1; 4,5,6 chain; 7 leaf.
        let parents = vec![None, Some(0), Some(1), Some(1), Some(0), Some(4), Some(5), Some(6)];
        let mk = |leaf_node, cluster| Candidate { weight: 1.0, leaf_node, cluster };
        let p = SelectionProblem {
            candidates: vec![mk(2, 0), mk(3, 1), mk(7, 2)],
            node_weight: SelectionProblem::uniform_node_weight(parents.len()),
            parents,
            num_clusters: 3,
            lambda_b: 1.5,
            lambda_d: 0.0,
        };
        let s = solve_tree(&p, LIMIT);
        assert_eq!(s.chosen, vec![0, 1], "divergent leaf should be pruned: {s:?}");
    }

    #[test]
    fn diversity_term_rescues_divergent_cluster() {
        // Same tree as above, but leaf 7 is the only member of its cluster
        // and λ_d is large: it must now be retained.
        let parents = vec![None, Some(0), Some(1), Some(1), Some(0), Some(4), Some(5), Some(6)];
        let mk = |leaf_node, cluster| Candidate { weight: 1.0, leaf_node, cluster };
        let p = SelectionProblem {
            candidates: vec![mk(2, 0), mk(3, 0), mk(7, 1)],
            node_weight: SelectionProblem::uniform_node_weight(parents.len()),
            parents,
            num_clusters: 2,
            lambda_b: 1.5,
            lambda_d: 3.0,
        };
        let s = solve_tree(&p, LIMIT);
        assert!(s.chosen.contains(&2), "diverse leaf must be kept: {s:?}");
    }

    #[test]
    fn redundant_cluster_members_pruned_first() {
        // Three leaves in one cluster + one in another, all same weight,
        // each on its own branch. Budget pressure should prune within the
        // big cluster, never the singleton cluster.
        let parents = vec![
            None,
            Some(0),
            Some(0),
            Some(0),
            Some(0), // 4 branch nodes
            Some(1),
            Some(2),
            Some(3),
            Some(4), // 4 leaves
        ];
        let mk = |leaf_node, cluster| Candidate { weight: 1.0, leaf_node, cluster };
        let p = SelectionProblem {
            candidates: vec![mk(5, 0), mk(6, 0), mk(7, 0), mk(8, 1)],
            node_weight: SelectionProblem::uniform_node_weight(parents.len()),
            parents,
            num_clusters: 2,
            lambda_b: 1.2,
            lambda_d: 1.0,
        };
        let s = solve_tree(&p, LIMIT);
        assert!(s.chosen.contains(&3), "singleton cluster leaf kept: {s:?}");
    }

    #[test]
    fn tree_matches_brute_force() {
        property(120, |rng: &mut Rng| {
            let p = random_problem(rng, 10);
            let brute = solve_brute(&p);
            let tree = solve_tree(&p, LIMIT);
            crate::prop_check!(
                (brute.objective - tree.objective).abs() < 1e-9,
                "brute {:?} vs tree {:?} on {p:?}",
                brute,
                tree
            );
            Ok(())
        });
    }

    #[test]
    fn prop_three_solvers_agree_on_nonuniform_node_weights() {
        // The engine weights nodes by KV *token* counts rather than the
        // paper's uniform |V_S| — all three solvers must still agree on the
        // optimum. Forcing wildly non-uniform weights stresses the cost
        // decomposition the uniform instances never exercise.
        property(40, |rng: &mut Rng| {
            let mut p = random_problem(rng, 7);
            p.node_weight =
                (0..p.num_nodes()).map(|_| 1.0 + rng.index(97) as f64).collect();
            p.validate().map_err(|e| e)?;
            let brute = solve_brute(&p);
            let ilp = solve_ilp(&p, LIMIT);
            let tree = solve_tree(&p, LIMIT);
            crate::prop_check!(
                (brute.objective - ilp.objective).abs() < 1e-6,
                "brute {brute:?} vs ilp {ilp:?} on {p:?}"
            );
            crate::prop_check!(
                (brute.objective - tree.objective).abs() < 1e-6,
                "brute {brute:?} vs tree {tree:?} on {p:?}"
            );
            // the winning subsets must score identically under the exact
            // objective as well (ties may differ in membership)
            crate::prop_check!(
                (p.objective(&ilp.chosen) - p.objective(&tree.chosen)).abs() < 1e-6,
                "ilp subset {ilp:?} vs tree subset {tree:?} on {p:?}"
            );
            Ok(())
        });
    }

    #[test]
    fn ilp_matches_brute_force() {
        property(40, |rng: &mut Rng| {
            let p = random_problem(rng, 7);
            let brute = solve_brute(&p);
            let ilp = solve_ilp(&p, LIMIT);
            crate::prop_check!(
                (brute.objective - ilp.objective).abs() < 1e-6,
                "brute {:?} vs ilp {:?} on {p:?}",
                brute,
                ilp
            );
            Ok(())
        });
    }

    #[test]
    fn objective_matches_manual_computation() {
        // 2 leaves sharing the root, different clusters.
        let p = SelectionProblem {
            candidates: vec![
                Candidate { weight: 3.0, leaf_node: 1, cluster: 0 },
                Candidate { weight: 1.0, leaf_node: 2, cluster: 1 },
            ],
            parents: vec![None, Some(0), Some(0)],
            node_weight: vec![1.0, 1.0, 1.0],
            num_clusters: 2,
            lambda_b: 1.0,
            lambda_d: 1.0,
        };
        // S = {0}: reward 3/4, nodes {0,1} → 2/3, clusters 1/2
        let expect = 0.75 - 2.0 / 3.0 + 0.5;
        assert!((p.objective(&[0]) - expect).abs() < 1e-12);
        // S = {0,1}: reward 1, nodes 3/3, clusters 2/2 → 1 - 1 + 1 = 1
        assert!((p.objective(&[0, 1]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn validate_catches_bad_instances() {
        let p = SelectionProblem {
            candidates: vec![Candidate { weight: 1.0, leaf_node: 5, cluster: 0 }],
            parents: vec![None],
            node_weight: vec![1.0],
            num_clusters: 1,
            lambda_b: 1.0,
            lambda_d: 0.0,
        };
        assert!(p.validate().is_err());
    }
}
