//! Dense two-phase primal simplex solver.
//!
//! Solves  max c·x  s.t.  A x ≤ b,  0 ≤ x ≤ ub  (b entries may be negative).
//! This is the LP-relaxation engine behind the branch-and-bound ILP solver in
//! [`super::bnb`], replacing the paper's PuLP + CBC stack. A dense tableau is
//! plenty for the ETS selection problems (hundreds of variables/rows) and is
//! simple enough to verify exhaustively in tests.
//!
//! The tableau is a single flat row-major allocation (row `i` at
//! `i*(total+1)`), and pivot row operations (scale / eliminate) go through
//! the [`crate::util::simd`] kernels — element-wise, so vectorization
//! cannot change a single bit of any solve.

use crate::util::simd;

/// Outcome of an LP solve.
#[derive(Clone, Debug, PartialEq)]
pub enum LpOutcome {
    /// Optimal: objective value and primal solution.
    Optimal { objective: f64, x: Vec<f64> },
    Infeasible,
    Unbounded,
}

/// An LP instance in `max c·x, A x ≤ b, 0 ≤ x ≤ ub` form.
#[derive(Clone, Debug, Default)]
pub struct Lp {
    /// Objective coefficients (length n).
    pub c: Vec<f64>,
    /// Constraint matrix rows (each length n).
    pub a: Vec<Vec<f64>>,
    /// Right-hand sides (length m).
    pub b: Vec<f64>,
    /// Upper bounds per variable (use f64::INFINITY for none).
    pub ub: Vec<f64>,
}

impl Lp {
    pub fn new(n: usize) -> Self {
        Self { c: vec![0.0; n], a: vec![], b: vec![], ub: vec![f64::INFINITY; n] }
    }

    /// Add a `row · x ≤ rhs` constraint.
    pub fn leq(&mut self, row: Vec<f64>, rhs: f64) {
        assert_eq!(row.len(), self.c.len());
        self.a.push(row);
        self.b.push(rhs);
    }

    /// Add a `row · x ≥ rhs` constraint (stored as `-row · x ≤ -rhs`).
    pub fn geq(&mut self, row: Vec<f64>, rhs: f64) {
        self.leq(row.iter().map(|v| -v).collect(), -rhs);
    }

    pub fn num_vars(&self) -> usize {
        self.c.len()
    }
}

const EPS: f64 = 1e-9;
const MAX_ITERS: usize = 50_000;

/// Solve the LP. Finite upper bounds are materialized as extra `x_i ≤ ub_i`
/// rows (simple and adequate at our scale).
pub fn solve(lp: &Lp) -> LpOutcome {
    let n = lp.num_vars();
    let mut rows: Vec<Vec<f64>> = lp.a.clone();
    let mut rhs: Vec<f64> = lp.b.clone();
    for (i, &u) in lp.ub.iter().enumerate() {
        if u.is_finite() {
            let mut row = vec![0.0; n];
            row[i] = 1.0;
            rows.push(row);
            rhs.push(u);
        }
    }
    let m = rows.len();

    // Normalize rows so rhs >= 0; track which need artificial variables.
    // Columns: [x (n)] [slack/surplus (m)] [artificials (k)] | rhs
    let mut needs_artificial = vec![false; m];
    for i in 0..m {
        if rhs[i] < 0.0 {
            for v in rows[i].iter_mut() {
                *v = -*v;
            }
            rhs[i] = -rhs[i];
            needs_artificial[i] = true; // slack becomes surplus (-1)
        }
    }
    let k: usize = needs_artificial.iter().filter(|&&x| x).count();
    let total = n + m + k;

    // Build tableau: m constraint rows + 1 objective row, flat row-major
    // (row i at i*w, w = total + 1).
    let w = total + 1;
    let mut t = vec![0.0f64; (m + 1) * w];
    let mut basis = vec![0usize; m];
    let mut art_col = n + m;
    for i in 0..m {
        t[i * w..i * w + n].copy_from_slice(&rows[i]);
        t[i * w + total] = rhs[i];
        if needs_artificial[i] {
            t[i * w + n + i] = -1.0; // surplus
            t[i * w + art_col] = 1.0;
            basis[i] = art_col;
            art_col += 1;
        } else {
            t[i * w + n + i] = 1.0; // slack
            basis[i] = n + i;
        }
    }

    // ---- Phase 1: maximize -(sum of artificials) ----
    if k > 0 {
        // Objective row: +1 for each artificial in "minimize sum" form; we
        // maximize the negation, i.e. obj coefficients -1 on artificials.
        for j in n + m..total {
            t[m * w + j] = -1.0;
        }
        // Price out artificial basics (objective row += basic row).
        for i in 0..m {
            if basis[i] >= n + m {
                let (head, tail) = t.split_at_mut(m * w);
                simd::add_assign(&mut tail[..w], &head[i * w..(i + 1) * w]);
            }
        }
        match run_simplex(&mut t, &mut basis, total, m) {
            SimplexStatus::Ok => {}
            SimplexStatus::Unbounded => return LpOutcome::Infeasible, // can't happen
            SimplexStatus::IterLimit => return LpOutcome::Infeasible,
        }
        // Objective row is stored in "+c" (enter-if-positive) form, so the
        // rhs cell accumulates the *negated* objective value: after phase 1,
        // t[m][total] == Σ artificials. Nonzero ⇒ infeasible.
        let phase1_obj = t[m * w + total];
        if phase1_obj > 1e-6 {
            return LpOutcome::Infeasible;
        }
        // Drive any artificial still in basis out (degenerate zero rows).
        for i in 0..m {
            if basis[i] >= n + m {
                // find a non-artificial column with nonzero coefficient
                let mut found = None;
                for j in 0..n + m {
                    if t[i * w + j].abs() > EPS {
                        found = Some(j);
                        break;
                    }
                }
                if let Some(j) = found {
                    pivot(&mut t, i, j, total, m);
                    basis[i] = j;
                }
                // else: redundant row; leave artificial at zero.
            }
        }
        // Zero-out artificial columns so phase 2 never re-enters them.
        for row in t.chunks_exact_mut(w) {
            row[n + m..total].fill(0.0);
        }
    }

    // ---- Phase 2: maximize c·x ----
    // Rebuild objective row: z - c·x = 0, expressed with reduced costs.
    t[m * w..].fill(0.0);
    t[m * w..m * w + n].copy_from_slice(&lp.c);
    // Price out basic variables (objective row -= coef * basic row).
    for i in 0..m {
        let bj = basis[i];
        let coef = t[m * w + bj];
        if coef.abs() > EPS {
            let (head, tail) = t.split_at_mut(m * w);
            simd::sub_scaled(&mut tail[..w], &head[i * w..(i + 1) * w], coef);
        }
    }
    match run_simplex(&mut t, &mut basis, total, m) {
        SimplexStatus::Ok => {}
        SimplexStatus::Unbounded => return LpOutcome::Unbounded,
        SimplexStatus::IterLimit => {
            // Extremely unlikely with Bland fallback; treat as numeric failure.
            return LpOutcome::Infeasible;
        }
    }

    let mut x = vec![0.0; n];
    for i in 0..m {
        if basis[i] < n {
            x[basis[i]] = t[i * w + total];
        }
    }
    let objective: f64 = lp.c.iter().zip(&x).map(|(c, v)| c * v).sum();
    LpOutcome::Optimal { objective, x }
}

enum SimplexStatus {
    Ok,
    Unbounded,
    IterLimit,
}

/// Run primal simplex iterations on the tableau. The objective row is row
/// `m`, stored so that a column with *positive* reduced cost improves the
/// (maximization) objective... we store the negated convention: entering
/// column j has t[m][j] > 0.
fn run_simplex(t: &mut [f64], basis: &mut [usize], total: usize, m: usize) -> SimplexStatus {
    let w = total + 1;
    let mut iters = 0usize;
    loop {
        iters += 1;
        if iters > MAX_ITERS {
            return SimplexStatus::IterLimit;
        }
        let bland = iters > 10_000; // anti-cycling fallback
        // Entering column: most positive reduced cost (or Bland: first) —
        // a contiguous scan of the flat objective row.
        let mut enter = None;
        let mut best = EPS;
        for (j, &rc) in t[m * w..m * w + total].iter().enumerate() {
            if rc > EPS {
                if bland {
                    enter = Some(j);
                    break;
                }
                if rc > best {
                    best = rc;
                    enter = Some(j);
                }
            }
        }
        let Some(j) = enter else { return SimplexStatus::Ok };
        // Ratio test.
        let mut leave = None;
        let mut best_ratio = f64::INFINITY;
        for i in 0..m {
            if t[i * w + j] > EPS {
                let ratio = t[i * w + total] / t[i * w + j];
                if ratio < best_ratio - EPS
                    || (bland
                        && (ratio - best_ratio).abs() <= EPS
                        && leave.map(|l| basis[l] > basis[i]).unwrap_or(false))
                {
                    best_ratio = ratio;
                    leave = Some(i);
                }
            }
        }
        let Some(i) = leave else { return SimplexStatus::Unbounded };
        pivot(t, i, j, total, m);
        basis[i] = j;
    }
}

fn pivot(t: &mut [f64], pr: usize, pc: usize, total: usize, m: usize) {
    let w = total + 1;
    let pv = t[pr * w + pc];
    debug_assert!(pv.abs() > EPS);
    let inv = 1.0 / pv;
    simd::scale(&mut t[pr * w..(pr + 1) * w], inv);
    for i in 0..=m {
        if i == pr {
            continue;
        }
        let factor = t[i * w + pc];
        if factor.abs() > EPS {
            // row_i -= factor * row_pr
            let (head, tail) = if i < pr {
                let (a, b) = t.split_at_mut(pr * w);
                (&mut a[i * w..(i + 1) * w], &b[..w])
            } else {
                let (a, b) = t.split_at_mut(i * w);
                (&mut b[..w], &a[pr * w..(pr + 1) * w])
            };
            simd::sub_scaled(head, tail, factor);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn optimal(out: LpOutcome) -> (f64, Vec<f64>) {
        match out {
            LpOutcome::Optimal { objective, x } => (objective, x),
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn textbook_2var() {
        // max 3x + 5y s.t. x <= 4, 2y <= 12, 3x + 2y <= 18 → x=2, y=6, z=36
        let mut lp = Lp::new(2);
        lp.c = vec![3.0, 5.0];
        lp.leq(vec![1.0, 0.0], 4.0);
        lp.leq(vec![0.0, 2.0], 12.0);
        lp.leq(vec![3.0, 2.0], 18.0);
        let (z, x) = optimal(solve(&lp));
        assert!((z - 36.0).abs() < 1e-6);
        assert!((x[0] - 2.0).abs() < 1e-6 && (x[1] - 6.0).abs() < 1e-6);
    }

    #[test]
    fn upper_bounds_respected() {
        // max x + y, x,y <= 0.5 → 1.0
        let mut lp = Lp::new(2);
        lp.c = vec![1.0, 1.0];
        lp.ub = vec![0.5, 0.5];
        let (z, x) = optimal(solve(&lp));
        assert!((z - 1.0).abs() < 1e-9);
        assert!(x.iter().all(|&v| v <= 0.5 + 1e-9));
    }

    #[test]
    fn geq_constraint_feasible() {
        // max -x s.t. x >= 2, x <= 10 → x = 2, z = -2  (needs phase 1)
        let mut lp = Lp::new(1);
        lp.c = vec![-1.0];
        lp.geq(vec![1.0], 2.0);
        lp.ub = vec![10.0];
        let (z, x) = optimal(solve(&lp));
        assert!((z + 2.0).abs() < 1e-6, "z = {z}");
        assert!((x[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn infeasible_detected() {
        // x >= 5 and x <= 2
        let mut lp = Lp::new(1);
        lp.c = vec![1.0];
        lp.geq(vec![1.0], 5.0);
        lp.ub = vec![2.0];
        assert_eq!(solve(&lp), LpOutcome::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut lp = Lp::new(1);
        lp.c = vec![1.0]; // max x, no constraints
        assert_eq!(solve(&lp), LpOutcome::Unbounded);
    }

    #[test]
    fn degenerate_ok() {
        // Degenerate vertex: redundant constraints meeting at the optimum.
        let mut lp = Lp::new(2);
        lp.c = vec![1.0, 1.0];
        lp.leq(vec![1.0, 0.0], 1.0);
        lp.leq(vec![0.0, 1.0], 1.0);
        lp.leq(vec![1.0, 1.0], 2.0);
        lp.leq(vec![2.0, 2.0], 4.0);
        let (z, _) = optimal(solve(&lp));
        assert!((z - 2.0).abs() < 1e-6);
    }

    #[test]
    fn selection_shaped_lp_relaxation_is_integral() {
        // Miniature ETS-shaped instance: 3 leaves, shared node y0 for leaves
        // 0 and 1; per-leaf nodes y1..y3. Vars: x0..x2, y0..y3.
        // max 0.5x0+0.3x1+0.2x2 - 0.1*(y0+y1+y2+y3)
        // s.t. y0 >= x0, y0 >= x1, y1 >= x0, y2 >= x1, y3 >= x2, sum x >= 1.
        let n = 7;
        let mut lp = Lp::new(n);
        lp.c = vec![0.5, 0.3, 0.2, -0.1, -0.1, -0.1, -0.1];
        lp.ub = vec![1.0; n];
        let mut row = |xi: usize, yv: usize, lp: &mut Lp| {
            let mut r = vec![0.0; n];
            r[xi] = 1.0;
            r[yv] = -1.0;
            lp.leq(r, 0.0); // x_i - y_v <= 0
        };
        row(0, 3, &mut lp);
        row(1, 3, &mut lp);
        row(0, 4, &mut lp);
        row(1, 5, &mut lp);
        row(2, 6, &mut lp);
        lp.geq(vec![1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0], 1.0);
        let (z, x) = optimal(solve(&lp));
        // Optimal integer solution: keep x0 and x1 (share y0):
        // 0.5 + 0.3 - 0.1*3 = 0.5. Keep all three: 1.0 - 0.4 = 0.6. That's
        // better. Check: keeping all = 0.5+0.3+0.2 - 0.1*4 = 0.6.
        assert!((z - 0.6).abs() < 1e-6, "z = {z}, x = {x:?}");
        for v in &x {
            assert!(v.abs() < 1e-6 || (v - 1.0).abs() < 1e-6, "fractional {x:?}");
        }
    }
}
