//! `ets` — launcher CLI for the Efficient Tree Search serving framework.
//!
//! Subcommands:
//!   eval   run a search policy over a synthetic problem set (accuracy/KV)
//!   serve  batched serving demo: concurrent problems through one engine
//!          (pass --pjrt for the real AOT transformer; needs the `pjrt`
//!          feature and `make artifacts`)
//!   info   show compiled artifact + workload configuration
//!
//! Global options can also come from a TOML config (`--config path`), with
//! CLI flags taking precedence.

use ets::coordinator::{ServeOptions, REPORT_VERSION};
use ets::engine::{PerfModel, COLD_LINK_BW_DEFAULT, H100_NVL};
use ets::eval::{evaluate_serve_with, evaluate_with_workers, EvalConfig, PolicySpec};
use ets::util::argparse::{Args, Spec};
use ets::util::error::{Error, Result};
use ets::util::json::Json;
use ets::util::stats;
use ets::util::toml::Doc;
use ets::workload::{dataset_by_name, model_by_name, WorkloadSpec};
use ets::{bail, err};

const USAGE: &str = "\
ets — Efficient Tree Search for Inference-Time Scaling (reproduction)

USAGE:
  ets eval  [--dataset D] [--model M] [--policy P] [--width N]
            [--problems K] [--seed S] [--workers W] [--json FILE]
  ets serve [--dataset D] [--model M] [--policy P] [--width N]
            [--problems K] [--concurrency C] [--capacity TOKENS]
            [--block-size TOKENS] [--shards N] [--cold-capacity TOKENS]
            [--cold-link-gbps GB] [--pipeline] [--prefix-share]
            [--pin-cores] [--async-decode] [--adaptive-budget] [--seed S]
            [--json FILE] [--trace-out FILE] [--metrics-out FILE]
            [--pjrt] [--requests K] [--artifacts DIR]
  ets info  [--artifacts DIR]

`--capacity` makes the KV budget *hard*: the scheduler gates admission on
free-block watermarks and preempts/resumes sessions under pressure
(recomputing evicted prefixes), never exceeding the block budget.
`--shards N` runs N shard-per-core engines (each owning capacity/N) on N
persistent workers, with deterministic least-loaded admission and
cross-shard migration of stuck sessions; results are identical for every
shard count at a fixed seed.
`--cold-capacity` adds a host-DRAM spill tier under the paged allocator:
eviction under pressure *demotes* unpinned KV spans to host memory instead
of destroying them, and resumes restore demoted spans over a modeled PCIe
link when that beats recompute. The cold budget is a second hard limit
(split across shards); spans are truly dropped only when both tiers are
full. Demotion/restore move real payload words, so results stay
byte-identical with the tier on or off. `--cold-link-gbps` sets the
modeled host link bandwidth (default 64 GB/s ≈ PCIe gen5 x16); same-round
spills and restores queue on the same per-shard lane.
`--pipeline` costs each round as max(decode, plan+commit) — shard k+1's
decode overlapping shard k's commit — instead of their sum; results are
byte-identical with it on or off. `--pipeline=0` forces lockstep,
overriding a `serve.pipeline` config value.
`--prefix-share` turns on the global prefix hub: shards publish
committed-prefix fingerprints at round barriers, admission routes requests
to the shard holding their longest published prefix, and resumes may import
peer-held spans billed min(NVLink transfer, recompute prefill). Placement
and costing only — results are byte-identical with it on or off.
`--prefix-share=0` forces it off, overriding a `serve.prefix_share` config
value.
`--pin-cores` pins each persistent shard worker to a CPU core (worker i →
core i mod num_cores), so the thread that owns a shard's radix cache and
block-allocator arena stays put. Placement only — results are
byte-identical with it on or off. `--pin-cores=0` forces it off,
overriding a `serve.pin_cores` config value. With `--async-decode` on,
`--pin-cores` also first-touch faults each shard's payload arena from its
pinned worker, so NUMA page placement follows the pin.
`--async-decode` turns on the true-async data plane: each problem's
decodes are served on an off-thread completion queue (AsyncLm), and each
shard speculatively plans round r+1 while round r's results drain.
Scheduling only — per-problem results are byte-identical with it on or
off. `--async-decode=0` forces it off, overriding a `serve.async_decode`
config value.
`--adaptive-budget` turns on the compute-optimal budget controller: at
each round barrier the coordinator scores every session's difficulty from
committed telemetry (round-1 reward spread, frontier entropy, semantic
cluster count), shrinks the width of easy/hopeless sessions mid-flight,
and grants the reclaimed KV blocks to contested ones; admission also
switches from the static per-policy kv-retention heuristic to an online
calibration of observed retained-leaves/width ratios. Adaptive mode is
its own serving mode (results differ from the baseline), but at a fixed
seed its results are byte-identical across shard counts, capacities, and
every scheduling flag. `--adaptive-budget=0` forces it off, overriding a
`serve.adaptive_budget` config value.
`--trace-out FILE` turns on the two-track serve trace and writes it as
Chrome trace-event JSON (open in https://ui.perfetto.dev or
chrome://tracing). The modeled session track (pid 0) is byte-identical
across shard counts and pipeline/async modes; the executed per-shard
tracks carry the global scheduler clock with wall-clock diagnostics in
args. Tracing is read-only: results and decision logs are identical with
it on or off.
`--metrics-out FILE` writes a Prometheus-style text exposition of the
run's counters, gauges, and latency summaries (TTFT/TPOT/completion and
per-phase round durations as p50/p90/p99 quantiles, microseconds).

POLICIES: rebase | beam-<k> | beam-sqrt | dvts-<k> | dvts-sqrt |
          ets[:<lambda_b>] | ets-kv[:<lambda_b>]
DATASETS: synth-math500 | synth-gsm8k
MODELS:   llemma-34b-sim | mistral-7b-sim";

fn main() {
    let spec = Spec::new(&[
        "dataset", "model", "policy", "width", "problems", "seed", "workers",
        "json", "config", "requests", "lambda-b", "artifacts", "concurrency",
        "capacity", "block-size", "shards", "cold-capacity", "cold-link-gbps",
        "trace-out", "metrics-out",
    ]);
    let args = match spec.parse(std::env::args()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    // Subcommands take no positional arguments: a stray one is almost
    // always a flag typo (`--pipeline 0` instead of `--pipeline=0`) and
    // silently ignoring it would silently change what runs.
    if args.positional.len() > 1 {
        eprintln!("error: unexpected argument '{}'\n\n{USAGE}", args.positional[1]);
        std::process::exit(2);
    }
    let result = match args.subcommand() {
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("info") => cmd_info(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<Doc> {
    match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            Doc::parse(&text).map_err(|e| err!("{path}: {e}"))
        }
        None => Ok(Doc::parse("").unwrap()),
    }
}

/// Resolve the (dataset, model, policy, width, problems) eval/serve config
/// shared by both subcommands.
fn eval_config(args: &Args, section: &str, default_problems: usize) -> Result<EvalConfig> {
    let cfg_doc = load_config(args)?;
    let key = |k: &str| format!("{section}.{k}");
    let dataset_name = args
        .get_or("dataset", &cfg_doc.str_or(&key("dataset"), "synth-math500"))
        .to_string();
    let model_name =
        args.get_or("model", &cfg_doc.str_or(&key("model"), "llemma-34b-sim")).to_string();
    let policy_name = args.get_or("policy", &cfg_doc.str_or(&key("policy"), "ets")).to_string();
    let dataset =
        dataset_by_name(&dataset_name).ok_or_else(|| err!("unknown dataset {dataset_name}"))?;
    let model = model_by_name(&model_name).ok_or_else(|| err!("unknown model {model_name}"))?;
    let policy = PolicySpec::parse(&policy_name).map_err(Error::msg)?;
    Ok(EvalConfig {
        spec: WorkloadSpec::new(dataset, model),
        policy,
        width: args
            .get_usize("width", cfg_doc.usize_or(&key("width"), 64))
            .map_err(Error::msg)?,
        n_problems: args
            .get_usize("problems", cfg_doc.usize_or(&key("problems"), default_problems))
            .map_err(Error::msg)?,
        seed: args.get_u64("seed", 20260710).map_err(Error::msg)?,
        max_steps: dataset.n_steps + 6,
    })
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = eval_config(args, "eval", 100)?;
    let workers = args.get_usize("workers", 0).map_err(Error::msg)?;
    let workers = if workers == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        workers
    };
    let t = std::time::Instant::now();
    let r = evaluate_with_workers(&cfg, workers);
    println!(
        "{:<20} {:<16} width={:<4} acc={:.1}%  kv={:.0}  unshared={:.0}  tokens={:.0}  calls={:.0}  [{:?}]",
        r.policy,
        r.dataset,
        r.width,
        100.0 * r.accuracy(),
        r.mean_kv_tokens,
        r.mean_unshared_kv_tokens,
        r.mean_new_tokens,
        r.mean_model_calls,
        t.elapsed()
    );
    if let Some(path) = args.get("json") {
        let j = Json::obj(vec![
            ("policy", Json::str(&r.policy)),
            ("dataset", Json::str(&r.dataset)),
            ("model", Json::str(&r.model)),
            ("width", Json::num(r.width as f64)),
            ("n_problems", Json::num(r.n_problems as f64)),
            ("accuracy", Json::num(r.accuracy())),
            ("mean_kv_tokens", Json::num(r.mean_kv_tokens)),
            ("mean_unshared_kv_tokens", Json::num(r.mean_unshared_kv_tokens)),
            ("mean_new_tokens", Json::num(r.mean_new_tokens)),
            ("mean_model_calls", Json::num(r.mean_model_calls)),
        ]);
        std::fs::write(path, j.to_string_compact())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Batched serving over the synthetic workload: up to `--concurrency`
/// problems interleave steps through one engine/radix cache, with every
/// merged batch costed on the H100 roofline.
fn cmd_serve(args: &Args) -> Result<()> {
    if args.flag("pjrt") {
        return cmd_serve_pjrt(args);
    }
    let cfg = eval_config(args, "serve", 16)?; // serving-demo problem default
    let cfg_doc = load_config(args)?;
    let concurrency = args
        .get_usize("concurrency", cfg_doc.usize_or("serve.concurrency", 8))
        .map_err(Error::msg)?;
    let defaults = ServeOptions::default();
    let opts = ServeOptions {
        concurrency,
        capacity_tokens: args
            .get_usize(
                "capacity",
                cfg_doc.usize_or("serve.capacity", defaults.capacity_tokens),
            )
            .map_err(Error::msg)?,
        block_size: args
            .get_usize(
                "block-size",
                cfg_doc.usize_or("serve.block_size", defaults.block_size),
            )
            .map_err(Error::msg)?,
        shards: args
            .get_usize("shards", cfg_doc.usize_or("serve.shards", defaults.shards))
            .map_err(Error::msg)?,
        cold_capacity_tokens: args
            .get_usize(
                "cold-capacity",
                cfg_doc.usize_or("serve.cold_capacity", defaults.cold_capacity_tokens),
            )
            .map_err(Error::msg)?,
        // bare `--pipeline` turns it on; `--pipeline=0|false` forces it off
        // (overriding a `serve.pipeline` config value, like every other
        // serve option the CLI takes precedence). The config accepts both
        // `serve.pipeline = true` and `= 1`.
        pipeline: match args.get("pipeline") {
            Some(v) => v != "0" && v != "false",
            None => {
                args.flag("pipeline")
                    || cfg_doc.bool_or("serve.pipeline", false)
                    || cfg_doc.usize_or("serve.pipeline", 0) != 0
            }
        },
        // same on/off grammar as --pipeline
        prefix_share: match args.get("prefix-share") {
            Some(v) => v != "0" && v != "false",
            None => {
                args.flag("prefix-share")
                    || cfg_doc.bool_or("serve.prefix_share", false)
                    || cfg_doc.usize_or("serve.prefix_share", 0) != 0
            }
        },
        // same on/off grammar as --pipeline
        pin_cores: match args.get("pin-cores") {
            Some(v) => v != "0" && v != "false",
            None => {
                args.flag("pin-cores")
                    || cfg_doc.bool_or("serve.pin_cores", false)
                    || cfg_doc.usize_or("serve.pin_cores", 0) != 0
            }
        },
        // same on/off grammar as --pipeline
        async_decode: match args.get("async-decode") {
            Some(v) => v != "0" && v != "false",
            None => {
                args.flag("async-decode")
                    || cfg_doc.bool_or("serve.async_decode", false)
                    || cfg_doc.usize_or("serve.async_decode", 0) != 0
            }
        },
        // same on/off grammar as --pipeline
        adaptive_budget: match args.get("adaptive-budget") {
            Some(v) => v != "0" && v != "false",
            None => {
                args.flag("adaptive-budget")
                    || cfg_doc.bool_or("serve.adaptive_budget", false)
                    || cfg_doc.usize_or("serve.adaptive_budget", 0) != 0
            }
        },
        // read-only observability: asking for a trace file is what turns
        // the recorder on (it is never worth paying for unobserved)
        trace: args.get("trace-out").is_some(),
        latency_hists: defaults.latency_hists,
    };
    if opts.capacity_tokens == 0 {
        bail!("--capacity must be a positive token budget");
    }
    if opts.shards == 0 {
        bail!("--shards must be at least 1");
    }
    let cold_link_gbps = args
        .get_f64(
            "cold-link-gbps",
            cfg_doc.f64_or("serve.cold_link_gbps", COLD_LINK_BW_DEFAULT / 1e9),
        )
        .map_err(Error::msg)?;
    if cold_link_gbps <= 0.0 {
        bail!("--cold-link-gbps must be a positive bandwidth");
    }
    let perf = PerfModel::new(H100_NVL, true, concurrency).cold_linked(cold_link_gbps * 1e9);
    let t0 = std::time::Instant::now();
    let r = evaluate_serve_with(&cfg, &opts, &perf);
    let wall = t0.elapsed();
    let secs = r.serve.batch_seconds();
    let mean_batch = if r.serve.batches.is_empty() {
        0.0
    } else {
        r.serve.batches.iter().map(|b| b.model_calls as f64).sum::<f64>()
            / r.serve.batches.len() as f64
    };
    println!(
        "served {} problems (width {}, policy {}) through {} shard engine(s), concurrency {}, {} rounds",
        cfg.n_problems,
        cfg.width,
        r.report.policy,
        r.serve.shards,
        concurrency,
        if r.serve.pipeline { "pipelined" } else { "lockstep" }
    );
    println!(
        "  acc={:.1}%  kvΣ/problem={:.0}  peak resident kv={} tokens  max concurrent={}",
        100.0 * r.report.accuracy(),
        r.report.mean_kv_tokens,
        r.serve.peak_resident_kv_tokens,
        r.serve.max_concurrent
    );
    println!(
        "  {} batches, mean {:.1} seqs/batch | batch latency p50 {:.1} ms p95 {:.1} ms",
        r.serve.batches.len(),
        mean_batch,
        1e3 * stats::median(&secs),
        1e3 * stats::percentile(&secs, 95.0),
    );
    println!(
        "  block budget: peak {} of {} blocks used ({} tokens/block)",
        r.serve.peak_used_blocks,
        r.serve.total_blocks,
        opts.block_size,
    );
    if opts.pin_cores {
        let pins: Vec<String> = r
            .serve
            .worker_cores
            .iter()
            .enumerate()
            .map(|(w, c)| match c {
                Some(core) => format!("{w}→{core}"),
                None => format!("{w}→os"),
            })
            .collect();
        println!("  core pinning: {}", pins.join("  "));
    }
    if opts.shards > 1 {
        println!(
            "  {} shards ({} tokens each), {} cross-shard migrations",
            r.serve.shards,
            opts.capacity_tokens / opts.shards,
            r.serve.migrations,
        );
        for st in &r.serve.shard_stats {
            println!(
                "    shard {}: admitted {}  peak {}/{} blocks  preempt {}  resume {}  mig in/out {}/{}  busy {:.2}s",
                st.shard,
                st.admitted,
                st.peak_used_blocks,
                st.total_blocks,
                st.preemptions,
                st.resumes,
                st.migrations_in,
                st.migrations_out,
                st.busy_seconds,
            );
        }
    }
    if r.serve.prefix_share {
        println!(
            "  prefix hub: {} hits ({:.0}% of admissions), {} fingerprints published ({} live / {} demoted / {} evicted at audit)",
            r.serve.hub_hits,
            100.0 * r.serve.hub_hit_rate(),
            r.serve.hub_published,
            r.serve.hub_live_entries,
            r.serve.hub_demoted_entries,
            r.serve.hub_evicted_entries,
        );
    }
    if r.serve.import_transfers + r.serve.import_recomputes + r.serve.migration_cold > 0 {
        println!(
            "  kv imports: {} tokens transferred over the link ({} transfers vs {} recomputes; migrations {}T/{}R/{} cold)",
            r.serve.imported_kv_tokens,
            r.serve.import_transfers,
            r.serve.import_recomputes,
            r.serve.migration_transfers,
            r.serve.migration_recomputes,
            r.serve.migration_cold,
        );
    }
    if r.serve.cold_capacity_tokens > 0 {
        println!(
            "  cold tier: {} tokens demoted to host DRAM, {} restored over PCIe ({} restores vs {} recomputes; {} tokens dropped at cold capacity)",
            r.serve.demoted_kv_tokens,
            r.serve.restored_kv_tokens,
            r.serve.cold_restores,
            r.serve.cold_recomputes,
            r.serve.cold_dropped_kv_tokens,
        );
    }
    if r.serve.async_decode {
        println!(
            "  async data plane: spec plans {} hits / {} misses, {} B transported / {} B recomputed arena payload",
            r.serve.spec_plan_hits,
            r.serve.spec_plan_misses,
            r.serve.transferred_kv_bytes,
            r.serve.recomputed_kv_bytes,
        );
    }
    if r.serve.adaptive_budget {
        println!(
            "  adaptive budget: {} width shrinks / {} grants, {} blocks reclaimed / {} granted, {} decisions, {:.1} block-seconds",
            r.serve.width_shrinks,
            r.serve.width_grants,
            r.serve.reclaimed_kv_blocks,
            r.serve.granted_kv_blocks,
            r.serve.budget_decisions.len(),
            r.serve.modeled_block_seconds(),
        );
    }
    if r.serve.kv_pressure_events() > 0 {
        println!(
            "  memory pressure: {} preemptions, {} resumes ({} tokens recomputed), {} admission-blocked rounds, {} deferred commits",
            r.serve.preemptions,
            r.serve.resumes,
            r.serve.recompute_tokens,
            r.serve.admission_blocked_rounds,
            r.serve.deferred_commits,
        );
    }
    println!(
        "  modeled serving time {:.2}s → {:.3} problems/s  [host wall {:?}]",
        r.serve.modeled_seconds,
        r.serve.throughput_problems_per_sec(),
        wall
    );
    let lat = &r.serve.latency;
    if !lat.completion.is_empty() {
        println!(
            "  request latency (modeled): ttft p50/p99 {:.1}/{:.1} ms  tpot p50/p99 {:.3}/{:.3} ms  completion p50/p99 {:.1}/{:.1} ms",
            lat.ttft.p50() as f64 / 1e3,
            lat.ttft.p99() as f64 / 1e3,
            lat.tpot.p50() as f64 / 1e3,
            lat.tpot.p99() as f64 / 1e3,
            lat.completion.p50() as f64 / 1e3,
            lat.completion.p99() as f64 / 1e3,
        );
    }
    if let Some(path) = args.get("trace-out") {
        let trace = r.serve.trace.as_ref().expect("--trace-out enables tracing");
        std::fs::write(path, trace.chrome_json(r.serve.shards).to_string_compact())?;
        println!(
            "wrote {path} ({} modeled + {} exec events, {} dropped) — open in https://ui.perfetto.dev",
            trace.modeled.len(),
            trace.exec.len(),
            trace.dropped
        );
        let audit = ets::obs::audit::reconcile(&r.serve).expect("traced run");
        if audit.ok() {
            println!("  trace/ledger audit: PASS ({} lines reconciled)", audit.lines.len());
        } else {
            // the trace file was already written — it is the evidence
            eprintln!("{}", audit.render());
            bail!("trace/ledger audit failed ({} mismatches)", audit.mismatches().len());
        }
    }
    if let Some(path) = args.get("metrics-out") {
        std::fs::write(path, ets::obs::report::prometheus_exposition(&r.serve))?;
        println!("wrote {path}");
    }
    if let Some(path) = args.get("json") {
        let j = Json::obj(vec![
            ("report_version", Json::num(REPORT_VERSION as f64)),
            ("policy", Json::str(&r.report.policy)),
            ("dataset", Json::str(&r.report.dataset)),
            ("width", Json::num(cfg.width as f64)),
            ("n_problems", Json::num(cfg.n_problems as f64)),
            ("concurrency", Json::num(concurrency as f64)),
            ("capacity_tokens", Json::num(opts.capacity_tokens as f64)),
            ("block_size", Json::num(opts.block_size as f64)),
            ("shards", Json::num(r.serve.shards as f64)),
            ("pipeline", Json::num(if r.serve.pipeline { 1.0 } else { 0.0 })),
            ("prefix_share", Json::num(if r.serve.prefix_share { 1.0 } else { 0.0 })),
            ("pin_cores", Json::num(if opts.pin_cores { 1.0 } else { 0.0 })),
            ("async_decode", Json::num(if r.serve.async_decode { 1.0 } else { 0.0 })),
            ("spec_plan_hits", Json::num(r.serve.spec_plan_hits as f64)),
            ("spec_plan_misses", Json::num(r.serve.spec_plan_misses as f64)),
            ("transferred_kv_bytes", Json::num(r.serve.transferred_kv_bytes as f64)),
            ("recomputed_kv_bytes", Json::num(r.serve.recomputed_kv_bytes as f64)),
            (
                "adaptive_budget",
                Json::num(if r.serve.adaptive_budget { 1.0 } else { 0.0 }),
            ),
            ("width_shrinks", Json::num(r.serve.width_shrinks as f64)),
            ("width_grants", Json::num(r.serve.width_grants as f64)),
            ("reclaimed_kv_blocks", Json::num(r.serve.reclaimed_kv_blocks as f64)),
            ("granted_kv_blocks", Json::num(r.serve.granted_kv_blocks as f64)),
            ("budget_decisions", Json::num(r.serve.budget_decisions.len() as f64)),
            ("modeled_block_seconds", Json::num(r.serve.modeled_block_seconds())),
            (
                "worker_cores",
                Json::Arr(
                    r.serve
                        .worker_cores
                        .iter()
                        .map(|c| match c {
                            Some(core) => Json::num(*core as f64),
                            None => Json::Null,
                        })
                        .collect(),
                ),
            ),
            ("hub_hits", Json::num(r.serve.hub_hits as f64)),
            ("hub_hit_rate", Json::num(r.serve.hub_hit_rate())),
            ("hub_published", Json::num(r.serve.hub_published as f64)),
            ("hub_live_entries", Json::num(r.serve.hub_live_entries as f64)),
            ("hub_demoted_entries", Json::num(r.serve.hub_demoted_entries as f64)),
            ("hub_evicted_entries", Json::num(r.serve.hub_evicted_entries as f64)),
            ("cold_capacity_tokens", Json::num(r.serve.cold_capacity_tokens as f64)),
            ("demoted_kv_tokens", Json::num(r.serve.demoted_kv_tokens as f64)),
            ("restored_kv_tokens", Json::num(r.serve.restored_kv_tokens as f64)),
            ("restored_kv_bytes", Json::num(r.serve.restored_kv_bytes as f64)),
            ("cold_restores", Json::num(r.serve.cold_restores as f64)),
            ("cold_recomputes", Json::num(r.serve.cold_recomputes as f64)),
            ("cold_dropped_kv_tokens", Json::num(r.serve.cold_dropped_kv_tokens as f64)),
            ("imported_kv_tokens", Json::num(r.serve.imported_kv_tokens as f64)),
            ("import_transfers", Json::num(r.serve.import_transfers as f64)),
            ("import_recomputes", Json::num(r.serve.import_recomputes as f64)),
            ("mean_used_blocks", Json::num(r.serve.mean_used_blocks())),
            ("migrations", Json::num(r.serve.migrations as f64)),
            ("accuracy", Json::num(r.report.accuracy())),
            ("mean_kv_tokens", Json::num(r.report.mean_kv_tokens)),
            ("batches", Json::num(r.serve.batches.len() as f64)),
            ("modeled_seconds", Json::num(r.serve.modeled_seconds)),
            ("throughput", Json::num(r.serve.throughput_problems_per_sec())),
            ("peak_resident_kv_tokens", Json::num(r.serve.peak_resident_kv_tokens as f64)),
            ("peak_used_blocks", Json::num(r.serve.peak_used_blocks as f64)),
            ("total_blocks", Json::num(r.serve.total_blocks as f64)),
            ("preemptions", Json::num(r.serve.preemptions as f64)),
            ("resumes", Json::num(r.serve.resumes as f64)),
            ("recompute_tokens", Json::num(r.serve.recompute_tokens as f64)),
            (
                "admission_blocked_rounds",
                Json::num(r.serve.admission_blocked_rounds as f64),
            ),
            ("deferred_commits", Json::num(r.serve.deferred_commits as f64)),
            (
                "peak_step_concurrency",
                Json::num(r.serve.peak_step_concurrency as f64),
            ),
            // report_version 2: modeled-latency percentiles (microseconds)
            ("ttft_p50_us", Json::num(lat.ttft.p50() as f64)),
            ("ttft_p90_us", Json::num(lat.ttft.p90() as f64)),
            ("ttft_p99_us", Json::num(lat.ttft.p99() as f64)),
            ("tpot_p50_us", Json::num(lat.tpot.p50() as f64)),
            ("tpot_p90_us", Json::num(lat.tpot.p90() as f64)),
            ("tpot_p99_us", Json::num(lat.tpot.p99() as f64)),
            ("completion_p50_us", Json::num(lat.completion.p50() as f64)),
            ("completion_p90_us", Json::num(lat.completion.p90() as f64)),
            ("completion_p99_us", Json::num(lat.completion.p99() as f64)),
            ("latency", lat.to_json()),
            (
                "trace_events",
                Json::num(r.serve.trace.as_ref().map_or(0, |t| t.exec.len()) as f64),
            ),
        ]);
        std::fs::write(path, j.to_string_compact())?;
        println!("wrote {path}");
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_serve_pjrt(args: &Args) -> Result<()> {
    use ets::embed::Embedder;
    use ets::engine::pjrt_lm::{PjrtEmbedder, PjrtLm, PjrtLmConfig, PjrtPrm};
    use ets::search::{run_search, EtsPolicy, RebasePolicy, SearchParams};
    use std::rc::Rc;

    let dir = args.get_or("artifacts", "artifacts").to_string();
    let requests = args.get_usize("requests", 4).map_err(Error::msg)?;
    let width = args.get_usize("width", 8).map_err(Error::msg)?;
    let policy_name = args.get_or("policy", "ets").to_string();
    let lambda_b = args.get_f64("lambda-b", 1.5).map_err(Error::msg)?;
    let arts = Rc::new(ets::runtime::Artifacts::open(dir)?);
    println!(
        "serving on PJRT/{} — model d={} L={} H={} S={} V={}",
        arts.runtime.platform_name(),
        arts.dims.d_model,
        arts.dims.n_layers,
        arts.dims.n_heads,
        arts.dims.max_seq,
        arts.dims.embed_out_dim
    );
    let mut total_tokens = 0u64;
    let mut total_kv = 0u64;
    let mut correct_like = 0usize;
    let t0 = std::time::Instant::now();
    for req in 0..requests {
        let mut rng = ets::util::rng::Rng::new(1000 + req as u64);
        let prompt: Vec<u32> = (0..12).map(|_| 2 + rng.below(200) as u32).collect();
        let mut lm =
            PjrtLm::new(arts.clone(), prompt.clone(), req as u64, PjrtLmConfig::default());
        let mut prm = PjrtPrm::new(arts.clone(), prompt);
        let params = SearchParams { width, max_steps: 8 };
        let outcome = if policy_name.starts_with("ets") {
            let mut pol = EtsPolicy::new(lambda_b, 1.0, PjrtEmbedder::new(arts.clone()));
            run_search(&mut lm, &mut prm, &mut pol, &params)
        } else {
            let mut pol = RebasePolicy::default();
            run_search(&mut lm, &mut prm, &mut pol, &params)
        };
        total_tokens += outcome.total_new_tokens();
        total_kv += outcome.total_kv_tokens();
        if outcome.answer.is_some() {
            correct_like += 1;
        }
        println!(
            "req {req}: answer={:?} completions={} kvΣ={} tokens={} prefills={} decodes={} radix_unique={}",
            outcome.answer,
            outcome.completions.len(),
            outcome.total_kv_tokens(),
            outcome.total_new_tokens(),
            lm.prefill_calls,
            lm.decode_calls,
            lm.radix.live_tokens(),
        );
        let _ = Embedder::dim(&mut PjrtEmbedder::new(arts.clone()));
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {requests} requests in {dt:.2}s — {:.2} req/s, {:.1} tok/s, Σkv {total_kv}, answered {correct_like}/{requests}",
        requests as f64 / dt,
        total_tokens as f64 / dt
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve_pjrt(_args: &Args) -> Result<()> {
    bail!("this binary was built without the `pjrt` feature; rebuild with `--features pjrt`")
}

#[cfg(feature = "pjrt")]
fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts").to_string();
    if !std::path::Path::new(&dir).join("meta.json").exists() {
        bail!("no artifacts at {dir}; run `make artifacts`");
    }
    let arts = ets::runtime::Artifacts::open(dir)?;
    let d = &arts.dims;
    println!("platform: {}", arts.runtime.platform_name());
    println!(
        "lm: vocab={} d_model={} layers={} heads={} head_dim={} max_seq={} batches={:?}",
        d.vocab, d.d_model, d.n_layers, d.n_heads, d.head_dim, d.max_seq, d.lm_batches
    );
    println!(
        "prm batch: {}  embed: batch={} seq={} dim={}",
        d.prm_batch, d.embed_batch, d.embed_max_seq, d.embed_out_dim
    );
    println!("datasets: synth-math500, synth-gsm8k  models: llemma-34b-sim, mistral-7b-sim");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_info(_args: &Args) -> Result<()> {
    println!("built without the `pjrt` feature — no compiled artifacts to inspect");
    println!("datasets: synth-math500, synth-gsm8k  models: llemma-34b-sim, mistral-7b-sim");
    println!("rebuild with `--features pjrt` (and run `make artifacts`) for the PJRT path");
    Ok(())
}
