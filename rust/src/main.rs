//! `ets` — launcher CLI for the Efficient Tree Search serving framework.
//!
//! Subcommands:
//!   eval   run a search policy over a synthetic problem set (accuracy/KV)
//!   serve  end-to-end PJRT serving demo (real AOT transformer on CPU)
//!   info   show compiled artifact + workload configuration
//!
//! Global options can also come from a TOML config (`--config path`), with
//! CLI flags taking precedence.

use anyhow::{anyhow, bail, Result};
use ets::eval::{evaluate_with_workers, EvalConfig, PolicySpec};
use ets::util::argparse::{Args, Spec};
use ets::util::json::Json;
use ets::util::toml::Doc;
use ets::workload::{dataset_by_name, model_by_name, WorkloadSpec};

const USAGE: &str = "\
ets — Efficient Tree Search for Inference-Time Scaling (reproduction)

USAGE:
  ets eval  [--dataset D] [--model M] [--policy P] [--width N]
            [--problems K] [--seed S] [--workers W] [--json FILE]
  ets serve [--requests K] [--width N] [--policy P] [--lambda-b X]
            [--artifacts DIR]
  ets info  [--artifacts DIR]

POLICIES: rebase | beam-<k> | beam-sqrt | dvts-<k> | dvts-sqrt |
          ets[:<lambda_b>] | ets-kv[:<lambda_b>]
DATASETS: synth-math500 | synth-gsm8k
MODELS:   llemma-34b-sim | mistral-7b-sim";

fn main() {
    let spec = Spec::new(&[
        "dataset", "model", "policy", "width", "problems", "seed", "workers",
        "json", "config", "requests", "lambda-b", "artifacts",
    ]);
    let args = match spec.parse(std::env::args()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand() {
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("info") => cmd_info(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<Doc> {
    match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            Doc::parse(&text).map_err(|e| anyhow!("{path}: {e}"))
        }
        None => Ok(Doc::parse("").unwrap()),
    }
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg_doc = load_config(args)?;
    let dataset_name =
        args.get_or("dataset", &cfg_doc.str_or("eval.dataset", "synth-math500")).to_string();
    let model_name =
        args.get_or("model", &cfg_doc.str_or("eval.model", "llemma-34b-sim")).to_string();
    let policy_name = args.get_or("policy", &cfg_doc.str_or("eval.policy", "ets")).to_string();
    let dataset = dataset_by_name(&dataset_name)
        .ok_or_else(|| anyhow!("unknown dataset {dataset_name}"))?;
    let model =
        model_by_name(&model_name).ok_or_else(|| anyhow!("unknown model {model_name}"))?;
    let policy = PolicySpec::parse(&policy_name).map_err(|e| anyhow!(e))?;
    let cfg = EvalConfig {
        spec: WorkloadSpec::new(dataset, model),
        policy,
        width: args.get_usize("width", cfg_doc.usize_or("eval.width", 64)).map_err(|e| anyhow!(e))?,
        n_problems: args
            .get_usize("problems", cfg_doc.usize_or("eval.problems", 100))
            .map_err(|e| anyhow!(e))?,
        seed: args.get_u64("seed", 20260710).map_err(|e| anyhow!(e))?,
        max_steps: dataset.n_steps + 6,
    };
    let workers = args.get_usize("workers", 0).map_err(|e| anyhow!(e))?;
    let workers = if workers == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        workers
    };
    let t = std::time::Instant::now();
    let r = evaluate_with_workers(&cfg, workers);
    println!(
        "{:<20} {:<16} width={:<4} acc={:.1}%  kv={:.0}  unshared={:.0}  tokens={:.0}  calls={:.0}  [{:?}]",
        r.policy,
        r.dataset,
        r.width,
        100.0 * r.accuracy(),
        r.mean_kv_tokens,
        r.mean_unshared_kv_tokens,
        r.mean_new_tokens,
        r.mean_model_calls,
        t.elapsed()
    );
    if let Some(path) = args.get("json") {
        let j = Json::obj(vec![
            ("policy", Json::str(&r.policy)),
            ("dataset", Json::str(&r.dataset)),
            ("model", Json::str(&r.model)),
            ("width", Json::num(r.width as f64)),
            ("n_problems", Json::num(r.n_problems as f64)),
            ("accuracy", Json::num(r.accuracy())),
            ("mean_kv_tokens", Json::num(r.mean_kv_tokens)),
            ("mean_unshared_kv_tokens", Json::num(r.mean_unshared_kv_tokens)),
            ("mean_new_tokens", Json::num(r.mean_new_tokens)),
            ("mean_model_calls", Json::num(r.mean_model_calls)),
        ]);
        std::fs::write(path, j.to_string_compact())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use ets::embed::Embedder;
    use ets::engine::pjrt_lm::{PjrtEmbedder, PjrtLm, PjrtLmConfig, PjrtPrm};
    use ets::search::{run_search, EtsPolicy, RebasePolicy, SearchParams, SearchPolicy};
    use std::rc::Rc;

    let dir = args.get_or("artifacts", "artifacts").to_string();
    let requests = args.get_usize("requests", 4).map_err(|e| anyhow!(e))?;
    let width = args.get_usize("width", 8).map_err(|e| anyhow!(e))?;
    let policy_name = args.get_or("policy", "ets").to_string();
    let lambda_b = args.get_f64("lambda-b", 1.5).map_err(|e| anyhow!(e))?;
    let arts = Rc::new(ets::runtime::Artifacts::open(dir)?);
    println!(
        "serving on PJRT/{} — model d={} L={} H={} S={} V={}",
        arts.runtime.platform_name(),
        arts.dims.d_model,
        arts.dims.n_layers,
        arts.dims.n_heads,
        arts.dims.max_seq,
        arts.dims.embed_out_dim
    );
    let mut total_tokens = 0u64;
    let mut total_kv = 0u64;
    let mut correct_like = 0usize;
    let t0 = std::time::Instant::now();
    for req in 0..requests {
        let mut rng = ets::util::rng::Rng::new(1000 + req as u64);
        let prompt: Vec<u32> =
            (0..12).map(|_| 2 + rng.below(200) as u32).collect();
        let mut lm = PjrtLm::new(
            arts.clone(),
            prompt.clone(),
            req as u64,
            PjrtLmConfig::default(),
        );
        let mut prm = PjrtPrm::new(arts.clone(), prompt);
        let params = SearchParams { width, max_steps: 8 };
        let outcome = if policy_name.starts_with("ets") {
            let mut pol = EtsPolicy::new(lambda_b, 1.0, PjrtEmbedder::new(arts.clone()));
            run_search(&mut lm, &mut prm, &mut pol, &params)
        } else {
            let mut pol = RebasePolicy::default();
            let _: String = SearchPolicy::name(&pol);
            run_search(&mut lm, &mut prm, &mut pol, &params)
        };
        total_tokens += outcome.total_new_tokens();
        total_kv += outcome.total_kv_tokens();
        if outcome.answer.is_some() {
            correct_like += 1;
        }
        println!(
            "req {req}: answer={:?} completions={} kvΣ={} tokens={} prefills={} decodes={} radix_unique={}",
            outcome.answer,
            outcome.completions.len(),
            outcome.total_kv_tokens(),
            outcome.total_new_tokens(),
            lm.prefill_calls,
            lm.decode_calls,
            lm.radix.live_tokens(),
        );
        let _ = Embedder::dim(&mut PjrtEmbedder::new(arts.clone()));
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {requests} requests in {dt:.2}s — {:.2} req/s, {:.1} tok/s, Σkv {total_kv}, answered {correct_like}/{requests}",
        requests as f64 / dt,
        total_tokens as f64 / dt
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts").to_string();
    if !std::path::Path::new(&dir).join("meta.json").exists() {
        bail!("no artifacts at {dir}; run `make artifacts`");
    }
    let arts = ets::runtime::Artifacts::open(dir)?;
    let d = &arts.dims;
    println!("platform: {}", arts.runtime.platform_name());
    println!(
        "lm: vocab={} d_model={} layers={} heads={} head_dim={} max_seq={} batches={:?}",
        d.vocab, d.d_model, d.n_layers, d.n_heads, d.head_dim, d.max_seq, d.lm_batches
    );
    println!("prm batch: {}  embed: batch={} seq={} dim={}", d.prm_batch, d.embed_batch, d.embed_max_seq, d.embed_out_dim);
    println!("datasets: synth-math500, synth-gsm8k  models: llemma-34b-sim, mistral-7b-sim");
    Ok(())
}
