//! The **global prefix hub**: a versioned, read-only directory of
//! committed-prefix fingerprints that the sharded serve scheduler uses to
//! recover cross-shard KV sharing.
//!
//! Since the shard-per-core split, shards are shared-nothing: identical or
//! overlapping prompts landing on different shards duplicate their prefix
//! KV, and a migrated session recomputes its whole prefix from scratch —
//! un-doing at fleet scale exactly the sharing ETS buys within one tree.
//! The hub closes that gap without giving shards any shared mutable state:
//!
//! * **publication** happens only at the coordinator's deterministic round
//!   barrier. Each shard publishes, for every resident session, the
//!   *committed prefix* of its sequences — the span the shard's radix cache
//!   actually holds, sized with the read-only
//!   [`crate::kvcache::RadixCache::peek_prefix`] walk (the same machinery
//!   the migration sizing probe uses, so publication can never perturb LRU
//!   order). A published span is a chain of **token-block fingerprints**:
//!   for each whole block of `block_size` tokens, the chained hash of every
//!   token up to and including that block, together with the covered length
//!   and the owning shard.
//! * **lookups** within a round see a fixed snapshot ([`PrefixHub::version`]
//!   stamps it), so routing and import decisions are byte-identical for any
//!   shard count and any worker timing.
//! * the hub is a *cost/placement* index, never a data plane: an import
//!   decision changes what the perf model charges (block transfer over the
//!   interconnect vs recompute prefill) and where the router places a
//!   request — the actual KV state transition is still the engine's own
//!   reserve → commit insert, so results cannot depend on the hub at all.
//!
//! Consistency contract: every fingerprint resolves, at publication time,
//! to a span fully resident on its owner (enforced by construction — spans
//! are sized by `peek_prefix` against the owner's cache). During the round
//! the owner may evict the span; the next barrier's [`PrefixHub::audit`]
//! classifies each entry as still-live or evicted-but-accounted before the
//! snapshot is rebuilt, so stale entries are counted, never silently lost.

use std::collections::HashMap;
use std::sync::Arc;

/// FNV-1a offset basis (64-bit).
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a prime (64-bit).
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Chain one token into a running fingerprint (FNV-1a over the token's
/// little-endian bytes, collapsed to one multiply per token).
#[inline]
fn chain(h: u64, tok: u32) -> u64 {
    (h ^ tok as u64).wrapping_mul(FNV_PRIME)
}

/// Chained fingerprint of `tokens[..k]` for every whole block `k` — the
/// hash at index `i` covers blocks `0..=i`.
fn block_chain(tokens: &[u32], block_size: usize) -> Vec<u64> {
    let bs = block_size.max(1);
    let blocks = tokens.len() / bs;
    let mut out = Vec::with_capacity(blocks);
    let mut h = FNV_OFFSET;
    for (i, &t) in tokens[..blocks * bs].iter().enumerate() {
        h = chain(h, t);
        if (i + 1) % bs == 0 {
            out.push(h);
        }
    }
    out
}

/// One published span: the longest-prefix entry a lookup resolves to.
#[derive(Clone, Debug)]
struct HubEntry {
    /// Shard whose cache held the span at publication time.
    shard: usize,
    /// Tokens of the publishing sequence this entry covers (a whole number
    /// of blocks; the entry's prefix is `span[..covered]`).
    covered: usize,
    /// The fingerprinted tokens themselves — kept so lookups can reject
    /// hash collisions exactly and audits can re-probe the owner's cache.
    /// Shared (`Arc`) across all block-level entries of one published
    /// sequence, so an L-token publication stores O(L) tokens total, not
    /// O(L²/block_size).
    span: Arc<[u32]>,
}

impl HubEntry {
    fn prefix(&self) -> &[u32] {
        &self.span[..self.covered]
    }
}

/// A successful longest-prefix lookup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HubMatch {
    /// Shard that published the span.
    pub shard: usize,
    /// Tokens covered (always a whole number of blocks).
    pub tokens: usize,
    /// Snapshot the match was served from.
    pub version: u64,
}

/// Outcome of one consistency audit over the current snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HubAudit {
    /// Entries whose span is still fully resident on the owning shard.
    pub live: u64,
    /// Entries the owner evicted since publication (accounted, not lost).
    pub evicted: u64,
    /// Entries evicted from the owner's hot tier but still reconstructible
    /// from its host-DRAM cold tier (hot prefix + demoted suffix cover the
    /// whole span) — accounted separately so hub accounting reconciles once
    /// spans can live below HBM.
    pub demoted: u64,
}

/// Versioned read-only directory of committed-prefix fingerprints.
///
/// Built fresh at every round barrier by the coordinator (the only writer);
/// everything else — the admission router, the resume/migration import
/// path — only reads it. One entry per (prefix hash); when two shards
/// publish the same span the *first* publisher in shard-index order wins,
/// which keeps the directory deterministic.
#[derive(Clone, Debug)]
pub struct PrefixHub {
    block_size: usize,
    version: u64,
    entries: HashMap<u64, HubEntry>,
    /// Fingerprints published into the current snapshot (Σ over publishes).
    published_this_round: u64,
}

impl PrefixHub {
    pub fn new(block_size: usize) -> Self {
        Self {
            block_size: block_size.max(1),
            version: 0,
            entries: HashMap::new(),
            published_this_round: 0,
        }
    }

    /// Snapshot version — bumped once per [`PrefixHub::begin_round`], so
    /// every lookup within a round observes the same directory.
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Entries in the current snapshot.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fingerprints published into the current snapshot.
    pub fn published(&self) -> u64 {
        self.published_this_round
    }

    /// Start a new snapshot: drop every entry and bump the version. Called
    /// once per round barrier, before the shards republish.
    pub fn begin_round(&mut self) {
        self.entries.clear();
        self.published_this_round = 0;
        self.version += 1;
    }

    /// Publish the committed prefix of one sequence for `shard`:
    /// `cached_tokens` is the span the shard's cache actually holds (the
    /// caller sizes it with the read-only `peek_prefix` walk). Only whole
    /// blocks are published — a partial tail block cannot be shared at
    /// block granularity. Returns the number of fingerprints added (already
    /// published prefixes — from this shard or an earlier one — add none).
    pub fn publish(&mut self, shard: usize, tokens: &[u32], cached_tokens: usize) -> usize {
        let cached = cached_tokens.min(tokens.len());
        let chain = block_chain(&tokens[..cached], self.block_size);
        if chain.is_empty() {
            return 0;
        }
        // one shared buffer for every block-level entry of this sequence
        let span: Arc<[u32]> = tokens[..chain.len() * self.block_size].into();
        let mut added = 0usize;
        for (i, h) in chain.into_iter().enumerate() {
            let covered = (i + 1) * self.block_size;
            self.entries.entry(h).or_insert_with(|| {
                added += 1;
                HubEntry { shard, covered, span: span.clone() }
            });
        }
        self.published_this_round += added as u64;
        added
    }

    /// Longest published prefix of `tokens`: walks the chained block
    /// fingerprints from short to long and returns the deepest hit. Hash
    /// collisions are rejected exactly (the stored span is compared), so a
    /// match is always a genuine token-prefix match. Hashing is incremental
    /// and stops at the first non-matching block — a miss at k blocks makes
    /// longer chains unmatchable, because every publisher publishes its
    /// full chain — so a cold probe (the common case: minted-id sequences
    /// on the resume path) costs one block of hashing and no allocation.
    pub fn lookup(&self, tokens: &[u32]) -> Option<HubMatch> {
        let bs = self.block_size;
        let mut best: Option<HubMatch> = None;
        let mut h = FNV_OFFSET;
        for k in 0..tokens.len() / bs {
            for &t in &tokens[k * bs..(k + 1) * bs] {
                h = chain(h, t);
            }
            let covered = (k + 1) * bs;
            match self.entries.get(&h) {
                Some(e) if e.prefix() == &tokens[..covered] => {
                    best =
                        Some(HubMatch { shard: e.shard, tokens: covered, version: self.version });
                }
                _ => break,
            }
        }
        best
    }

    /// Consistency audit of the current snapshot: `resolve(shard, span)`
    /// returns how many tokens of `span` the owner's cache still holds
    /// (the coordinator passes the read-only `peek_prefix`), and
    /// `cold_resolve(shard, span, hot)` whether the owner's cold tier
    /// contiguously covers the rest of the span beyond the `hot` resident
    /// tokens (the read-only `cold_probe` walk). Every entry is classified
    /// live (fully hot), demoted (hot + cold still cover it), or evicted —
    /// published fingerprints can go stale mid-round, never missing.
    pub fn audit(
        &self,
        mut resolve: impl FnMut(usize, &[u32]) -> usize,
        mut cold_resolve: impl FnMut(usize, &[u32], usize) -> bool,
    ) -> HubAudit {
        let mut out = HubAudit::default();
        for e in self.entries.values() {
            let hot = resolve(e.shard, e.prefix());
            if hot >= e.covered {
                out.live += 1;
            } else if cold_resolve(e.shard, e.prefix(), hot) {
                out.demoted += 1;
            } else {
                out.evicted += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::RadixCache;

    fn seq(start: u32, len: usize) -> Vec<u32> {
        (0..len as u32).map(|t| start + t).collect()
    }

    #[test]
    fn publish_then_lookup_longest_whole_block_prefix() {
        let mut hub = PrefixHub::new(4);
        hub.begin_round();
        let s = seq(100, 10); // 2 whole blocks + 2-token tail
        assert_eq!(hub.publish(1, &s, 10), 2, "two whole blocks published");
        // full-sequence lookup resolves to the longest whole-block span
        let m = hub.lookup(&s).unwrap();
        assert_eq!(m.shard, 1);
        assert_eq!(m.tokens, 8);
        assert_eq!(m.version, hub.version());
        // a shorter overlapping prompt matches its own whole blocks
        let m = hub.lookup(&seq(100, 5)).unwrap();
        assert_eq!(m.tokens, 4);
        // diverging after one block matches exactly one block
        let mut d = seq(100, 4);
        d.extend(seq(900, 4));
        assert_eq!(hub.lookup(&d).unwrap().tokens, 4);
        // an unrelated prompt misses
        assert_eq!(hub.lookup(&seq(5000, 8)), None);
        // sub-block prompts cannot match
        assert_eq!(hub.lookup(&seq(100, 3)), None);
    }

    #[test]
    fn cached_span_caps_what_is_published() {
        let mut hub = PrefixHub::new(4);
        hub.begin_round();
        let s = seq(0, 16);
        // the owner only holds 9 tokens → 2 whole blocks publishable
        assert_eq!(hub.publish(0, &s, 9), 2);
        assert_eq!(hub.lookup(&s).unwrap().tokens, 8);
        // a partial block (3 cached tokens) publishes nothing
        let mut hub2 = PrefixHub::new(4);
        hub2.begin_round();
        assert_eq!(hub2.publish(0, &s, 3), 0);
        assert!(hub2.is_empty());
    }

    #[test]
    fn first_publisher_wins_deterministically() {
        let mut hub = PrefixHub::new(4);
        hub.begin_round();
        let s = seq(7, 8);
        assert_eq!(hub.publish(0, &s, 8), 2);
        // shard 2 republishing the same span adds nothing and cannot steal
        assert_eq!(hub.publish(2, &s, 8), 0);
        assert_eq!(hub.lookup(&s).unwrap().shard, 0);
        // but a *longer* committed span from shard 2 extends the chain
        let long = seq(7, 16);
        assert_eq!(hub.publish(2, &long, 16), 2);
        let m = hub.lookup(&long).unwrap();
        assert_eq!((m.shard, m.tokens), (2, 16));
        // the short prefix still resolves to its original owner
        assert_eq!(hub.lookup(&s).unwrap().shard, 0);
    }

    #[test]
    fn begin_round_clears_and_versions_the_snapshot() {
        let mut hub = PrefixHub::new(4);
        hub.begin_round();
        let v1 = hub.version();
        hub.publish(0, &seq(1, 8), 8);
        assert_eq!(hub.len(), 2);
        assert_eq!(hub.published(), 2);
        hub.begin_round();
        assert!(hub.is_empty());
        assert_eq!(hub.published(), 0);
        assert_eq!(hub.version(), v1 + 1);
        assert_eq!(hub.lookup(&seq(1, 8)), None, "stale snapshot must be gone");
    }

    #[test]
    fn audit_classifies_live_and_evicted_spans() {
        let mut cache = RadixCache::with_block_size(1 << 12, 4);
        let s = seq(40, 8);
        cache.insert(&s);
        let mut hub = PrefixHub::new(4);
        hub.begin_round();
        hub.publish(0, &s, cache.peek_prefix(&s));
        let audit = hub.audit(|_, span| cache.peek_prefix(span), |_, _, _| false);
        assert_eq!(audit, HubAudit { live: 2, evicted: 0, demoted: 0 });
        // the owner evicts mid-round (no cold tier): the next audit
        // accounts the loss as evicted
        cache.evict(usize::MAX);
        let audit = hub.audit(
            |_, span| cache.peek_prefix(span),
            |_, span, hot| cache.cold_probe(span, hot) <= hot,
        );
        assert_eq!(audit.live, 0);
        assert_eq!(audit.evicted, 2);
        assert_eq!(audit.demoted, 0);
    }

    #[test]
    fn audit_classifies_demoted_spans_and_identity_reconciles() {
        // With a cold tier attached, a mid-round eviction demotes instead
        // of destroying: the audit must classify those entries Demoted and
        // the published == live + evicted + demoted identity must hold
        // through every tier transition.
        let mut cache = RadixCache::with_block_size(1 << 12, 4);
        cache.attach_cold_tier(1 << 12);
        let s = seq(40, 8);
        cache.insert(&s);
        let mut hub = PrefixHub::new(4);
        hub.begin_round();
        hub.publish(0, &s, cache.peek_prefix(&s));
        let identity = |a: HubAudit| a.live + a.evicted + a.demoted;
        let audit = hub.audit(
            |_, span| cache.peek_prefix(span),
            |_, span, hot| cache.cold_probe(span, hot) <= hot,
        );
        assert_eq!(audit, HubAudit { live: 2, evicted: 0, demoted: 0 });
        assert_eq!(identity(audit), hub.published());
        // demote-instead-of-destroy: both entries are reconstructible
        cache.evict(usize::MAX);
        let audit = hub.audit(
            |_, span| cache.peek_prefix(span),
            |_, span, hot| cache.cold_probe(span, hot) <= hot,
        );
        assert_eq!(audit, HubAudit { live: 0, evicted: 0, demoted: 2 });
        assert_eq!(identity(audit), hub.published());
        // a sequence the cold tier never saw stays evicted
        let t = seq(900, 8);
        hub.publish(0, &t, 8);
        let audit = hub.audit(
            |_, span| cache.peek_prefix(span),
            |_, span, hot| cache.cold_probe(span, hot) <= hot,
        );
        assert_eq!(audit, HubAudit { live: 0, evicted: 2, demoted: 2 });
        assert_eq!(identity(audit), hub.published());
    }

    #[test]
    fn fingerprints_share_the_peek_prefix_walk() {
        // Publication sized by peek_prefix must agree with what lookups
        // find: insert a sequence, publish its peeked span, and the lookup
        // of an identical prompt resolves to exactly the cached whole-block
        // prefix.
        let mut cache = RadixCache::with_block_size(1 << 12, 8);
        let s = seq(3_000, 20); // 2 whole blocks + tail
        cache.insert(&s);
        let cached = cache.peek_prefix(&s);
        assert_eq!(cached, 20);
        let mut hub = PrefixHub::new(8);
        hub.begin_round();
        hub.publish(3, &s, cached);
        let m = hub.lookup(&s).unwrap();
        assert_eq!((m.shard, m.tokens), (3, 16));
    }

    #[test]
    fn collisions_are_rejected_by_span_comparison() {
        use std::sync::Arc;
        // Force a synthetic collision by inserting an entry manually: the
        // lookup must reject it because the stored span differs.
        let mut hub = PrefixHub::new(2);
        hub.begin_round();
        let a = seq(10, 4);
        hub.publish(0, &a, 4);
        let b = seq(20, 4);
        // graft b's chain hashes onto a's entries (worst-case collision)
        let span: Arc<[u32]> = a.clone().into();
        for (i, h) in block_chain(&b, 2).into_iter().enumerate() {
            hub.entries
                .insert(h, HubEntry { shard: 1, covered: (i + 1) * 2, span: span.clone() });
        }
        assert_eq!(hub.lookup(&b), None, "span mismatch must reject the hit");
    }
}
