//! Host-DRAM cold tier for the paged KV cache: a per-shard [`SpillArena`]
//! that eviction *demotes* spans into instead of destroying them, so a
//! later resume can restore the payload over a modeled PCIe link instead of
//! paying a recompute prefill.
//!
//! Spans are keyed by the **full root-path token sequence** they terminate:
//! a demoted radix leaf covering path tokens `[start, end)` is stored under
//! fingerprints of `tokens[..end]`. Because the eviction cascade removes
//! leaves bottom-up, the spans of one trajectory *tile* its path — the leaf
//! span ends where the trajectory ends, its parent's span ends where the
//! leaf's starts — and a backward walk ([`SpillArena::probe_back`]) stitches
//! them into one contiguous restorable suffix. Each span is additionally
//! indexed at every whole-block boundary it covers, so probes at block
//! granularity (the prefix hub's audit, trajectories re-split at different
//! node extents) resolve *into* a span, not only at its end.
//!
//! The arena is the pressure ladder's **third rung**: evict-to-cold before
//! evict-to-nothing. Its capacity (in the same block units as the hot
//! allocator) is a second hard budget — admitting past it drops the arena's
//! own LRU spans, and only *that* is true destruction. The arena keeps its
//! **own LRU clock**, never the cache's: demotions and restores must not
//! perturb the hot tier's eviction order, or cold-tier {on,off} would stop
//! being result-identical.
//!
//! Payload words move through the same `read_span`/`write_words` surface as
//! the PR 7 transport plane, so a restore is bit-identical to the local
//! hash-fill recompute by construction (asserted in debug builds at the
//! write site, [`crate::kvcache::RadixCache::write_node_payload`]).

use std::collections::{BTreeSet, HashMap};

/// FNV-1a over a token sequence — the span fingerprint (same chaining as
/// the prefix hub's block fingerprints). Collisions are survivable (the
/// arena exact-compares token sequences behind the hash); the map is never
/// *iterated* for decisions, so `HashMap` order cannot leak into behavior.
fn seq_hash(tokens: &[u32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in tokens {
        h = (h ^ t as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// One demoted span: the payload words of path tokens `[start, end)` of the
/// trajectory `tokens[..end]` (where `end == tokens.len()`).
#[derive(Clone, Debug)]
struct SpillSpan {
    /// Full root-path token sequence up to and including this span.
    tokens: Vec<u32>,
    /// First token slot the payload covers (`tokens[start..]` ↔ `words`).
    start: usize,
    /// Payload words for `tokens[start..]`, exactly `end - start` of them.
    words: Vec<u64>,
    /// Arena-local LRU clock value of the last admit/restore touch.
    last_access: u64,
}

impl SpillSpan {
    fn blocks(&self, block_size: usize) -> usize {
        self.words.len().div_ceil(block_size)
    }
}

/// Host-DRAM spill arena: demoted-span store with a hard block budget and
/// its own LRU. See the module docs for the tiling/keying scheme.
#[derive(Clone, Debug, Default)]
pub struct SpillArena {
    block_size: usize,
    /// Hard budget, in hot-tier block units.
    capacity_blocks: usize,
    /// Σ blocks held by live spans — maintained incrementally and asserted
    /// against a full rescan in [`SpillArena::check_invariants`], same
    /// discipline as the hot tier's `evictable_block_count`.
    used_blocks: usize,
    /// Arena-local LRU clock (never the cache's — see module docs).
    clock: u64,
    /// Span slots; `None` slots are on `free`.
    spans: Vec<Option<SpillSpan>>,
    free: Vec<usize>,
    /// Fingerprint of `tokens[..k]` → span slots holding slot `k - 1`, for
    /// every probe point `k` of each span: its exact end, plus every
    /// whole-block boundary inside `(start, end)`. A `Vec` per bucket for
    /// hash collisions *and* genuinely-shared prefixes of diverging
    /// trajectories; lookups exact-compare tokens behind the hash.
    index: HashMap<u64, Vec<usize>>,
    /// Live spans keyed by `(last_access, slot)`; first element is the LRU
    /// drop victim when the budget overflows.
    lru: BTreeSet<(u64, usize)>,
    /// Tokens ever demoted into the arena (Σ over admit events).
    demoted_tokens: u64,
    /// Tokens ever restored out of the arena (Σ over restore events).
    restored_tokens: u64,
    /// Tokens truly destroyed: dropped at admit (oversized span) or by the
    /// arena's own LRU when the second budget overflows.
    dropped_tokens: u64,
}

impl SpillArena {
    /// Arena with a `ceil(capacity_tokens / block_size)`-block hard budget.
    pub fn new(capacity_tokens: usize, block_size: usize) -> Self {
        let bs = block_size.max(1);
        Self {
            block_size: bs,
            capacity_blocks: capacity_tokens.div_ceil(bs),
            ..Self::default()
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity_blocks
    }

    pub fn used_blocks(&self) -> usize {
        self.used_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.capacity_blocks - self.used_blocks
    }

    /// Live demoted spans currently held.
    pub fn live_spans(&self) -> usize {
        self.lru.len()
    }

    /// Tokens ever demoted into the arena (monotone counter).
    pub fn demoted_tokens(&self) -> u64 {
        self.demoted_tokens
    }

    /// Tokens ever restored out of the arena (monotone counter).
    pub fn restored_tokens(&self) -> u64 {
        self.restored_tokens
    }

    /// Tokens truly destroyed (both tiers full, or span > whole budget).
    pub fn dropped_tokens(&self) -> u64 {
        self.dropped_tokens
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn touch(&mut self, slot: usize) {
        let now = self.tick();
        let s = self.spans[slot].as_mut().expect("touch of a freed slot");
        self.lru.remove(&(s.last_access, slot));
        s.last_access = now;
        self.lru.insert((now, slot));
    }

    /// The index keys of a span over `tokens` starting at `start`: the
    /// running fingerprint at its exact end and at every whole-block
    /// boundary strictly inside `(start, end)`.
    fn span_keys(&self, tokens: &[u32], start: usize) -> Vec<u64> {
        let end = tokens.len();
        let mut keys = Vec::new();
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for (i, &t) in tokens.iter().enumerate() {
            h = (h ^ t as u64).wrapping_mul(0x100_0000_01b3);
            let k = i + 1;
            if k > start && (k == end || k % self.block_size == 0) {
                keys.push(h);
            }
        }
        keys
    }

    /// A live span that *contains* probe point `tokens.len()`: its
    /// trajectory starts with `tokens` and its payload begins before the
    /// probe point. Bucket order is deterministic (insertion-ordered by the
    /// deterministic admit sequence), and any hit is sound — shared
    /// prefixes of diverging trajectories hold identical payload words by
    /// [`crate::kvcache::payload_word`] construction.
    fn find(&self, tokens: &[u32]) -> Option<usize> {
        let slots = self.index.get(&seq_hash(tokens))?;
        slots.iter().copied().find(|&i| {
            self.spans[i].as_ref().is_some_and(|s| {
                s.start < tokens.len()
                    && s.tokens.len() >= tokens.len()
                    && s.tokens[..tokens.len()] == *tokens
            })
        })
    }

    /// Destroy span `slot` (LRU overflow or replace-by-wider).
    fn drop_span(&mut self, slot: usize) {
        let s = self.spans[slot].take().expect("dropping a freed slot");
        self.lru.remove(&(s.last_access, slot));
        self.used_blocks -= s.blocks(self.block_size);
        self.dropped_tokens += s.words.len() as u64;
        for h in self.span_keys(&s.tokens, s.start) {
            if let Some(slots) = self.index.get_mut(&h) {
                slots.retain(|&i| i != slot);
                if slots.is_empty() {
                    self.index.remove(&h);
                }
            }
        }
        self.free.push(slot);
    }

    /// Drop LRU spans until `blocks` more fit under the budget.
    fn make_room(&mut self, blocks: usize) {
        while self.used_blocks + blocks > self.capacity_blocks {
            let Some(&(_, slot)) = self.lru.iter().next() else { break };
            self.drop_span(slot);
        }
    }

    /// Demote the payload of path tokens `[start, end)` of trajectory
    /// `tokens` (`end == tokens.len()`, `words.len() == end - start`) into
    /// the arena. Returns whether the span is (still) held: an oversized
    /// span — bigger than the whole budget — is dropped outright, and a
    /// span some held span already covers is merely LRU-touched (payload
    /// agreement debug-asserted). Counts toward
    /// [`SpillArena::demoted_tokens`] either way — the demotion *happened*;
    /// what the arena keeps is a capacity question.
    pub fn admit(&mut self, tokens: &[u32], start: usize, words: &[u64]) -> bool {
        debug_assert_eq!(
            tokens.len() - start,
            words.len(),
            "span payload must cover tokens[start..]"
        );
        if words.is_empty() {
            return false;
        }
        self.demoted_tokens += words.len() as u64;
        let blocks = words.len().div_ceil(self.block_size);
        if blocks > self.capacity_blocks {
            self.dropped_tokens += words.len() as u64;
            return false;
        }
        if let Some(slot) = self.find(tokens) {
            let s = self.spans[slot].as_ref().expect("find returned a live slot");
            if s.start <= start {
                // a held span already covers everything this one would add
                debug_assert_eq!(
                    &s.words[start - s.start..tokens.len() - s.start],
                    words,
                    "re-demoted span diverges from the held payload"
                );
                self.touch(slot);
                return true;
            }
            if s.tokens.len() == tokens.len() {
                // same trajectory, strictly narrower: replace with ours
                self.drop_span(slot);
            }
            // else: a longer trajectory overlapping ours partially — both
            // stay (ours adds the `[start, s.start)` words it lacks)
        }
        self.make_room(blocks);
        let now = self.tick();
        let span = SpillSpan {
            tokens: tokens.to_vec(),
            start,
            words: words.to_vec(),
            last_access: now,
        };
        let keys = self.span_keys(tokens, start);
        let slot = if let Some(slot) = self.free.pop() {
            self.spans[slot] = Some(span);
            slot
        } else {
            self.spans.push(Some(span));
            self.spans.len() - 1
        };
        for h in keys {
            self.index.entry(h).or_default().push(slot);
        }
        self.lru.insert((now, slot));
        self.used_blocks += blocks;
        true
    }

    /// Read-only backward probe: the earliest slot `m` such that the arena
    /// contiguously covers `tokens[m..]` (stitching tiled spans), walking no
    /// further once coverage reaches `start`. Returns `tokens.len()` when
    /// the arena holds nothing ending at (or containing) this trajectory's
    /// end. Touches no LRU clock — sizing probes must not perturb drop
    /// order.
    pub fn probe_back(&self, tokens: &[u32], start: usize) -> usize {
        let mut end = tokens.len();
        while end > start {
            let Some(slot) = self.find(&tokens[..end]) else { break };
            end = self.spans[slot].as_ref().expect("live slot").start;
        }
        end
    }

    /// Restore the payload words of `tokens[from..]`, stitched from the
    /// tiled spans the backward walk traverses. `None` when coverage is
    /// incomplete (a span was dropped since the probe) — the caller stays
    /// on its already-materialized recompute words. LRU-touches every span
    /// read; counts toward [`SpillArena::restored_tokens`].
    pub fn restore(&mut self, tokens: &[u32], from: usize) -> Option<Vec<u64>> {
        let end = tokens.len();
        if from >= end {
            return Some(Vec::new());
        }
        // Collect (slot, lo, hi) segments back to front, then splice.
        let mut segs: Vec<(usize, usize, usize)> = Vec::new();
        let mut cur = end;
        while cur > from {
            let slot = self.find(&tokens[..cur])?;
            let s = self.spans[slot].as_ref().expect("live slot");
            segs.push((slot, s.start.max(from), cur));
            cur = s.start;
        }
        let mut out = Vec::with_capacity(end - from);
        for &(slot, lo, hi) in segs.iter().rev() {
            let s = self.spans[slot].as_ref().expect("live slot");
            out.extend_from_slice(&s.words[lo - s.start..hi - s.start]);
        }
        debug_assert_eq!(out.len(), end - from);
        for &(slot, _, _) in &segs {
            self.touch(slot);
        }
        self.restored_tokens += (end - from) as u64;
        Some(out)
    }

    /// Check internal invariants (tests / debug): incremental counters vs
    /// full rescan, LRU/index/slot agreement — the same lockstep discipline
    /// as the hot tier's evictable set.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut block_sum = 0usize;
        let mut expect_lru: BTreeSet<(u64, usize)> = BTreeSet::new();
        for (i, slot) in self.spans.iter().enumerate() {
            let Some(s) = slot else {
                if !self.free.contains(&i) {
                    return Err(format!("freed slot {i} missing from free list"));
                }
                continue;
            };
            if s.words.len() != s.tokens.len() - s.start {
                return Err(format!("slot {i}: words/tokens length mismatch"));
            }
            if s.words.is_empty() {
                return Err(format!("slot {i}: empty span"));
            }
            block_sum += s.blocks(self.block_size);
            expect_lru.insert((s.last_access, i));
            for h in self.span_keys(&s.tokens, s.start) {
                if !self.index.get(&h).is_some_and(|v| v.contains(&i)) {
                    return Err(format!("slot {i} unreachable through the index"));
                }
            }
        }
        if block_sum != self.used_blocks {
            return Err(format!(
                "cold block counter drift: sum {block_sum} != counter {}",
                self.used_blocks
            ));
        }
        if self.used_blocks > self.capacity_blocks {
            return Err("cold block budget exceeded".into());
        }
        if expect_lru != self.lru {
            return Err(format!(
                "cold LRU drift: expect {expect_lru:?} got {:?}",
                self.lru
            ));
        }
        for (h, slots) in &self.index {
            if slots.is_empty() {
                return Err(format!("empty index bucket {h:#x}"));
            }
            for &i in slots {
                let Some(s) = self.spans.get(i).and_then(|s| s.as_ref()) else {
                    return Err(format!("index bucket {h:#x} points at freed slot {i}"));
                };
                if !self.span_keys(&s.tokens, s.start).contains(h) {
                    return Err(format!("slot {i} filed under a foreign fingerprint"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::payload_word;

    fn words_of(tokens: &[u32]) -> Vec<u64> {
        tokens.iter().map(|&t| payload_word(t)).collect()
    }

    #[test]
    fn admit_probe_restore_roundtrip() {
        let mut a = SpillArena::new(1 << 12, 16);
        let seq: Vec<u32> = (100..164).collect();
        assert!(a.admit(&seq, 0, &words_of(&seq)));
        assert_eq!(a.probe_back(&seq, 0), 0);
        let got = a.restore(&seq, 0).unwrap();
        assert_eq!(got, words_of(&seq));
        assert_eq!(a.demoted_tokens(), 64);
        assert_eq!(a.restored_tokens(), 64);
        a.check_invariants().unwrap();
    }

    #[test]
    fn tiled_spans_stitch_into_one_contiguous_suffix() {
        // eviction order demotes the leaf first, then its parent: the leaf
        // span [40, 64) lands before the parent span [0, 40)
        let mut a = SpillArena::new(1 << 12, 16);
        let seq: Vec<u32> = (0..64).collect();
        assert!(a.admit(&seq, 40, &words_of(&seq[40..])));
        // leaf alone: coverage stops at 40
        assert_eq!(a.probe_back(&seq, 0), 40);
        assert!(a.admit(&seq[..40], 0, &words_of(&seq[..40])));
        // parent + leaf tile the whole path
        assert_eq!(a.probe_back(&seq, 0), 0);
        assert_eq!(a.restore(&seq, 0).unwrap(), words_of(&seq));
        // a mid-path restore slices both spans correctly
        assert_eq!(a.restore(&seq, 30).unwrap(), words_of(&seq[30..]));
        a.check_invariants().unwrap();
    }

    #[test]
    fn probe_stops_at_start_floor() {
        let mut a = SpillArena::new(1 << 12, 16);
        let seq: Vec<u32> = (0..64).collect();
        assert!(a.admit(&seq, 40, &words_of(&seq[40..])));
        assert!(a.admit(&seq[..40], 0, &words_of(&seq[..40])));
        // caller already holds [0, 48): the walk stops after the first span
        assert_eq!(a.probe_back(&seq, 48), 40);
        a.check_invariants().unwrap();
    }

    #[test]
    fn block_boundary_probes_resolve_into_a_span() {
        // the hub audit probes block-aligned *prefixes* of a published
        // span; those must resolve into a containing span, not just at
        // exact span ends
        let mut a = SpillArena::new(1 << 12, 4);
        let seq: Vec<u32> = (500..524).collect(); // 24 tokens, 6 blocks
        assert!(a.admit(&seq, 0, &words_of(&seq)));
        // block-aligned prefix probes land inside the span
        assert_eq!(a.probe_back(&seq[..8], 0), 0);
        assert_eq!(a.probe_back(&seq[..20], 0), 0);
        // and restores of those prefixes slice the span's words
        assert_eq!(a.restore(&seq[..8], 0).unwrap(), words_of(&seq[..8]));
        // a non-aligned interior probe point is not indexed
        assert_eq!(a.probe_back(&seq[..7], 0), 7);
        // a diverging trajectory misses despite the shared prefix length
        let other: Vec<u32> = (900..908).collect();
        assert_eq!(a.probe_back(&other, 0), 8);
        a.check_invariants().unwrap();
    }

    #[test]
    fn second_budget_drops_lru_spans() {
        // 4-block budget at block_size 16 = 64 tokens
        let mut a = SpillArena::new(64, 16);
        let s1: Vec<u32> = (0..32).collect();
        let s2: Vec<u32> = (1000..1032).collect();
        let s3: Vec<u32> = (2000..2032).collect();
        assert!(a.admit(&s1, 0, &words_of(&s1)));
        assert!(a.admit(&s2, 0, &words_of(&s2)));
        assert_eq!(a.used_blocks(), 4);
        // third span overflows the budget: s1 (LRU) is truly destroyed
        assert!(a.admit(&s3, 0, &words_of(&s3)));
        assert_eq!(a.used_blocks(), 4);
        assert_eq!(a.probe_back(&s1, 0), s1.len());
        assert_eq!(a.probe_back(&s2, 0), 0);
        assert_eq!(a.probe_back(&s3, 0), 0);
        assert_eq!(a.dropped_tokens(), 32);
        // a restore MRU-touches s2, so the next overflow victim is s3
        let s4: Vec<u32> = (3000..3032).collect();
        a.restore(&s2, 0).unwrap();
        assert!(a.admit(&s4, 0, &words_of(&s4)));
        assert_eq!(a.probe_back(&s3, 0), s3.len());
        assert_eq!(a.probe_back(&s2, 0), 0);
        a.check_invariants().unwrap();
    }

    #[test]
    fn oversized_span_is_dropped_outright() {
        let mut a = SpillArena::new(32, 16);
        let seq: Vec<u32> = (0..64).collect();
        assert!(!a.admit(&seq, 0, &words_of(&seq)));
        assert_eq!(a.used_blocks(), 0);
        assert_eq!(a.demoted_tokens(), 64);
        assert_eq!(a.dropped_tokens(), 64);
        a.check_invariants().unwrap();
    }

    #[test]
    fn re_demotion_replaces_with_the_wider_span() {
        let mut a = SpillArena::new(1 << 12, 16);
        let seq: Vec<u32> = (0..64).collect();
        assert!(a.admit(&seq, 40, &words_of(&seq[40..])));
        // same trajectory, wider coverage: replaces the narrow span
        assert!(a.admit(&seq, 16, &words_of(&seq[16..])));
        assert_eq!(a.probe_back(&seq, 0), 16);
        assert_eq!(a.live_spans(), 1);
        // narrower re-demotion of the same trajectory only touches
        assert!(a.admit(&seq, 40, &words_of(&seq[40..])));
        assert_eq!(a.probe_back(&seq, 0), 16);
        assert_eq!(a.live_spans(), 1);
        // a prefix already inside the held span dedups to a touch too
        assert!(a.admit(&seq[..48], 32, &words_of(&seq[32..48])));
        assert_eq!(a.live_spans(), 1);
        a.check_invariants().unwrap();
    }

    #[test]
    fn restore_of_partial_coverage_returns_none() {
        let mut a = SpillArena::new(1 << 12, 16);
        let seq: Vec<u32> = (0..64).collect();
        assert!(a.admit(&seq, 40, &words_of(&seq[40..])));
        assert!(a.restore(&seq, 0).is_none());
        assert_eq!(a.restore(&seq, 40).unwrap(), words_of(&seq[40..]));
        a.check_invariants().unwrap();
    }
}
