//! Radix-tree KV-cache manager (SGLang RadixAttention semantics).
//!
//! The serving engine stores one KV entry per *token*, deduplicated across
//! sequences that share a prefix — exactly the mechanism whose effectiveness
//! the paper's search policies trade on. This module reproduces the
//! bookkeeping: prefix matching, node splitting, reference counting while a
//! sequence is scheduled, and LRU eviction of unreferenced branches.
//!
//! Token KV payloads themselves live with the model executor; this tree
//! tracks token *counts* and identity so the engine can (a) compute how many
//! new KV slots a sequence needs, (b) account memory, (c) evict.

use std::collections::{HashMap, HashSet};

/// Handle to a node in the radix tree.
pub type NodeIdx = usize;

#[derive(Clone, Debug)]
struct RNode {
    /// Token span stored at this node (edge label).
    key: Vec<u32>,
    parent: Option<NodeIdx>,
    /// child-first-token → node index.
    children: HashMap<u32, NodeIdx>,
    /// Number of active sequences pinning this node (and its ancestors).
    refcount: usize,
    /// LRU clock of the last match/insert touching this node.
    last_access: u64,
    /// Free-list marker.
    dead: bool,
}

/// Result of an [`RadixCache::insert`].
#[derive(Clone, Debug, PartialEq)]
pub struct InsertOutcome {
    /// Tokens newly allocated (not found in the tree).
    pub new_tokens: usize,
    /// Tokens reused from existing nodes.
    pub shared_tokens: usize,
    /// Node holding the end of the inserted sequence.
    pub node: NodeIdx,
}

/// Radix-tree KV cache with token-granularity accounting.
#[derive(Clone, Debug)]
pub struct RadixCache {
    nodes: Vec<RNode>,
    free: Vec<NodeIdx>,
    root: NodeIdx,
    clock: u64,
    /// Unique tokens currently cached.
    live_tokens: usize,
    /// Capacity in tokens (eviction target; callers enforce policy).
    pub capacity_tokens: usize,
}

impl RadixCache {
    pub fn new(capacity_tokens: usize) -> Self {
        let root = RNode {
            key: vec![],
            parent: None,
            children: HashMap::new(),
            refcount: 1, // root is never evictable
            last_access: 0,
            dead: false,
        };
        Self {
            nodes: vec![root],
            free: vec![],
            root: 0,
            clock: 0,
            live_tokens: 0,
            capacity_tokens,
        }
    }

    pub fn live_tokens(&self) -> usize {
        self.live_tokens
    }

    /// Number of live (non-root, non-freed) nodes.
    pub fn live_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| !n.dead).count() - 1
    }

    fn alloc(&mut self, node: RNode) -> NodeIdx {
        self.live_tokens += node.key.len();
        if let Some(idx) = self.free.pop() {
            self.nodes[idx] = node;
            idx
        } else {
            self.nodes.push(node);
            self.nodes.len() - 1
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Longest cached prefix of `tokens`: (matched token count, end node).
    /// Touches LRU clocks along the path.
    pub fn match_prefix(&mut self, tokens: &[u32]) -> (usize, NodeIdx) {
        let now = self.tick();
        let mut cur = self.root;
        let mut matched = 0usize;
        self.nodes[cur].last_access = now;
        while matched < tokens.len() {
            let Some(&child) = self.nodes[cur].children.get(&tokens[matched]) else {
                break;
            };
            let klen = self.nodes[child].key.len();
            let common = self.nodes[child]
                .key
                .iter()
                .zip(&tokens[matched..])
                .take_while(|(a, b)| a == b)
                .count();
            self.nodes[child].last_access = now;
            matched += common;
            if common < klen {
                break; // partial edge match: stop (match granularity = token)
            }
            cur = child;
        }
        (matched, cur)
    }

    /// Insert `tokens`, sharing any existing prefix. Splits edges on partial
    /// matches. Returns allocation accounting and the terminal node.
    pub fn insert(&mut self, tokens: &[u32]) -> InsertOutcome {
        let now = self.tick();
        let mut cur = self.root;
        let mut pos = 0usize;
        let mut shared = 0usize;
        self.nodes[cur].last_access = now;
        while pos < tokens.len() {
            match self.nodes[cur].children.get(&tokens[pos]).copied() {
                None => {
                    // Append the remaining tokens as a fresh child.
                    let node = RNode {
                        key: tokens[pos..].to_vec(),
                        parent: Some(cur),
                        children: HashMap::new(),
                        refcount: 0,
                        last_access: now,
                        dead: false,
                    };
                    let idx = self.alloc(node);
                    self.nodes[cur].children.insert(tokens[pos], idx);
                    return InsertOutcome {
                        new_tokens: tokens.len() - pos,
                        shared_tokens: shared,
                        node: idx,
                    };
                }
                Some(child) => {
                    let klen = self.nodes[child].key.len();
                    let common = self.nodes[child]
                        .key
                        .iter()
                        .zip(&tokens[pos..])
                        .take_while(|(a, b)| a == b)
                        .count();
                    self.nodes[child].last_access = now;
                    if common == klen {
                        // Full edge consumed.
                        shared += common;
                        pos += common;
                        cur = child;
                    } else {
                        // Split child at `common`.
                        let split = self.split(child, common, now);
                        shared += common;
                        pos += common;
                        cur = split;
                        // loop continues: either tokens exhausted or a new
                        // branch is appended under the split node.
                    }
                }
            }
        }
        InsertOutcome { new_tokens: 0, shared_tokens: shared, node: cur }
    }

    /// Split `node`'s edge after `at` tokens; returns the new upper node.
    fn split(&mut self, node: NodeIdx, at: usize, now: u64) -> NodeIdx {
        debug_assert!(at > 0 && at < self.nodes[node].key.len());
        let parent = self.nodes[node].parent.expect("split of root");
        let upper_key = self.nodes[node].key[..at].to_vec();
        let lower_key = self.nodes[node].key[at..].to_vec();
        let upper = RNode {
            key: upper_key,
            parent: Some(parent),
            children: HashMap::new(),
            // the upper part inherits pins: any sequence pinning the lower
            // node transitively pins its prefix (unlock walks through here)
            refcount: self.nodes[node].refcount,
            last_access: now,
            dead: false,
        };
        // Note: alloc counts upper's tokens as new, but the split conserves
        // total tokens (lower loses `at` tokens) — adjust below.
        let upper_idx = self.alloc(upper);
        self.live_tokens -= at; // conserve: split moves tokens, not adds
        let first_upper = self.nodes[upper_idx].key[0];
        let first_lower = lower_key[0];
        self.nodes[parent].children.insert(first_upper, upper_idx);
        self.nodes[node].key = lower_key;
        self.nodes[node].parent = Some(upper_idx);
        self.nodes[upper_idx].children.insert(first_lower, node);
        upper_idx
    }

    /// Pin the path root..=node (active sequence).
    pub fn lock(&mut self, node: NodeIdx) {
        let mut cur = Some(node);
        while let Some(idx) = cur {
            self.nodes[idx].refcount += 1;
            cur = self.nodes[idx].parent;
        }
    }

    /// Unpin the path root..=node.
    pub fn unlock(&mut self, node: NodeIdx) {
        let mut cur = Some(node);
        while let Some(idx) = cur {
            assert!(self.nodes[idx].refcount > 0, "unlock without lock");
            self.nodes[idx].refcount -= 1;
            cur = self.nodes[idx].parent;
        }
    }

    /// Tokens stored along the path root..=`node` — the sequence length a
    /// cached sequence end represents.
    pub fn path_tokens(&self, node: NodeIdx) -> usize {
        let mut tokens = 0usize;
        let mut cur = Some(node);
        while let Some(idx) = cur {
            tokens += self.nodes[idx].key.len();
            cur = self.nodes[idx].parent;
        }
        tokens
    }

    /// Unique tokens on the union of root-paths of `nodes` — the radix-shared
    /// KV footprint of a set of sequence ends. This is the engine's canonical
    /// "live KV" view (each shared prefix counted once).
    pub fn path_union_tokens(&self, nodes: &[NodeIdx]) -> usize {
        let mut seen: HashSet<NodeIdx> = HashSet::new();
        let mut tokens = 0usize;
        for &n in nodes {
            let mut cur = Some(n);
            while let Some(idx) = cur {
                if !seen.insert(idx) {
                    break; // the rest of this path is already counted
                }
                tokens += self.nodes[idx].key.len();
                cur = self.nodes[idx].parent;
            }
        }
        tokens
    }

    /// Sum of tokens held by pinned (refcount > 0) nodes.
    pub fn pinned_tokens(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| !n.dead && n.refcount > 0)
            .map(|n| n.key.len())
            .sum()
    }

    /// Free the unpinned tail of the path ending at `node`: remove childless
    /// refcount-0 nodes walking toward the root, stopping at the first node
    /// that is still shared (has children) or pinned. O(path length) — the
    /// targeted release the engine uses after unpinning a retired sequence,
    /// instead of sweeping the whole arena. Returns tokens freed.
    pub fn release_branch(&mut self, node: NodeIdx) -> usize {
        let mut freed = 0usize;
        let mut cur = Some(node);
        while let Some(idx) = cur {
            if idx == self.root || self.nodes[idx].dead {
                break;
            }
            let n = &self.nodes[idx];
            if !n.children.is_empty() || n.refcount > 0 {
                break;
            }
            let parent = n.parent;
            freed += self.remove_leaf(idx);
            cur = parent;
        }
        freed
    }

    /// Evict *every* unpinned branch regardless of recency (full-arena
    /// sweep; [`RadixCache::release_branch`] is the cheap per-sequence
    /// variant). Returns tokens freed.
    pub fn evict_unpinned(&mut self) -> usize {
        let mut freed = 0usize;
        loop {
            let victims: Vec<NodeIdx> = self
                .nodes
                .iter()
                .enumerate()
                .filter(|&(idx, n)| {
                    !n.dead && idx != self.root && n.children.is_empty() && n.refcount == 0
                })
                .map(|(idx, _)| idx)
                .collect();
            if victims.is_empty() {
                return freed;
            }
            // removing a layer of leaves may expose the next layer
            for v in victims {
                freed += self.remove_leaf(v);
            }
        }
    }

    /// Evict least-recently-used unpinned leaves until at least
    /// `target_tokens` have been freed (or nothing evictable remains).
    /// Returns tokens freed.
    pub fn evict(&mut self, target_tokens: usize) -> usize {
        let mut freed = 0usize;
        while freed < target_tokens {
            // Find the LRU evictable leaf: no children, refcount 0, not root.
            let mut victim: Option<NodeIdx> = None;
            let mut oldest = u64::MAX;
            for (idx, n) in self.nodes.iter().enumerate() {
                if !n.dead
                    && idx != self.root
                    && n.children.is_empty()
                    && n.refcount == 0
                    && n.last_access < oldest
                {
                    oldest = n.last_access;
                    victim = Some(idx);
                }
            }
            let Some(idx) = victim else { break };
            freed += self.remove_leaf(idx);
        }
        freed
    }

    fn remove_leaf(&mut self, idx: NodeIdx) -> usize {
        debug_assert!(self.nodes[idx].children.is_empty());
        let parent = self.nodes[idx].parent.expect("removing root");
        let first = self.nodes[idx].key[0];
        self.nodes[parent].children.remove(&first);
        let tokens = self.nodes[idx].key.len();
        self.live_tokens -= tokens;
        self.nodes[idx].dead = true;
        self.nodes[idx].key = vec![];
        self.nodes[idx].children = HashMap::new();
        self.free.push(idx);
        tokens
    }

    /// Check internal invariants (tests / debug).
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut token_sum = 0usize;
        for (idx, n) in self.nodes.iter().enumerate() {
            if n.dead {
                continue;
            }
            token_sum += n.key.len();
            if idx != self.root && n.key.is_empty() {
                return Err(format!("non-root node {idx} with empty key"));
            }
            for (&first, &child) in &n.children {
                let c = &self.nodes[child];
                if c.dead {
                    return Err(format!("child {child} of {idx} is dead"));
                }
                if c.parent != Some(idx) {
                    return Err(format!("parent link broken for {child}"));
                }
                if c.key.first() != Some(&first) {
                    return Err(format!("child key map mismatch at {child}"));
                }
            }
        }
        if token_sum != self.live_tokens {
            return Err(format!(
                "token accounting drift: sum {token_sum} != live {}",
                self.live_tokens
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;

    #[test]
    fn insert_and_full_prefix_match() {
        let mut c = RadixCache::new(1 << 20);
        let seq: Vec<u32> = (0..100).collect();
        let out = c.insert(&seq);
        assert_eq!(out.new_tokens, 100);
        assert_eq!(out.shared_tokens, 0);
        assert_eq!(c.live_tokens(), 100);
        let (m, _) = c.match_prefix(&seq);
        assert_eq!(m, 100);
        c.check_invariants().unwrap();
    }

    #[test]
    fn shared_prefix_deduplicates() {
        let mut c = RadixCache::new(1 << 20);
        let a: Vec<u32> = (0..100).collect();
        let mut b = a.clone();
        b.extend(200..250);
        let mut d = a.clone();
        d.extend(300..350);
        c.insert(&a);
        let ob = c.insert(&b);
        assert_eq!(ob.shared_tokens, 100);
        assert_eq!(ob.new_tokens, 50);
        let od = c.insert(&d);
        assert_eq!(od.shared_tokens, 100);
        assert_eq!(od.new_tokens, 50);
        assert_eq!(c.live_tokens(), 200);
        c.check_invariants().unwrap();
    }

    #[test]
    fn partial_match_splits_edge() {
        let mut c = RadixCache::new(1 << 20);
        c.insert(&[1, 2, 3, 4, 5]);
        let out = c.insert(&[1, 2, 3, 9, 9]);
        assert_eq!(out.shared_tokens, 3);
        assert_eq!(out.new_tokens, 2);
        assert_eq!(c.live_tokens(), 7);
        let (m, _) = c.match_prefix(&[1, 2, 3, 4, 5]);
        assert_eq!(m, 5);
        let (m, _) = c.match_prefix(&[1, 2, 3, 9, 9]);
        assert_eq!(m, 5);
        let (m, _) = c.match_prefix(&[1, 2, 3]);
        assert_eq!(m, 3);
        c.check_invariants().unwrap();
    }

    #[test]
    fn eviction_frees_lru_leaf_only() {
        let mut c = RadixCache::new(1 << 20);
        let a = c.insert(&[1, 2, 3]).node;
        c.insert(&[1, 2, 3, 4, 5]); // extends under a
        std::hint::black_box(a);
        c.insert(&[7, 8]);
        // touch [1,2,3,4,5] so [7,8] is LRU
        c.match_prefix(&[1, 2, 3, 4, 5]);
        let freed = c.evict(1);
        assert_eq!(freed, 2, "should evict the [7,8] leaf");
        let (m, _) = c.match_prefix(&[7, 8]);
        assert_eq!(m, 0);
        let (m, _) = c.match_prefix(&[1, 2, 3, 4, 5]);
        assert_eq!(m, 5);
        c.check_invariants().unwrap();
    }

    #[test]
    fn locked_nodes_survive_eviction() {
        let mut c = RadixCache::new(1 << 20);
        let n = c.insert(&[1, 2, 3]).node;
        c.lock(n);
        let freed = c.evict(100);
        assert_eq!(freed, 0);
        assert_eq!(c.live_tokens(), 3);
        c.unlock(n);
        let freed = c.evict(100);
        assert_eq!(freed, 3);
        assert_eq!(c.live_tokens(), 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn eviction_cascades_up_freed_branches() {
        let mut c = RadixCache::new(1 << 20);
        c.insert(&[1, 2]);
        c.insert(&[1, 2, 3]);
        c.insert(&[1, 2, 4]);
        // evict everything: leaves first, then their parent becomes a leaf
        let freed = c.evict(usize::MAX);
        assert_eq!(freed, 4);
        assert_eq!(c.live_tokens(), 0);
        assert_eq!(c.live_nodes(), 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn reinsert_after_eviction() {
        let mut c = RadixCache::new(1 << 20);
        c.insert(&[5, 6, 7]);
        c.evict(usize::MAX);
        let out = c.insert(&[5, 6, 7]);
        assert_eq!(out.new_tokens, 3);
        c.check_invariants().unwrap();
    }

    #[test]
    fn split_preserves_pins_of_the_lower_node() {
        // Lock a sequence end, then insert a diverging sequence that splits
        // an edge *inside* the locked path: the pin must survive the split
        // (the upper node inherits the refcount), so eviction cannot touch
        // the locked path.
        let mut c = RadixCache::new(1 << 20);
        let end = c.insert(&[1, 2, 3, 4, 5]).node;
        c.lock(end);
        let other = c.insert(&[1, 2, 9]).node; // splits [1,2,3,4,5] after 2
        c.check_invariants().unwrap();
        std::hint::black_box(other);
        c.evict_unpinned();
        let (m, _) = c.match_prefix(&[1, 2, 3, 4, 5]);
        assert_eq!(m, 5, "locked path lost after split");
        let (m, _) = c.match_prefix(&[1, 2, 9]);
        assert_eq!(m, 2, "unpinned branch should be gone");
        assert_eq!(c.path_tokens(end), 5);
        c.unlock(end);
        c.evict_unpinned();
        assert_eq!(c.live_tokens(), 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn refcount_pin_blocks_lru_eviction_until_unlock() {
        let mut c = RadixCache::new(1 << 20);
        let pinned = c.insert(&[1, 2, 3]).node;
        c.insert(&[9, 9]);
        c.lock(pinned);
        // [1,2,3] is LRU-older than [9,9] after this touch
        c.match_prefix(&[9, 9]);
        let freed = c.evict(usize::MAX);
        assert_eq!(freed, 2, "only the unpinned [9,9] leaf is evictable");
        assert_eq!(c.live_tokens(), 3);
        c.unlock(pinned);
        assert_eq!(c.evict(usize::MAX), 3);
        c.check_invariants().unwrap();
    }

    #[test]
    fn path_accounting_views() {
        let mut c = RadixCache::new(1 << 20);
        let a = c.insert(&[1, 2, 3, 4]).node;
        let b = c.insert(&[1, 2, 7, 8, 9]).node;
        // shared prefix [1,2]; total unique = 2 + 2 + 3 = 7
        assert_eq!(c.path_tokens(a), 4);
        assert_eq!(c.path_tokens(b), 5);
        assert_eq!(c.path_union_tokens(&[a, b]), 7);
        assert_eq!(c.path_union_tokens(&[a]), 4);
        assert_eq!(c.path_union_tokens(&[a, a]), 4);
        assert_eq!(c.path_union_tokens(&[]), 0);
        assert_eq!(c.live_tokens(), 7);
        c.lock(a);
        assert_eq!(c.pinned_tokens(), 4);
        c.unlock(a);
    }

    #[test]
    fn release_branch_frees_exclusive_tail_only() {
        let mut c = RadixCache::new(1 << 20);
        let shared = c.insert(&[1, 2]).node;
        let a = c.insert(&[1, 2, 3, 4]).node;
        let b = c.insert(&[1, 2, 7]).node;
        c.lock(a);
        c.lock(b);
        c.unlock(a);
        // a's exclusive [3,4] tail goes; the shared [1,2] prefix stays
        // (pinned through b) and b's branch is untouched
        assert_eq!(c.release_branch(a), 2);
        assert_eq!(c.live_tokens(), 3);
        let (m, _) = c.match_prefix(&[1, 2, 7]);
        assert_eq!(m, 3);
        // releasing an already-shared interior node is a no-op
        assert_eq!(c.release_branch(shared), 0);
        c.unlock(b);
        assert_eq!(c.release_branch(b), 3, "now the whole chain unwinds");
        assert_eq!(c.live_tokens(), 0);
        // releasing a dead node is a safe no-op
        assert_eq!(c.release_branch(b), 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn evict_unpinned_cascades_and_spares_locks() {
        let mut c = RadixCache::new(1 << 20);
        let keep = c.insert(&[1, 2, 3]).node;
        c.insert(&[1, 2, 3, 4, 5]);
        c.insert(&[1, 7]);
        c.insert(&[8, 9, 10]);
        c.lock(keep);
        let freed = c.evict_unpinned();
        // everything except the pinned [1,2,3] path goes, including the
        // [4,5] extension below the pin and multi-level branches
        assert_eq!(freed, 2 + 1 + 3);
        assert_eq!(c.live_tokens(), 3);
        assert_eq!(c.path_union_tokens(&[keep]), 3);
        c.check_invariants().unwrap();
        c.unlock(keep);
        assert_eq!(c.evict_unpinned(), 3);
        assert_eq!(c.live_nodes(), 0);
    }

    #[test]
    fn prop_radix_semantics_match_naive_model() {
        // Model: a set of inserted sequences. Invariants:
        //  (1) match_prefix(s) for any inserted s == len(s)
        //  (2) live_tokens == |distinct prefixes| (trie token count)
        property(80, |rng: &mut Rng| {
            let mut c = RadixCache::new(1 << 20);
            let mut inserted: Vec<Vec<u32>> = vec![];
            let vocab = 4u32; // small vocab → lots of shared prefixes
            for _ in 0..(1 + rng.index(25)) {
                let len = 1 + rng.index(12);
                let seq: Vec<u32> = if !inserted.is_empty() && rng.chance(0.5) {
                    // extend or mutate an existing sequence
                    let base = &inserted[rng.index(inserted.len())];
                    let cut = rng.index(base.len() + 1);
                    let mut s = base[..cut].to_vec();
                    for _ in 0..len {
                        s.push(rng.below(vocab as u64) as u32);
                    }
                    s
                } else {
                    (0..len).map(|_| rng.below(vocab as u64) as u32).collect()
                };
                c.insert(&seq);
                inserted.push(seq);
                c.check_invariants().map_err(|e| e)?;
            }
            // (1) full prefix matches
            for s in &inserted {
                let (m, _) = c.match_prefix(s);
                crate::prop_check!(m == s.len(), "match {m} != len {}", s.len());
            }
            // (2) trie token count
            let mut prefixes: std::collections::HashSet<Vec<u32>> =
                std::collections::HashSet::new();
            for s in &inserted {
                for l in 1..=s.len() {
                    prefixes.insert(s[..l].to_vec());
                }
            }
            crate::prop_check!(
                c.live_tokens() == prefixes.len(),
                "live {} != trie {}",
                c.live_tokens(),
                prefixes.len()
            );
            Ok(())
        });
    }

    #[test]
    fn prop_eviction_preserves_invariants_and_locked_paths() {
        property(60, |rng: &mut Rng| {
            let mut c = RadixCache::new(1 << 20);
            let mut locked: Vec<(Vec<u32>, NodeIdx)> = vec![];
            for _ in 0..(1 + rng.index(15)) {
                let len = 1 + rng.index(10);
                let seq: Vec<u32> =
                    (0..len).map(|_| rng.below(3) as u32).collect();
                let out = c.insert(&seq);
                if rng.chance(0.3) {
                    c.lock(out.node);
                    locked.push((seq, out.node));
                }
            }
            c.evict(rng.index(40));
            c.check_invariants().map_err(|e| e)?;
            for (seq, _) in &locked {
                let (m, _) = c.match_prefix(seq);
                crate::prop_check!(m == seq.len(), "locked path evicted");
            }
            for (_, n) in &locked {
                c.unlock(*n);
            }
            c.evict(usize::MAX);
            crate::prop_check!(c.live_tokens() == 0, "full evict left tokens");
            c.check_invariants().map_err(|e| e)?;
            Ok(())
        });
    }
}
