//! Radix-tree KV-cache manager (SGLang RadixAttention semantics) over a
//! paged block allocator (vLLM PagedAttention semantics).
//!
//! The serving engine stores one KV entry per *token*, deduplicated across
//! sequences that share a prefix — exactly the mechanism whose effectiveness
//! the paper's search policies trade on. This module reproduces the
//! bookkeeping: prefix matching, node splitting, reference counting while a
//! sequence is scheduled, and LRU eviction of unreferenced branches.
//!
//! Token KV payloads themselves live with the model executor; this tree
//! tracks token *counts* and identity so the engine can (a) compute how many
//! new KV slots a sequence needs, (b) account memory, (c) evict.
//!
//! Physical memory is accounted in fixed-size **blocks** via
//! [`BlockAllocator`]: each radix node owns a span of blocks covering its
//! token range, allocated from a free list whose size is the *hard* capacity
//! budget — an insert that cannot get blocks is a bug in the caller's
//! admission control, so callers reserve first ([`RadixCache::try_reserve`])
//! and only then insert. [`KvPressure`] is the typed "no blocks" error the
//! reserve protocol surfaces to the serve scheduler, which reacts by
//! evicting unpinned branches or preempting low-priority sessions.
//!
//! Eviction is O(log n) per freed leaf: an ordered set of currently
//! evictable leaves keyed by `(last_access, node)` replaces the full-arena
//! rescan the seed implementation did per block.
//!
//! Node edges live in a single flat, sorted, arena-backed store
//! ([`EdgeArena`]): each node owns a contiguous `(first-token, child)` span
//! looked up by binary search, so prefix walks stream one allocation
//! instead of chasing a per-node `HashMap` — and removing a node recycles
//! its span through a size-classed free list instead of reallocating.

pub mod coldtier;
pub mod prefixhub;

use coldtier::SpillArena;
use std::collections::{BTreeSet, HashSet};

/// Flat, sorted edge store shared by every node of one [`RadixCache`].
///
/// Each node owns a contiguous span of `(first-token, child)` pairs, kept
/// sorted by token so lookups are binary searches over one cache line (or
/// two) rather than a hash probe into a per-node allocation. Spans have
/// power-of-two capacities; outgrown or cleared spans go onto a
/// size-classed free list and are reused by later nodes, so steady-state
/// insert/evict churn allocates nothing.
#[derive(Clone, Debug, Default)]
struct EdgeArena {
    /// All spans back to back; a node's edges at `off..off+len`.
    edges: Vec<(u32, NodeIdx)>,
    /// Freed span offsets by capacity class: `free[k]` holds offsets of
    /// spans with capacity `1 << k`.
    free: Vec<Vec<u32>>,
}

/// A node's handle into the [`EdgeArena`]: offset, live length, capacity
/// (capacity 0 = no span allocated).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
struct EdgeSpan {
    off: u32,
    len: u32,
    cap: u32,
}

impl EdgeSpan {
    fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl EdgeArena {
    /// The sorted `(first-token, child)` pairs of one span.
    fn slice(&self, s: EdgeSpan) -> &[(u32, NodeIdx)] {
        &self.edges[s.off as usize..(s.off + s.len) as usize]
    }

    /// Child reached over the edge whose label starts with `token`.
    fn get(&self, s: EdgeSpan, token: u32) -> Option<NodeIdx> {
        let span = self.slice(s);
        span.binary_search_by_key(&token, |e| e.0).ok().map(|i| span[i].1)
    }

    /// Allocate a fresh span of capacity `1 << class` (freelist first).
    fn alloc_span(&mut self, class: u32) -> u32 {
        while self.free.len() <= class as usize {
            self.free.push(Vec::new());
        }
        if let Some(off) = self.free[class as usize].pop() {
            return off;
        }
        let off = self.edges.len() as u32;
        self.edges.resize(self.edges.len() + (1usize << class), (0, 0));
        off
    }

    /// Return a span's storage to its size-class free list.
    fn release_span(&mut self, s: &mut EdgeSpan) {
        if s.cap > 0 {
            let class = s.cap.trailing_zeros();
            while self.free.len() <= class as usize {
                self.free.push(Vec::new());
            }
            self.free[class as usize].push(s.off);
        }
        *s = EdgeSpan::default();
    }

    /// Insert (or replace, matching `HashMap::insert` semantics) the edge
    /// for `token`, keeping the span sorted.
    fn insert(&mut self, s: &mut EdgeSpan, token: u32, child: NodeIdx) {
        let pos = {
            let span = self.slice(*s);
            match span.binary_search_by_key(&token, |e| e.0) {
                Ok(i) => {
                    // existing edge relabeled (split path): replace in place
                    self.edges[s.off as usize + i] = (token, child);
                    return;
                }
                Err(i) => i,
            }
        };
        if s.len == s.cap {
            // grow: move to a span of the next capacity class
            let new_cap = (s.cap * 2).max(1);
            let new_off = self.alloc_span(new_cap.trailing_zeros());
            for i in 0..s.len as usize {
                self.edges[new_off as usize + i] = self.edges[s.off as usize + i];
            }
            let mut old = *s;
            self.release_span(&mut old);
            *s = EdgeSpan { off: new_off, len: s.len, cap: new_cap };
        }
        let base = s.off as usize;
        let mut i = s.len as usize;
        while i > pos {
            self.edges[base + i] = self.edges[base + i - 1];
            i -= 1;
        }
        self.edges[base + pos] = (token, child);
        s.len += 1;
    }

    /// Remove the edge for `token` (present by contract); an emptied span
    /// is recycled immediately.
    fn remove(&mut self, s: &mut EdgeSpan, token: u32) {
        let pos = {
            let span = self.slice(*s);
            span.binary_search_by_key(&token, |e| e.0)
                .expect("removing a missing edge")
        };
        let base = s.off as usize;
        for i in pos..s.len as usize - 1 {
            self.edges[base + i] = self.edges[base + i + 1];
        }
        s.len -= 1;
        if s.len == 0 {
            self.release_span(s);
        }
    }
}

/// Handle to a node in the radix tree.
pub type NodeIdx = usize;

/// Handle to a physical KV block.
pub type BlockId = usize;

/// Default tokens per KV block (vLLM's classic page size).
pub const DEFAULT_BLOCK_SIZE: usize = 16;

/// Typed out-of-blocks error of the reserve protocol: the request could not
/// be satisfied from the free list. Carries the signals the scheduler needs
/// to choose a remedy (evict vs. preempt vs. defer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvPressure {
    /// Blocks the failed reservation asked for.
    pub needed_blocks: usize,
    /// Blocks actually free (net of open reservations) at failure time.
    pub free_blocks: usize,
    /// Blocks held by currently evictable (unpinned, childless) leaves.
    pub evictable_blocks: usize,
}

impl std::fmt::Display for KvPressure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "KV pressure: need {} blocks, {} free, {} evictable",
            self.needed_blocks, self.free_blocks, self.evictable_blocks
        )
    }
}

/// One payload word per token slot. Stands in for the model executor's
/// per-token KV page contents: a keyed hash of the token value, so two
/// arenas that hold the same token independently hold the same word — which
/// is exactly what makes a cross-shard block *copy* bit-identical to a
/// local recompute by construction (the transport plane's invariant).
/// Position-independent on purpose: [`RadixCache::split`] re-pages a node's
/// tokens into fresh blocks, so a word keyed on its slot would not survive
/// a split.
#[inline]
pub fn payload_word(token: u32) -> u64 {
    // splitmix64 finalizer over the token value
    let mut z = (token as u64).wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Fixed-size block allocator: a free list of physical KV block ids, plus
/// the backing payload arena.
///
/// Accounting is tracked for real so double-frees and budget overruns are
/// structurally impossible: a block is either on the free list or owned by
/// exactly one radix node's span. Since the transport plane landed, the
/// allocator also owns a real per-shard *arena* — one [`payload_word`] per
/// token slot — so cross-shard imports have actual bytes to move and NUMA
/// first-touch has actual pages to fault in. The arena is `vec![0; ..]`
/// (calloc-backed): pages stay virtual until written or explicitly
/// [`BlockAllocator::fault_in`]-touched.
#[derive(Clone, Debug)]
pub struct BlockAllocator {
    block_size: usize,
    total_blocks: usize,
    /// LIFO free list.
    free: Vec<BlockId>,
    /// Blocks earmarked by open reservations (admission control). `alloc`
    /// deliberately ignores this: the single-threaded commit path releases
    /// its reservation immediately before drawing the blocks it covers.
    reserved: usize,
    /// Payload arena: `total_blocks * block_size` words, one per token
    /// slot. Token `j` of a span lives at `blocks[j / block_size]`, slot
    /// `j % block_size`.
    payload: Vec<u64>,
}

impl BlockAllocator {
    pub fn new(total_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0, "block_size must be positive");
        Self {
            block_size,
            total_blocks,
            free: (0..total_blocks).rev().collect(),
            reserved: 0,
            payload: vec![0u64; total_blocks * block_size],
        }
    }

    /// Write the payload words for `tokens` into `blocks` (the span that
    /// holds them), starting at the span's first slot. This is the
    /// "recompute" data path: every committed token materializes its word
    /// locally. The transport plane's copy path must land the same words
    /// (see [`payload_word`]).
    pub fn write_span(&mut self, blocks: &[BlockId], tokens: &[u32]) {
        debug_assert!(blocks.len() * self.block_size >= tokens.len(), "span too short");
        for (j, &t) in tokens.iter().enumerate() {
            self.payload[blocks[j / self.block_size] * self.block_size + j % self.block_size] =
                payload_word(t);
        }
    }

    /// Read the payload words backing the first `len` token slots of
    /// `blocks`, in slot order — the source side of a block transfer.
    pub fn read_span(&self, blocks: &[BlockId], len: usize) -> Vec<u64> {
        debug_assert!(blocks.len() * self.block_size >= len, "span too short");
        (0..len)
            .map(|j| self.payload[blocks[j / self.block_size] * self.block_size + j % self.block_size])
            .collect()
    }

    /// Write pre-read payload `words` into the token slots of `blocks`
    /// starting at slot `offset` — the destination side of a block
    /// transfer. Slots before `offset` are untouched.
    pub fn write_words(&mut self, blocks: &[BlockId], offset: usize, words: &[u64]) {
        debug_assert!(
            blocks.len() * self.block_size >= offset + words.len(),
            "span too short"
        );
        for (i, &w) in words.iter().enumerate() {
            let j = offset + i;
            self.payload[blocks[j / self.block_size] * self.block_size + j % self.block_size] = w;
        }
    }

    /// Touch every word of the payload arena so its pages are faulted in by
    /// the *calling* thread (NUMA first-touch: pages land on the caller's
    /// node). Returns the arena size in bytes. Volatile reads so the loop
    /// cannot be optimized away.
    pub fn fault_in(&mut self) -> usize {
        for w in self.payload.iter_mut() {
            // volatile write-back of the same value: forces the page fault,
            // changes no contents, and cannot be optimized away
            unsafe {
                let p = w as *mut u64;
                p.write_volatile(p.read_volatile());
            }
        }
        self.payload.len() * std::mem::size_of::<u64>()
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Free blocks net of open reservations.
    pub fn available_blocks(&self) -> usize {
        self.free.len().saturating_sub(self.reserved)
    }

    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    /// Blocks needed to hold `tokens` tokens (0 for 0).
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Earmark `blocks` for an imminent commit. Fails without side effects
    /// when the free list (net of prior reservations) cannot cover them.
    pub fn try_reserve(&mut self, blocks: usize) -> bool {
        if self.available_blocks() >= blocks {
            self.reserved += blocks;
            true
        } else {
            false
        }
    }

    /// Release a reservation (commit or abandon). Callers release exactly
    /// what they reserved, right before allocating the covered spans.
    pub fn release_reservation(&mut self, blocks: usize) {
        debug_assert!(self.reserved >= blocks, "reservation underflow");
        self.reserved = self.reserved.saturating_sub(blocks);
    }

    /// Draw a span of `blocks` blocks off the free list.
    pub fn alloc(&mut self, blocks: usize) -> Option<Vec<BlockId>> {
        if self.free.len() < blocks {
            return None;
        }
        Some((0..blocks).map(|_| self.free.pop().expect("free list len checked")).collect())
    }

    /// Return a span to the free list.
    pub fn release_span(&mut self, span: Vec<BlockId>) {
        self.free.extend(span);
    }
}

#[derive(Clone, Debug)]
struct RNode {
    /// Token span stored at this node (edge label).
    key: Vec<u32>,
    parent: Option<NodeIdx>,
    /// This node's sorted `(child-first-token, child)` span in the cache's
    /// shared [`EdgeArena`].
    edges: EdgeSpan,
    /// Number of active sequences pinning this node (and its ancestors).
    refcount: usize,
    /// LRU clock of the last match/insert touching this node.
    last_access: u64,
    /// Free-list marker.
    dead: bool,
    /// Physical KV blocks backing this node's tokens
    /// (`blocks_for(key.len())` of them).
    blocks: Vec<BlockId>,
}

/// Result of an [`RadixCache::insert`].
#[derive(Clone, Debug, PartialEq)]
pub struct InsertOutcome {
    /// Tokens newly allocated (not found in the tree).
    pub new_tokens: usize,
    /// Tokens reused from existing nodes.
    pub shared_tokens: usize,
    /// Node holding the end of the inserted sequence.
    pub node: NodeIdx,
}

/// Radix-tree KV cache with block-granularity accounting and a hard
/// capacity budget enforced by the [`BlockAllocator`].
#[derive(Clone, Debug)]
pub struct RadixCache {
    nodes: Vec<RNode>,
    /// Flat sorted edge store all nodes' child spans live in.
    edge_store: EdgeArena,
    free: Vec<NodeIdx>,
    root: NodeIdx,
    clock: u64,
    /// Unique tokens currently cached.
    live_tokens: usize,
    /// Physical block accounting + the hard budget.
    allocator: BlockAllocator,
    /// Currently evictable leaves (childless, refcount 0, not root), keyed
    /// by `(last_access, idx)` so the first element is the LRU victim.
    evictable: BTreeSet<(u64, NodeIdx)>,
    /// Σ blocks held by members of `evictable` — kept in lockstep so
    /// pressure signals don't re-scan the set (O(1) instead of O(n)).
    evictable_block_count: usize,
    /// Host-DRAM cold tier, when attached: eviction demotes spans here
    /// instead of destroying them, and resumes restore from here instead of
    /// recomputing. `None` = the PR 2 evict-to-nothing ladder.
    cold: Option<SpillArena>,
}

impl RadixCache {
    /// Cache with a `capacity_tokens` budget at [`DEFAULT_BLOCK_SIZE`].
    pub fn new(capacity_tokens: usize) -> Self {
        Self::with_block_size(capacity_tokens, DEFAULT_BLOCK_SIZE)
    }

    /// Cache whose hard budget is `ceil(capacity_tokens / block_size)`
    /// blocks of `block_size` tokens each.
    pub fn with_block_size(capacity_tokens: usize, block_size: usize) -> Self {
        let root = RNode {
            key: vec![],
            parent: None,
            edges: EdgeSpan::default(),
            refcount: 1, // root is never evictable
            last_access: 0,
            dead: false,
            blocks: vec![],
        };
        let bs = block_size.max(1);
        let total_blocks = capacity_tokens.div_ceil(bs);
        Self {
            nodes: vec![root],
            edge_store: EdgeArena::default(),
            free: vec![],
            root: 0,
            clock: 0,
            live_tokens: 0,
            allocator: BlockAllocator::new(total_blocks, bs),
            evictable: BTreeSet::new(),
            evictable_block_count: 0,
            cold: None,
        }
    }

    /// Attach a host-DRAM cold tier of `capacity_tokens` (same block units
    /// as the hot allocator). From here on, [`RadixCache::evict`] /
    /// [`RadixCache::evict_unpinned`] *demote* spans into it instead of
    /// destroying them; [`RadixCache::release_branch`] still destroys
    /// (pruned trajectories are dead data no resume will ever ask for).
    pub fn attach_cold_tier(&mut self, capacity_tokens: usize) {
        self.cold = Some(SpillArena::new(capacity_tokens, self.allocator.block_size()));
    }

    /// The attached cold tier, if any (telemetry / tests).
    pub fn cold(&self) -> Option<&SpillArena> {
        self.cold.as_ref()
    }

    pub fn live_tokens(&self) -> usize {
        self.live_tokens
    }

    /// Number of live (non-root, non-freed) nodes.
    pub fn live_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| !n.dead).count() - 1
    }

    pub fn block_size(&self) -> usize {
        self.allocator.block_size()
    }

    pub fn total_blocks(&self) -> usize {
        self.allocator.total_blocks()
    }

    pub fn used_blocks(&self) -> usize {
        self.allocator.used_blocks()
    }

    /// Free blocks net of open reservations.
    pub fn free_blocks(&self) -> usize {
        self.allocator.available_blocks()
    }

    /// Token capacity implied by the block budget.
    pub fn capacity_tokens(&self) -> usize {
        self.allocator.total_blocks() * self.allocator.block_size()
    }

    /// Blocks needed to hold `tokens` new tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        self.allocator.blocks_for(tokens)
    }

    /// Blocks held by currently evictable leaves — what one pass of LRU
    /// eviction could free without touching pinned paths (cascading frees
    /// may release more). O(1): a running counter maintained alongside the
    /// evictable set.
    pub fn evictable_blocks(&self) -> usize {
        self.evictable_block_count
    }

    /// Reserve `blocks` ahead of an insert burst; the typed failure carries
    /// the pressure signals. Callers release with
    /// [`RadixCache::release_reservation`] right before inserting.
    pub fn try_reserve(&mut self, blocks: usize) -> Result<(), KvPressure> {
        if self.allocator.try_reserve(blocks) {
            Ok(())
        } else {
            Err(KvPressure {
                needed_blocks: blocks,
                free_blocks: self.allocator.available_blocks(),
                evictable_blocks: self.evictable_blocks(),
            })
        }
    }

    pub fn release_reservation(&mut self, blocks: usize) {
        self.allocator.release_reservation(blocks);
    }

    fn alloc_span(&mut self, tokens: usize) -> Vec<BlockId> {
        let need = self.allocator.blocks_for(tokens);
        self.allocator.alloc(need).expect(
            "KV block budget exhausted mid-insert — callers must try_reserve before inserting",
        )
    }

    /// Re-sync `idx`'s membership in the evictable set. Must be called after
    /// any change to a node's refcount / children / dead flag; last_access
    /// and block-span changes go through [`RadixCache::touch`] /
    /// [`RadixCache::drop_evictable`] instead (the set key embeds the old
    /// clock value, the counter the old span size).
    fn refresh_evictable(&mut self, idx: NodeIdx) {
        let n = &self.nodes[idx];
        let key = (n.last_access, idx);
        let span = n.blocks.len();
        if !n.dead && idx != self.root && n.edges.is_empty() && n.refcount == 0 {
            if self.evictable.insert(key) {
                self.evictable_block_count += span;
            }
        } else if self.evictable.remove(&key) {
            self.evictable_block_count -= span;
        }
    }

    /// Remove `idx` from the evictable set (counter-consistent) ahead of a
    /// mutation that changes its set key or block span.
    fn drop_evictable(&mut self, idx: NodeIdx) {
        if self.evictable.remove(&(self.nodes[idx].last_access, idx)) {
            self.evictable_block_count -= self.nodes[idx].blocks.len();
        }
    }

    /// Update a node's LRU clock, keeping the evictable set keyed correctly.
    fn touch(&mut self, idx: NodeIdx, now: u64) {
        self.drop_evictable(idx);
        self.nodes[idx].last_access = now;
        self.refresh_evictable(idx);
    }

    fn alloc(&mut self, node: RNode) -> NodeIdx {
        self.live_tokens += node.key.len();
        let idx = if let Some(idx) = self.free.pop() {
            self.nodes[idx] = node;
            idx
        } else {
            self.nodes.push(node);
            self.nodes.len() - 1
        };
        self.refresh_evictable(idx);
        idx
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Add (or relabel) `node`'s edge for `token` in the shared arena.
    /// `EdgeSpan` is `Copy`: the span is copied out, mutated against the
    /// arena, and written back — the borrow split the flat store needs.
    fn add_edge(&mut self, node: NodeIdx, token: u32, child: NodeIdx) {
        let mut span = self.nodes[node].edges;
        self.edge_store.insert(&mut span, token, child);
        self.nodes[node].edges = span;
    }

    /// Drop `node`'s edge for `token`; an emptied span is recycled.
    fn del_edge(&mut self, node: NodeIdx, token: u32) {
        let mut span = self.nodes[node].edges;
        self.edge_store.remove(&mut span, token);
        self.nodes[node].edges = span;
    }

    /// The one prefix traversal both lookup flavors share: (matched token
    /// count, end node), calling `visit` on every node walked — including a
    /// partially-matched edge's child. The resume-reservation probe bound
    /// is only sound if the sizing walk and the insert-time walk agree
    /// exactly, so any change to match granularity or edge handling lives
    /// here and nowhere else.
    fn prefix_walk(&self, tokens: &[u32], mut visit: impl FnMut(NodeIdx)) -> (usize, NodeIdx) {
        let mut cur = self.root;
        let mut matched = 0usize;
        visit(cur);
        while matched < tokens.len() {
            let Some(child) = self.edge_store.get(self.nodes[cur].edges, tokens[matched]) else {
                break;
            };
            let klen = self.nodes[child].key.len();
            let common = self.nodes[child]
                .key
                .iter()
                .zip(&tokens[matched..])
                .take_while(|(a, b)| a == b)
                .count();
            visit(child);
            matched += common;
            if common < klen {
                break; // partial edge match: stop (match granularity = token)
            }
            cur = child;
        }
        (matched, cur)
    }

    /// Longest cached prefix of `tokens`, read-only and allocation-free:
    /// like [`RadixCache::match_prefix`] but touches no LRU clock. For
    /// sizing probes — e.g. a resume reservation estimated against a
    /// migration *candidate* shard's cache — that must not perturb eviction
    /// order on caches that end up not being used.
    pub fn peek_prefix(&self, tokens: &[u32]) -> usize {
        self.prefix_walk(tokens, |_| {}).0
    }

    /// Read the payload words backing tokens `start..start + len` of the
    /// cached prefix `tokens` — the *source* side of a cross-shard block
    /// transfer. Read-only (no LRU clock), like [`RadixCache::peek_prefix`].
    /// Returns `None` when the cache does not hold the full range (the
    /// owner may have evicted it since the hub snapshot).
    pub fn read_prefix_payload(
        &self,
        tokens: &[u32],
        start: usize,
        len: usize,
    ) -> Option<Vec<u64>> {
        if len == 0 {
            return Some(Vec::new());
        }
        let mut path: Vec<NodeIdx> = Vec::new();
        let (matched, _) = self.prefix_walk(tokens, |idx| path.push(idx));
        if matched < start + len {
            return None;
        }
        let mut out = Vec::with_capacity(len);
        let mut base = 0usize; // token offset of the current node's first slot
        for idx in path {
            let klen = self.nodes[idx].key.len();
            let lo = start.max(base);
            let hi = (start + len).min(base + klen);
            if lo < hi {
                let words = self.allocator.read_span(&self.nodes[idx].blocks, klen);
                out.extend_from_slice(&words[lo - base..hi - base]);
            }
            base += klen;
            if base >= start + len {
                break;
            }
        }
        debug_assert_eq!(out.len(), len);
        Some(out)
    }

    /// Write pre-read payload `words` into the blocks of `node` starting at
    /// token slot `offset` — the *destination* side of a block transfer.
    /// The transported words must be bit-identical to what a local
    /// recompute would have written ([`payload_word`] keys on token value
    /// alone), asserted in debug builds.
    pub fn write_node_payload(&mut self, node: NodeIdx, offset: usize, words: &[u64]) {
        debug_assert!(
            words
                .iter()
                .enumerate()
                .all(|(i, &w)| w == payload_word(self.nodes[node].key[offset + i])),
            "transported payload diverges from local recompute"
        );
        let blocks = std::mem::take(&mut self.nodes[node].blocks);
        self.allocator.write_words(&blocks, offset, words);
        self.nodes[node].blocks = blocks;
    }

    /// Fault in the backing payload arena from the calling thread (NUMA
    /// first-touch). Returns the arena size in bytes.
    pub fn fault_in_arena(&mut self) -> usize {
        self.allocator.fault_in()
    }

    /// Read-only cold-tier probe: the earliest slot `m` such that the cold
    /// tier contiguously covers `tokens[m..]`, walking no further once
    /// coverage reaches `start`. `tokens.len()` when there is no cold tier
    /// or it holds nothing ending at this trajectory. Like
    /// [`RadixCache::peek_prefix`], perturbs no LRU state — neither tier's.
    pub fn cold_probe(&self, tokens: &[u32], start: usize) -> usize {
        match &self.cold {
            Some(cold) => cold.probe_back(tokens, start),
            None => tokens.len(),
        }
    }

    /// Execute a cold-tier restore: copy the payload words of
    /// `seq[from..]` out of the [`SpillArena`] into `node`'s blocks, where
    /// `node` is an insert's fresh suffix child covering `seq[node_base..]`.
    /// The restore-vs-recompute *decision* already happened upstream
    /// ([`crate::engine::PerfModel::tier_choice`]); this is the data plane,
    /// bit-identical to the hash-fill the insert already performed
    /// (debug-asserted in [`RadixCache::write_node_payload`]). Returns
    /// tokens actually copied — 0 when the arena dropped the span since the
    /// sizing probe, leaving the recompute words in place.
    pub fn restore_node_payload(
        &mut self,
        node: NodeIdx,
        seq: &[u32],
        from: usize,
        node_base: usize,
    ) -> usize {
        debug_assert!(from >= node_base, "restore range must land inside the node");
        let Some(cold) = self.cold.as_mut() else { return 0 };
        let Some(words) = cold.restore(seq, from) else { return 0 };
        self.write_node_payload(node, from - node_base, &words);
        words.len()
    }

    /// Longest cached prefix of `tokens`: (matched token count, end node).
    /// Touches LRU clocks along the path.
    pub fn match_prefix(&mut self, tokens: &[u32]) -> (usize, NodeIdx) {
        let mut visited: Vec<NodeIdx> = Vec::new();
        let (matched, end) = self.prefix_walk(tokens, |idx| visited.push(idx));
        let now = self.tick();
        for idx in visited {
            self.touch(idx, now);
        }
        (matched, end)
    }

    /// Insert `tokens`, sharing any existing prefix. Splits edges on partial
    /// matches. Returns allocation accounting and the terminal node.
    ///
    /// Block discipline: the new suffix costs `blocks_for(suffix)` and an
    /// edge split can cost one extra block of fragmentation, so a caller
    /// that reserved `blocks_for(new tokens) + 1` can never see this panic.
    pub fn insert(&mut self, tokens: &[u32]) -> InsertOutcome {
        let now = self.tick();
        let mut cur = self.root;
        let mut pos = 0usize;
        let mut shared = 0usize;
        self.touch(cur, now);
        while pos < tokens.len() {
            match self.edge_store.get(self.nodes[cur].edges, tokens[pos]) {
                None => {
                    // Append the remaining tokens as a fresh child.
                    let span = self.alloc_span(tokens.len() - pos);
                    self.allocator.write_span(&span, &tokens[pos..]);
                    let node = RNode {
                        key: tokens[pos..].to_vec(),
                        parent: Some(cur),
                        edges: EdgeSpan::default(),
                        refcount: 0,
                        last_access: now,
                        dead: false,
                        blocks: span,
                    };
                    let idx = self.alloc(node);
                    self.add_edge(cur, tokens[pos], idx);
                    self.refresh_evictable(cur); // gained a child
                    return InsertOutcome {
                        new_tokens: tokens.len() - pos,
                        shared_tokens: shared,
                        node: idx,
                    };
                }
                Some(child) => {
                    let klen = self.nodes[child].key.len();
                    let common = self.nodes[child]
                        .key
                        .iter()
                        .zip(&tokens[pos..])
                        .take_while(|(a, b)| a == b)
                        .count();
                    self.touch(child, now);
                    if common == klen {
                        // Full edge consumed.
                        shared += common;
                        pos += common;
                        cur = child;
                    } else {
                        // Split child at `common`.
                        let split = self.split(child, common, now);
                        shared += common;
                        pos += common;
                        cur = split;
                        // loop continues: either tokens exhausted or a new
                        // branch is appended under the split node.
                    }
                }
            }
        }
        InsertOutcome { new_tokens: 0, shared_tokens: shared, node: cur }
    }

    /// Split `node`'s edge after `at` tokens; returns the new upper node.
    fn split(&mut self, node: NodeIdx, at: usize, now: u64) -> NodeIdx {
        debug_assert!(at > 0 && at < self.nodes[node].key.len());
        let parent = self.nodes[node].parent.expect("split of root");
        let upper_key = self.nodes[node].key[..at].to_vec();
        let lower_key = self.nodes[node].key[at..].to_vec();
        // Re-page the split halves: release the old span first, so the two
        // fresh spans need at most one extra block (page fragmentation).
        // `node` may sit in the evictable set; pull it out before its span
        // changes so the block counter stays exact (re-added below).
        self.drop_evictable(node);
        let old_span = std::mem::take(&mut self.nodes[node].blocks);
        self.allocator.release_span(old_span);
        let upper_span = self.alloc_span(at);
        let lower_span = self.alloc_span(lower_key.len());
        // re-page the payload words along with the accounting
        self.allocator.write_span(&upper_span, &upper_key);
        self.allocator.write_span(&lower_span, &lower_key);
        let upper = RNode {
            key: upper_key,
            parent: Some(parent),
            edges: EdgeSpan::default(),
            // the upper part inherits pins: any sequence pinning the lower
            // node transitively pins its prefix (unlock walks through here)
            refcount: self.nodes[node].refcount,
            last_access: now,
            dead: false,
            blocks: upper_span,
        };
        // Note: alloc counts upper's tokens as new, but the split conserves
        // total tokens (lower loses `at` tokens) — adjust below.
        let upper_idx = self.alloc(upper);
        self.live_tokens -= at; // conserve: split moves tokens, not adds
        let first_upper = self.nodes[upper_idx].key[0];
        let first_lower = lower_key[0];
        self.add_edge(parent, first_upper, upper_idx); // relabels node → upper
        self.nodes[node].key = lower_key;
        self.nodes[node].blocks = lower_span;
        self.nodes[node].parent = Some(upper_idx);
        self.add_edge(upper_idx, first_lower, node);
        self.refresh_evictable(upper_idx); // gained a child: not evictable
        self.refresh_evictable(node); // re-add with the re-paged span
        upper_idx
    }

    /// Pin the path root..=node (active sequence).
    pub fn lock(&mut self, node: NodeIdx) {
        let mut cur = Some(node);
        while let Some(idx) = cur {
            self.nodes[idx].refcount += 1;
            self.refresh_evictable(idx);
            cur = self.nodes[idx].parent;
        }
    }

    /// Unpin the path root..=node.
    pub fn unlock(&mut self, node: NodeIdx) {
        let mut cur = Some(node);
        while let Some(idx) = cur {
            assert!(self.nodes[idx].refcount > 0, "unlock without lock");
            self.nodes[idx].refcount -= 1;
            self.refresh_evictable(idx);
            cur = self.nodes[idx].parent;
        }
    }

    /// The full token sequence along the path root..=`node` — the
    /// trajectory a demoted span is fingerprinted under. Only called on the
    /// demote path (parent links are intact until [`RadixCache::remove_leaf`]
    /// finishes, so the walk is always sound there).
    fn path_token_vec(&self, node: NodeIdx) -> Vec<u32> {
        let mut rev_nodes: Vec<NodeIdx> = Vec::new();
        let mut cur = Some(node);
        while let Some(idx) = cur {
            rev_nodes.push(idx);
            cur = self.nodes[idx].parent;
        }
        let mut out = Vec::with_capacity(self.path_tokens(node));
        for idx in rev_nodes.into_iter().rev() {
            out.extend_from_slice(&self.nodes[idx].key);
        }
        out
    }

    /// Tokens stored along the path root..=`node` — the sequence length a
    /// cached sequence end represents.
    pub fn path_tokens(&self, node: NodeIdx) -> usize {
        let mut tokens = 0usize;
        let mut cur = Some(node);
        while let Some(idx) = cur {
            tokens += self.nodes[idx].key.len();
            cur = self.nodes[idx].parent;
        }
        tokens
    }

    /// Unique tokens on the union of root-paths of `nodes` — the radix-shared
    /// KV footprint of a set of sequence ends. This is the engine's canonical
    /// "live KV" view (each shared prefix counted once).
    pub fn path_union_tokens(&self, nodes: &[NodeIdx]) -> usize {
        let mut seen: HashSet<NodeIdx> = HashSet::new();
        let mut tokens = 0usize;
        for &n in nodes {
            let mut cur = Some(n);
            while let Some(idx) = cur {
                if !seen.insert(idx) {
                    break; // the rest of this path is already counted
                }
                tokens += self.nodes[idx].key.len();
                cur = self.nodes[idx].parent;
            }
        }
        tokens
    }

    /// Sum of tokens held by pinned (refcount > 0) nodes.
    pub fn pinned_tokens(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| !n.dead && n.refcount > 0)
            .map(|n| n.key.len())
            .sum()
    }

    /// Free the unpinned tail of the path ending at `node`: remove childless
    /// refcount-0 nodes walking toward the root, stopping at the first node
    /// that is still shared (has children) or pinned. O(path length) — the
    /// targeted release the engine uses after unpinning a retired sequence,
    /// instead of sweeping the whole arena. Returns tokens freed.
    pub fn release_branch(&mut self, node: NodeIdx) -> usize {
        let mut freed = 0usize;
        let mut cur = Some(node);
        while let Some(idx) = cur {
            if idx == self.root || self.nodes[idx].dead {
                break;
            }
            let n = &self.nodes[idx];
            if !n.edges.is_empty() || n.refcount > 0 {
                break;
            }
            let parent = n.parent;
            // demote: false — a released branch is a pruned/retired
            // trajectory no resume will ever re-insert; spilling it would
            // only dilute the cold tier's budget
            freed += self.remove_leaf(idx, false);
            cur = parent;
        }
        freed
    }

    /// Evict *every* unpinned branch regardless of recency (the evictable
    /// set makes the cascade O(log n) per removed leaf;
    /// [`RadixCache::release_branch`] is the cheap per-sequence variant).
    /// Returns tokens freed.
    pub fn evict_unpinned(&mut self) -> usize {
        let mut freed = 0usize;
        // removing a leaf may make its parent evictable; the set picks the
        // cascade up automatically
        loop {
            let Some(&(_, idx)) = self.evictable.iter().next() else { break };
            freed += self.remove_leaf(idx, true);
        }
        freed
    }

    /// Evict least-recently-used unpinned leaves until at least
    /// `target_tokens` have been freed (or nothing evictable remains).
    /// O(log n) per freed leaf via the ordered evictable set.
    /// Returns tokens freed.
    pub fn evict(&mut self, target_tokens: usize) -> usize {
        let mut freed = 0usize;
        while freed < target_tokens {
            let Some(&(_, idx)) = self.evictable.iter().next() else { break };
            freed += self.remove_leaf(idx, true);
        }
        freed
    }

    /// Remove a childless unpinned leaf, releasing its blocks. With
    /// `demote` set and a cold tier attached, the span's payload words are
    /// copied into the [`SpillArena`] first — demote-instead-of-destroy,
    /// the pressure ladder's third rung. The HBM blocks are freed in the
    /// *identical* order either way, and the arena keeps its own LRU clock,
    /// so cold-tier {on,off} cannot diverge in anything but cost/telemetry.
    fn remove_leaf(&mut self, idx: NodeIdx, demote: bool) -> usize {
        debug_assert!(self.nodes[idx].edges.is_empty());
        debug_assert_eq!(self.nodes[idx].refcount, 0, "removing a pinned leaf");
        if demote && self.cold.is_some() {
            let path = self.path_token_vec(idx);
            let klen = self.nodes[idx].key.len();
            let words = self.allocator.read_span(&self.nodes[idx].blocks, klen);
            let cold = self.cold.as_mut().expect("checked above");
            cold.admit(&path, path.len() - klen, &words);
        }
        let parent = self.nodes[idx].parent.expect("removing root");
        let first = self.nodes[idx].key[0];
        self.del_edge(parent, first);
        let tokens = self.nodes[idx].key.len();
        self.live_tokens -= tokens;
        self.drop_evictable(idx);
        let span = std::mem::take(&mut self.nodes[idx].blocks);
        self.allocator.release_span(span);
        self.nodes[idx].dead = true;
        self.nodes[idx].key = vec![];
        // recycle this node's edge-span capacity instead of the old
        // `children = HashMap::new()` reallocation
        let mut edges = self.nodes[idx].edges;
        self.edge_store.release_span(&mut edges);
        self.nodes[idx].edges = edges;
        self.free.push(idx);
        self.refresh_evictable(parent); // may have become a childless leaf
        tokens
    }

    /// Check internal invariants (tests / debug).
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut token_sum = 0usize;
        let mut block_sum = 0usize;
        let mut seen_blocks: HashSet<BlockId> = HashSet::new();
        let mut expect_evictable: BTreeSet<(u64, NodeIdx)> = BTreeSet::new();
        for (idx, n) in self.nodes.iter().enumerate() {
            if n.dead {
                if !n.blocks.is_empty() {
                    return Err(format!("dead node {idx} still holds blocks"));
                }
                if n.edges != EdgeSpan::default() {
                    return Err(format!("dead node {idx} still holds an edge span"));
                }
                continue;
            }
            token_sum += n.key.len();
            block_sum += n.blocks.len();
            if n.blocks.len() != self.allocator.blocks_for(n.key.len()) {
                return Err(format!(
                    "node {idx}: {} blocks for {} tokens (block_size {})",
                    n.blocks.len(),
                    n.key.len(),
                    self.allocator.block_size()
                ));
            }
            for &b in &n.blocks {
                if b >= self.allocator.total_blocks() {
                    return Err(format!("node {idx} holds out-of-range block {b}"));
                }
                if !seen_blocks.insert(b) {
                    return Err(format!("block {b} owned twice"));
                }
            }
            if idx != self.root && n.key.is_empty() {
                return Err(format!("non-root node {idx} with empty key"));
            }
            if idx != self.root && n.edges.is_empty() && n.refcount == 0 {
                expect_evictable.insert((n.last_access, idx));
            }
            let span = self.edge_store.slice(n.edges);
            for w in span.windows(2) {
                if w[0].0 >= w[1].0 {
                    return Err(format!("edge span of {idx} not strictly sorted"));
                }
            }
            for &(first, child) in span {
                let c = &self.nodes[child];
                if c.dead {
                    return Err(format!("child {child} of {idx} is dead"));
                }
                if c.parent != Some(idx) {
                    return Err(format!("parent link broken for {child}"));
                }
                if c.key.first() != Some(&first) {
                    return Err(format!("child key map mismatch at {child}"));
                }
            }
        }
        if token_sum != self.live_tokens {
            return Err(format!(
                "token accounting drift: sum {token_sum} != live {}",
                self.live_tokens
            ));
        }
        if block_sum != self.allocator.used_blocks() {
            return Err(format!(
                "block accounting drift: spans {block_sum} != used {}",
                self.allocator.used_blocks()
            ));
        }
        if self.allocator.used_blocks() > self.allocator.total_blocks() {
            return Err("block budget exceeded".into());
        }
        if expect_evictable != self.evictable {
            return Err(format!(
                "evictable set drift: expect {expect_evictable:?} got {:?}",
                self.evictable
            ));
        }
        let expect_blocks: usize = self
            .evictable
            .iter()
            .map(|&(_, idx)| self.nodes[idx].blocks.len())
            .sum();
        if expect_blocks != self.evictable_block_count {
            return Err(format!(
                "evictable block counter drift: sum {expect_blocks} != counter {}",
                self.evictable_block_count
            ));
        }
        if let Some(cold) = &self.cold {
            cold.check_invariants()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;

    #[test]
    fn insert_and_full_prefix_match() {
        let mut c = RadixCache::new(1 << 20);
        let seq: Vec<u32> = (0..100).collect();
        let out = c.insert(&seq);
        assert_eq!(out.new_tokens, 100);
        assert_eq!(out.shared_tokens, 0);
        assert_eq!(c.live_tokens(), 100);
        let (m, _) = c.match_prefix(&seq);
        assert_eq!(m, 100);
        c.check_invariants().unwrap();
    }

    #[test]
    fn shared_prefix_deduplicates() {
        let mut c = RadixCache::new(1 << 20);
        let a: Vec<u32> = (0..100).collect();
        let mut b = a.clone();
        b.extend(200..250);
        let mut d = a.clone();
        d.extend(300..350);
        c.insert(&a);
        let ob = c.insert(&b);
        assert_eq!(ob.shared_tokens, 100);
        assert_eq!(ob.new_tokens, 50);
        let od = c.insert(&d);
        assert_eq!(od.shared_tokens, 100);
        assert_eq!(od.new_tokens, 50);
        assert_eq!(c.live_tokens(), 200);
        c.check_invariants().unwrap();
    }

    #[test]
    fn partial_match_splits_edge() {
        let mut c = RadixCache::new(1 << 20);
        c.insert(&[1, 2, 3, 4, 5]);
        let out = c.insert(&[1, 2, 3, 9, 9]);
        assert_eq!(out.shared_tokens, 3);
        assert_eq!(out.new_tokens, 2);
        assert_eq!(c.live_tokens(), 7);
        let (m, _) = c.match_prefix(&[1, 2, 3, 4, 5]);
        assert_eq!(m, 5);
        let (m, _) = c.match_prefix(&[1, 2, 3, 9, 9]);
        assert_eq!(m, 5);
        let (m, _) = c.match_prefix(&[1, 2, 3]);
        assert_eq!(m, 3);
        c.check_invariants().unwrap();
    }

    #[test]
    fn payload_arena_holds_token_keyed_words_across_splits() {
        let mut c = RadixCache::with_block_size(1 << 12, 4);
        let a: Vec<u32> = (10..30).collect();
        c.insert(&a);
        let want: Vec<u64> = a.iter().map(|&t| payload_word(t)).collect();
        assert_eq!(c.read_prefix_payload(&a, 0, 20).unwrap(), want);
        // a diverging insert splits mid-node and re-pages both halves; the
        // words must survive the re-page because they key on token value
        let mut b = a[..7].to_vec();
        b.extend(900..910);
        c.insert(&b);
        assert_eq!(c.read_prefix_payload(&a, 0, 20).unwrap(), want);
        assert_eq!(
            c.read_prefix_payload(&b, 7, 10).unwrap(),
            (900..910).map(|t| payload_word(t)).collect::<Vec<_>>()
        );
        // interior sub-ranges read the same words the full read sees
        assert_eq!(c.read_prefix_payload(&a, 5, 9).unwrap(), want[5..14]);
        // a range past the cached span is refused, not fabricated
        let mut longer = a.clone();
        longer.push(31);
        assert!(c.read_prefix_payload(&longer, 0, 21).is_none());
        c.check_invariants().unwrap();
    }

    #[test]
    fn transported_words_match_a_local_recompute_bit_for_bit() {
        // two shared-nothing arenas: src recomputes, dst imports the copy
        let mut src = RadixCache::with_block_size(1 << 12, 4);
        let mut dst = RadixCache::with_block_size(1 << 12, 4);
        let seq: Vec<u32> = (500..532).collect();
        src.insert(&seq);
        let out = dst.insert(&seq);
        let words = src.read_prefix_payload(&seq, 0, 32).unwrap();
        // the write asserts copy ≡ recompute in debug builds
        dst.write_node_payload(out.node, 0, &words);
        assert_eq!(dst.read_prefix_payload(&seq, 0, 32).unwrap(), words);
    }

    #[test]
    fn fault_in_reports_the_arena_footprint_and_changes_nothing() {
        let mut c = RadixCache::with_block_size(1 << 10, 16);
        let seq: Vec<u32> = (0..40).collect();
        c.insert(&seq);
        let before = c.read_prefix_payload(&seq, 0, 40).unwrap();
        let bytes = c.fault_in_arena();
        assert_eq!(bytes, c.total_blocks() * 16 * std::mem::size_of::<u64>());
        assert_eq!(c.read_prefix_payload(&seq, 0, 40).unwrap(), before);
        c.check_invariants().unwrap();
    }

    #[test]
    fn eviction_frees_lru_leaf_only() {
        let mut c = RadixCache::new(1 << 20);
        let a = c.insert(&[1, 2, 3]).node;
        c.insert(&[1, 2, 3, 4, 5]); // extends under a
        std::hint::black_box(a);
        c.insert(&[7, 8]);
        // touch [1,2,3,4,5] so [7,8] is LRU
        c.match_prefix(&[1, 2, 3, 4, 5]);
        let freed = c.evict(1);
        assert_eq!(freed, 2, "should evict the [7,8] leaf");
        let (m, _) = c.match_prefix(&[7, 8]);
        assert_eq!(m, 0);
        let (m, _) = c.match_prefix(&[1, 2, 3, 4, 5]);
        assert_eq!(m, 5);
        c.check_invariants().unwrap();
    }

    #[test]
    fn locked_nodes_survive_eviction() {
        let mut c = RadixCache::new(1 << 20);
        let n = c.insert(&[1, 2, 3]).node;
        c.lock(n);
        let freed = c.evict(100);
        assert_eq!(freed, 0);
        assert_eq!(c.live_tokens(), 3);
        c.unlock(n);
        let freed = c.evict(100);
        assert_eq!(freed, 3);
        assert_eq!(c.live_tokens(), 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn eviction_cascades_up_freed_branches() {
        let mut c = RadixCache::new(1 << 20);
        c.insert(&[1, 2]);
        c.insert(&[1, 2, 3]);
        c.insert(&[1, 2, 4]);
        // evict everything: leaves first, then their parent becomes a leaf
        let freed = c.evict(usize::MAX);
        assert_eq!(freed, 4);
        assert_eq!(c.live_tokens(), 0);
        assert_eq!(c.live_nodes(), 0);
        assert_eq!(c.used_blocks(), 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn reinsert_after_eviction() {
        let mut c = RadixCache::new(1 << 20);
        c.insert(&[5, 6, 7]);
        c.evict(usize::MAX);
        let out = c.insert(&[5, 6, 7]);
        assert_eq!(out.new_tokens, 3);
        c.check_invariants().unwrap();
    }

    #[test]
    fn split_preserves_pins_of_the_lower_node() {
        // Lock a sequence end, then insert a diverging sequence that splits
        // an edge *inside* the locked path: the pin must survive the split
        // (the upper node inherits the refcount), so eviction cannot touch
        // the locked path.
        let mut c = RadixCache::new(1 << 20);
        let end = c.insert(&[1, 2, 3, 4, 5]).node;
        c.lock(end);
        let other = c.insert(&[1, 2, 9]).node; // splits [1,2,3,4,5] after 2
        c.check_invariants().unwrap();
        std::hint::black_box(other);
        c.evict_unpinned();
        let (m, _) = c.match_prefix(&[1, 2, 3, 4, 5]);
        assert_eq!(m, 5, "locked path lost after split");
        let (m, _) = c.match_prefix(&[1, 2, 9]);
        assert_eq!(m, 2, "unpinned branch should be gone");
        assert_eq!(c.path_tokens(end), 5);
        c.unlock(end);
        c.evict_unpinned();
        assert_eq!(c.live_tokens(), 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn refcount_pin_blocks_lru_eviction_until_unlock() {
        let mut c = RadixCache::new(1 << 20);
        let pinned = c.insert(&[1, 2, 3]).node;
        c.insert(&[9, 9]);
        c.lock(pinned);
        // [1,2,3] is LRU-older than [9,9] after this touch
        c.match_prefix(&[9, 9]);
        let freed = c.evict(usize::MAX);
        assert_eq!(freed, 2, "only the unpinned [9,9] leaf is evictable");
        assert_eq!(c.live_tokens(), 3);
        c.unlock(pinned);
        assert_eq!(c.evict(usize::MAX), 3);
        c.check_invariants().unwrap();
    }

    #[test]
    fn path_accounting_views() {
        let mut c = RadixCache::new(1 << 20);
        let a = c.insert(&[1, 2, 3, 4]).node;
        let b = c.insert(&[1, 2, 7, 8, 9]).node;
        // shared prefix [1,2]; total unique = 2 + 2 + 3 = 7
        assert_eq!(c.path_tokens(a), 4);
        assert_eq!(c.path_tokens(b), 5);
        assert_eq!(c.path_union_tokens(&[a, b]), 7);
        assert_eq!(c.path_union_tokens(&[a]), 4);
        assert_eq!(c.path_union_tokens(&[a, a]), 4);
        assert_eq!(c.path_union_tokens(&[]), 0);
        assert_eq!(c.live_tokens(), 7);
        c.lock(a);
        assert_eq!(c.pinned_tokens(), 4);
        c.unlock(a);
    }

    #[test]
    fn release_branch_frees_exclusive_tail_only() {
        let mut c = RadixCache::new(1 << 20);
        let shared = c.insert(&[1, 2]).node;
        let a = c.insert(&[1, 2, 3, 4]).node;
        let b = c.insert(&[1, 2, 7]).node;
        c.lock(a);
        c.lock(b);
        c.unlock(a);
        // a's exclusive [3,4] tail goes; the shared [1,2] prefix stays
        // (pinned through b) and b's branch is untouched
        assert_eq!(c.release_branch(a), 2);
        assert_eq!(c.live_tokens(), 3);
        let (m, _) = c.match_prefix(&[1, 2, 7]);
        assert_eq!(m, 3);
        // releasing an already-shared interior node is a no-op
        assert_eq!(c.release_branch(shared), 0);
        c.unlock(b);
        assert_eq!(c.release_branch(b), 3, "now the whole chain unwinds");
        assert_eq!(c.live_tokens(), 0);
        // releasing a dead node is a safe no-op
        assert_eq!(c.release_branch(b), 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn evict_unpinned_cascades_and_spares_locks() {
        let mut c = RadixCache::new(1 << 20);
        let keep = c.insert(&[1, 2, 3]).node;
        c.insert(&[1, 2, 3, 4, 5]);
        c.insert(&[1, 7]);
        c.insert(&[8, 9, 10]);
        c.lock(keep);
        let freed = c.evict_unpinned();
        // everything except the pinned [1,2,3] path goes, including the
        // [4,5] extension below the pin and multi-level branches
        assert_eq!(freed, 2 + 1 + 3);
        assert_eq!(c.live_tokens(), 3);
        assert_eq!(c.path_union_tokens(&[keep]), 3);
        c.check_invariants().unwrap();
        c.unlock(keep);
        assert_eq!(c.evict_unpinned(), 3);
        assert_eq!(c.live_nodes(), 0);
    }

    #[test]
    fn block_accounting_tracks_inserts_splits_and_evictions() {
        let mut c = RadixCache::with_block_size(16 * 64, 16);
        assert_eq!(c.total_blocks(), 64);
        assert_eq!(c.used_blocks(), 0);
        let seq: Vec<u32> = (0..40).collect(); // 40 tokens → 3 blocks
        c.insert(&seq);
        assert_eq!(c.used_blocks(), 3);
        assert_eq!(c.free_blocks(), 61);
        // diverge after 20 tokens: split re-pages into 2 + 2 blocks, the
        // new 10-token branch adds 1 → 5 total
        let mut d: Vec<u32> = (0..20).collect();
        d.extend(100..110);
        c.insert(&d);
        assert_eq!(c.used_blocks(), 2 + 2 + 1);
        c.check_invariants().unwrap();
        c.evict(usize::MAX);
        assert_eq!(c.used_blocks(), 0);
        assert_eq!(c.free_blocks(), 64);
        c.check_invariants().unwrap();
    }

    #[test]
    fn reserve_protocol_enforces_hard_budget() {
        let mut c = RadixCache::with_block_size(16 * 4, 16); // 4 blocks
        c.try_reserve(3).unwrap();
        // a second reservation beyond the remainder fails with signals
        let err = c.try_reserve(2).unwrap_err();
        assert_eq!(err.needed_blocks, 2);
        assert_eq!(err.free_blocks, 1);
        assert_eq!(err.evictable_blocks, 0);
        c.release_reservation(3);
        // commit path: reserve, release right before inserting, insert
        c.try_reserve(3).unwrap();
        c.release_reservation(3);
        let seq: Vec<u32> = (0..33).collect(); // 3 blocks
        c.insert(&seq);
        assert_eq!(c.used_blocks(), 3);
        let err = c.try_reserve(2).unwrap_err();
        assert_eq!(err.free_blocks, 1);
        assert_eq!(err.evictable_blocks, 3, "the unpinned leaf is reclaimable");
        c.check_invariants().unwrap();
    }

    #[test]
    fn lru_survives_repeated_evict_reinsert_cycles() {
        // The O(log n) evictable set must stay consistent across many
        // insert → touch → evict → reinsert cycles (node slots are reused
        // from the free list, LRU keys change on every touch).
        let mut c = RadixCache::with_block_size(1 << 14, 4);
        for cycle in 0..40u32 {
            // three branches off a shared prefix
            let mk = |tag: u32| {
                let mut s = vec![1, 2, 3];
                s.extend((0..5).map(|t| 100 + tag * 10 + t));
                s
            };
            c.insert(&mk(0));
            c.insert(&mk(1));
            c.insert(&mk(2));
            // touch branches 1 and 2 so branch 0 is the LRU victim
            c.match_prefix(&mk(1));
            c.match_prefix(&mk(2));
            let freed = c.evict(1);
            assert_eq!(freed, 5, "cycle {cycle}: LRU victim must be branch 0");
            let (m, _) = c.match_prefix(&mk(0));
            assert_eq!(m, 3, "cycle {cycle}: branch 0 back to shared prefix");
            let (m, _) = c.match_prefix(&mk(1));
            assert_eq!(m, 8, "cycle {cycle}: branch 1 untouched");
            c.check_invariants().unwrap();
            // drain fully; reinsertion next cycle reuses freed node slots
            c.evict(usize::MAX);
            assert_eq!(c.live_tokens(), 0);
            assert_eq!(c.used_blocks(), 0);
            c.check_invariants().unwrap();
        }
    }

    #[test]
    fn prop_radix_semantics_match_naive_model() {
        // Model: a set of inserted sequences. Invariants:
        //  (1) match_prefix(s) for any inserted s == len(s)
        //  (2) live_tokens == |distinct prefixes| (trie token count)
        property(80, |rng: &mut Rng| {
            let mut c = RadixCache::with_block_size(1 << 20, 1 + rng.index(8));
            let mut inserted: Vec<Vec<u32>> = vec![];
            let vocab = 4u32; // small vocab → lots of shared prefixes
            for _ in 0..(1 + rng.index(25)) {
                let len = 1 + rng.index(12);
                let seq: Vec<u32> = if !inserted.is_empty() && rng.chance(0.5) {
                    // extend or mutate an existing sequence
                    let base = &inserted[rng.index(inserted.len())];
                    let cut = rng.index(base.len() + 1);
                    let mut s = base[..cut].to_vec();
                    for _ in 0..len {
                        s.push(rng.below(vocab as u64) as u32);
                    }
                    s
                } else {
                    (0..len).map(|_| rng.below(vocab as u64) as u32).collect()
                };
                c.insert(&seq);
                inserted.push(seq);
                c.check_invariants().map_err(|e| e)?;
            }
            // (1) full prefix matches
            for s in &inserted {
                let (m, _) = c.match_prefix(s);
                crate::prop_check!(m == s.len(), "match {m} != len {}", s.len());
            }
            // (2) trie token count
            let mut prefixes: std::collections::HashSet<Vec<u32>> =
                std::collections::HashSet::new();
            for s in &inserted {
                for l in 1..=s.len() {
                    prefixes.insert(s[..l].to_vec());
                }
            }
            crate::prop_check!(
                c.live_tokens() == prefixes.len(),
                "live {} != trie {}",
                c.live_tokens(),
                prefixes.len()
            );
            Ok(())
        });
    }

    #[test]
    fn prop_eviction_preserves_invariants_and_locked_paths() {
        property(60, |rng: &mut Rng| {
            let mut c = RadixCache::with_block_size(1 << 20, 1 + rng.index(8));
            let mut locked: Vec<(Vec<u32>, NodeIdx)> = vec![];
            for _ in 0..(1 + rng.index(15)) {
                let len = 1 + rng.index(10);
                let seq: Vec<u32> =
                    (0..len).map(|_| rng.below(3) as u32).collect();
                let out = c.insert(&seq);
                if rng.chance(0.3) {
                    c.lock(out.node);
                    locked.push((seq, out.node));
                }
            }
            c.evict(rng.index(40));
            c.check_invariants().map_err(|e| e)?;
            for (seq, _) in &locked {
                let (m, _) = c.match_prefix(seq);
                crate::prop_check!(m == seq.len(), "locked path evicted");
            }
            for (_, n) in &locked {
                c.unlock(*n);
            }
            c.evict(usize::MAX);
            crate::prop_check!(c.live_tokens() == 0, "full evict left tokens");
            crate::prop_check!(c.used_blocks() == 0, "full evict left blocks");
            c.check_invariants().map_err(|e| e)?;
            Ok(())
        });
    }

    /// Faithful port of the pre-flat-edge cache: per-node `HashMap` children,
    /// same node arena + LIFO free list, same clock/LRU discipline. Because
    /// allocation order and access stamps are replicated exactly, node
    /// indices and eviction order must agree with [`RadixCache`] op-for-op —
    /// the only difference is the edge store under test.
    struct ModelNode {
        key: Vec<u32>,
        parent: Option<usize>,
        children: std::collections::HashMap<u32, usize>,
        refcount: usize,
        last_access: u64,
        dead: bool,
    }

    struct ModelRadix {
        nodes: Vec<ModelNode>,
        free: Vec<usize>,
        clock: u64,
        live_tokens: usize,
        evictable: BTreeSet<(u64, usize)>,
    }

    impl ModelRadix {
        fn new() -> Self {
            let root = ModelNode {
                key: vec![],
                parent: None,
                children: Default::default(),
                refcount: 1,
                last_access: 0,
                dead: false,
            };
            Self {
                nodes: vec![root],
                free: vec![],
                clock: 0,
                live_tokens: 0,
                evictable: BTreeSet::new(),
            }
        }

        fn refresh(&mut self, idx: usize) {
            let n = &self.nodes[idx];
            let key = (n.last_access, idx);
            if !n.dead && idx != 0 && n.children.is_empty() && n.refcount == 0 {
                self.evictable.insert(key);
            } else {
                self.evictable.remove(&key);
            }
        }

        fn touch(&mut self, idx: usize, now: u64) {
            self.evictable.remove(&(self.nodes[idx].last_access, idx));
            self.nodes[idx].last_access = now;
            self.refresh(idx);
        }

        fn alloc(&mut self, node: ModelNode) -> usize {
            self.live_tokens += node.key.len();
            let idx = if let Some(idx) = self.free.pop() {
                self.nodes[idx] = node;
                idx
            } else {
                self.nodes.push(node);
                self.nodes.len() - 1
            };
            self.refresh(idx);
            idx
        }

        fn walk(&self, tokens: &[u32]) -> (usize, usize, Vec<usize>) {
            let mut cur = 0usize;
            let mut matched = 0usize;
            let mut visited = vec![cur];
            while matched < tokens.len() {
                let Some(&child) = self.nodes[cur].children.get(&tokens[matched]) else {
                    break;
                };
                let klen = self.nodes[child].key.len();
                let common = self.nodes[child]
                    .key
                    .iter()
                    .zip(&tokens[matched..])
                    .take_while(|(a, b)| a == b)
                    .count();
                visited.push(child);
                matched += common;
                if common < klen {
                    break;
                }
                cur = child;
            }
            (matched, cur, visited)
        }

        fn match_prefix(&mut self, tokens: &[u32]) -> (usize, usize) {
            let (matched, end, visited) = self.walk(tokens);
            self.clock += 1;
            let now = self.clock;
            for idx in visited {
                self.touch(idx, now);
            }
            (matched, end)
        }

        fn insert(&mut self, tokens: &[u32]) -> (usize, usize, usize) {
            self.clock += 1;
            let now = self.clock;
            let mut cur = 0usize;
            let mut pos = 0usize;
            let mut shared = 0usize;
            self.touch(cur, now);
            while pos < tokens.len() {
                match self.nodes[cur].children.get(&tokens[pos]).copied() {
                    None => {
                        let node = ModelNode {
                            key: tokens[pos..].to_vec(),
                            parent: Some(cur),
                            children: Default::default(),
                            refcount: 0,
                            last_access: now,
                            dead: false,
                        };
                        let idx = self.alloc(node);
                        self.nodes[cur].children.insert(tokens[pos], idx);
                        self.refresh(cur);
                        return (tokens.len() - pos, shared, idx);
                    }
                    Some(child) => {
                        let klen = self.nodes[child].key.len();
                        let common = self.nodes[child]
                            .key
                            .iter()
                            .zip(&tokens[pos..])
                            .take_while(|(a, b)| a == b)
                            .count();
                        self.touch(child, now);
                        if common == klen {
                            shared += common;
                            pos += common;
                            cur = child;
                        } else {
                            let split = self.split(child, common, now);
                            shared += common;
                            pos += common;
                            cur = split;
                        }
                    }
                }
            }
            (0, shared, cur)
        }

        fn split(&mut self, node: usize, at: usize, now: u64) -> usize {
            let parent = self.nodes[node].parent.unwrap();
            let upper_key = self.nodes[node].key[..at].to_vec();
            let lower_key = self.nodes[node].key[at..].to_vec();
            let upper = ModelNode {
                key: upper_key,
                parent: Some(parent),
                children: Default::default(),
                refcount: self.nodes[node].refcount,
                last_access: now,
                dead: false,
            };
            let upper_idx = self.alloc(upper);
            self.live_tokens -= at;
            let first_upper = self.nodes[upper_idx].key[0];
            let first_lower = lower_key[0];
            self.nodes[parent].children.insert(first_upper, upper_idx);
            self.nodes[node].key = lower_key;
            self.nodes[node].parent = Some(upper_idx);
            self.nodes[upper_idx].children.insert(first_lower, node);
            self.refresh(upper_idx);
            self.refresh(node);
            upper_idx
        }

        fn lock(&mut self, node: usize) {
            let mut cur = Some(node);
            while let Some(idx) = cur {
                self.nodes[idx].refcount += 1;
                self.refresh(idx);
                cur = self.nodes[idx].parent;
            }
        }

        fn unlock(&mut self, node: usize) {
            let mut cur = Some(node);
            while let Some(idx) = cur {
                self.nodes[idx].refcount -= 1;
                self.refresh(idx);
                cur = self.nodes[idx].parent;
            }
        }

        fn remove_leaf(&mut self, idx: usize) -> usize {
            let parent = self.nodes[idx].parent.unwrap();
            let first = self.nodes[idx].key[0];
            self.nodes[parent].children.remove(&first);
            let tokens = self.nodes[idx].key.len();
            self.live_tokens -= tokens;
            self.evictable.remove(&(self.nodes[idx].last_access, idx));
            self.nodes[idx].dead = true;
            self.nodes[idx].key = vec![];
            self.nodes[idx].children = Default::default();
            self.free.push(idx);
            self.refresh(parent);
            tokens
        }

        fn evict(&mut self, target_tokens: usize) -> usize {
            let mut freed = 0usize;
            while freed < target_tokens {
                let Some(&(_, idx)) = self.evictable.iter().next() else { break };
                freed += self.remove_leaf(idx);
            }
            freed
        }

        fn evict_unpinned(&mut self) -> usize {
            let mut freed = 0usize;
            loop {
                let Some(&(_, idx)) = self.evictable.iter().next() else { break };
                freed += self.remove_leaf(idx);
            }
            freed
        }

        fn release_branch(&mut self, node: usize) -> usize {
            let mut freed = 0usize;
            let mut cur = Some(node);
            while let Some(idx) = cur {
                if idx == 0 || self.nodes[idx].dead {
                    break;
                }
                let n = &self.nodes[idx];
                if !n.children.is_empty() || n.refcount > 0 {
                    break;
                }
                let parent = n.parent;
                freed += self.remove_leaf(idx);
                cur = parent;
            }
            freed
        }
    }

    #[test]
    fn prop_flat_edges_match_hashmap_reference_model() {
        // Drive the flat-edge cache and the HashMap-edge reference through
        // identical random insert / match / pin / evict / release sequences
        // and demand identical observable behavior at every step: insert
        // accounting, node indices, match lengths, freed-token counts, and
        // live-token totals.
        property(60, |rng: &mut Rng| {
            let mut real = RadixCache::with_block_size(1 << 20, 1 + rng.index(8));
            let mut model = ModelRadix::new();
            let vocab = 4u64;
            let mut seqs: Vec<Vec<u32>> = vec![];
            let mut locked: Vec<NodeIdx> = vec![];
            let mk_seq = |rng: &mut Rng, seqs: &[Vec<u32>]| -> Vec<u32> {
                let len = 1 + rng.index(10);
                if !seqs.is_empty() && rng.chance(0.5) {
                    let base = &seqs[rng.index(seqs.len())];
                    let cut = rng.index(base.len() + 1);
                    let mut s = base[..cut].to_vec();
                    for _ in 0..len {
                        s.push(rng.below(vocab) as u32);
                    }
                    s
                } else {
                    (0..len).map(|_| rng.below(vocab) as u32).collect()
                }
            };
            for _ in 0..(10 + rng.index(30)) {
                match rng.index(6) {
                    0 | 1 => {
                        let s = mk_seq(rng, &seqs);
                        let out = real.insert(&s);
                        let got = (out.new_tokens, out.shared_tokens, out.node);
                        let want = model.insert(&s);
                        crate::prop_check!(
                            got == want,
                            "insert diverged: real {got:?} vs model {want:?}"
                        );
                        if rng.chance(0.3) {
                            real.lock(out.node);
                            model.lock(out.node);
                            locked.push(out.node);
                        }
                        seqs.push(s);
                    }
                    2 => {
                        let s = mk_seq(rng, &seqs);
                        let got = real.match_prefix(&s);
                        let want = model.match_prefix(&s);
                        crate::prop_check!(
                            got == want,
                            "match diverged: real {got:?} vs model {want:?}"
                        );
                    }
                    3 => {
                        let target = rng.index(30);
                        let a = real.evict(target);
                        let b = model.evict(target);
                        crate::prop_check!(a == b, "evict freed {a} vs model {b}");
                    }
                    4 => {
                        if let Some(i) = (!locked.is_empty()).then(|| rng.index(locked.len())) {
                            let n = locked.swap_remove(i);
                            real.unlock(n);
                            model.unlock(n);
                            let a = real.release_branch(n);
                            let b = model.release_branch(n);
                            crate::prop_check!(a == b, "release freed {a} vs model {b}");
                        }
                    }
                    _ => {
                        let a = real.evict_unpinned();
                        let b = model.evict_unpinned();
                        crate::prop_check!(a == b, "evict_unpinned freed {a} vs model {b}");
                    }
                }
                crate::prop_check!(
                    real.live_tokens() == model.live_tokens,
                    "live tokens drift: real {} vs model {}",
                    real.live_tokens(),
                    model.live_tokens
                );
                real.check_invariants().map_err(|e| e)?;
            }
            for &n in &locked {
                real.unlock(n);
                model.unlock(n);
            }
            let a = real.evict_unpinned();
            let b = model.evict_unpinned();
            crate::prop_check!(a == b, "final drain freed {a} vs model {b}");
            crate::prop_check!(real.live_tokens() == 0, "final drain left tokens");
            real.check_invariants().map_err(|e| e)?;
            Ok(())
        });
    }

    #[test]
    fn write_words_read_span_roundtrip_partial_tail_blocks() {
        // Span lengths deliberately NOT multiples of block_size: the last
        // block is partially occupied and the transfer surface must neither
        // read past the span nor clobber slots beyond it.
        for bs in [1usize, 3, 7, 16] {
            let mut a = BlockAllocator::new(64, bs);
            for len in [1usize, bs.max(2) - 1, bs + 1, 3 * bs - 1, 3 * bs + 1] {
                let tokens: Vec<u32> = (0..len as u32).map(|t| 7 * t + 13).collect();
                let words: Vec<u64> = tokens.iter().map(|&t| payload_word(t)).collect();
                let blocks = a.alloc(a.blocks_for(len)).unwrap();
                // recompute path then full read-back
                a.write_span(&blocks, &tokens);
                assert_eq!(a.read_span(&blocks, len), words, "bs {bs} len {len}");
                // transfer path: land the same words through write_words
                a.write_words(&blocks, 0, &words);
                assert_eq!(a.read_span(&blocks, len), words, "bs {bs} len {len}");
                // offset write covering only the (partial) tail
                let off = len / 2;
                a.write_words(&blocks, off, &words[off..]);
                assert_eq!(a.read_span(&blocks, len), words, "bs {bs} len {len} off {off}");
                // partial read stops mid-block
                assert_eq!(a.read_span(&blocks, off), words[..off], "bs {bs} len {len}");
                a.release_span(blocks);
            }
        }
    }

    #[test]
    fn prop_demote_restore_match_prefix_agrees_with_never_evicting_reference() {
        // Tiered cache under explicit eviction pressure (demoting into the
        // cold tier) vs a reference cache that never evicts, driven
        // op-for-op: after every demote→re-insert→restore cycle the tiered
        // cache must hold the same prefix lengths and the same payload
        // words as the reference. `write_node_payload` additionally
        // debug-asserts every restored word against the local recompute.
        property(60, |rng: &mut Rng| {
            let bs = 1 + rng.index(8);
            let mut tiered = RadixCache::with_block_size(1 << 20, bs);
            tiered.attach_cold_tier(1 << 20);
            let mut reference = RadixCache::with_block_size(1 << 20, bs);
            let mut inserted: Vec<Vec<u32>> = vec![];
            for _ in 0..(1 + rng.index(20)) {
                let len = 1 + rng.index(10);
                // mostly extend existing sequences so eviction cascades
                // demote tiled mid-tree spans, not just whole trajectories
                let seq: Vec<u32> = if !inserted.is_empty() && rng.chance(0.6) {
                    let base = &inserted[rng.index(inserted.len())];
                    let cut = rng.index(base.len() + 1);
                    let mut s = base[..cut].to_vec();
                    for _ in 0..len {
                        s.push(rng.below(4) as u32);
                    }
                    s
                } else {
                    (0..len).map(|_| rng.below(4) as u32).collect()
                };
                tiered.insert(&seq);
                reference.insert(&seq);
                inserted.push(seq);
                if rng.chance(0.5) {
                    // pressure: demote some LRU branches into the cold tier
                    tiered.evict(1 + rng.index(20));
                }
                tiered.check_invariants().map_err(|e| e)?;
                // resume one sequence: re-insert, restore the cold-covered
                // suffix, and compare against the reference
                let s = inserted[rng.index(inserted.len())].clone();
                let resident = tiered.peek_prefix(&s);
                let out = tiered.insert(&s);
                crate::prop_check!(
                    out.shared_tokens == resident,
                    "insert shared {} != peek {resident}",
                    out.shared_tokens
                );
                if out.new_tokens > 0 {
                    let m = tiered.cold_probe(&s, out.shared_tokens);
                    let from = m.max(out.shared_tokens);
                    let restored =
                        tiered.restore_node_payload(out.node, &s, from, out.shared_tokens);
                    crate::prop_check!(
                        restored == s.len() - from,
                        "probe promised [{from}, {}) but restored {restored}",
                        s.len()
                    );
                }
                reference.insert(&s);
                let (mt, _) = tiered.match_prefix(&s);
                let (mr, _) = reference.match_prefix(&s);
                crate::prop_check!(
                    mt == s.len() && mr == s.len(),
                    "re-inserted prefix incomplete: tiered {mt} reference {mr} of {}",
                    s.len()
                );
                let wt = tiered
                    .read_prefix_payload(&s, 0, s.len())
                    .ok_or_else(|| "tiered payload missing".to_string())?;
                let wr = reference
                    .read_prefix_payload(&s, 0, s.len())
                    .ok_or_else(|| "reference payload missing".to_string())?;
                crate::prop_check!(
                    wt == wr,
                    "tiered payload diverges from never-evicting reference"
                );
                tiered.check_invariants().map_err(|e| e)?;
                reference.check_invariants().map_err(|e| e)?;
            }
            Ok(())
        });
    }
}
