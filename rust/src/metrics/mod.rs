//! Reporting helpers: aligned text tables (the benches print paper-style
//! rows) and JSON result dumps.

use crate::util::json::Json;

/// A simple aligned text table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
    }

    /// Render with per-column width = max cell width.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Dump as JSON (list of objects keyed by header).
    pub fn to_json(&self) -> Json {
        Json::arr(self.rows.iter().map(|row| {
            Json::Obj(
                self.header
                    .iter()
                    .zip(row)
                    .map(|(h, c)| (h.clone(), Json::Str(c.clone())))
                    .collect(),
            )
        }))
    }

    /// Print and append the JSON form to `target/bench_results.jsonl`.
    pub fn emit(&self) {
        println!("{}", self.render());
        let line = Json::obj(vec![
            ("title", Json::str(&self.title)),
            ("rows", self.to_json()),
        ])
        .to_string_compact();
        let _ = std::fs::create_dir_all("target");
        use std::io::Write;
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open("target/bench_results.jsonl")
        {
            let _ = writeln!(f, "{line}");
        }
    }
}

/// Format a ratio like "1.8x" (0 → "-").
pub fn ratio(base: f64, x: f64) -> String {
    if x > 0.0 && base > 0.0 {
        format!("{:.2}x", base / x)
    } else {
        "-".into()
    }
}

/// Format a percentage.
pub fn pct(x: f64) -> String {
    format!("{:.1}", 100.0 * x)
}

/// Format a duration in seconds as milliseconds ("12.3ms").
pub fn ms(seconds: f64) -> String {
    format!("{:.1}ms", 1e3 * seconds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "long-header", "c"]);
        t.row(vec!["1".into(), "2".into(), "3".into()]);
        t.row(vec!["100".into(), "x".into(), "yyy".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn helpers() {
        assert_eq!(ratio(180.0, 100.0), "1.80x");
        assert_eq!(ratio(1.0, 0.0), "-");
        assert_eq!(pct(0.525), "52.5");
        assert_eq!(ms(0.0123), "12.3ms");
    }
}
