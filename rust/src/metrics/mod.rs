//! Legacy reporting home — the `Table`/format helpers moved to
//! [`crate::obs::report`] when the `obs` subsystem landed. Re-exported here
//! so existing callers (benches, `main.rs`) keep compiling unchanged.

pub use crate::obs::report::{ms, pct, ratio, Table};
