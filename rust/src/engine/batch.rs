//! The batched expansion engine: one [`RadixCache`] is the *single source of
//! truth* for KV accounting across every live trajectory of every problem it
//! serves.
//!
//! The search driver no longer keeps its own token counters. Instead it
//! hands the engine [`ExpandRequest`] batches; the engine
//!
//! * **insert-on-expand** — every new step's full token sequence is inserted
//!   into the radix tree (synthetic generators get engine-minted unique ids,
//!   so radix sharing exactly mirrors tree-prefix sharing; PJRT generators
//!   contribute their real sampled ids),
//! * **refcount-while-live** — the sequence end of every live leaf is
//!   pinned; expanding a leaf pins the children before unpinning the parent
//!   so shared prefixes never become evictable mid-step,
//! * **release-on-prune/complete** — retiring trajectories unpins them and
//!   reclaims every unpinned branch immediately.
//!
//! The KV metrics the driver reports ("live" = union of pinned paths,
//! "unshared" = Σ per-leaf sequence lengths) are views computed from the
//! cache ([`RadixCache::path_union_tokens`] / [`RadixCache::path_tokens`]),
//! which is what makes the multi-problem `serve` path's resident-set numbers
//! and the per-problem search metrics mutually consistent by construction.

use crate::kvcache::{NodeIdx, RadixCache};
use crate::tree::{NodeId, SearchTree};
use std::collections::{HashMap, HashSet};

/// Default engine cache capacity, in tokens.
pub const DEFAULT_KV_CAPACITY: usize = 1 << 22;

/// One leaf expansion in a step batch: sample `n` continuations of the
/// trajectory ending at `leaf`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExpandRequest {
    pub leaf: NodeId,
    pub n: usize,
}

/// Per-problem view over the shared cache: which radix nodes this problem's
/// prompt and live leaves have pinned.
#[derive(Clone, Debug)]
pub struct KvLedger {
    /// Token ids of the prompt (prefix of every sequence of this problem).
    prompt_ids: Vec<u32>,
    /// Pinned radix node holding the prompt; `None` once closed.
    prompt_node: Option<NodeIdx>,
    /// tree leaf -> pinned radix node holding its sequence end.
    locked: HashMap<NodeId, NodeIdx>,
    /// True while every admitted step used engine-minted unique token ids,
    /// in which case cache accounting provably equals tree accounting (the
    /// step-level invariant the driver asserts in debug builds).
    exact_accounting: bool,
}

impl KvLedger {
    /// Radix nodes currently pinned by this problem (sequence ends).
    pub fn pinned(&self) -> impl Iterator<Item = NodeIdx> + '_ {
        self.locked.values().copied().chain(self.prompt_node)
    }

    /// Whether cache accounting is exactly the tree accounting (engine-minted
    /// ids only; real-token generators can legitimately dedup further).
    pub fn exact_accounting(&self) -> bool {
        self.exact_accounting
    }

    pub fn live_leaves(&self) -> usize {
        self.locked.len()
    }
}

/// Shared batched engine: radix cache + token-id mint + batch telemetry.
#[derive(Clone, Debug)]
pub struct BatchEngine {
    cache: RadixCache,
    /// Next synthetic token id (ids are never reused, so distinct steps can
    /// only share KV through genuine path-prefix sharing).
    next_token: u32,
    /// Problems ever registered.
    pub problems_registered: u64,
    /// Expansion batches executed via [`BatchEngine::expand`].
    pub batches_executed: u64,
    /// Tokens admitted into the cache (Σ new_tokens over inserts).
    pub tokens_admitted: u64,
    /// Tokens reclaimed by release-on-prune/complete.
    pub tokens_reclaimed: u64,
}

impl BatchEngine {
    pub fn new(capacity_tokens: usize) -> Self {
        Self {
            cache: RadixCache::new(capacity_tokens),
            next_token: 1, // 0 is the conventional padding id
            problems_registered: 0,
            batches_executed: 0,
            tokens_admitted: 0,
            tokens_reclaimed: 0,
        }
    }

    fn mint_tokens(&mut self, n: usize) -> Vec<u32> {
        (0..n)
            .map(|_| {
                let t = self.next_token;
                self.next_token = self.next_token.wrapping_add(1).max(1);
                t
            })
            .collect()
    }

    /// Register a problem whose prompt has no real token ids: mint
    /// `prompt_tokens` unique ids, insert, and pin them for the lifetime of
    /// the search.
    pub fn register(&mut self, prompt_tokens: usize) -> KvLedger {
        let ids = self.mint_tokens(prompt_tokens);
        self.register_ledger(ids, true)
    }

    /// Register a problem with real prompt token ids (PJRT path). Identical
    /// prompts across problems will share cache honestly, which also means
    /// cache accounting may undercut tree accounting — `exact_accounting`
    /// is cleared.
    pub fn register_with_prompt(&mut self, prompt_ids: Vec<u32>) -> KvLedger {
        self.register_ledger(prompt_ids, false)
    }

    fn register_ledger(&mut self, prompt_ids: Vec<u32>, exact: bool) -> KvLedger {
        let out = self.cache.insert(&prompt_ids);
        self.tokens_admitted += out.new_tokens as u64;
        self.cache.lock(out.node);
        self.problems_registered += 1;
        KvLedger {
            prompt_ids,
            prompt_node: Some(out.node),
            locked: HashMap::new(),
            exact_accounting: exact,
        }
    }

    /// Full token sequence of `node` under this ledger's problem: prompt ids
    /// followed by every step's ids along the root path.
    pub fn sequence(ledger: &KvLedger, tree: &SearchTree, node: NodeId) -> Vec<u32> {
        let mut seq = ledger.prompt_ids.clone();
        for n in tree.path(node) {
            seq.extend_from_slice(&tree.get(n).step.token_ids);
        }
        seq
    }

    /// Run one step's allocation through the generator as a single batched
    /// call. Returns per-request continuations (request order preserved).
    pub fn expand<G: crate::lm::StepGenerator>(
        &mut self,
        lm: &mut G,
        tree: &SearchTree,
        requests: &[ExpandRequest],
    ) -> Vec<Vec<crate::tree::StepInfo>> {
        let reqs: Vec<(NodeId, usize)> = requests.iter().map(|r| (r.leaf, r.n)).collect();
        self.batches_executed += 1;
        lm.expand_batch(tree, &reqs)
    }

    /// Charge a step's freshly added children to the cache: mint ids for
    /// synthetic steps, insert every child's sequence (insert-on-expand),
    /// pin the children, then unpin the parents they replace on the
    /// frontier.
    pub fn admit(&mut self, ledger: &mut KvLedger, tree: &mut SearchTree, children: &[NodeId]) {
        for &c in children {
            let (needs_ids, tokens) = {
                let step = &tree.get(c).step;
                (step.token_ids.is_empty(), step.tokens)
            };
            if needs_ids && tokens > 0 {
                let ids = self.mint_tokens(tokens);
                tree.get_mut(c).step.token_ids = ids;
            } else if !needs_ids {
                // real surface ids: radix dedup may exceed tree-level sharing
                ledger.exact_accounting = false;
            }
        }
        let mut parents: HashSet<NodeId> = HashSet::new();
        for &c in children {
            let seq = Self::sequence(ledger, tree, c);
            let out = self.cache.insert(&seq);
            self.tokens_admitted += out.new_tokens as u64;
            self.cache.lock(out.node);
            ledger.locked.insert(c, out.node);
            if let Some(p) = tree.get(c).parent {
                parents.insert(p);
            }
        }
        for p in parents {
            if let Some(idx) = ledger.locked.remove(&p) {
                self.cache.unlock(idx);
            }
        }
    }

    /// Release-on-prune/complete: unpin every leaf not in `keep` and free
    /// each one's now-exclusive branch (an O(path) walk-up per retired
    /// sequence — shared prefixes stay, other problems' pins are never
    /// touched). Returns tokens freed.
    pub fn retire(&mut self, ledger: &mut KvLedger, keep: &[NodeId]) -> usize {
        let keep: HashSet<NodeId> = keep.iter().copied().collect();
        let drop: Vec<NodeId> =
            ledger.locked.keys().copied().filter(|k| !keep.contains(k)).collect();
        let mut freed = 0usize;
        for k in drop {
            if let Some(idx) = ledger.locked.remove(&k) {
                self.cache.unlock(idx);
                freed += self.cache.release_branch(idx);
            }
        }
        self.tokens_reclaimed += freed as u64;
        freed
    }

    /// Close a problem: unpin everything it holds (including the prompt) and
    /// free the branches that become unreferenced. Idempotent.
    pub fn close(&mut self, ledger: &mut KvLedger) {
        let mut freed = 0usize;
        for (_, idx) in ledger.locked.drain() {
            self.cache.unlock(idx);
            freed += self.cache.release_branch(idx);
        }
        if let Some(p) = ledger.prompt_node.take() {
            self.cache.unlock(p);
            freed += self.cache.release_branch(p);
        }
        self.tokens_reclaimed += freed as u64;
    }

    /// Live (radix-shared) KV tokens of one problem: unique tokens on the
    /// union of its pinned paths. This is the paper's per-step "KV cache
    /// size", read from the cache rather than recomputed from the tree.
    pub fn live_kv(&self, ledger: &KvLedger) -> usize {
        let nodes: Vec<NodeIdx> = ledger.pinned().collect();
        self.cache.path_union_tokens(&nodes)
    }

    /// KV tokens the same frontier would cost a sharing-oblivious server:
    /// every pinned leaf pays its full sequence length.
    pub fn unshared_kv(&self, ledger: &KvLedger) -> usize {
        ledger.locked.values().map(|&n| self.cache.path_tokens(n)).sum()
    }

    /// Unique tokens resident in the shared cache (all problems).
    pub fn live_tokens(&self) -> usize {
        self.cache.live_tokens()
    }

    pub fn cache(&self) -> &RadixCache {
        &self.cache
    }

    pub fn check_invariants(&self) -> Result<(), String> {
        self.cache.check_invariants()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::StepInfo;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;

    fn child(tree: &mut SearchTree, parent: NodeId, tokens: usize) -> NodeId {
        tree.add_child(parent, StepInfo { tokens, ..Default::default() }, 0.5)
    }

    fn live_step_tokens(t: &SearchTree) -> usize {
        (0..t.len()).filter(|&i| t.get(i).live).map(|i| t.get(i).step.tokens).sum()
    }

    #[test]
    fn admit_then_live_matches_tree_accounting() {
        let mut eng = BatchEngine::new(1 << 20);
        let mut tree = SearchTree::new();
        let root = tree.init_root(100);
        let mut ledger = eng.register(100);
        let a = child(&mut tree, root, 10);
        let b = child(&mut tree, root, 20);
        eng.admit(&mut ledger, &mut tree, &[a, b]);
        assert!(ledger.exact_accounting());
        assert_eq!(eng.live_kv(&ledger), 130);
        assert_eq!(eng.unshared_kv(&ledger), 110 + 120);
        assert_eq!(eng.live_tokens(), 130);
        assert_eq!(eng.live_kv(&ledger), live_step_tokens(&tree));
        eng.check_invariants().unwrap();
    }

    #[test]
    fn expanding_a_leaf_moves_the_pin_to_its_children() {
        let mut eng = BatchEngine::new(1 << 20);
        let mut tree = SearchTree::new();
        let root = tree.init_root(5);
        let mut ledger = eng.register(5);
        let a = child(&mut tree, root, 3);
        eng.admit(&mut ledger, &mut tree, &[a]);
        let c1 = child(&mut tree, a, 7);
        let c2 = child(&mut tree, a, 9);
        eng.admit(&mut ledger, &mut tree, &[c1, c2]);
        assert_eq!(ledger.live_leaves(), 2, "parent pin replaced by children");
        assert_eq!(eng.live_kv(&ledger), 5 + 3 + 7 + 9);
        // the shared prefix (prompt + a) stays pinned through the children
        assert_eq!(eng.unshared_kv(&ledger), (5 + 3 + 7) + (5 + 3 + 9));
        eng.check_invariants().unwrap();
    }

    #[test]
    fn retire_reclaims_pruned_branches_only() {
        let mut eng = BatchEngine::new(1 << 20);
        let mut tree = SearchTree::new();
        let root = tree.init_root(4);
        let mut ledger = eng.register(4);
        let a = child(&mut tree, root, 10);
        let b = child(&mut tree, root, 6);
        eng.admit(&mut ledger, &mut tree, &[a, b]);
        tree.retain_paths(&[a]);
        let freed = eng.retire(&mut ledger, &[a]);
        assert_eq!(freed, 6, "b's exclusive tokens reclaimed");
        assert_eq!(eng.live_kv(&ledger), 14);
        assert_eq!(eng.live_kv(&ledger), live_step_tokens(&tree));
        assert_eq!(eng.live_tokens(), 14);
        eng.check_invariants().unwrap();
    }

    #[test]
    fn close_releases_everything_and_is_idempotent() {
        let mut eng = BatchEngine::new(1 << 20);
        let mut tree = SearchTree::new();
        let root = tree.init_root(8);
        let mut ledger = eng.register(8);
        let a = child(&mut tree, root, 5);
        eng.admit(&mut ledger, &mut tree, &[a]);
        assert!(eng.live_tokens() > 0);
        eng.close(&mut ledger);
        assert_eq!(eng.live_tokens(), 0);
        eng.close(&mut ledger); // second close is a no-op
        assert_eq!(eng.live_tokens(), 0);
        eng.check_invariants().unwrap();
    }

    #[test]
    fn problems_share_one_cache_without_interference() {
        let mut eng = BatchEngine::new(1 << 20);
        let mut t1 = SearchTree::new();
        let mut t2 = SearchTree::new();
        let r1 = t1.init_root(50);
        let r2 = t2.init_root(70);
        let mut l1 = eng.register(50);
        let mut l2 = eng.register(70);
        let a1 = child(&mut t1, r1, 10);
        let a2 = child(&mut t2, r2, 20);
        eng.admit(&mut l1, &mut t1, &[a1]);
        eng.admit(&mut l2, &mut t2, &[a2]);
        assert_eq!(eng.live_kv(&l1), 60);
        assert_eq!(eng.live_kv(&l2), 90);
        assert_eq!(eng.live_tokens(), 150, "disjoint problems sum exactly");
        // retiring problem 1 cannot disturb problem 2's pins
        eng.retire(&mut l1, &[]);
        assert_eq!(eng.live_kv(&l1), 50, "prompt stays pinned until close");
        assert_eq!(eng.live_kv(&l2), 90);
        eng.close(&mut l1);
        eng.close(&mut l2);
        assert_eq!(eng.live_tokens(), 0);
        eng.check_invariants().unwrap();
    }

    #[test]
    fn prop_cache_accounting_tracks_random_trees() {
        property(60, |rng: &mut Rng| {
            let mut eng = BatchEngine::new(1 << 20);
            let mut tree = SearchTree::new();
            let prompt = 1 + rng.index(40);
            let root = tree.init_root(prompt);
            let mut ledger = eng.register(prompt);
            let mut frontier = vec![root];
            for _ in 0..(1 + rng.index(6)) {
                // expand a random subset of the frontier, then retire to it
                let keep: Vec<NodeId> = frontier
                    .iter()
                    .copied()
                    .filter(|_| rng.chance(0.7))
                    .collect();
                let keep = if keep.is_empty() { vec![frontier[0]] } else { keep };
                tree.retain_paths(&keep);
                eng.retire(&mut ledger, &keep);
                let mut next = vec![];
                for &leaf in &keep {
                    let fanout = 1 + rng.index(4);
                    let children: Vec<NodeId> = (0..fanout)
                        .map(|_| child(&mut tree, leaf, 1 + rng.index(30)))
                        .collect();
                    eng.admit(&mut ledger, &mut tree, &children);
                    next.extend(children);
                }
                frontier = next;
                // the step-level invariant: cache view == tree truth
                crate::prop_check!(
                    eng.live_kv(&ledger) == live_step_tokens(&tree),
                    "cache {} != tree {}",
                    eng.live_kv(&ledger),
                    live_step_tokens(&tree)
                );
                crate::prop_check!(
                    eng.live_tokens() == eng.live_kv(&ledger),
                    "single problem must own the whole cache"
                );
                crate::prop_check!(
                    eng.live_kv(&ledger) <= eng.unshared_kv(&ledger) + prompt,
                    "shared exceeded unshared"
                );
                eng.check_invariants()?;
            }
            eng.close(&mut ledger);
            crate::prop_check!(eng.live_tokens() == 0, "close left tokens");
            Ok(())
        });
    }
}
