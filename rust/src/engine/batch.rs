//! The batched expansion engine: one [`RadixCache`] is the *single source of
//! truth* for KV accounting across every live trajectory of every problem it
//! serves.
//!
//! The search driver no longer keeps its own token counters. Instead it
//! hands the engine [`ExpandRequest`] batches; the engine
//!
//! * **insert-on-expand** — every new step's full token sequence is inserted
//!   into the radix tree (synthetic generators get engine-minted unique ids,
//!   so radix sharing exactly mirrors tree-prefix sharing; PJRT generators
//!   contribute their real sampled ids),
//! * **refcount-while-live** — the sequence end of every live leaf is
//!   pinned; expanding a leaf pins the children before unpinning the parent
//!   so shared prefixes never become evictable mid-step,
//! * **release-on-prune/complete** — retiring trajectories unpins them and
//!   reclaims every unpinned branch immediately.
//!
//! Capacity is a **hard block budget**: all admissions go through a
//! `reserve → commit` protocol. [`BatchEngine::try_reserve`] earmarks the
//! worst-case block need of an insert burst and fails with [`KvPressure`]
//! (carrying free/evictable-block signals) when the budget cannot cover it;
//! only after a successful reservation does the commit path touch the cache,
//! so a failed step leaves no partial state behind. The serve scheduler
//! reacts to pressure by LRU-evicting unpinned branches
//! ([`BatchEngine::relieve_pressure`]) and, when that is not enough,
//! preempting whole sessions: [`BatchEngine::suspend`] releases every block
//! a ledger pins (the search tree keeps the trajectory), and
//! [`BatchEngine::try_resume`] later re-admits it by *recomputing* the
//! evicted prefix through the radix cache (the recompute-prefill cost is
//! what the perf model charges for a resume).
//!
//! The KV metrics the driver reports ("live" = union of pinned paths,
//! "unshared" = Σ per-leaf sequence lengths) are views computed from the
//! cache ([`RadixCache::path_union_tokens`] / [`RadixCache::path_tokens`]),
//! which is what makes the multi-problem `serve` path's resident-set numbers
//! and the per-problem search metrics mutually consistent by construction.

use crate::kvcache::prefixhub::PrefixHub;
use crate::kvcache::{KvPressure, NodeIdx, RadixCache, DEFAULT_BLOCK_SIZE};
use crate::tree::{NodeId, SearchTree};
use std::collections::{HashMap, HashSet};

/// Default engine cache capacity, in tokens.
pub const DEFAULT_KV_CAPACITY: usize = 1 << 22;

/// One leaf expansion in a step batch: sample `n` continuations of the
/// trajectory ending at `leaf`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExpandRequest {
    pub leaf: NodeId,
    pub n: usize,
}

/// Aggregate memory-pressure signals the scheduler steers by.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PressureSignals {
    pub block_size: usize,
    pub total_blocks: usize,
    pub used_blocks: usize,
    /// Free blocks net of open reservations.
    pub free_blocks: usize,
    /// Blocks one LRU pass could reclaim from unpinned leaves.
    pub evictable_blocks: usize,
    /// Admission headroom the scheduler keeps in reserve: new problems are
    /// only admitted while `free_blocks` stays above this low watermark
    /// (plus the admission's own need), so running sessions keep room to
    /// grow before preemption kicks in.
    pub low_watermark_blocks: usize,
}

/// Per-problem view over the shared cache: which radix nodes this problem's
/// prompt and live leaves have pinned.
#[derive(Clone, Debug)]
pub struct KvLedger {
    /// Token ids of the prompt (prefix of every sequence of this problem).
    prompt_ids: Vec<u32>,
    /// Pinned radix node holding the prompt; `None` once closed/suspended.
    prompt_node: Option<NodeIdx>,
    /// tree leaf -> pinned radix node holding its sequence end.
    locked: HashMap<NodeId, NodeIdx>,
    /// Tree leaves whose pins were released by a suspend; re-pinned (with
    /// their prefixes recomputed) on resume. Empty while resident.
    suspended_leaves: Vec<NodeId>,
    /// True while every admitted step used engine-minted unique token ids,
    /// in which case cache accounting provably equals tree accounting (the
    /// step-level invariant the driver asserts in debug builds).
    exact_accounting: bool,
}

impl KvLedger {
    /// Radix nodes currently pinned by this problem (sequence ends).
    pub fn pinned(&self) -> impl Iterator<Item = NodeIdx> + '_ {
        self.locked.values().copied().chain(self.prompt_node)
    }

    /// Token ids of the problem's prompt — what the coordinator fingerprints
    /// into the global prefix hub at round barriers (and what prompt-affinity
    /// routing matches admissions against).
    pub fn prompt_ids(&self) -> &[u32] {
        &self.prompt_ids
    }

    /// Whether cache accounting is exactly the tree accounting (engine-minted
    /// ids only; real-token generators can legitimately dedup further).
    pub fn exact_accounting(&self) -> bool {
        self.exact_accounting
    }

    pub fn live_leaves(&self) -> usize {
        self.locked.len()
    }

    /// Step-span leaves this problem currently retains — the pinned leaves
    /// while resident, the suspend-remembered ones otherwise. This is the
    /// numerator of the serve scheduler's online `kv_retention`
    /// calibration: observed retained-leaves over live width replaces the
    /// policy's static retention heuristic once real telemetry exists.
    pub fn retained_leaves(&self) -> usize {
        if self.suspended_leaves.is_empty() {
            self.locked.len()
        } else {
            self.suspended_leaves.len()
        }
    }

    /// Tree leaves ending this problem's committed step spans, in
    /// deterministic order: the pinned leaves (sorted) while resident, the
    /// suspend-remembered leaves otherwise. These are the sequence ends the
    /// coordinator fingerprints into the prefix hub as *mid-tree step
    /// spans*, so a hub import or cold-tier restore can satisfy partial
    /// trajectories instead of only whole prompts.
    pub fn span_leaves(&self) -> Vec<NodeId> {
        if self.suspended_leaves.is_empty() {
            let mut leaves: Vec<NodeId> = self.locked.keys().copied().collect();
            leaves.sort_unstable();
            leaves
        } else {
            self.suspended_leaves.clone()
        }
    }

    /// True between a suspend and the matching resume: nothing is pinned
    /// and the problem's KV may be evicted by others at any time.
    pub fn is_suspended(&self) -> bool {
        self.prompt_node.is_none()
            && (!self.suspended_leaves.is_empty() || self.locked.is_empty())
    }
}

/// What a [`BatchEngine::try_resume`] had to recompute.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResumeStats {
    /// Tokens whose KV was evicted while suspended and must be re-prefilled.
    pub recomputed_tokens: usize,
    /// Tokens still resident (survived eviction, re-pinned for free).
    pub retained_tokens: usize,
    /// Of `recomputed_tokens`, the span an [`ImportSource`] peer still holds
    /// — *importable* as a cross-shard block transfer instead of a local
    /// recompute prefill. Always `<= recomputed_tokens`; purely a costing
    /// signal (the cache state transition is the same insert either way),
    /// so the scheduler's `min(transfer, recompute)` choice can never
    /// change search results.
    pub imported_tokens: usize,
}

/// Where a resume may *import* a missing KV span from instead of
/// recomputing it. Read-only against every cache it touches — sizing an
/// import must not perturb anyone's LRU order.
#[derive(Clone, Copy)]
pub enum ImportSource<'a> {
    /// The coordinator's global prefix directory: spans published by peer
    /// shards at the last round barrier. Entries owned by `local_shard`
    /// itself are ignored (importing from yourself is a no-op). `peers`
    /// maps shard index → that shard's cache, for the transport plane's
    /// decision-gated block copy (`None` slots — including the local
    /// shard's own — are unreachable this round and fall back to
    /// recompute).
    Hub { hub: &'a PrefixHub, local_shard: usize, peers: &'a [Option<&'a RadixCache>] },
    /// A specific peer's cache, probed directly with the read-only
    /// `peek_prefix` walk — the migration path, where the source shard is
    /// known and its warm (unpinned, not-yet-evicted) copy of the migrant's
    /// working set is the transferable span.
    Peer { cache: &'a RadixCache },
}

impl<'a> ImportSource<'a> {
    /// Tokens of `seq`'s prefix the import source holds (whole-block
    /// granularity for the hub; token granularity for a direct peer probe).
    fn available(&self, seq: &[u32]) -> usize {
        match self {
            ImportSource::Hub { hub, local_shard, .. } => hub
                .lookup(seq)
                .filter(|m| m.shard != *local_shard)
                .map_or(0, |m| m.tokens),
            ImportSource::Peer { cache } => cache.peek_prefix(seq),
        }
    }

    /// The peer arena a committed transfer reads from, if reachable this
    /// round: the hub resolves the owning shard and looks it up in `peers`;
    /// a direct peer probe *is* the source.
    fn source_cache(&self, seq: &[u32]) -> Option<&'a RadixCache> {
        match self {
            ImportSource::Hub { hub, local_shard, peers } => {
                let m = hub.lookup(seq).filter(|m| m.shard != *local_shard)?;
                peers.get(m.shard).copied().flatten()
            }
            ImportSource::Peer { cache } => Some(cache),
        }
    }
}

/// One importable span [`BatchEngine::try_resume_with`] recorded for the
/// transport plane: where the words would land locally, and which source
/// range they cover. The insert has *already* hash-filled the span (the
/// recompute data path); the scheduler's `min(transfer, recompute)` choice
/// then either executes the copy ([`BatchEngine::commit_pending_imports`] —
/// bit-identical by construction, see [`crate::kvcache::payload_word`]) or
/// drops the record ([`BatchEngine::discard_pending_imports`]).
#[derive(Clone, Debug)]
pub struct PendingImport {
    /// The full re-inserted sequence whose prefix the source holds.
    pub seq: Vec<u32>,
    /// Tokens already resident locally; the imported range starts here.
    pub start: usize,
    /// Importable token count (`seq[start..start + len]`).
    pub len: usize,
    /// Destination node (the insert's fresh suffix child) in the local
    /// cache; the range lands at its slot 0.
    pub node: NodeIdx,
}

/// One cold-tier-restorable span [`BatchEngine::try_resume_with`] recorded:
/// the local [`crate::kvcache::coldtier::SpillArena`] holds the payload of
/// `seq[start..]`, demoted there by an earlier eviction. Like
/// [`PendingImport`], the insert has already hash-filled the span; the
/// scheduler's `min(restore, recompute)` choice
/// ([`crate::engine::PerfModel::tier_choice`]) either executes the copy
/// ([`BatchEngine::commit_pending_restores`]) or drops the record
/// ([`BatchEngine::discard_pending_restores`]).
#[derive(Clone, Debug)]
pub struct PendingRestore {
    /// The full re-inserted sequence whose suffix the cold tier holds.
    pub seq: Vec<u32>,
    /// First token slot the restore covers (`seq[start..]`).
    pub start: usize,
    /// Restorable token count (`seq.len() - start`).
    pub len: usize,
    /// Destination node (the insert's fresh suffix child).
    pub node: NodeIdx,
    /// The node's first token slot in sequence coordinates (the insert's
    /// `shared_tokens`); the restore lands at node slot `start - node_base`.
    pub node_base: usize,
}

/// Shared batched engine: radix cache + token-id mint + batch telemetry.
#[derive(Clone, Debug)]
pub struct BatchEngine {
    cache: RadixCache,
    /// Next synthetic token id (ids are never reused — not even across the
    /// engines of a sharded fleet, see [`BatchEngine::for_shard`] — so
    /// distinct steps can only share KV through genuine path-prefix
    /// sharing).
    next_token: u32,
    /// Mint step: each engine of a fleet owns a disjoint residue class of
    /// the id space (1 for a standalone engine).
    id_stride: u32,
    /// Problems ever registered.
    pub problems_registered: u64,
    /// Expansion batches executed via [`BatchEngine::expand`].
    pub batches_executed: u64,
    /// Tokens admitted into the cache (Σ new_tokens over inserts).
    pub tokens_admitted: u64,
    /// Tokens reclaimed by release-on-prune/complete.
    pub tokens_reclaimed: u64,
    /// Sessions preempted (suspend calls).
    pub suspensions: u64,
    /// Sessions resumed (successful try_resume calls).
    pub resumes: u64,
    /// Tokens re-prefilled by resumes (the recompute cost of preemption).
    pub tokens_recomputed: u64,
    /// LRU evictions run to relieve reservation pressure.
    pub pressure_evictions: u64,
    /// Importable spans recorded by the last [`BatchEngine::try_resume_with`],
    /// awaiting the scheduler's transfer-vs-recompute decision
    /// ([`BatchEngine::commit_pending_imports`] /
    /// [`BatchEngine::discard_pending_imports`]).
    pending_imports: Vec<PendingImport>,
    /// Cold-tier-restorable spans recorded by the last
    /// [`BatchEngine::try_resume_with`], awaiting the scheduler's
    /// restore-vs-recompute decision.
    pending_restores: Vec<PendingRestore>,
}

impl BatchEngine {
    pub fn new(capacity_tokens: usize) -> Self {
        Self::with_block_size(capacity_tokens, DEFAULT_BLOCK_SIZE)
    }

    pub fn with_block_size(capacity_tokens: usize, block_size: usize) -> Self {
        Self::for_shard(capacity_tokens, block_size, 0, 1)
    }

    /// Build shard `shard` of a `shards`-engine fleet whose engines may
    /// *exchange sessions* (the shard-per-core serve scheduler migrates
    /// suspended sessions across shards).
    ///
    /// Each engine mints synthetic token ids from its own arithmetic
    /// progression `shard + 1, shard + 1 + stride, …` where `stride` is
    /// `shards` rounded up to a power of two: the residue classes are
    /// disjoint and — because a power-of-two stride divides 2³² — stay
    /// disjoint even across `u32` wrap-around, so two shards can *never*
    /// mint the same id. A migrated session's re-inserted sequences can
    /// therefore only share the target cache through genuine prefix
    /// sharing: cross-problem dedup of unrelated prompts (physically
    /// impossible on real hardware) cannot happen, and a migrated resume
    /// is charged its honest recompute prefill. `for_shard(c, b, 0, 1)`
    /// is the single-engine minting scheme (ids 1, 2, 3, …).
    pub fn for_shard(
        capacity_tokens: usize,
        block_size: usize,
        shard: u32,
        shards: u32,
    ) -> Self {
        let stride = shards.max(1).next_power_of_two();
        debug_assert!(shard < stride, "shard index outside the fleet");
        Self {
            cache: RadixCache::with_block_size(capacity_tokens, block_size),
            id_stride: stride,
            // + 1: 0 is the conventional padding id (skipped at mint time
            // for the residue class that contains it)
            next_token: shard.wrapping_add(1),
            problems_registered: 0,
            batches_executed: 0,
            tokens_admitted: 0,
            tokens_reclaimed: 0,
            suspensions: 0,
            resumes: 0,
            tokens_recomputed: 0,
            pressure_evictions: 0,
            pending_imports: Vec::new(),
            pending_restores: Vec::new(),
        }
    }

    /// Attach a host-DRAM cold tier of `capacity_tokens` to this engine's
    /// cache (see [`RadixCache::attach_cold_tier`]): pressure evictions
    /// demote instead of destroy, and resumes record restorable spans for
    /// the scheduler's tier decision.
    pub fn attach_cold_tier(&mut self, capacity_tokens: usize) {
        self.cache.attach_cold_tier(capacity_tokens);
    }

    fn mint_tokens(&mut self, n: usize) -> Vec<u32> {
        (0..n)
            .map(|_| {
                if self.next_token == 0 {
                    // 0 is the padding id — skip it (stays in this shard's
                    // residue class: the stride is a power of two)
                    self.next_token = self.id_stride;
                }
                let t = self.next_token;
                self.next_token = self.next_token.wrapping_add(self.id_stride);
                t
            })
            .collect()
    }

    // -- pressure signals & the reserve protocol ---------------------------

    /// Current pressure signals (free blocks, evictable blocks, watermarks).
    pub fn pressure(&self) -> PressureSignals {
        let total = self.cache.total_blocks();
        PressureSignals {
            block_size: self.cache.block_size(),
            total_blocks: total,
            used_blocks: self.cache.used_blocks(),
            free_blocks: self.cache.free_blocks(),
            evictable_blocks: self.cache.evictable_blocks(),
            low_watermark_blocks: (total / 16).max(1),
        }
    }

    /// Worst-case blocks an insert of `tokens` new tokens can need: the
    /// paged suffix plus one block of split fragmentation. Use the
    /// ledger-aware [`BatchEngine::blocks_for_insert`] when the insert's id
    /// provenance is known — engine-minted unique ids can never split an
    /// edge, so exact-accounting inserts skip the slack block.
    pub fn blocks_for_step(&self, tokens: usize) -> usize {
        self.cache.blocks_for(tokens) + 1
    }

    /// Blocks needed to hold `tokens` new tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        self.cache.blocks_for(tokens)
    }

    /// Worst-case blocks for inserting one sequence under `ledger`:
    /// engine-minted unique ids (exact accounting, no real surface ids in
    /// this step) append at node boundaries and never split, so only
    /// real-id inserts pay the split-slack block.
    pub fn blocks_for_insert(
        &self,
        ledger: &KvLedger,
        tokens: usize,
        has_real_ids: bool,
    ) -> usize {
        let slack = usize::from(!ledger.exact_accounting() || has_real_ids);
        self.cache.blocks_for(tokens) + slack
    }

    /// Earmark `blocks` for an imminent commit; typed failure on pressure.
    pub fn try_reserve(&mut self, blocks: usize) -> Result<(), KvPressure> {
        self.cache.try_reserve(blocks)
    }

    /// Should the scheduler admit a new problem with this prompt? True when
    /// the prompt fits with the low-watermark headroom to spare (the
    /// headroom is waived while the cache is empty, so a capacity that fits
    /// exactly one problem still admits it).
    pub fn can_admit(&self, prompt_tokens: usize) -> bool {
        let sig = self.pressure();
        let need = self.blocks_for_step(prompt_tokens);
        if sig.used_blocks == 0 {
            sig.free_blocks >= need
        } else {
            sig.free_blocks >= need + sig.low_watermark_blocks
        }
    }

    /// LRU-evict unpinned branches to free up to `needed_blocks` blocks.
    /// Returns blocks actually freed (0 when nothing is evictable).
    pub fn relieve_pressure(&mut self, needed_blocks: usize) -> usize {
        let before = self.cache.used_blocks();
        let freed_tokens =
            self.cache.evict(needed_blocks.saturating_mul(self.cache.block_size()));
        if freed_tokens > 0 {
            self.pressure_evictions += 1;
            self.tokens_reclaimed += freed_tokens as u64;
        }
        before - self.cache.used_blocks()
    }

    /// Evict just enough to satisfy a failed reservation: the deficit
    /// between what it asked for and what was free — warm suspended working
    /// sets beyond the deficit are left cached (they may resume for free).
    pub fn relieve(&mut self, p: &KvPressure) -> usize {
        self.relieve_pressure(p.needed_blocks.saturating_sub(p.free_blocks).max(1))
    }

    // -- registration ------------------------------------------------------

    /// Register a problem whose prompt has no real token ids: mint
    /// `prompt_tokens` unique ids, insert, and pin them for the lifetime of
    /// the search.
    ///
    /// Panics when the block budget cannot even hold the prompt — the serve
    /// scheduler gates admission with [`BatchEngine::can_admit`] first.
    pub fn register(&mut self, prompt_tokens: usize) -> KvLedger {
        let ids = self.mint_tokens(prompt_tokens);
        self.register_ledger(ids, true)
    }

    /// Register a problem with real prompt token ids (PJRT path). Identical
    /// prompts across problems will share cache honestly, which also means
    /// cache accounting may undercut tree accounting — `exact_accounting`
    /// is cleared.
    pub fn register_with_prompt(&mut self, prompt_ids: Vec<u32>) -> KvLedger {
        self.register_ledger(prompt_ids, false)
    }

    fn register_ledger(&mut self, prompt_ids: Vec<u32>, exact: bool) -> KvLedger {
        let out = self.cache.insert(&prompt_ids);
        self.tokens_admitted += out.new_tokens as u64;
        self.cache.lock(out.node);
        self.problems_registered += 1;
        KvLedger {
            prompt_ids,
            prompt_node: Some(out.node),
            locked: HashMap::new(),
            suspended_leaves: Vec::new(),
            exact_accounting: exact,
        }
    }

    /// Full token sequence of `node` under this ledger's problem: prompt ids
    /// followed by every step's ids along the root path.
    pub fn sequence(ledger: &KvLedger, tree: &SearchTree, node: NodeId) -> Vec<u32> {
        let mut seq = ledger.prompt_ids.clone();
        for n in tree.path(node) {
            seq.extend_from_slice(&tree.get(n).step.token_ids);
        }
        seq
    }

    /// Run one step's allocation through the generator as a single batched
    /// call. Returns per-request continuations (request order preserved).
    /// Equivalent to [`BatchEngine::submit`] immediately followed by
    /// [`BatchEngine::poll`].
    pub fn expand<G: crate::lm::StepGenerator>(
        &mut self,
        lm: &mut G,
        tree: &SearchTree,
        requests: &[ExpandRequest],
    ) -> Vec<Vec<crate::tree::StepInfo>> {
        let batch = self.submit(lm, tree, requests);
        self.poll(lm, batch)
    }

    /// Phase 1 of the two-phase decode: dispatch one step's allocation to
    /// the generator without waiting for the results. The generator's RNG
    /// advances here (sync backends resolve eagerly inside the handle), so
    /// when the scheduler polls cannot change what was sampled. A batch is
    /// counted as executed at submit time.
    pub fn submit<G: crate::lm::StepGenerator>(
        &mut self,
        lm: &mut G,
        tree: &SearchTree,
        requests: &[ExpandRequest],
    ) -> crate::lm::PendingBatch {
        let reqs: Vec<(NodeId, usize)> = requests.iter().map(|r| (r.leaf, r.n)).collect();
        self.batches_executed += 1;
        lm.submit_batch(tree, &reqs)
    }

    /// Phase 2 of the two-phase decode: wait for a submitted batch.
    pub fn poll<G: crate::lm::StepGenerator>(
        &mut self,
        lm: &mut G,
        batch: crate::lm::PendingBatch,
    ) -> Vec<Vec<crate::tree::StepInfo>> {
        lm.poll_batch(batch)
    }

    // -- admission (reserve → commit) --------------------------------------

    /// Charge a step's freshly added children to the cache with the full
    /// reserve → commit protocol: reserve the worst-case block need of the
    /// burst *before* touching the cache or minting ids, then mint ids for
    /// synthetic steps, insert every child's sequence (insert-on-expand),
    /// pin the children, and unpin the parents they replace on the
    /// frontier. `Err(KvPressure)` leaves the engine and tree untouched.
    pub fn try_admit(
        &mut self,
        ledger: &mut KvLedger,
        tree: &mut SearchTree,
        children: &[NodeId],
    ) -> Result<(), KvPressure> {
        let need: usize = children
            .iter()
            .map(|&c| {
                let step = tree.get(c).step;
                self.blocks_for_insert(ledger, step.tokens, !step.token_ids.is_empty())
            })
            .sum();
        self.try_reserve(need)?;
        self.commit_admit(ledger, tree, children, need);
        Ok(())
    }

    /// Infallible admission for callers with ample capacity (the solo
    /// `run_search` path): on pressure, LRU-evicts and retries once, then
    /// panics — a single problem's step not fitting means the engine was
    /// built with a budget below one search's working set.
    pub fn admit(&mut self, ledger: &mut KvLedger, tree: &mut SearchTree, children: &[NodeId]) {
        if let Err(p) = self.try_admit(ledger, tree, children) {
            self.relieve(&p);
            self.try_admit(ledger, tree, children).unwrap_or_else(|p| {
                panic!("KV block budget below a single step's need: {p}")
            });
        }
    }

    /// Commit half of the protocol: the caller already holds a reservation
    /// of `reserved` blocks covering the burst's worst case.
    pub fn commit_admit(
        &mut self,
        ledger: &mut KvLedger,
        tree: &mut SearchTree,
        children: &[NodeId],
        reserved: usize,
    ) {
        debug_assert!(!ledger.is_suspended(), "admitting into a suspended ledger");
        self.cache.release_reservation(reserved);
        for &c in children {
            let (needs_ids, tokens) = {
                let step = tree.get(c).step;
                (step.token_ids.is_empty(), step.tokens)
            };
            if needs_ids && tokens > 0 {
                let ids = self.mint_tokens(tokens);
                tree.set_token_ids(c, ids);
            } else if !needs_ids {
                // real surface ids: radix dedup may exceed tree-level sharing
                ledger.exact_accounting = false;
            }
        }
        let mut parents: HashSet<NodeId> = HashSet::new();
        for &c in children {
            let seq = Self::sequence(ledger, tree, c);
            let out = self.cache.insert(&seq);
            self.tokens_admitted += out.new_tokens as u64;
            self.cache.lock(out.node);
            ledger.locked.insert(c, out.node);
            if let Some(p) = tree.get(c).parent {
                parents.insert(p);
            }
        }
        for p in parents {
            if let Some(idx) = ledger.locked.remove(&p) {
                self.cache.unlock(idx);
            }
        }
        debug_assert!(
            self.cache.used_blocks() <= self.cache.total_blocks(),
            "block budget exceeded after commit"
        );
    }

    /// Release-on-prune/complete: unpin every leaf not in `keep` and free
    /// each one's now-exclusive branch (an O(path) walk-up per retired
    /// sequence — shared prefixes stay, other problems' pins are never
    /// touched). Returns tokens freed.
    pub fn retire(&mut self, ledger: &mut KvLedger, keep: &[NodeId]) -> usize {
        let keep: HashSet<NodeId> = keep.iter().copied().collect();
        let drop: Vec<NodeId> =
            ledger.locked.keys().copied().filter(|k| !keep.contains(k)).collect();
        let mut freed = 0usize;
        for k in drop {
            if let Some(idx) = ledger.locked.remove(&k) {
                self.cache.unlock(idx);
                freed += self.cache.release_branch(idx);
            }
        }
        self.tokens_reclaimed += freed as u64;
        freed
    }

    // -- preemption --------------------------------------------------------

    /// Preempt a problem: drop every pin it holds (prompt included) and
    /// *remember* the pinned tree leaves so [`BatchEngine::try_resume`] can
    /// rebuild the working set. Release is lazy, vLLM-style: the blocks
    /// stay cached but become evictable, so LRU eviction reclaims them only
    /// under actual pressure and an undisturbed resume is free (warm). The
    /// search tree itself is untouched — suspension trades KV residency for
    /// recompute, never search state. Returns the tokens unpinned (the
    /// problem's live KV at suspension).
    pub fn suspend(&mut self, ledger: &mut KvLedger) -> usize {
        let unpinned = self.live_kv(ledger);
        let mut leaves: Vec<(NodeId, NodeIdx)> = ledger.locked.drain().collect();
        // deterministic unlock/re-insert order regardless of map iteration
        leaves.sort_unstable_by_key(|&(leaf, _)| leaf);
        for (leaf, idx) in leaves {
            self.cache.unlock(idx);
            ledger.suspended_leaves.push(leaf);
        }
        if let Some(p) = ledger.prompt_node.take() {
            self.cache.unlock(p);
        }
        self.suspensions += 1;
        unpinned
    }

    /// Token sequences of a suspended ledger's leaves, in suspension order.
    /// Engine-independent: the migration router computes them once per
    /// stuck session and reuses them across every candidate-shard probe.
    pub(crate) fn suspended_sequences(ledger: &KvLedger, tree: &SearchTree) -> Vec<Vec<u32>> {
        ledger
            .suspended_leaves
            .iter()
            .map(|&leaf| Self::sequence(ledger, tree, leaf))
            .collect()
    }

    /// Worst-case blocks a [`BatchEngine::try_resume`] of this suspended
    /// ledger would reserve *on this engine*, given the working-set
    /// sequences from [`BatchEngine::suspended_sequences`]. A suspended
    /// ledger holds no cache node indices — only tree leaves and token ids
    /// — so this is callable against a *different* engine than the one the
    /// session was suspended from: the sharded coordinator sizes a
    /// cross-shard migration by asking each candidate target shard's
    /// engine whether it could cover the resume reservation.
    ///
    /// The reservation is the min of two valid upper bounds: a *cold*
    /// estimate (prompt + the union of suspended tree paths, paged, plus
    /// split slack — tight when everything was evicted) and a *probe*
    /// estimate from read-only `peek_prefix` misses (tight when the cache
    /// is still warm). Residency can only shrink the actual draw below
    /// either bound.
    pub(crate) fn resume_need_blocks_for(
        &self,
        ledger: &KvLedger,
        tree: &SearchTree,
        seqs: &[Vec<u32>],
    ) -> usize {
        // Per-insert split slack is unconditional here, unlike admission:
        // even with minted ids a re-insert can SPLIT — a partially evicted
        // working set lets the first re-inserted leaf coalesce several
        // steps into one radix node, which the next leaf's re-insert then
        // splits at a non-block-aligned step boundary.
        // cold bound: every union node paged separately (the tree root is
        // skipped — its tokens *are* the prompt), + 1 split slack per insert
        let mut seen: HashSet<NodeId> = HashSet::new();
        let mut need_cold = self.cache.blocks_for(ledger.prompt_ids.len()) + 1;
        for &leaf in &ledger.suspended_leaves {
            for n in tree.path(leaf) {
                if tree.get(n).parent.is_some() && seen.insert(n) {
                    need_cold += self.cache.blocks_for(tree.get(n).step.tokens);
                }
            }
            need_cold += 1;
        }
        // probe bound: blocks for each insert's actual prefix miss. The
        // probe is read-only (`peek_prefix`): sizing a resume — possibly
        // against a migration candidate that is never chosen — must not
        // touch LRU clocks and perturb that cache's eviction order.
        let matched = self.cache.peek_prefix(&ledger.prompt_ids);
        let mut need_probe =
            self.cache.blocks_for(ledger.prompt_ids.len() - matched) + 1;
        for seq in seqs {
            let matched = self.cache.peek_prefix(seq);
            need_probe += self.cache.blocks_for(seq.len() - matched) + 1;
        }
        need_cold.min(need_probe)
    }

    /// Resume a suspended problem: reserve a worst-case block need
    /// ([`BatchEngine::resume_need_blocks_for`]), then re-insert and re-pin the
    /// prompt and every suspended leaf's sequence. Tokens the cache no
    /// longer holds are *recomputed* (re-prefilled) — the latency cost the
    /// perf model charges resumed sessions; tokens that survived eviction
    /// re-pin for free. `Err(KvPressure)` leaves everything suspended.
    pub fn try_resume(
        &mut self,
        ledger: &mut KvLedger,
        tree: &SearchTree,
    ) -> Result<ResumeStats, KvPressure> {
        self.try_resume_with(ledger, tree, None)
    }

    /// [`BatchEngine::try_resume`] with an optional [`ImportSource`]: each
    /// re-inserted sequence's *missing* span is intersected with what the
    /// source holds, and the overlap is reported as
    /// [`ResumeStats::imported_tokens`] for the scheduler's
    /// `min(transfer, recompute)` costing. Per-insert capping by that
    /// insert's own `new_tokens` makes the sum exact (inserts dedup against
    /// each other through the cache, so no span is counted twice). The
    /// cache mutation is identical with or without a source.
    pub fn try_resume_with(
        &mut self,
        ledger: &mut KvLedger,
        tree: &SearchTree,
        import: Option<ImportSource<'_>>,
    ) -> Result<ResumeStats, KvPressure> {
        let seqs = Self::suspended_sequences(ledger, tree);
        let need = self.resume_need_blocks_for(ledger, tree, &seqs);
        // MRU-touch the still-cached parts of the working set this resume
        // is about to re-pin: when the reservation below fails, the
        // caller's relieve() pass must evict *other* warm data first, not
        // the very prefix the retried resume wants to reuse. (The sizing
        // probe itself is read-only — it also runs against migration
        // candidates that must not be perturbed.)
        self.cache.match_prefix(&ledger.prompt_ids);
        for seq in &seqs {
            self.cache.match_prefix(seq);
        }
        self.try_reserve(need)?;
        self.cache.release_reservation(need);
        let mut stats = ResumeStats::default();
        self.pending_imports.clear();
        self.pending_restores.clear();
        // The cold-tier-covered tail of one insert's recomputed suffix:
        // clamped to the insert's own fresh child `[shared, len)`, so —
        // like imports — no span is ever counted twice across inserts.
        fn restorable(
            cache: &RadixCache,
            seq: &[u32],
            out: &crate::kvcache::InsertOutcome,
        ) -> Option<PendingRestore> {
            if out.new_tokens == 0 {
                return None;
            }
            let from = cache.cold_probe(seq, out.shared_tokens).max(out.shared_tokens);
            (from < seq.len()).then(|| PendingRestore {
                seq: seq.to_vec(),
                start: from,
                len: seq.len() - from,
                node: out.node,
                node_base: out.shared_tokens,
            })
        }
        // The portion of one insert's recomputed suffix a peer could have
        // shipped instead: the peer's prefix coverage beyond what was
        // already resident locally, capped by what this insert actually
        // added (`new_tokens` are disjoint across the resume's inserts).
        fn importable(
            import: &Option<ImportSource<'_>>,
            seq: &[u32],
            out: &crate::kvcache::InsertOutcome,
        ) -> usize {
            import
                .as_ref()
                .map_or(0, |src| src.available(seq))
                .saturating_sub(out.shared_tokens)
                .min(out.new_tokens)
        }
        let out = self.cache.insert(&ledger.prompt_ids);
        stats.recomputed_tokens += out.new_tokens;
        stats.retained_tokens += out.shared_tokens;
        let n = importable(&import, &ledger.prompt_ids, &out);
        stats.imported_tokens += n;
        if n > 0 {
            self.pending_imports.push(PendingImport {
                seq: ledger.prompt_ids.clone(),
                start: out.shared_tokens,
                len: n,
                node: out.node,
            });
        }
        if let Some(r) = restorable(&self.cache, &ledger.prompt_ids, &out) {
            self.pending_restores.push(r);
        }
        self.cache.lock(out.node);
        ledger.prompt_node = Some(out.node);
        let leaves = std::mem::take(&mut ledger.suspended_leaves);
        for (leaf, seq) in leaves.into_iter().zip(&seqs) {
            let out = self.cache.insert(seq);
            stats.recomputed_tokens += out.new_tokens;
            stats.retained_tokens += out.shared_tokens;
            let n = importable(&import, seq, &out);
            stats.imported_tokens += n;
            if n > 0 {
                self.pending_imports.push(PendingImport {
                    seq: seq.clone(),
                    start: out.shared_tokens,
                    len: n,
                    node: out.node,
                });
            }
            if let Some(r) = restorable(&self.cache, seq, &out) {
                self.pending_restores.push(r);
            }
            self.cache.lock(out.node);
            ledger.locked.insert(leaf, out.node);
        }
        debug_assert!(stats.imported_tokens <= stats.recomputed_tokens);
        debug_assert!(self.restorable_tokens() <= stats.recomputed_tokens);
        self.tokens_admitted += stats.recomputed_tokens as u64;
        self.tokens_recomputed += stats.recomputed_tokens as u64;
        self.resumes += 1;
        Ok(stats)
    }

    /// Execute the decision-gated block copy for the importable spans the
    /// last [`BatchEngine::try_resume_with`] recorded: read each span's
    /// payload words from the source arena and land them in the local one —
    /// the transport plane's actual data movement, bit-identical to the
    /// hash-fill the insert already performed (asserted in debug builds via
    /// [`crate::kvcache::RadixCache::write_node_payload`]). Returns tokens
    /// actually copied; spans whose source evicted them since the sizing
    /// probe (or whose owning shard is unreachable this round) copy nothing
    /// and stay on the already-materialized recompute words.
    pub fn commit_pending_imports(&mut self, src: ImportSource<'_>) -> usize {
        let pending = std::mem::take(&mut self.pending_imports);
        let mut copied = 0usize;
        for p in pending {
            let Some(cache) = src.source_cache(&p.seq) else { continue };
            let Some(words) = cache.read_prefix_payload(&p.seq, p.start, p.len) else {
                continue;
            };
            self.cache.write_node_payload(p.node, 0, &words);
            copied += p.len;
        }
        copied
    }

    /// Drop the last resume's importable-span records: the scheduler priced
    /// the transfer and chose recompute, whose words the insert already
    /// materialized locally. Returns tokens whose copy was skipped.
    pub fn discard_pending_imports(&mut self) -> usize {
        let dropped = self.pending_imports.iter().map(|p| p.len).sum();
        self.pending_imports.clear();
        dropped
    }

    /// Tokens the last [`BatchEngine::try_resume_with`] found restorable
    /// from the cold tier — the input to the scheduler's
    /// [`crate::engine::PerfModel::tier_choice`] decision. Always `<=` the
    /// resume's `recomputed_tokens` (each span is clamped to its insert's
    /// fresh child).
    pub fn restorable_tokens(&self) -> usize {
        self.pending_restores.iter().map(|p| p.len).sum()
    }

    /// Execute the decision-gated cold-tier copies the last
    /// [`BatchEngine::try_resume_with`] recorded: stitch each span's payload
    /// words out of the local [`crate::kvcache::coldtier::SpillArena`] and
    /// land them in the hot arena — bit-identical to the hash-fill the
    /// insert already performed (debug-asserted at the write site). Returns
    /// tokens actually copied; spans the arena's own LRU dropped since the
    /// sizing probe copy nothing and stay on the recompute words.
    pub fn commit_pending_restores(&mut self) -> usize {
        let pending = std::mem::take(&mut self.pending_restores);
        let mut copied = 0usize;
        for p in pending {
            copied += self.cache.restore_node_payload(p.node, &p.seq, p.start, p.node_base);
        }
        copied
    }

    /// Drop the last resume's restorable-span records: the scheduler priced
    /// the PCIe restore and chose recompute, whose words the insert already
    /// materialized locally. Returns tokens whose copy was skipped.
    pub fn discard_pending_restores(&mut self) -> usize {
        let dropped = self.pending_restores.iter().map(|p| p.len).sum();
        self.pending_restores.clear();
        dropped
    }

    /// Close a problem but keep its *prompt* KV cached: decode branches are
    /// released exactly as in [`BatchEngine::close`] (step spans are not
    /// reusable across requests — minted ids never, sampled continuations
    /// practically never — so keeping them warm would only dilute the
    /// cache), while the prompt path is merely unpinned — warm and evictable, like
    /// a suspend that will never resume. This is the SGLang/vLLM
    /// cross-*request* reuse semantic: a future request with the same
    /// prompt re-pins the span for free instead of re-prefilling, which is
    /// what the global prefix hub advertises across shards. LRU eviction
    /// reclaims the warm span under actual pressure. Idempotent.
    pub fn close_keep_cached(&mut self, ledger: &mut KvLedger) {
        let mut freed = 0usize;
        // release decode branches first: the walk-up stops at the prompt
        // path, which is still pinned until the unlock below
        for (_, idx) in ledger.locked.drain() {
            self.cache.unlock(idx);
            freed += self.cache.release_branch(idx);
        }
        ledger.suspended_leaves.clear();
        if let Some(p) = ledger.prompt_node.take() {
            self.cache.unlock(p); // warm, not released — future prompts re-pin it
        }
        self.tokens_reclaimed += freed as u64;
    }

    /// Close a problem: unpin everything it holds (including the prompt) and
    /// free the branches that become unreferenced. Idempotent.
    pub fn close(&mut self, ledger: &mut KvLedger) {
        let mut freed = 0usize;
        for (_, idx) in ledger.locked.drain() {
            self.cache.unlock(idx);
            freed += self.cache.release_branch(idx);
        }
        ledger.suspended_leaves.clear();
        if let Some(p) = ledger.prompt_node.take() {
            self.cache.unlock(p);
            freed += self.cache.release_branch(p);
        }
        self.tokens_reclaimed += freed as u64;
    }

    /// Live (radix-shared) KV tokens of one problem: unique tokens on the
    /// union of its pinned paths. This is the paper's per-step "KV cache
    /// size", read from the cache rather than recomputed from the tree.
    pub fn live_kv(&self, ledger: &KvLedger) -> usize {
        let nodes: Vec<NodeIdx> = ledger.pinned().collect();
        self.cache.path_union_tokens(&nodes)
    }

    /// KV tokens the same frontier would cost a sharing-oblivious server:
    /// every pinned leaf pays its full sequence length.
    pub fn unshared_kv(&self, ledger: &KvLedger) -> usize {
        ledger.locked.values().map(|&n| self.cache.path_tokens(n)).sum()
    }

    /// Unique tokens resident in the shared cache (all problems).
    pub fn live_tokens(&self) -> usize {
        self.cache.live_tokens()
    }

    pub fn used_blocks(&self) -> usize {
        self.cache.used_blocks()
    }

    pub fn total_blocks(&self) -> usize {
        self.cache.total_blocks()
    }

    pub fn block_size(&self) -> usize {
        self.cache.block_size()
    }

    pub fn cache(&self) -> &RadixCache {
        &self.cache
    }

    /// Touch every payload word of this engine's block arena from the
    /// calling thread (see [`RadixCache::fault_in_arena`]) and return the
    /// arena footprint in bytes. The serve workers call this from their
    /// pinned cores so first-touch page placement lands NUMA-local.
    pub fn fault_in_arena(&mut self) -> usize {
        self.cache.fault_in_arena()
    }

    pub fn check_invariants(&self) -> Result<(), String> {
        self.cache.check_invariants()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::StepInfo;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;

    fn child(tree: &mut SearchTree, parent: NodeId, tokens: usize) -> NodeId {
        tree.add_child(parent, StepInfo { tokens, ..Default::default() }, 0.5)
    }

    fn live_step_tokens(t: &SearchTree) -> usize {
        (0..t.len()).filter(|&i| t.get(i).live).map(|i| t.get(i).step.tokens).sum()
    }

    #[test]
    fn admit_then_live_matches_tree_accounting() {
        let mut eng = BatchEngine::new(1 << 20);
        let mut tree = SearchTree::new();
        let root = tree.init_root(100);
        let mut ledger = eng.register(100);
        let a = child(&mut tree, root, 10);
        let b = child(&mut tree, root, 20);
        eng.admit(&mut ledger, &mut tree, &[a, b]);
        assert!(ledger.exact_accounting());
        assert_eq!(eng.live_kv(&ledger), 130);
        assert_eq!(eng.unshared_kv(&ledger), 110 + 120);
        assert_eq!(eng.live_tokens(), 130);
        assert_eq!(eng.live_kv(&ledger), live_step_tokens(&tree));
        eng.check_invariants().unwrap();
    }

    #[test]
    fn expanding_a_leaf_moves_the_pin_to_its_children() {
        let mut eng = BatchEngine::new(1 << 20);
        let mut tree = SearchTree::new();
        let root = tree.init_root(5);
        let mut ledger = eng.register(5);
        let a = child(&mut tree, root, 3);
        eng.admit(&mut ledger, &mut tree, &[a]);
        let c1 = child(&mut tree, a, 7);
        let c2 = child(&mut tree, a, 9);
        eng.admit(&mut ledger, &mut tree, &[c1, c2]);
        assert_eq!(ledger.live_leaves(), 2, "parent pin replaced by children");
        assert_eq!(eng.live_kv(&ledger), 5 + 3 + 7 + 9);
        // the shared prefix (prompt + a) stays pinned through the children
        assert_eq!(eng.unshared_kv(&ledger), (5 + 3 + 7) + (5 + 3 + 9));
        eng.check_invariants().unwrap();
    }

    #[test]
    fn retire_reclaims_pruned_branches_only() {
        let mut eng = BatchEngine::new(1 << 20);
        let mut tree = SearchTree::new();
        let root = tree.init_root(4);
        let mut ledger = eng.register(4);
        let a = child(&mut tree, root, 10);
        let b = child(&mut tree, root, 6);
        eng.admit(&mut ledger, &mut tree, &[a, b]);
        tree.retain_paths(&[a]);
        let freed = eng.retire(&mut ledger, &[a]);
        assert_eq!(freed, 6, "b's exclusive tokens reclaimed");
        assert_eq!(eng.live_kv(&ledger), 14);
        assert_eq!(eng.live_kv(&ledger), live_step_tokens(&tree));
        assert_eq!(eng.live_tokens(), 14);
        eng.check_invariants().unwrap();
    }

    #[test]
    fn close_releases_everything_and_is_idempotent() {
        let mut eng = BatchEngine::new(1 << 20);
        let mut tree = SearchTree::new();
        let root = tree.init_root(8);
        let mut ledger = eng.register(8);
        let a = child(&mut tree, root, 5);
        eng.admit(&mut ledger, &mut tree, &[a]);
        assert!(eng.live_tokens() > 0);
        eng.close(&mut ledger);
        assert_eq!(eng.live_tokens(), 0);
        eng.close(&mut ledger); // second close is a no-op
        assert_eq!(eng.live_tokens(), 0);
        eng.check_invariants().unwrap();
    }

    #[test]
    fn problems_share_one_cache_without_interference() {
        let mut eng = BatchEngine::new(1 << 20);
        let mut t1 = SearchTree::new();
        let mut t2 = SearchTree::new();
        let r1 = t1.init_root(50);
        let r2 = t2.init_root(70);
        let mut l1 = eng.register(50);
        let mut l2 = eng.register(70);
        let a1 = child(&mut t1, r1, 10);
        let a2 = child(&mut t2, r2, 20);
        eng.admit(&mut l1, &mut t1, &[a1]);
        eng.admit(&mut l2, &mut t2, &[a2]);
        assert_eq!(eng.live_kv(&l1), 60);
        assert_eq!(eng.live_kv(&l2), 90);
        assert_eq!(eng.live_tokens(), 150, "disjoint problems sum exactly");
        // retiring problem 1 cannot disturb problem 2's pins
        eng.retire(&mut l1, &[]);
        assert_eq!(eng.live_kv(&l1), 50, "prompt stays pinned until close");
        assert_eq!(eng.live_kv(&l2), 90);
        eng.close(&mut l1);
        eng.close(&mut l2);
        assert_eq!(eng.live_tokens(), 0);
        eng.check_invariants().unwrap();
    }

    #[test]
    fn try_admit_fails_cleanly_under_pressure_and_succeeds_after_relief() {
        // Budget: 8 blocks of 16 tokens. A 64-token prompt takes 4 blocks.
        let mut eng = BatchEngine::with_block_size(16 * 8, 16);
        let mut tree = SearchTree::new();
        let root = tree.init_root(64);
        let mut ledger = eng.register(64);
        assert_eq!(eng.used_blocks(), 4);
        // Two 40-token children need 2 * 3 = 6 blocks (minted ids never
        // split, so no slack) > 4 free.
        let a = child(&mut tree, root, 40);
        let b = child(&mut tree, root, 40);
        let err = eng.try_admit(&mut ledger, &mut tree, &[a, b]).unwrap_err();
        assert_eq!(err.needed_blocks, 6);
        assert_eq!(err.free_blocks, 4);
        // the failed attempt left no partial state behind
        assert_eq!(eng.live_tokens(), 64);
        assert!(tree.get(a).step.token_ids.is_empty(), "no ids minted on failure");
        eng.check_invariants().unwrap();
        // one 40-token child (3 blocks) fits
        eng.try_admit(&mut ledger, &mut tree, &[a]).unwrap();
        assert_eq!(eng.live_kv(&ledger), 104);
        eng.check_invariants().unwrap();
    }

    #[test]
    fn pressure_signals_track_free_and_evictable_blocks() {
        let mut eng = BatchEngine::with_block_size(16 * 16, 16);
        let sig = eng.pressure();
        assert_eq!(sig.total_blocks, 16);
        assert_eq!(sig.free_blocks, 16);
        assert_eq!(sig.evictable_blocks, 0);
        assert!(eng.can_admit(64));
        let mut tree = SearchTree::new();
        let root = tree.init_root(64);
        let mut ledger = eng.register(64);
        let a = child(&mut tree, root, 32);
        eng.admit(&mut ledger, &mut tree, &[a]);
        let sig = eng.pressure();
        assert_eq!(sig.used_blocks, 6);
        assert_eq!(sig.free_blocks, 10);
        assert_eq!(sig.evictable_blocks, 0, "live session fully pinned");
        // closing unpins; branches are reclaimed eagerly so nothing lingers
        eng.close(&mut ledger);
        assert_eq!(eng.pressure().free_blocks, 16);
        eng.check_invariants().unwrap();
    }

    #[test]
    fn suspend_unpins_lazily_and_evicted_working_sets_recompute_on_resume() {
        let mut eng = BatchEngine::with_block_size(1 << 16, 16);
        let mut tree = SearchTree::new();
        let root = tree.init_root(30);
        let mut ledger = eng.register(30);
        let a = child(&mut tree, root, 20);
        let b = child(&mut tree, root, 25);
        eng.admit(&mut ledger, &mut tree, &[a, b]);
        let live_before = eng.live_kv(&ledger);
        assert_eq!(live_before, 75);
        let unpinned = eng.suspend(&mut ledger);
        assert!(ledger.is_suspended());
        assert_eq!(unpinned, 75, "all pins dropped");
        // lazy release: blocks stay cached (warm) but are now evictable
        assert_eq!(eng.live_tokens(), 75);
        assert!(eng.pressure().evictable_blocks > 0);
        // pressure arrives: LRU eviction reclaims the suspended working set
        let freed_blocks = eng.relieve_pressure(usize::MAX);
        assert!(freed_blocks > 0);
        assert_eq!(eng.live_tokens(), 0);
        // resume recomputes exactly what was evicted
        let stats = eng.try_resume(&mut ledger, &tree).unwrap();
        assert!(!ledger.is_suspended());
        assert_eq!(stats.recomputed_tokens, 75);
        assert_eq!(eng.live_kv(&ledger), live_before, "working set restored");
        assert_eq!(eng.unshared_kv(&ledger), (30 + 20) + (30 + 25));
        assert_eq!(ledger.live_leaves(), 2);
        eng.check_invariants().unwrap();
        // a second search step continues normally after the round trip
        let c = child(&mut tree, a, 12);
        eng.admit(&mut ledger, &mut tree, &[c]);
        assert_eq!(eng.live_kv(&ledger), 75 + 12);
        eng.close(&mut ledger);
        assert_eq!(eng.live_tokens(), 0);
    }

    #[test]
    fn evicted_working_sets_restore_from_the_cold_tier_on_resume() {
        // Same pressure story as above, but with a cold tier attached:
        // eviction demotes the suspended working set instead of destroying
        // it, and the resume reports the whole span restorable over PCIe.
        let mut eng = BatchEngine::with_block_size(1 << 16, 16);
        eng.attach_cold_tier(1 << 16);
        let mut tree = SearchTree::new();
        let root = tree.init_root(30);
        let mut ledger = eng.register(30);
        let a = child(&mut tree, root, 20);
        let b = child(&mut tree, root, 25);
        eng.admit(&mut ledger, &mut tree, &[a, b]);
        eng.suspend(&mut ledger);
        assert!(eng.relieve_pressure(usize::MAX) > 0);
        assert_eq!(eng.live_tokens(), 0);
        let cold = eng.cache().cold().unwrap();
        assert_eq!(cold.demoted_tokens(), 75, "every evicted token demoted");
        assert!(cold.used_blocks() > 0);
        // resume accounting is *identical* to the evict-only path — the
        // cold tier changes cost, never what
        let stats = eng.try_resume(&mut ledger, &tree).unwrap();
        assert_eq!(stats.recomputed_tokens, 75);
        assert_eq!(eng.restorable_tokens(), 75, "full working set restorable");
        // restore chosen: stitched copies land bit-identically
        // (debug-asserted inside write_node_payload)
        let copied = eng.commit_pending_restores();
        assert_eq!(copied, 75);
        assert_eq!(eng.commit_pending_restores(), 0);
        assert_eq!(eng.cache().cold().unwrap().restored_tokens(), 75);
        eng.close(&mut ledger);
        eng.check_invariants().unwrap();
    }

    #[test]
    fn undisturbed_resume_is_warm_and_free() {
        let mut eng = BatchEngine::with_block_size(1 << 16, 16);
        let mut tree = SearchTree::new();
        let root = tree.init_root(30);
        let mut ledger = eng.register(30);
        let a = child(&mut tree, root, 20);
        eng.admit(&mut ledger, &mut tree, &[a]);
        eng.suspend(&mut ledger);
        // nothing else ran, so nothing was evicted: resume is free
        let stats = eng.try_resume(&mut ledger, &tree).unwrap();
        assert_eq!(stats.recomputed_tokens, 0, "cache still warm");
        assert!(stats.retained_tokens > 0);
        assert_eq!(eng.live_kv(&ledger), 50);
        eng.close(&mut ledger);
        assert_eq!(eng.live_tokens(), 0);
    }

    #[test]
    fn resume_fails_with_pressure_when_the_working_set_cannot_fit() {
        let mut eng = BatchEngine::with_block_size(16 * 8, 16); // 8 blocks
        let mut tree = SearchTree::new();
        let root = tree.init_root(48);
        let mut ledger = eng.register(48); // 3 blocks
        let a = child(&mut tree, root, 30);
        eng.admit(&mut ledger, &mut tree, &[a]); // +2 blocks
        eng.suspend(&mut ledger);
        // flush the suspended working set so another problem can hog it
        assert!(eng.relieve_pressure(usize::MAX) >= 5);
        assert_eq!(eng.used_blocks(), 0);
        let mut tree2 = SearchTree::new();
        tree2.init_root(96);
        let mut hog = eng.register(96); // 6 blocks
        let err = eng.try_resume(&mut ledger, &tree).unwrap_err();
        // cold need: prompt (3+1 slack) + a's node (2) + a's slack (1) = 7
        // (resume slack is unconditional: re-inserts can split)
        assert_eq!(err.needed_blocks, 7);
        assert!(err.needed_blocks > err.free_blocks, "{err}");
        assert!(ledger.is_suspended(), "failed resume stays suspended");
        eng.close(&mut hog);
        let stats = eng.try_resume(&mut ledger, &tree).unwrap();
        assert_eq!(stats.recomputed_tokens, 78, "full working set recomputed");
        eng.close(&mut ledger);
        eng.check_invariants().unwrap();
    }

    #[test]
    fn resume_reports_importable_span_from_a_peer_cache() {
        // Suspend on engine A, evict, then resume on engine B while A still
        // holds the working set warm: everything B recomputes is importable
        // from A — and the import signal changes no accounting.
        let mut src = BatchEngine::for_shard(1 << 16, 16, 0, 2);
        let mut tree = SearchTree::new();
        let root = tree.init_root(32);
        let mut ledger = src.register(32);
        let a = child(&mut tree, root, 20);
        src.admit(&mut ledger, &mut tree, &[a]);
        src.suspend(&mut ledger);
        // resume on a different shard's engine, importing from the source
        let mut dst = BatchEngine::for_shard(1 << 16, 16, 1, 2);
        let stats = dst
            .try_resume_with(
                &mut ledger,
                &tree,
                Some(ImportSource::Peer { cache: src.cache() }),
            )
            .unwrap();
        assert_eq!(stats.recomputed_tokens, 52, "cold target recomputes everything");
        assert_eq!(
            stats.imported_tokens, 52,
            "the warm source covers the full recomputed span"
        );
        assert_eq!(dst.live_kv(&ledger), 52);
        // transfer chosen: the transport plane moves the actual words —
        // and they are bit-identical to the local hash-fill (debug-asserted
        // inside write_node_payload)
        let copied = dst.commit_pending_imports(ImportSource::Peer { cache: src.cache() });
        assert_eq!(copied, 52, "every importable token must ship");
        assert_eq!(dst.commit_pending_imports(ImportSource::Peer { cache: src.cache() }), 0);
        dst.close(&mut ledger);
        dst.check_invariants().unwrap();
        src.check_invariants().unwrap();
    }

    #[test]
    fn hub_import_skips_own_shard_and_respects_block_granularity() {
        use crate::kvcache::prefixhub::PrefixHub;
        let mut eng = BatchEngine::with_block_size(1 << 16, 16);
        let mut tree = SearchTree::new();
        tree.init_root(32);
        let prompt_ids: Vec<u32> = (0..32).map(|t| 500_000 + t).collect();
        let mut ledger = eng.register_with_prompt(prompt_ids.clone());
        eng.suspend(&mut ledger);
        eng.relieve_pressure(usize::MAX); // cold resume
        let mut hub = PrefixHub::new(16);
        hub.begin_round();
        hub.publish(3, &prompt_ids, 32);
        // entries owned by the local shard are not importable
        let stats = eng
            .try_resume_with(
                &mut ledger,
                &tree,
                Some(ImportSource::Hub { hub: &hub, local_shard: 3, peers: &[] }),
            )
            .unwrap();
        assert_eq!(stats.recomputed_tokens, 32);
        assert_eq!(stats.imported_tokens, 0, "own-shard entries never import");
        // a peer's entry imports the whole-block overlap of the recompute
        eng.suspend(&mut ledger);
        eng.relieve_pressure(usize::MAX);
        let stats = eng
            .try_resume_with(
                &mut ledger,
                &tree,
                Some(ImportSource::Hub { hub: &hub, local_shard: 1, peers: &[] }),
            )
            .unwrap();
        assert_eq!(stats.imported_tokens, 32);
        assert!(stats.imported_tokens <= stats.recomputed_tokens);
        eng.close(&mut ledger);
        eng.check_invariants().unwrap();
    }

    #[test]
    fn hub_transport_copies_from_the_owning_peer_or_falls_back() {
        use crate::kvcache::prefixhub::PrefixHub;
        // shard 3 holds the span; shard 1 resumes cold and imports via hub
        let mut owner = BatchEngine::for_shard(1 << 16, 16, 3, 4);
        let prompt_ids: Vec<u32> = (0..32).map(|t| 700_000 + t).collect();
        let _owner_ledger = owner.register_with_prompt(prompt_ids.clone());
        let mut eng = BatchEngine::for_shard(1 << 16, 16, 1, 4);
        let mut tree = SearchTree::new();
        tree.init_root(32);
        let mut ledger = eng.register_with_prompt(prompt_ids.clone());
        eng.suspend(&mut ledger);
        eng.relieve_pressure(usize::MAX); // cold resume
        let mut hub = PrefixHub::new(16);
        hub.begin_round();
        hub.publish(3, &prompt_ids, 32);
        let peers: Vec<Option<&crate::kvcache::RadixCache>> =
            vec![None, None, None, Some(owner.cache())];
        let src = ImportSource::Hub { hub: &hub, local_shard: 1, peers: &peers };
        let stats = eng.try_resume_with(&mut ledger, &tree, Some(src)).unwrap();
        assert_eq!(stats.imported_tokens, 32);
        let copied = eng.commit_pending_imports(src);
        assert_eq!(copied, 32, "the owning peer's arena must ship the span");
        // an unreachable owner (no peer slot) copies nothing — the local
        // hash-fill words already materialized, so this is a safe fallback
        eng.suspend(&mut ledger);
        eng.relieve_pressure(usize::MAX);
        let dark = ImportSource::Hub { hub: &hub, local_shard: 1, peers: &[] };
        let stats = eng.try_resume_with(&mut ledger, &tree, Some(dark)).unwrap();
        assert_eq!(stats.imported_tokens, 32, "costing signal is peer-blind");
        assert_eq!(eng.commit_pending_imports(dark), 0);
        // and a recompute decision just drops the records
        eng.suspend(&mut ledger);
        eng.relieve_pressure(usize::MAX);
        let stats = eng.try_resume_with(&mut ledger, &tree, Some(src)).unwrap();
        assert_eq!(stats.imported_tokens, 32);
        assert_eq!(eng.discard_pending_imports(), 32);
        assert_eq!(eng.commit_pending_imports(src), 0, "discard clears the queue");
        eng.close(&mut ledger);
        eng.check_invariants().unwrap();
        owner.check_invariants().unwrap();
    }

    #[test]
    fn prop_cache_accounting_tracks_random_trees() {
        property(60, |rng: &mut Rng| {
            let mut eng = BatchEngine::new(1 << 20);
            let mut tree = SearchTree::new();
            let prompt = 1 + rng.index(40);
            let root = tree.init_root(prompt);
            let mut ledger = eng.register(prompt);
            let mut frontier = vec![root];
            for _ in 0..(1 + rng.index(6)) {
                // expand a random subset of the frontier, then retire to it
                let keep: Vec<NodeId> = frontier
                    .iter()
                    .copied()
                    .filter(|_| rng.chance(0.7))
                    .collect();
                let keep = if keep.is_empty() { vec![frontier[0]] } else { keep };
                tree.retain_paths(&keep);
                eng.retire(&mut ledger, &keep);
                // occasionally suspend + resume mid-search: the round trip
                // must be invisible to the accounting
                if rng.chance(0.3) {
                    eng.suspend(&mut ledger);
                    eng.try_resume(&mut ledger, &tree).map_err(|e| e.to_string())?;
                }
                let mut next = vec![];
                for &leaf in &keep {
                    let fanout = 1 + rng.index(4);
                    let children: Vec<NodeId> = (0..fanout)
                        .map(|_| child(&mut tree, leaf, 1 + rng.index(30)))
                        .collect();
                    eng.admit(&mut ledger, &mut tree, &children);
                    next.extend(children);
                }
                frontier = next;
                // the step-level invariant: cache view == tree truth
                crate::prop_check!(
                    eng.live_kv(&ledger) == live_step_tokens(&tree),
                    "cache {} != tree {}",
                    eng.live_kv(&ledger),
                    live_step_tokens(&tree)
                );
                crate::prop_check!(
                    eng.live_tokens() == eng.live_kv(&ledger),
                    "single problem must own the whole cache"
                );
                crate::prop_check!(
                    eng.live_kv(&ledger) <= eng.unshared_kv(&ledger) + prompt,
                    "shared exceeded unshared"
                );
                eng.check_invariants()?;
            }
            eng.close(&mut ledger);
            crate::prop_check!(eng.live_tokens() == 0, "close left tokens");
            Ok(())
        });
    }
}
