//! PJRT-backed providers: the real (tiny) transformer LM, PRM head, and
//! sentence embedder running through the AOT artifacts — Python never runs
//! here. These power the end-to-end serving example and the wall-clock
//! throughput measurements.
//!
//! Serving shape: one prefill per expansion prefix, then batched lock-step
//! decode (batch = the compiled `lm_decode_b{B}` variant) sampling with
//! temperature 1.0 until the step separator token or the per-step cap. KV
//! states are host-resident `[L, H, S, D]` buffers handed to PJRT per call;
//! a per-node cache avoids re-prefilling shared prefixes (the radix-sharing
//! benefit, at step granularity).

use crate::kvcache::RadixCache;
use crate::lm::{PendingBatch, StepGenerator};
use crate::reward::RewardModel;
use crate::embed::Embedder;
use crate::runtime::{lit_f32, lit_i32, to_vec_f32, Artifacts};
use crate::tree::{NodeId, SearchTree, StepInfo};
use crate::util::error::Result;
use crate::util::rng::Rng;
use std::collections::{HashMap, VecDeque};
use std::rc::Rc;

/// Step separator token id (ends a reasoning step).
pub const SEP_TOKEN: u32 = 1;

/// KV state of one sequence: `[L, H, S, D]` flattened, plus valid length.
#[derive(Clone)]
struct KvState {
    k: Vec<f32>,
    v: Vec<f32>,
    len: usize,
}

/// Configuration for the PJRT LM.
#[derive(Clone, Debug)]
pub struct PjrtLmConfig {
    /// Max new tokens per reasoning step.
    pub max_step_tokens: usize,
    /// Steps until a trajectory terminates.
    pub max_depth: usize,
    /// Sampling temperature.
    pub temperature: f64,
    /// Decode batch variant to use (must be one of meta's `lm_batches`).
    pub batch: usize,
}

impl Default for PjrtLmConfig {
    fn default() -> Self {
        Self { max_step_tokens: 10, max_depth: 3, temperature: 1.0, batch: 4 }
    }
}

/// The AOT transformer as a [`StepGenerator`].
pub struct PjrtLm {
    arts: Rc<Artifacts>,
    pub cfg: PjrtLmConfig,
    prompt: Vec<u32>,
    rng: Rng,
    /// leaf node -> its sequence KV (populated as children are expanded).
    node_kv: HashMap<NodeId, KvState>,
    /// (parent, paraphrase tag) -> child KV, claimed when the child becomes
    /// a leaf that gets expanded.
    pending: HashMap<(NodeId, u64), KvState>,
    /// Radix accounting of unique cached tokens (SGLang-style bookkeeping).
    pub radix: RadixCache,
    /// Double buffer for the two-phase submit/poll surface: results of
    /// in-flight batches keyed by ticket, in submission order. Capacity 2
    /// (the classic double buffer): one batch being committed by the
    /// scheduler while the next one decodes.
    in_flight: VecDeque<(u64, Vec<Vec<StepInfo>>)>,
    next_ticket: u64,
    /// Telemetry.
    pub decode_calls: u64,
    pub prefill_calls: u64,
}

impl PjrtLm {
    /// `prompt` token ids (without padding); `seed` drives sampling.
    pub fn new(arts: Rc<Artifacts>, prompt: Vec<u32>, seed: u64, cfg: PjrtLmConfig) -> Self {
        assert!(
            arts.dims.lm_batches.contains(&cfg.batch),
            "no lm_decode_b{} artifact",
            cfg.batch
        );
        Self {
            arts,
            cfg,
            prompt,
            rng: Rng::new(seed),
            node_kv: HashMap::new(),
            pending: HashMap::new(),
            radix: RadixCache::new(1 << 22),
            in_flight: VecDeque::new(),
            next_ticket: 0,
            decode_calls: 0,
            prefill_calls: 0,
        }
    }

    fn kv_elems(&self) -> usize {
        let d = &self.arts.dims;
        d.n_layers * d.n_heads * d.max_seq * d.head_dim
    }

    /// Full token sequence for a node (prompt + steps along the path).
    fn sequence(&self, tree: &SearchTree, node: NodeId) -> Vec<u32> {
        let mut seq = self.prompt.clone();
        for n in tree.path(node) {
            seq.extend_from_slice(&tree.get(n).step.token_ids);
        }
        seq
    }

    /// Get (or compute by prefill) the KV state for a leaf.
    fn leaf_kv(&mut self, tree: &SearchTree, leaf: NodeId) -> Result<KvState> {
        if let Some(kv) = self.node_kv.get(&leaf) {
            return Ok(kv.clone());
        }
        // claim from pending if this leaf was produced by us
        if let Some(parent) = tree.get(leaf).parent {
            let key = (parent, tree.get(leaf).step.paraphrase);
            if let Some(kv) = self.pending.remove(&key) {
                self.node_kv.insert(leaf, kv.clone());
                return Ok(kv);
            }
        }
        // prefill the full sequence
        let d = self.arts.dims.clone();
        let seq = self.sequence(tree, leaf);
        assert!(seq.len() <= d.max_seq, "sequence overflows max_seq");
        let mut tokens = vec![0i32; d.max_seq];
        for (i, &t) in seq.iter().enumerate() {
            tokens[i] = t as i32;
        }
        let exe = self.arts.executable("lm_prefill_b1")?;
        let out = exe.run(&[
            lit_i32(&tokens, &[1, d.max_seq as i64])?,
            lit_i32(&[seq.len() as i32], &[1])?,
        ])?;
        self.prefill_calls += 1;
        let kv = KvState {
            k: to_vec_f32(&out[1])?,
            v: to_vec_f32(&out[2])?,
            len: seq.len(),
        };
        self.node_kv.insert(leaf, kv.clone());
        Ok(kv)
    }

    /// Sample from logits with temperature.
    fn sample(&mut self, logits: &[f32]) -> u32 {
        let t = self.cfg.temperature.max(1e-3);
        let weights: Vec<f64> = {
            let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
            logits.iter().map(|&l| ((l as f64 - m) / t).exp()).collect()
        };
        // never emit padding token 0; SEP stays samplable
        let mut w = weights;
        w[0] = 0.0;
        self.rng.weighted(&w) as u32
    }
}

impl StepGenerator for PjrtLm {
    fn expand(&mut self, tree: &SearchTree, leaf: NodeId, n: usize) -> Vec<StepInfo> {
        let d = self.arts.dims.clone();
        let b = self.cfg.batch;
        let base_kv = self.leaf_kv(tree, leaf).expect("prefill failed");
        let depth = tree.depth(leaf);
        let is_last = depth + 1 >= self.cfg.max_depth;
        let kvn = self.kv_elems();
        let mut out = Vec::with_capacity(n);
        let decode = self.arts.executable(&format!("lm_decode_b{b}")).expect("decode exe");

        for chunk_start in (0..n).step_by(b) {
            let chunk = (n - chunk_start).min(b);
            // replicate the leaf KV into b slots
            let mut k = Vec::with_capacity(b * kvn);
            let mut v = Vec::with_capacity(b * kvn);
            for _ in 0..b {
                k.extend_from_slice(&base_kv.k);
                v.extend_from_slice(&base_kv.v);
            }
            let mut lens = vec![base_kv.len; b];
            let mut seqs: Vec<Vec<u32>> = vec![vec![]; b];
            let mut done = vec![false; b];
            // lanes beyond `chunk` are padding lanes: run but discard
            // lock-step decode
            let mut last_tokens = vec![SEP_TOKEN as i32; b];
            for _ in 0..self.cfg.max_step_tokens {
                if done.iter().take(chunk).all(|&x| x) {
                    break;
                }
                if lens.iter().any(|&l| l >= d.max_seq) {
                    break;
                }
                let pos: Vec<i32> = lens.iter().map(|&l| l as i32).collect();
                let outb = decode
                    .run(&[
                        lit_i32(&last_tokens, &[b as i64]).unwrap(),
                        lit_i32(&pos, &[b as i64]).unwrap(),
                        lit_f32(&k, &[b as i64, d.n_layers as i64, d.n_heads as i64, d.max_seq as i64, d.head_dim as i64])
                            .unwrap(),
                        lit_f32(&v, &[b as i64, d.n_layers as i64, d.n_heads as i64, d.max_seq as i64, d.head_dim as i64])
                            .unwrap(),
                    ])
                    .expect("decode failed");
                self.decode_calls += 1;
                let logits = to_vec_f32(&outb[0]).unwrap();
                k = to_vec_f32(&outb[1]).unwrap();
                v = to_vec_f32(&outb[2]).unwrap();
                for lane in 0..b {
                    if done[lane] {
                        continue;
                    }
                    let tok = self.sample(&logits[lane * d.vocab..(lane + 1) * d.vocab]);
                    lens[lane] += 1;
                    last_tokens[lane] = tok as i32;
                    if tok == SEP_TOKEN {
                        done[lane] = true;
                    } else {
                        seqs[lane].push(tok);
                    }
                }
            }
            // build StepInfos + stash child KV
            for lane in 0..chunk {
                let toks = seqs[lane].clone();
                let paraphrase = self.rng.next_u64();
                let sem = toks.iter().fold(0u64, |h, &t| {
                    h.wrapping_mul(131).wrapping_add(t as u64)
                });
                let answer = if is_last {
                    Some(*toks.last().unwrap_or(&0) as i64)
                } else {
                    None
                };
                // per-lane KV slice
                let kv = KvState {
                    k: k[lane * kvn..(lane + 1) * kvn].to_vec(),
                    v: v[lane * kvn..(lane + 1) * kvn].to_vec(),
                    len: lens[lane],
                };
                self.pending.insert((leaf, paraphrase), kv);
                // radix accounting of the full sequence
                let mut full = self.sequence(tree, leaf);
                full.extend_from_slice(&toks);
                self.radix.insert(&full);
                out.push(StepInfo {
                    tokens: toks.len().max(1),
                    sem,
                    paraphrase,
                    token_ids: toks,
                    terminal: is_last,
                    answer,
                    path_id: sem ^ (leaf as u64) << 32,
                    alive: false, // unknown for a real LM
                });
            }
        }
        out
    }

    /// Two-phase submit: decode the batch into the double buffer and hand
    /// back a ticket. PJRT executions in the shim are host-synchronous, so
    /// the work runs eagerly here; the *surface* is what matters — the serve
    /// scheduler submits shard *k+1*'s decode before polling shard *k*'s,
    /// and a backend with truly async PJRT donation (or a network hop) slots in
    /// behind the same ticket protocol with no scheduler change. The buffer
    /// holds at most two batches (double buffering): submitting a third
    /// while two are un-polled is a scheduler bug and panics.
    fn submit_batch(&mut self, tree: &SearchTree, requests: &[(NodeId, usize)]) -> PendingBatch {
        assert!(
            self.in_flight.len() < 2,
            "PjrtLm double buffer overflow: poll before submitting a third batch"
        );
        let results = self.expand_batch(tree, requests);
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        self.in_flight.push_back((ticket, results));
        PendingBatch::Ticket(ticket)
    }

    /// Two-phase poll: redeem a ticket from the double buffer. Tickets must
    /// be polled in submission order (the buffer is a FIFO).
    fn poll_batch(&mut self, batch: PendingBatch) -> Vec<Vec<StepInfo>> {
        match batch {
            PendingBatch::Ready(results) => results,
            PendingBatch::Ticket(id) => {
                let (front, results) =
                    self.in_flight.pop_front().expect("poll_batch: no batch in flight");
                assert_eq!(front, id, "PjrtLm tickets must be polled in order");
                results
            }
        }
    }

    fn prompt_tokens(&self) -> usize {
        self.prompt.len()
    }

    fn prompt_token_ids(&self) -> Option<Vec<u32>> {
        Some(self.prompt.clone())
    }
}

/// The AOT PRM head as a [`RewardModel`].
pub struct PjrtPrm {
    arts: Rc<Artifacts>,
    prompt: Vec<u32>,
    pub calls: u64,
}

impl PjrtPrm {
    pub fn new(arts: Rc<Artifacts>, prompt: Vec<u32>) -> Self {
        Self { arts, prompt, calls: 0 }
    }
}

impl RewardModel for PjrtPrm {
    fn score(&mut self, tree: &SearchTree, nodes: &[NodeId]) -> Vec<f64> {
        let d = self.arts.dims.clone();
        let b = d.prm_batch;
        let exe = self.arts.executable(&format!("prm_score_b{b}")).expect("prm exe");
        let mut scores = Vec::with_capacity(nodes.len());
        for chunk in nodes.chunks(b) {
            let mut tokens = vec![0i32; b * d.max_seq];
            let mut lens = vec![1i32; b];
            for (lane, &node) in chunk.iter().enumerate() {
                let mut seq = self.prompt.clone();
                for n in tree.path(node) {
                    seq.extend_from_slice(&tree.get(n).step.token_ids);
                }
                seq.truncate(d.max_seq);
                for (i, &t) in seq.iter().enumerate() {
                    tokens[lane * d.max_seq + i] = t as i32;
                }
                lens[lane] = seq.len().max(1) as i32;
            }
            let out = exe
                .run(&[
                    lit_i32(&tokens, &[b as i64, d.max_seq as i64]).unwrap(),
                    lit_i32(&lens, &[b as i64]).unwrap(),
                ])
                .expect("prm failed");
            self.calls += 1;
            let s = to_vec_f32(&out[0]).unwrap();
            for lane in 0..chunk.len() {
                scores.push(s[lane] as f64);
            }
        }
        scores
    }
}

/// The AOT sentence encoder as an [`Embedder`].
pub struct PjrtEmbedder {
    arts: Rc<Artifacts>,
    pub calls: u64,
}

impl PjrtEmbedder {
    pub fn new(arts: Rc<Artifacts>) -> Self {
        Self { arts, calls: 0 }
    }
}

impl Embedder for PjrtEmbedder {
    fn embed(&mut self, tree: &SearchTree, nodes: &[NodeId]) -> Vec<Vec<f32>> {
        let d = self.arts.dims.clone();
        let (b, se, de) = (d.embed_batch, d.embed_max_seq, d.embed_out_dim);
        let exe = self.arts.executable(&format!("embed_b{b}")).expect("embed exe");
        let mut out = Vec::with_capacity(nodes.len());
        for chunk in nodes.chunks(b) {
            let mut tokens = vec![0i32; b * se];
            let mut lens = vec![1i32; b];
            for (lane, &node) in chunk.iter().enumerate() {
                let ids = &tree.get(node).step.token_ids;
                let l = ids.len().min(se);
                for i in 0..l {
                    tokens[lane * se + i] = ids[i] as i32;
                }
                lens[lane] = l.max(1) as i32;
            }
            let res = exe
                .run(&[
                    lit_i32(&tokens, &[b as i64, se as i64]).unwrap(),
                    lit_i32(&lens, &[b as i64]).unwrap(),
                ])
                .expect("embed failed");
            self.calls += 1;
            let e = to_vec_f32(&res[0]).unwrap();
            for lane in 0..chunk.len() {
                out.push(e[lane * de..(lane + 1) * de].to_vec());
            }
        }
        out
    }

    fn dim(&self) -> usize {
        self.arts.dims.embed_out_dim
    }
}
