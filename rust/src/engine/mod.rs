//! Serving engine: the batched expansion engine that routes all KV
//! accounting through the shared radix cache, the H100 roofline performance
//! model (Fig. 2 / Table 2 substitution), and — behind the `pjrt` feature —
//! the PJRT-backed providers that run the real AOT transformer on the
//! request path.

pub mod batch;
pub mod perfmodel;
#[cfg(feature = "pjrt")]
pub mod pjrt_lm;

pub use batch::{
    BatchEngine, ExpandRequest, ImportSource, KvLedger, PressureSignals, ResumeStats,
    DEFAULT_KV_CAPACITY,
};
pub use perfmodel::{
    BatchStats, Hardware, LatencyEstimate, PerfModel, RoundCost, TransferDecision,
    COLD_LINK_BW_DEFAULT, H100_NVL,
};
