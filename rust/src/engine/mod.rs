//! Serving engine: the H100 roofline performance model (Fig. 2 / Table 2
//! substitution) and the PJRT-backed providers that run the real AOT
//! transformer on the request path.

pub mod perfmodel;
pub mod pjrt_lm;

pub use perfmodel::{Hardware, LatencyEstimate, PerfModel, H100_NVL};
