//! Memory-bandwidth-bound serving performance model (H100-class roofline).
//!
//! The paper's Fig. 2 point: search runtime tracks *bytes moved* (weights +
//! unique KV), not FLOPs or model calls, because generative decoding is
//! memory-bandwidth-bound. This model replays a [`SearchOutcome`]'s per-step
//! records through a roofline of a serving node and reports estimated
//! latency — the substitution for the paper's 2×H100-NVL testbed.
//!
//! Per decode iteration of one search step (batch = live continuations):
//!   * weight bytes are read once (amortized over the whole batch),
//!   * the step's *unique* KV bytes are read once when the server exploits
//!     radix/tree sharing (`shared_kv = true`, the SGLang setting), else the
//!     per-sequence duplicated KV is read,
//!   * compute time = 2 · params · batch / peak_flops (never dominant here),
//!   * if the KV working set exceeds free HBM, the batch fragments into
//!     waves, each re-reading the weights — the second Fig. 2 effect.

use crate::search::{SearchOutcome, StepMetrics};
use crate::workload::ModelProfile;

/// Serving hardware description.
#[derive(Clone, Debug)]
pub struct Hardware {
    pub name: &'static str,
    /// HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// HBM capacity, bytes.
    pub mem_cap: f64,
    /// Peak dense compute, FLOP/s (bf16).
    pub peak_flops: f64,
    /// Inter-device interconnect bandwidth, bytes/s (NVLink-class): what a
    /// cross-shard KV block transfer pays per byte instead of a recompute
    /// prefill. Serving replicas modeled by shards are assumed link-peers.
    pub link_bw: f64,
}

/// NVIDIA H100 NVL (the paper's testbed GPU; NVLink 4 pairs).
pub const H100_NVL: Hardware = Hardware {
    name: "h100-nvl",
    mem_bw: 3.35e12,
    mem_cap: 94.0e9,
    peak_flops: 1.6e15,
    link_bw: 0.9e12,
};

/// Default host link for the cold KV tier: a PCIe gen5 x16-class lane
/// (~64 GB/s), the path a demoted span pays to come back from host DRAM.
pub const COLD_LINK_BW_DEFAULT: f64 = 64.0e9;

/// Performance-model configuration for one serving setup.
#[derive(Clone, Debug)]
pub struct PerfModel {
    pub hw: Hardware,
    /// Does the serving stack exploit radix/tree KV sharing (SGLang)?
    pub shared_kv: bool,
    /// Problems co-scheduled on the node (the paper's "parallel threads").
    pub threads: usize,
    /// Host↔device link bandwidth for the cold KV tier, bytes/s (PCIe-class
    /// — an order of magnitude under [`Hardware::link_bw`]): what a
    /// demote-to-host spill or a cold-tier restore pays per byte instead of
    /// a recompute prefill. Set via [`PerfModel::cold_linked`].
    pub cold_link_bw: f64,
}

/// Latency estimate for one problem's search.
#[derive(Clone, Debug, Default)]
pub struct LatencyEstimate {
    pub seconds: f64,
    /// Total bytes moved (weights + KV reads).
    pub bytes_moved: f64,
    /// Number of batch fragmentation waves beyond 1 across all steps.
    pub extra_waves: u64,
}

/// Aggregate shape of one engine batch — possibly many problems' expansions
/// decoding in lockstep through one [`crate::engine::BatchEngine`]. This is
/// what the multi-problem `serve` path costs per round (real continuous
/// batching, as opposed to [`PerfModel::latency`]'s per-problem replay).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BatchStats {
    /// Sequences decoding in lockstep (continuations sampled this round).
    pub model_calls: usize,
    /// Tokens emitted by the whole batch.
    pub new_tokens: usize,
    /// KV tokens read per decode iteration. Under radix sharing this is the
    /// engine cache's unique resident set; without sharing it is the
    /// duplicated per-sequence footprint.
    pub read_kv_tokens: usize,
    /// Unique KV tokens resident on the node (drives wave fragmentation).
    pub resident_kv_tokens: usize,
    /// Tokens re-prefilled this round to rebuild the KV of sessions resumed
    /// after preemption (recompute-for-resume; charged as a compute-bound
    /// prefill pass plus the KV write traffic, ahead of the decode).
    pub recompute_prefill_tokens: usize,
    /// Tokens whose KV was *imported* from a peer shard this round instead
    /// of recomputed: the prefix-hub resume/migration path found the span
    /// resident on a peer and the `min(transfer, recompute)` decision chose
    /// the block copy. Charged as paged KV bytes over the interconnect
    /// ([`Hardware::link_bw`]) plus the local HBM write, on the plan+commit
    /// side of the pipeline boundary.
    pub transfer_kv_tokens: usize,
    /// Tokens whose KV was *restored* from the host-DRAM cold tier this
    /// round instead of recomputed: eviction had demoted the span
    /// (payload copied out, blocks freed) and the `min(restore, recompute)`
    /// [`PerfModel::tier_choice`] decision chose the PCIe copy back.
    /// Charged as paged KV bytes over [`PerfModel::cold_link_bw`] plus the
    /// local HBM write, on the plan+commit side of the pipeline boundary.
    pub restored_kv_tokens: usize,
    /// KV block size of the paged allocator, in tokens. Memory is charged
    /// per *block*, not per token: a partially filled page still moves and
    /// occupies the whole page. 0 is treated as 1 (token granularity).
    pub block_size: usize,
    /// Modeled decode-side seconds the round's generator backend adds on
    /// top of the roofline ([`crate::lm::StepGenerator::decode_overhead_seconds`]:
    /// network hops, kernel-launch tails, injected test latency). Charged
    /// once per round, on the decode side of the pipeline boundary.
    pub injected_decode_seconds: f64,
}

/// Cost of one serve round on one shard, decomposed at the *pipeline
/// boundary* the plan → decode → commit split creates:
///
/// * `decode_seconds` — the generator-bound part: lockstep decode
///   iterations on the accelerator, plus any backend-injected decode
///   overhead. This is the only phase that touches the [`crate::lm::StepGenerator`].
/// * `overhead_seconds` — plan + commit: the recompute-prefill pass for
///   sessions resumed this round, plus the paged KV *write* traffic of the
///   round's newly committed tokens (the commit phase materializes the
///   decode's KV into the radix cache's blocks).
///
/// A lockstep round pays the phases back to back; a pipelined round
/// overlaps shard *k+1*'s decode with shard *k*'s plan + commit on the
/// same accelerator timeline, so it pays only its slower phase.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundCost {
    pub decode_seconds: f64,
    pub overhead_seconds: f64,
    /// Total bytes moved by both phases (reads + commit writes).
    pub bytes_moved: f64,
    /// Batch fragmentation waves beyond 1 across the decode iterations.
    pub extra_waves: u64,
}

impl RoundCost {
    /// Lockstep round: plan + commit then decode, serialized.
    pub fn lockstep_seconds(&self) -> f64 {
        self.decode_seconds + self.overhead_seconds
    }

    /// Pipelined round: decode overlaps the neighbouring shard's
    /// plan + commit — the round costs `max(decode, plan + commit)`.
    pub fn pipelined_seconds(&self) -> f64 {
        self.decode_seconds.max(self.overhead_seconds)
    }

    pub fn seconds(&self, pipelined: bool) -> f64 {
        if pipelined {
            self.pipelined_seconds()
        } else {
            self.lockstep_seconds()
        }
    }
}

/// Block-seconds of one serve round: the round's modeled wall-clock
/// weighted by the KV blocks resident while it ran. Summed over a serve
/// this is the denominator of the adaptive budget controller's objective —
/// expected accuracy per modeled block-second — and the unit the
/// adaptive-budget bench holds fixed when comparing against the static
/// baseline.
pub fn block_seconds(used_blocks: usize, seconds: f64) -> f64 {
    used_blocks as f64 * seconds
}

/// The two modeled ways to rebuild an evicted-or-absent KV span that a peer
/// shard still holds, costed by [`PerfModel::import_choice`]: copy the
/// blocks over the interconnect, or recompute the prefill locally. The serve
/// scheduler picks the cheaper one per import and records the choice.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TransferDecision {
    /// Paged KV bytes over [`Hardware::link_bw`] plus the local HBM write.
    pub transfer_seconds: f64,
    /// A local recompute prefill of the same span (one weight read, the
    /// span's compute, its paged KV write) — the pre-hub charge.
    pub recompute_seconds: f64,
}

impl TransferDecision {
    /// True when the block copy beats recomputing the prefill.
    pub fn use_transfer(&self) -> bool {
        self.transfer_seconds < self.recompute_seconds
    }

    /// Seconds of the chosen (cheaper) path.
    pub fn chosen_seconds(&self) -> f64 {
        self.transfer_seconds.min(self.recompute_seconds)
    }
}

impl PerfModel {
    pub fn new(hw: Hardware, shared_kv: bool, threads: usize) -> Self {
        Self { hw, shared_kv, threads: threads.max(1), cold_link_bw: COLD_LINK_BW_DEFAULT }
    }

    /// Override the cold-tier host link bandwidth (bytes/s) — the CLI's
    /// `--cold-link-gbps` lands here. Costing only: the link speed moves the
    /// restore-vs-recompute break-even, never any search result.
    pub fn cold_linked(mut self, bytes_per_sec: f64) -> Self {
        self.cold_link_bw = bytes_per_sec.max(1.0);
        self
    }

    /// The recompute-prefill roofline for a `tokens`-long span: a
    /// compute-bound forward pass plus one weight read and the span's paged
    /// KV write. Returns (seconds, bytes). The single formula behind both
    /// [`PerfModel::round_cost`]'s resumed-session charge and
    /// [`PerfModel::import_choice`]'s recompute side — keeping the billed
    /// cost and the transfer-vs-recompute decision in lockstep by
    /// construction.
    fn prefill_cost(&self, tokens: usize, block_size: usize, model: &ModelProfile) -> (f64, f64) {
        let bs = block_size.max(1) as f64;
        let paged = (tokens as f64 / bs).ceil() * bs;
        let comp = model.weight_bytes as f64 * tokens as f64 / self.hw.peak_flops;
        let bytes = model.weight_bytes as f64 + paged * model.kv_bytes_per_token as f64;
        (comp.max(bytes / self.hw.mem_bw), bytes)
    }

    /// Cost both ways to materialize a `tokens`-long KV span a peer shard
    /// holds: transfer (paged bytes over the interconnect + local write) vs
    /// recompute (the same prefill formula [`PerfModel::round_cost`] charges
    /// resumed sessions — both fold through [`PerfModel::prefill_cost`]).
    /// The caller applies `min` — this is the transfer-aware costing behind
    /// cross-shard imports and the migration cost model.
    pub fn import_choice(
        &self,
        tokens: usize,
        block_size: usize,
        model: &ModelProfile,
    ) -> TransferDecision {
        self.import_choice_contended(tokens, block_size, model, 0.0)
    }

    /// [`PerfModel::import_choice`] with link *contention*: `queued_bytes`
    /// is the paged KV volume that earlier transfers in the same round have
    /// already committed to the shared interconnect. This transfer's bytes
    /// queue behind them — the link is one shared resource per round, not a
    /// fresh point-to-point wire per import — so a late import in a
    /// transfer-heavy round sees a slower effective link and may flip to
    /// recompute. The recompute side is contention-free (local HBM).
    /// `queued_bytes == 0.0` reduces exactly to the uncontended price.
    pub fn import_choice_contended(
        &self,
        tokens: usize,
        block_size: usize,
        model: &ModelProfile,
        queued_bytes: f64,
    ) -> TransferDecision {
        if tokens == 0 {
            return TransferDecision::default();
        }
        let bs = block_size.max(1) as f64;
        let paged = (tokens as f64 / bs).ceil() * bs;
        let kv_bytes = paged * model.kv_bytes_per_token as f64;
        let transfer_seconds =
            (queued_bytes + kv_bytes) / self.hw.link_bw + kv_bytes / self.hw.mem_bw;
        let (recompute_seconds, _) = self.prefill_cost(tokens, block_size, model);
        TransferDecision { transfer_seconds, recompute_seconds }
    }

    /// Paged KV bytes a `tokens`-long span occupies on the wire — the
    /// volume a chosen transfer adds to the round's shared-link queue.
    pub fn link_bytes(&self, tokens: usize, block_size: usize, model: &ModelProfile) -> f64 {
        let bs = block_size.max(1) as f64;
        (tokens as f64 / bs).ceil() * bs * model.kv_bytes_per_token as f64
    }

    /// Cost both ways to rematerialize a `tokens`-long KV span the *cold
    /// tier* (host DRAM) holds: restore it over the PCIe-class host link
    /// ([`PerfModel::cold_link_bw`], paged bytes + the local HBM write) vs
    /// recompute the prefill locally — the same
    /// [`PerfModel::prefill_cost`] formula every other decision folds
    /// through, so the billed cost and the choice stay in lockstep.
    ///
    /// `queued_bytes` is the paged volume this round's earlier cold-lane
    /// traffic — demote spills *and* chosen restores, which share the one
    /// host link — has already committed to the lane; this restore queues
    /// behind it, so a spill-heavy round prices later restores back toward
    /// recompute. `queued_bytes == 0.0` is the uncontended price.
    pub fn tier_choice(
        &self,
        tokens: usize,
        block_size: usize,
        model: &ModelProfile,
        queued_bytes: f64,
    ) -> TransferDecision {
        if tokens == 0 {
            return TransferDecision::default();
        }
        let kv_bytes = self.link_bytes(tokens, block_size, model);
        let transfer_seconds =
            (queued_bytes + kv_bytes) / self.cold_link_bw + kv_bytes / self.hw.mem_bw;
        let (recompute_seconds, _) = self.prefill_cost(tokens, block_size, model);
        TransferDecision { transfer_seconds, recompute_seconds }
    }

    /// Estimate the wall-clock of one problem's search on this setup.
    ///
    /// `outcome` carries per-step batch sizes and KV footprints; `model` the
    /// weight/KV byte costs. Co-scheduled threads multiply the KV working
    /// set and amortize weight reads (they decode in lockstep batches).
    pub fn latency(&self, outcome: &SearchOutcome, model: &ModelProfile) -> LatencyEstimate {
        let mut total_s = 0.0;
        let mut bytes = 0.0;
        let mut extra_waves = 0u64;
        for step in &outcome.steps {
            let e = self.step_latency(step, model);
            total_s += e.seconds;
            bytes += e.bytes_moved;
            extra_waves += e.extra_waves;
        }
        LatencyEstimate { seconds: total_s, bytes_moved: bytes, extra_waves }
    }

    /// Roofline cost of a single committed search step — the per-step body
    /// of [`PerfModel::latency`], exposed so the trace layer
    /// ([`crate::obs::trace`]) can fold a session's committed steps into its
    /// session-local modeled timeline. Depends only on the step's committed
    /// telemetry and this model's configuration, never on scheduling — which
    /// is what makes the modeled trace track byte-identical across shard
    /// counts and pipeline/async modes.
    pub fn step_latency(&self, step: &StepMetrics, model: &ModelProfile) -> LatencyEstimate {
        if step.model_calls == 0 {
            return LatencyEstimate::default();
        }
        let threads = self.threads as f64;
        let batch = step.model_calls as f64;
        // average decode iterations to emit this step's tokens
        let iters = (step.new_tokens as f64 / batch).max(1.0);
        // KV working set for this step (per problem), bytes
        let kv_unique = step.live_kv_tokens as f64 * model.kv_bytes_per_token as f64;
        let kv_dup = step.unshared_kv_tokens as f64 * model.kv_bytes_per_token as f64;
        let kv_read = if self.shared_kv { kv_unique } else { kv_dup };
        // resident set on the node: co-scheduled problems each hold
        // their (allocated = duplicated unless shared) KV
        let resident = threads * (if self.shared_kv { kv_unique } else { kv_dup });
        let free = (self.hw.mem_cap - model.weight_bytes as f64).max(1.0);
        let waves = (resident / free).ceil().max(1.0);
        let extra_waves = (waves as u64).saturating_sub(1) * step.new_tokens as u64
            / step.model_calls.max(1) as u64;
        // per decode iteration: weights once per wave (amortized over
        // all co-scheduled sequences), KV of *this* problem read once
        let weight_read = model.weight_bytes as f64 * waves / threads;
        let bytes_per_iter = weight_read + kv_read;
        let mem_s = bytes_per_iter / self.hw.mem_bw;
        // compute: 2 * params * batch tokens (params ≈ weight_bytes / 2
        // for bf16)
        let flops = model.weight_bytes as f64 * batch;
        let comp_s = flops / self.hw.peak_flops;
        LatencyEstimate {
            seconds: iters * mem_s.max(comp_s),
            bytes_moved: iters * bytes_per_iter,
            extra_waves,
        }
    }

    /// Wall-clock of one *merged* engine batch, lockstep (phases run back
    /// to back — [`RoundCost::lockstep_seconds`] of
    /// [`PerfModel::round_cost`]). Kept as the single-number entry point for
    /// per-problem replays and non-pipelined callers.
    pub fn batch_latency(&self, b: &BatchStats, model: &ModelProfile) -> LatencyEstimate {
        let cost = self.round_cost(b, model);
        LatencyEstimate {
            seconds: cost.lockstep_seconds(),
            bytes_moved: cost.bytes_moved,
            extra_waves: cost.extra_waves,
        }
    }

    /// Cost one *merged* engine round, decomposed at the pipeline boundary
    /// ([`RoundCost`]).
    ///
    /// **Decode phase** — every co-scheduled problem's continuations decode
    /// in lockstep, so the weights are read once per iteration for the
    /// whole batch (the amortization continuous batching buys) and the full
    /// resident KV working set is streamed each iteration; if the resident
    /// set exceeds free HBM the batch fragments into waves, each re-reading
    /// the weights, exactly as in [`PerfModel::latency`]. Backend-injected
    /// decode overhead (`b.injected_decode_seconds`) lands here.
    ///
    /// **Plan + commit phase** — rounds that resumed preempted sessions pay
    /// a recompute-prefill pass (`b.recompute_prefill_tokens`): a
    /// compute-bound forward over the evicted prefix plus one weight read
    /// and that prefix's KV write traffic. Committing the round's decode
    /// output then writes `b.new_tokens` of fresh KV into the paged cache.
    ///
    /// KV bytes are charged at *block* granularity (`b.block_size`)
    /// throughout: the paged allocator moves whole pages, so a partially
    /// filled tail block costs as much as a full one.
    pub fn round_cost(&self, b: &BatchStats, model: &ModelProfile) -> RoundCost {
        let bs = b.block_size.max(1) as f64;
        let page = |tokens: usize| (tokens as f64 / bs).ceil() * bs;
        let kv_b = model.kv_bytes_per_token as f64;
        let mut cost = RoundCost::default();
        // plan + commit: recompute-prefill for resumed sessions (the same
        // formula import_choice prices the recompute alternative with)
        if b.recompute_prefill_tokens > 0 {
            let (prefill_s, prefill_bytes) =
                self.prefill_cost(b.recompute_prefill_tokens, b.block_size, model);
            cost.overhead_seconds += prefill_s;
            cost.bytes_moved += prefill_bytes;
        }
        // plan + commit: KV imported from peer shards — paged bytes over
        // the interconnect, then written into the local paged cache
        if b.transfer_kv_tokens > 0 {
            let link_bytes = page(b.transfer_kv_tokens) * kv_b;
            cost.overhead_seconds +=
                link_bytes / self.hw.link_bw + link_bytes / self.hw.mem_bw;
            cost.bytes_moved += link_bytes;
        }
        // plan + commit: KV restored from the host-DRAM cold tier — paged
        // bytes over the PCIe-class host link, then written into HBM.
        // Demote spills are *not* billed here: spilling is write-behind DMA
        // overlapping compute, so demotions cost only the lane contention
        // they add to the round's tier_choice decisions.
        if b.restored_kv_tokens > 0 {
            let cold_bytes = page(b.restored_kv_tokens) * kv_b;
            cost.overhead_seconds +=
                cold_bytes / self.cold_link_bw + cold_bytes / self.hw.mem_bw;
            cost.bytes_moved += cold_bytes;
        }
        // plan + commit: paged KV writes of the round's new tokens
        if b.new_tokens > 0 {
            let commit_bytes = page(b.new_tokens) * kv_b;
            cost.overhead_seconds += commit_bytes / self.hw.mem_bw;
            cost.bytes_moved += commit_bytes;
        }
        // Backend-injected decode latency is billed whenever the backend
        // decoded this round, even when every commit then deferred under
        // pressure (model_calls == 0): the device time was spent regardless
        // of whether the scheduler could admit the results.
        cost.decode_seconds = b.injected_decode_seconds;
        if b.model_calls == 0 || b.new_tokens == 0 {
            return cost;
        }
        // decode: lockstep iterations over the merged batch
        let batch = b.model_calls as f64;
        let iters = (b.new_tokens as f64 / batch).max(1.0);
        let kv_read = page(b.read_kv_tokens) * kv_b;
        let resident = page(b.resident_kv_tokens) * kv_b;
        let free = (self.hw.mem_cap - model.weight_bytes as f64).max(1.0);
        let waves = (resident / free).ceil().max(1.0);
        let bytes_per_iter = model.weight_bytes as f64 * waves + kv_read;
        let mem_s = bytes_per_iter / self.hw.mem_bw;
        let comp_s = model.weight_bytes as f64 * batch / self.hw.peak_flops;
        cost.decode_seconds += iters * mem_s.max(comp_s);
        cost.bytes_moved += iters * bytes_per_iter;
        cost.extra_waves = (waves as u64).saturating_sub(1) * iters as u64;
        cost
    }

    /// Aggregate throughput (problems/s) for a set of per-problem outcomes
    /// co-scheduled `threads` at a time.
    pub fn throughput(&self, outcomes: &[SearchOutcome], model: &ModelProfile) -> f64 {
        if outcomes.is_empty() {
            return 0.0;
        }
        let total_s: f64 =
            outcomes.iter().map(|o| self.latency(o, model).seconds).sum();
        // threads problems progress concurrently; each problem's latency is
        // computed under the shared-node contention model above
        outcomes.len() as f64 / (total_s / self.threads as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::{StepMetrics, SearchOutcome};
    use crate::workload::LLEMMA_34B_SIM;

    fn outcome(steps: Vec<StepMetrics>) -> SearchOutcome {
        SearchOutcome {
            answer: None,
            completions: vec![],
            steps,
            tree: crate::tree::SearchTree::new(),
            completed_leaves: vec![],
            recompute_tokens: 0,
        }
    }

    fn step(model_calls: usize, new_tokens: usize, live: usize, unshared: usize) -> StepMetrics {
        StepMetrics {
            live_kv_tokens: live,
            unshared_kv_tokens: unshared,
            new_tokens,
            model_calls,
            frontier: model_calls,
            prm_calls: model_calls,
        }
    }

    #[test]
    fn shared_kv_is_faster_when_sharing_exists() {
        let o = outcome(vec![step(64, 64 * 50, 10_000, 80_000)]);
        let shared = PerfModel::new(H100_NVL, true, 8).latency(&o, &LLEMMA_34B_SIM);
        let dup = PerfModel::new(H100_NVL, false, 8).latency(&o, &LLEMMA_34B_SIM);
        assert!(shared.seconds < dup.seconds, "{shared:?} vs {dup:?}");
    }

    #[test]
    fn more_kv_means_more_latency() {
        let small = outcome(vec![step(64, 64 * 50, 10_000, 10_000)]);
        let big = outcome(vec![step(64, 64 * 50, 200_000, 200_000)]);
        let pm = PerfModel::new(H100_NVL, true, 8);
        assert!(
            pm.latency(&big, &LLEMMA_34B_SIM).seconds
                > pm.latency(&small, &LLEMMA_34B_SIM).seconds
        );
    }

    #[test]
    fn fragmentation_kicks_in_at_capacity() {
        // enormous duplicated KV with many threads → waves > 1
        let o = outcome(vec![step(256, 256 * 50, 500_000, 3_000_000)]);
        let pm = PerfModel::new(H100_NVL, false, 32);
        let est = pm.latency(&o, &LLEMMA_34B_SIM);
        assert!(est.extra_waves > 0, "{est:?}");
        let pm_shared = PerfModel::new(H100_NVL, true, 32);
        let est_s = pm_shared.latency(&o, &LLEMMA_34B_SIM);
        assert!(est_s.seconds < est.seconds);
    }

    #[test]
    fn same_flops_different_kv_different_runtime() {
        // The Fig. 2 claim: equal model calls + tokens, different KV →
        // different runtime.
        let a = outcome(vec![step(64, 64 * 50, 30_000, 60_000)]);
        let b = outcome(vec![step(64, 64 * 50, 150_000, 300_000)]);
        let pm = PerfModel::new(H100_NVL, true, 8);
        let (ta, tb) = (
            pm.latency(&a, &LLEMMA_34B_SIM).seconds,
            pm.latency(&b, &LLEMMA_34B_SIM).seconds,
        );
        assert!(tb > ta * 1.5, "{ta} vs {tb}");
    }

    #[test]
    fn merged_batches_amortize_weight_reads() {
        // Two problems fused into one batch finish faster than the same work
        // run as two sequential batches: same tokens, same KV, one weight
        // stream per iteration instead of two.
        let pm = PerfModel::new(H100_NVL, true, 1);
        let single = BatchStats {
            model_calls: 64,
            new_tokens: 64 * 50,
            read_kv_tokens: 3_000,
            resident_kv_tokens: 3_000,
            ..Default::default()
        };
        let merged = BatchStats {
            model_calls: 128,
            new_tokens: 128 * 50,
            read_kv_tokens: 6_000,
            resident_kv_tokens: 6_000,
            ..Default::default()
        };
        let two_rounds = 2.0 * pm.batch_latency(&single, &LLEMMA_34B_SIM).seconds;
        let one_round = pm.batch_latency(&merged, &LLEMMA_34B_SIM).seconds;
        assert!(
            one_round < 0.75 * two_rounds,
            "merged {one_round} vs sequential {two_rounds}"
        );
    }

    #[test]
    fn batch_latency_grows_with_resident_kv_and_fragments() {
        let pm = PerfModel::new(H100_NVL, true, 1);
        let small = BatchStats {
            model_calls: 64,
            new_tokens: 64 * 50,
            read_kv_tokens: 10_000,
            resident_kv_tokens: 10_000,
            ..Default::default()
        };
        let big = BatchStats {
            model_calls: 64,
            new_tokens: 64 * 50,
            read_kv_tokens: 200_000,
            resident_kv_tokens: 200_000,
            ..Default::default()
        };
        let (ts, tb) = (
            pm.batch_latency(&small, &LLEMMA_34B_SIM),
            pm.batch_latency(&big, &LLEMMA_34B_SIM),
        );
        assert!(tb.seconds > ts.seconds);
        assert!(tb.extra_waves > 0, "200k tokens must not fit free HBM: {tb:?}");
        assert_eq!(ts.extra_waves, 0, "{ts:?}");
    }

    #[test]
    fn empty_batch_costs_nothing() {
        let pm = PerfModel::new(H100_NVL, true, 1);
        let est = pm.batch_latency(&BatchStats::default(), &LLEMMA_34B_SIM);
        assert_eq!(est.seconds, 0.0);
        assert_eq!(est.bytes_moved, 0.0);
    }

    #[test]
    fn recompute_prefill_charges_resumed_sessions() {
        let pm = PerfModel::new(H100_NVL, true, 1);
        let plain = BatchStats {
            model_calls: 64,
            new_tokens: 64 * 50,
            read_kv_tokens: 30_000,
            resident_kv_tokens: 30_000,
            ..Default::default()
        };
        let resumed = BatchStats { recompute_prefill_tokens: 20_000, ..plain.clone() };
        let (tp, tr) = (
            pm.batch_latency(&plain, &LLEMMA_34B_SIM),
            pm.batch_latency(&resumed, &LLEMMA_34B_SIM),
        );
        assert!(tr.seconds > tp.seconds, "resume must not be free: {tr:?} vs {tp:?}");
        assert!(tr.bytes_moved > tp.bytes_moved);
        // a recompute-only round (resumes, no decode) still costs time
        let only = BatchStats { recompute_prefill_tokens: 5_000, ..Default::default() };
        let est = pm.batch_latency(&only, &LLEMMA_34B_SIM);
        assert!(est.seconds > 0.0);
    }

    #[test]
    fn kv_is_charged_per_block_not_per_token() {
        let pm = PerfModel::new(H100_NVL, true, 1);
        // 1 token into a 16-token page: the whole page moves. new_tokens is
        // block-aligned so the commit-write charge (also paged) cancels in
        // the aligned comparison below.
        let tiny = BatchStats {
            model_calls: 8,
            new_tokens: 16,
            read_kv_tokens: 33, // 3 pages of 16
            resident_kv_tokens: 33,
            block_size: 16,
            ..Default::default()
        };
        let exact = BatchStats { block_size: 1, ..tiny.clone() };
        let (tb, tt) = (
            pm.batch_latency(&tiny, &LLEMMA_34B_SIM),
            pm.batch_latency(&exact, &LLEMMA_34B_SIM),
        );
        assert!(
            tb.bytes_moved > tt.bytes_moved,
            "paged KV reads must round up to blocks: {tb:?} vs {tt:?}"
        );
        // block-aligned working sets cost the same either way
        let aligned = BatchStats {
            read_kv_tokens: 48,
            resident_kv_tokens: 48,
            ..tiny.clone()
        };
        let aligned_exact = BatchStats { block_size: 1, ..aligned.clone() };
        assert_eq!(
            pm.batch_latency(&aligned, &LLEMMA_34B_SIM).bytes_moved,
            pm.batch_latency(&aligned_exact, &LLEMMA_34B_SIM).bytes_moved
        );
    }

    #[test]
    fn round_cost_decomposes_batch_latency_at_the_pipeline_boundary() {
        let pm = PerfModel::new(H100_NVL, true, 1);
        let b = BatchStats {
            model_calls: 64,
            new_tokens: 64 * 50,
            read_kv_tokens: 30_000,
            resident_kv_tokens: 30_000,
            recompute_prefill_tokens: 10_000,
            block_size: 16,
            ..Default::default()
        };
        let cost = pm.round_cost(&b, &LLEMMA_34B_SIM);
        assert!(cost.decode_seconds > 0.0);
        assert!(cost.overhead_seconds > 0.0, "recompute + commit writes must cost");
        // lockstep is exactly the sum; batch_latency folds through it
        let est = pm.batch_latency(&b, &LLEMMA_34B_SIM);
        assert_eq!(est.seconds, cost.lockstep_seconds());
        assert_eq!(est.bytes_moved, cost.bytes_moved);
        // the pipelined round hides the smaller phase entirely
        assert_eq!(cost.pipelined_seconds(), cost.decode_seconds.max(cost.overhead_seconds));
        assert!(cost.pipelined_seconds() < cost.lockstep_seconds());
        assert_eq!(cost.seconds(false), cost.lockstep_seconds());
        assert_eq!(cost.seconds(true), cost.pipelined_seconds());
    }

    #[test]
    fn injected_decode_overhead_lands_on_the_decode_side() {
        let pm = PerfModel::new(H100_NVL, true, 1);
        let plain = BatchStats {
            model_calls: 64,
            new_tokens: 64 * 50,
            read_kv_tokens: 30_000,
            resident_kv_tokens: 30_000,
            ..Default::default()
        };
        let injected = BatchStats { injected_decode_seconds: 0.5, ..plain.clone() };
        let (cp, ci) = (
            pm.round_cost(&plain, &LLEMMA_34B_SIM),
            pm.round_cost(&injected, &LLEMMA_34B_SIM),
        );
        assert_eq!(ci.decode_seconds, cp.decode_seconds + 0.5);
        assert_eq!(ci.overhead_seconds, cp.overhead_seconds);
        // a decode-bound pipelined round costs only its decode phase
        assert_eq!(ci.pipelined_seconds(), ci.decode_seconds);
        // a round whose commits all deferred (no model calls recorded) still
        // bills the backend's decode time — the device ran regardless
        let deferred = BatchStats {
            recompute_prefill_tokens: 5_000,
            injected_decode_seconds: 0.5,
            ..Default::default()
        };
        assert_eq!(pm.round_cost(&deferred, &LLEMMA_34B_SIM).decode_seconds, 0.5);
        // and with no backend hint, no decode work means zero decode cost
        let idle = BatchStats { recompute_prefill_tokens: 5_000, ..Default::default() };
        assert_eq!(pm.round_cost(&idle, &LLEMMA_34B_SIM).decode_seconds, 0.0);
    }

    #[test]
    fn transferred_kv_lands_on_the_overhead_side() {
        let pm = PerfModel::new(H100_NVL, true, 1);
        let plain = BatchStats {
            model_calls: 64,
            new_tokens: 64 * 50,
            read_kv_tokens: 30_000,
            resident_kv_tokens: 30_000,
            block_size: 16,
            ..Default::default()
        };
        let imported = BatchStats { transfer_kv_tokens: 4_000, ..plain.clone() };
        let (cp, ci) = (
            pm.round_cost(&plain, &LLEMMA_34B_SIM),
            pm.round_cost(&imported, &LLEMMA_34B_SIM),
        );
        assert_eq!(ci.decode_seconds, cp.decode_seconds, "imports never touch decode");
        assert!(ci.overhead_seconds > cp.overhead_seconds, "transfers must cost");
        assert!(ci.bytes_moved > cp.bytes_moved);
        // the transfer bill matches the import_choice transfer estimate
        let d = pm.import_choice(4_000, 16, &LLEMMA_34B_SIM);
        let delta = ci.overhead_seconds - cp.overhead_seconds;
        assert!((delta - d.transfer_seconds).abs() < 1e-12, "{delta} vs {d:?}");
    }

    #[test]
    fn restored_kv_lands_on_the_overhead_side() {
        let pm = PerfModel::new(H100_NVL, true, 1);
        let plain = BatchStats {
            model_calls: 64,
            new_tokens: 64 * 50,
            read_kv_tokens: 30_000,
            resident_kv_tokens: 30_000,
            block_size: 16,
            ..Default::default()
        };
        let restored = BatchStats { restored_kv_tokens: 4_000, ..plain.clone() };
        let (cp, cr) = (
            pm.round_cost(&plain, &LLEMMA_34B_SIM),
            pm.round_cost(&restored, &LLEMMA_34B_SIM),
        );
        assert_eq!(cr.decode_seconds, cp.decode_seconds, "restores never touch decode");
        assert!(cr.overhead_seconds > cp.overhead_seconds, "restores must cost");
        assert!(cr.bytes_moved > cp.bytes_moved);
        // the restore bill matches the tier_choice transfer estimate — the
        // billed cost and the restore-vs-recompute decision stay in lockstep
        let d = pm.tier_choice(4_000, 16, &LLEMMA_34B_SIM, 0.0);
        let delta = cr.overhead_seconds - cp.overhead_seconds;
        assert!((delta - d.transfer_seconds).abs() < 1e-12, "{delta} vs {d:?}");
    }

    #[test]
    fn tier_choice_prefers_pcie_restore_but_flips_under_contention() {
        let pm = PerfModel::new(H100_NVL, true, 1);
        let d = pm.tier_choice(2_000, 16, &LLEMMA_34B_SIM, 0.0);
        assert!(d.transfer_seconds > 0.0 && d.recompute_seconds > 0.0);
        assert!(
            d.use_transfer(),
            "a PCIe-class restore must beat a weight-read-floored recompute \
             prefill: {d:?}"
        );
        // the PCIe lane is slower than NVLink, so a restore costs more than
        // the equivalent cross-shard import — but still beats recompute
        let nv = pm.import_choice(2_000, 16, &LLEMMA_34B_SIM);
        assert!(d.transfer_seconds > nv.transfer_seconds, "{d:?} vs {nv:?}");
        assert_eq!(d.recompute_seconds, nv.recompute_seconds);
        // spill/restore traffic queued on the lane earlier in the round
        // slows only the restore side, and enough of it flips the choice
        let busy = pm.tier_choice(2_000, 16, &LLEMMA_34B_SIM, 1.0e9);
        assert!(busy.transfer_seconds > d.transfer_seconds);
        assert_eq!(busy.recompute_seconds, d.recompute_seconds);
        let jammed = pm.tier_choice(2_000, 16, &LLEMMA_34B_SIM, 1.0e12);
        assert!(!jammed.use_transfer(), "{jammed:?}");
        // a commodity cold link (1 GB/s) makes recompute cheaper outright
        let slow = PerfModel::new(H100_NVL, true, 1).cold_linked(1.0e9);
        assert!(!slow.tier_choice(2_000, 16, &LLEMMA_34B_SIM, 0.0).use_transfer());
        // nothing to restore, nothing to charge
        assert_eq!(pm.tier_choice(0, 16, &LLEMMA_34B_SIM, 0.0), TransferDecision::default());
    }

    #[test]
    fn import_choice_prefers_nvlink_transfer_but_flips_on_a_slow_link() {
        let pm = PerfModel::new(H100_NVL, true, 1);
        let d = pm.import_choice(2_000, 16, &LLEMMA_34B_SIM);
        assert!(d.transfer_seconds > 0.0 && d.recompute_seconds > 0.0);
        assert!(
            d.use_transfer(),
            "an NVLink-class block copy must beat a weight-read-floored \
             recompute prefill: {d:?}"
        );
        assert_eq!(d.chosen_seconds(), d.transfer_seconds);
        // a commodity-network link (1 GB/s) makes recompute the cheaper path
        let slow = Hardware { link_bw: 1.0e9, ..H100_NVL };
        let d = PerfModel::new(slow, true, 1).import_choice(2_000, 16, &LLEMMA_34B_SIM);
        assert!(!d.use_transfer(), "{d:?}");
        assert_eq!(d.chosen_seconds(), d.recompute_seconds);
        // nothing to import, nothing to charge
        assert_eq!(pm.import_choice(0, 16, &LLEMMA_34B_SIM), TransferDecision::default());
    }

    #[test]
    fn link_contention_queues_transfers_and_can_flip_the_choice() {
        let pm = PerfModel::new(H100_NVL, true, 1);
        // zero queue reduces exactly to the uncontended price
        assert_eq!(
            pm.import_choice_contended(2_000, 16, &LLEMMA_34B_SIM, 0.0),
            pm.import_choice(2_000, 16, &LLEMMA_34B_SIM)
        );
        // queued bytes slow only the transfer side, monotonically
        let free = pm.import_choice_contended(2_000, 16, &LLEMMA_34B_SIM, 0.0);
        let busy = pm.import_choice_contended(2_000, 16, &LLEMMA_34B_SIM, 1.0e9);
        assert!(busy.transfer_seconds > free.transfer_seconds);
        assert_eq!(busy.recompute_seconds, free.recompute_seconds);
        // enough queued traffic flips an otherwise-winning transfer to
        // recompute — the same span, same link, different round pressure
        assert!(free.use_transfer());
        let jammed = pm.import_choice_contended(2_000, 16, &LLEMMA_34B_SIM, 1.0e12);
        assert!(!jammed.use_transfer(), "{jammed:?}");
        // the wire volume a chosen transfer enqueues is the paged span
        let bytes = pm.link_bytes(33, 16, &LLEMMA_34B_SIM);
        assert_eq!(bytes, 48.0 * LLEMMA_34B_SIM.kv_bytes_per_token as f64);
    }

    #[test]
    fn throughput_scales_with_threads() {
        let o = outcome(vec![step(64, 64 * 50, 30_000, 60_000)]);
        let outs = vec![o.clone(), o.clone(), o];
        let t1 = PerfModel::new(H100_NVL, true, 1).throughput(&outs, &LLEMMA_34B_SIM);
        let t8 = PerfModel::new(H100_NVL, true, 8).throughput(&outs, &LLEMMA_34B_SIM);
        assert!(t8 > t1, "t8 {t8} t1 {t1}");
    }
}
