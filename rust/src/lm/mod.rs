//! Step generators ("the LM"): given a frontier leaf, sample `n` candidate
//! continuations.
//!
//! Two implementations:
//! * [`SynthLm`] — the calibrated synthetic generator over the workload's
//!   latent fate space (accuracy experiments; no model in the loop).
//! * [`crate::engine::pjrt_lm::PjrtLm`] — the real tiny transformer executed
//!   through the AOT artifacts via PJRT (throughput / end-to-end proof).

use crate::tree::{NodeId, SearchTree, StepInfo};
use crate::util::rng::Rng;
use crate::workload::{extend_path_id, Problem};

/// Samples step continuations for frontier leaves.
pub trait StepGenerator {
    /// Sample `n` continuations of the trajectory ending at `leaf`.
    fn expand(&mut self, tree: &SearchTree, leaf: NodeId, n: usize) -> Vec<StepInfo>;

    /// Sample continuations for a whole step's allocation in one call — the
    /// batched entry point [`crate::engine::BatchEngine`] drives. Results are
    /// per-request, in request order. The default runs the requests through
    /// [`StepGenerator::expand`] sequentially (deterministic RNG order);
    /// batched backends override this to fuse the decode.
    fn expand_batch(
        &mut self,
        tree: &SearchTree,
        requests: &[(NodeId, usize)],
    ) -> Vec<Vec<StepInfo>> {
        requests.iter().map(|&(leaf, n)| self.expand(tree, leaf, n)).collect()
    }

    /// Tokens in the problem prompt (root node size).
    fn prompt_tokens(&self) -> usize;

    /// Surface token ids of the prompt, when the generator has real ones
    /// (PJRT path). `None` lets the engine mint synthetic unique ids for its
    /// radix accounting.
    fn prompt_token_ids(&self) -> Option<Vec<u32>> {
        None
    }
}

/// Boxed generators — heterogeneous backends behind one serve loop (covers
/// `Box<dyn StepGenerator>` and `Box<dyn StepGenerator + Send>`; the `Send`
/// variant is what lets the sharded coordinator hand sessions to worker
/// threads and migrate them across shards).
impl<G: StepGenerator + ?Sized> StepGenerator for Box<G> {
    fn expand(&mut self, tree: &SearchTree, leaf: NodeId, n: usize) -> Vec<StepInfo> {
        (**self).expand(tree, leaf, n)
    }

    fn expand_batch(
        &mut self,
        tree: &SearchTree,
        requests: &[(NodeId, usize)],
    ) -> Vec<Vec<StepInfo>> {
        (**self).expand_batch(tree, requests)
    }

    fn prompt_tokens(&self) -> usize {
        (**self).prompt_tokens()
    }

    fn prompt_token_ids(&self) -> Option<Vec<u32>> {
        (**self).prompt_token_ids()
    }
}

impl<G: StepGenerator + ?Sized> StepGenerator for &mut G {
    fn expand(&mut self, tree: &SearchTree, leaf: NodeId, n: usize) -> Vec<StepInfo> {
        (**self).expand(tree, leaf, n)
    }

    fn expand_batch(
        &mut self,
        tree: &SearchTree,
        requests: &[(NodeId, usize)],
    ) -> Vec<Vec<StepInfo>> {
        (**self).expand_batch(tree, requests)
    }

    fn prompt_tokens(&self) -> usize {
        (**self).prompt_tokens()
    }

    fn prompt_token_ids(&self) -> Option<Vec<u32>> {
        (**self).prompt_token_ids()
    }
}

/// Synthetic LM over one [`Problem`]'s latent solution space.
///
/// Sampling model per continuation:
/// 1. pick a semantic group from the dataset's `n_groups` under a
///    *concentrated* proposal distribution (P(rank r) ∝ ζ^r over a
///    deterministic per-context preference order): an LM sampled k times at
///    the same state mostly re-proposes its top one or two approaches, so
///    extra samples from one node are largely redundant — the premise of
///    the paper's coverage term;
/// 2. pick a paraphrase variant id (surface form);
/// 3. the step's on-track fate is the problem's deterministic function of
///    (parent path, group) — redundant same-group steps share their fate;
/// 4. after `n_steps` on-track steps the trajectory terminates with the true
///    answer; a doomed trajectory terminates at the same depth with a wrong
///    answer (deterministic per path).
pub struct SynthLm {
    pub problem: Problem,
    /// Proposal concentration: P(rank r) ∝ zeta^r. Lower = more peaked.
    pub zeta: f64,
    rng: Rng,
}

impl SynthLm {
    pub fn new(problem: Problem, seed: u64) -> Self {
        let rng = Rng::new(seed ^ problem.seed);
        Self { problem, zeta: 0.6, rng }
    }

    /// Sample a semantic group for a node: deterministic per-context
    /// preference order, geometric rank distribution.
    fn sample_group(&mut self, parent_path_id: u64, n_groups: usize) -> u64 {
        // preference permutation seeded by the context
        let mut perm: Vec<u64> = (0..n_groups as u64).collect();
        let mut prng = Rng::new(self.problem.seed ^ parent_path_id.wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
        prng.shuffle(&mut perm);
        // geometric rank, truncated
        let mut rank = 0usize;
        while rank + 1 < n_groups && self.rng.f64() < self.zeta {
            rank += 1;
        }
        perm[rank]
    }
}

impl StepGenerator for SynthLm {
    fn expand(&mut self, tree: &SearchTree, leaf: NodeId, n: usize) -> Vec<StepInfo> {
        let parent = tree.get(leaf);
        debug_assert!(!parent.step.terminal, "expanding a terminal node");
        let parent_path_id = parent.step.path_id;
        let parent_alive = parent.step.alive;
        let n_groups = self.problem.spec.dataset.n_groups;
        let n_steps = self.problem.spec.dataset.n_steps;
        let depth = tree.depth(leaf); // completed steps so far
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let group = self.sample_group(parent_path_id, n_groups);
            let paraphrase = self.rng.next_u64() & 0xFFFF;
            let path_id = extend_path_id(parent_path_id, group);
            let alive = parent_alive && self.problem.group_on_track(parent_path_id, group);
            let is_last = depth + 1 >= n_steps;
            let answer = if is_last {
                Some(if alive {
                    self.problem.answer
                } else {
                    self.problem.wrong_answer(path_id)
                })
            } else {
                None
            };
            out.push(StepInfo {
                tokens: self.problem.step_tokens(path_id ^ paraphrase),
                sem: group,
                paraphrase,
                token_ids: vec![],
                terminal: is_last,
                answer,
                path_id,
                alive,
            });
        }
        out
    }

    fn prompt_tokens(&self) -> usize {
        self.problem.spec.dataset.prompt_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{ProblemSet, WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

    fn make() -> SynthLm {
        let spec = WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM);
        let p = ProblemSet::generate(&spec, 1, 9).problems.remove(0);
        SynthLm::new(p, 1)
    }

    #[test]
    fn expands_n_children_with_consistent_latents() {
        let mut lm = make();
        let mut tree = SearchTree::new();
        let root = tree.init_root(lm.prompt_tokens());
        let steps = lm.expand(&tree, root, 32);
        assert_eq!(steps.len(), 32);
        // same group from the same parent → same fate and same path id
        for a in &steps {
            for b in &steps {
                if a.sem == b.sem {
                    assert_eq!(a.alive, b.alive, "same group, different fate");
                    assert_eq!(a.path_id, b.path_id);
                }
            }
            assert!(!a.terminal, "first of 8 steps can't be terminal");
        }
    }

    #[test]
    fn doomed_parent_stays_doomed() {
        let mut lm = make();
        let mut tree = SearchTree::new();
        let root = tree.init_root(lm.prompt_tokens());
        // manufacture a doomed child
        let doomed = tree.add_child(
            root,
            StepInfo { tokens: 5, alive: false, path_id: 77, ..Default::default() },
            0.1,
        );
        for s in lm.expand(&tree, doomed, 16) {
            assert!(!s.alive);
        }
    }

    #[test]
    fn terminal_at_n_steps_with_correct_answer_iff_alive() {
        let mut lm = make();
        let n_steps = lm.problem.spec.dataset.n_steps;
        let truth = lm.problem.answer;
        let mut tree = SearchTree::new();
        let mut cur = tree.init_root(lm.prompt_tokens());
        // walk a chain of depth n_steps - 1
        for _ in 0..n_steps - 1 {
            let s = lm.expand(&tree, cur, 1).remove(0);
            assert!(!s.terminal);
            cur = tree.add_child(cur, s, 0.5);
        }
        let finals = lm.expand(&tree, cur, 20);
        for s in finals {
            assert!(s.terminal);
            let ans = s.answer.unwrap();
            if s.alive {
                assert_eq!(ans, truth);
            } else {
                assert_ne!(ans, truth);
            }
        }
    }
}
