//! Step generators ("the LM"): given a frontier leaf, sample `n` candidate
//! continuations.
//!
//! Two implementations:
//! * [`SynthLm`] — the calibrated synthetic generator over the workload's
//!   latent fate space (accuracy experiments; no model in the loop).
//! * [`crate::engine::pjrt_lm::PjrtLm`] — the real tiny transformer executed
//!   through the AOT artifacts via PJRT (throughput / end-to-end proof).

pub mod async_lm;

pub use async_lm::AsyncLm;

use crate::tree::{NodeId, SearchTree, StepInfo};
use crate::util::rng::Rng;
use crate::workload::{extend_path_id, Problem};

/// Handle to a decode batch submitted through the two-phase
/// [`StepGenerator::submit_batch`] / [`StepGenerator::poll_batch`] surface.
///
/// Backends fall into two shapes:
///
/// * synchronous generators (everything built on the blanket adapter)
///   resolve the batch *at submit time* and carry the results inside the
///   handle — `poll_batch` just unwraps them;
/// * pipelined backends ([`crate::engine::pjrt_lm::PjrtLm`] and, later, any
///   network-backed generator) return a [`PendingBatch::Ticket`] at submit
///   time and redeem it in `poll_batch`, which is what lets a scheduler
///   keep one shard's decode in flight while it commits another shard's
///   results.
///
/// Handles are not interchangeable across generators: polling a ticket on a
/// generator that did not issue it is a logic error (panics).
#[derive(Debug)]
pub enum PendingBatch {
    /// Results computed eagerly at submit time (blanket sync adapter).
    Ready(Vec<Vec<StepInfo>>),
    /// Backend-issued ticket; redeem via [`StepGenerator::poll_batch`].
    Ticket(u64),
}

impl PendingBatch {
    /// True when the backend deferred the work behind a ticket (a genuinely
    /// pipelined submit) rather than resolving it eagerly.
    pub fn is_ticket(&self) -> bool {
        matches!(self, PendingBatch::Ticket(_))
    }
}

/// Samples step continuations for frontier leaves.
pub trait StepGenerator {
    /// Sample `n` continuations of the trajectory ending at `leaf`.
    fn expand(&mut self, tree: &SearchTree, leaf: NodeId, n: usize) -> Vec<StepInfo>;

    /// Sample continuations for a whole step's allocation in one call — the
    /// batched entry point [`crate::engine::BatchEngine`] drives. Results are
    /// per-request, in request order. The default runs the requests through
    /// [`StepGenerator::expand`] sequentially (deterministic RNG order);
    /// batched backends override this to fuse the decode.
    fn expand_batch(
        &mut self,
        tree: &SearchTree,
        requests: &[(NodeId, usize)],
    ) -> Vec<Vec<StepInfo>> {
        requests.iter().map(|&(leaf, n)| self.expand(tree, leaf, n)).collect()
    }

    /// Phase 1 of the two-phase decode surface: dispatch a whole step's
    /// allocation and return a handle without waiting for the results. The
    /// blanket adapter runs [`StepGenerator::expand_batch`] eagerly and
    /// stores the results in the handle, so every existing synchronous
    /// generator is automatically a (degenerate) two-phase backend.
    /// Pipelined backends override both phases to genuinely decouple
    /// dispatch from completion.
    ///
    /// The per-generator RNG advances at *submit* time in either shape, so
    /// when a scheduler polls — immediately, or a round later — cannot
    /// change what was sampled.
    fn submit_batch(&mut self, tree: &SearchTree, requests: &[(NodeId, usize)]) -> PendingBatch {
        PendingBatch::Ready(self.expand_batch(tree, requests))
    }

    /// Phase 2: wait for a submitted batch and return its per-request
    /// continuations (request order preserved). The blanket adapter only
    /// understands [`PendingBatch::Ready`]; a backend that issues tickets
    /// must override [`StepGenerator::try_poll_batch`] to redeem them.
    ///
    /// This convenience wrapper panics on the typed error path — callers
    /// that can degrade gracefully (worker threads that should not die on a
    /// misrouted handle) call `try_poll_batch` directly.
    fn poll_batch(&mut self, batch: PendingBatch) -> Vec<Vec<StepInfo>> {
        self.try_poll_batch(batch).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible phase 2: like [`StepGenerator::poll_batch`], but a handle
    /// this generator cannot redeem (a ticket crossed between generators, a
    /// dead completion worker) surfaces as a typed [`crate::util::error`]
    /// instead of a panic.
    fn try_poll_batch(&mut self, batch: PendingBatch) -> crate::util::error::Result<Vec<Vec<StepInfo>>> {
        match batch {
            PendingBatch::Ready(results) => Ok(results),
            PendingBatch::Ticket(id) => Err(crate::err!(
                "poll_batch: ticket {id} polled on a generator that never \
                 issues tickets (handle crossed generators?)"
            )),
        }
    }

    /// Modeled decode-side latency this backend adds per *round* on top of
    /// the roofline (network round trips, kernel-launch tails, injected
    /// test latency). The serve scheduler folds the maximum hint across a
    /// round's decoding sessions into the round's modeled decode cost —
    /// which is exactly the part a pipelined round hides behind
    /// plan + commit. 0.0 (the default) means the roofline alone.
    fn decode_overhead_seconds(&self) -> f64 {
        0.0
    }

    /// Tokens in the problem prompt (root node size).
    fn prompt_tokens(&self) -> usize;

    /// Surface token ids of the prompt, when the generator has real ones
    /// (PJRT path). `None` lets the engine mint synthetic unique ids for its
    /// radix accounting.
    fn prompt_token_ids(&self) -> Option<Vec<u32>> {
        None
    }
}

/// Boxed generators — heterogeneous backends behind one serve loop (covers
/// `Box<dyn StepGenerator>` and `Box<dyn StepGenerator + Send>`; the `Send`
/// variant is what lets the sharded coordinator hand sessions to worker
/// threads and migrate them across shards).
impl<G: StepGenerator + ?Sized> StepGenerator for Box<G> {
    fn expand(&mut self, tree: &SearchTree, leaf: NodeId, n: usize) -> Vec<StepInfo> {
        (**self).expand(tree, leaf, n)
    }

    fn expand_batch(
        &mut self,
        tree: &SearchTree,
        requests: &[(NodeId, usize)],
    ) -> Vec<Vec<StepInfo>> {
        (**self).expand_batch(tree, requests)
    }

    fn submit_batch(&mut self, tree: &SearchTree, requests: &[(NodeId, usize)]) -> PendingBatch {
        (**self).submit_batch(tree, requests)
    }

    fn poll_batch(&mut self, batch: PendingBatch) -> Vec<Vec<StepInfo>> {
        (**self).poll_batch(batch)
    }

    fn try_poll_batch(&mut self, batch: PendingBatch) -> crate::util::error::Result<Vec<Vec<StepInfo>>> {
        (**self).try_poll_batch(batch)
    }

    fn decode_overhead_seconds(&self) -> f64 {
        (**self).decode_overhead_seconds()
    }

    fn prompt_tokens(&self) -> usize {
        (**self).prompt_tokens()
    }

    fn prompt_token_ids(&self) -> Option<Vec<u32>> {
        (**self).prompt_token_ids()
    }
}

impl<G: StepGenerator + ?Sized> StepGenerator for &mut G {
    fn expand(&mut self, tree: &SearchTree, leaf: NodeId, n: usize) -> Vec<StepInfo> {
        (**self).expand(tree, leaf, n)
    }

    fn expand_batch(
        &mut self,
        tree: &SearchTree,
        requests: &[(NodeId, usize)],
    ) -> Vec<Vec<StepInfo>> {
        (**self).expand_batch(tree, requests)
    }

    fn submit_batch(&mut self, tree: &SearchTree, requests: &[(NodeId, usize)]) -> PendingBatch {
        (**self).submit_batch(tree, requests)
    }

    fn poll_batch(&mut self, batch: PendingBatch) -> Vec<Vec<StepInfo>> {
        (**self).poll_batch(batch)
    }

    fn try_poll_batch(&mut self, batch: PendingBatch) -> crate::util::error::Result<Vec<Vec<StepInfo>>> {
        (**self).try_poll_batch(batch)
    }

    fn decode_overhead_seconds(&self) -> f64 {
        (**self).decode_overhead_seconds()
    }

    fn prompt_tokens(&self) -> usize {
        (**self).prompt_tokens()
    }

    fn prompt_token_ids(&self) -> Option<Vec<u32>> {
        (**self).prompt_token_ids()
    }
}

/// Synthetic LM over one [`Problem`]'s latent solution space.
///
/// Sampling model per continuation:
/// 1. pick a semantic group from the dataset's `n_groups` under a
///    *concentrated* proposal distribution (P(rank r) ∝ ζ^r over a
///    deterministic per-context preference order): an LM sampled k times at
///    the same state mostly re-proposes its top one or two approaches, so
///    extra samples from one node are largely redundant — the premise of
///    the paper's coverage term;
/// 2. pick a paraphrase variant id (surface form);
/// 3. the step's on-track fate is the problem's deterministic function of
///    (parent path, group) — redundant same-group steps share their fate;
/// 4. after `n_steps` on-track steps the trajectory terminates with the true
///    answer; a doomed trajectory terminates at the same depth with a wrong
///    answer (deterministic per path).
pub struct SynthLm {
    pub problem: Problem,
    /// Proposal concentration: P(rank r) ∝ zeta^r. Lower = more peaked.
    pub zeta: f64,
    rng: Rng,
    /// Real surface token ids for the prompt, when set: the engine registers
    /// them instead of minting unique ids, so two problems given the *same*
    /// ids honestly share prompt KV — the duplicate-heavy workloads the
    /// cross-shard prefix hub exists for. Sampling is untouched: prompt ids
    /// feed only the KV accounting, never the fate model.
    prompt_ids: Option<Vec<u32>>,
}

impl SynthLm {
    pub fn new(problem: Problem, seed: u64) -> Self {
        let rng = Rng::new(seed ^ problem.seed);
        Self { problem, zeta: 0.6, rng, prompt_ids: None }
    }

    /// Give the prompt real surface token ids (must cover exactly the
    /// dataset's `prompt_tokens`). See the `prompt_ids` field.
    pub fn with_prompt_ids(mut self, ids: Vec<u32>) -> Self {
        debug_assert_eq!(
            ids.len(),
            self.problem.spec.dataset.prompt_tokens,
            "prompt ids must cover the dataset's prompt length"
        );
        self.prompt_ids = Some(ids);
        self
    }

    /// Sample a semantic group for a node: deterministic per-context
    /// preference order, geometric rank distribution.
    fn sample_group(&mut self, parent_path_id: u64, n_groups: usize) -> u64 {
        // preference permutation seeded by the context
        let mut perm: Vec<u64> = (0..n_groups as u64).collect();
        let mut prng = Rng::new(self.problem.seed ^ parent_path_id.wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
        prng.shuffle(&mut perm);
        // geometric rank, truncated
        let mut rank = 0usize;
        while rank + 1 < n_groups && self.rng.f64() < self.zeta {
            rank += 1;
        }
        perm[rank]
    }
}

impl StepGenerator for SynthLm {
    fn expand(&mut self, tree: &SearchTree, leaf: NodeId, n: usize) -> Vec<StepInfo> {
        let parent = tree.get(leaf);
        debug_assert!(!parent.step.terminal, "expanding a terminal node");
        let parent_path_id = parent.step.path_id;
        let parent_alive = parent.step.alive;
        let n_groups = self.problem.spec.dataset.n_groups;
        let n_steps = self.problem.spec.dataset.n_steps;
        let depth = tree.depth(leaf); // completed steps so far
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let group = self.sample_group(parent_path_id, n_groups);
            let paraphrase = self.rng.next_u64() & 0xFFFF;
            let path_id = extend_path_id(parent_path_id, group);
            let alive = parent_alive && self.problem.group_on_track(parent_path_id, group);
            let is_last = depth + 1 >= n_steps;
            let answer = if is_last {
                Some(if alive {
                    self.problem.answer
                } else {
                    self.problem.wrong_answer(path_id)
                })
            } else {
                None
            };
            out.push(StepInfo {
                tokens: self.problem.step_tokens(path_id ^ paraphrase),
                sem: group,
                paraphrase,
                token_ids: vec![],
                terminal: is_last,
                answer,
                path_id,
                alive,
            });
        }
        out
    }

    fn prompt_tokens(&self) -> usize {
        self.problem.spec.dataset.prompt_tokens
    }

    fn prompt_token_ids(&self) -> Option<Vec<u32>> {
        self.prompt_ids.clone()
    }
}

/// Wrapper that makes any generator report a fixed modeled decode latency
/// per round ([`StepGenerator::decode_overhead_seconds`]) without changing
/// what it samples. This is the stand-in for a slow real-model backend
/// (PJRT device time, a network hop): the serve scheduler's pipelined mode
/// hides plan + commit behind exactly this kind of decode-bound round, and
/// `benches/table2_throughput.rs` uses the wrapper to measure the modeled
/// overlap savings.
pub struct InjectedLatency<G> {
    pub inner: G,
    /// Modeled decode seconds added per round.
    pub seconds_per_round: f64,
}

impl<G> InjectedLatency<G> {
    pub fn new(inner: G, seconds_per_round: f64) -> Self {
        Self { inner, seconds_per_round }
    }
}

impl<G: StepGenerator> StepGenerator for InjectedLatency<G> {
    fn expand(&mut self, tree: &SearchTree, leaf: NodeId, n: usize) -> Vec<StepInfo> {
        self.inner.expand(tree, leaf, n)
    }

    fn expand_batch(
        &mut self,
        tree: &SearchTree,
        requests: &[(NodeId, usize)],
    ) -> Vec<Vec<StepInfo>> {
        self.inner.expand_batch(tree, requests)
    }

    fn submit_batch(&mut self, tree: &SearchTree, requests: &[(NodeId, usize)]) -> PendingBatch {
        self.inner.submit_batch(tree, requests)
    }

    fn poll_batch(&mut self, batch: PendingBatch) -> Vec<Vec<StepInfo>> {
        self.inner.poll_batch(batch)
    }

    fn try_poll_batch(&mut self, batch: PendingBatch) -> crate::util::error::Result<Vec<Vec<StepInfo>>> {
        self.inner.try_poll_batch(batch)
    }

    fn decode_overhead_seconds(&self) -> f64 {
        self.seconds_per_round + self.inner.decode_overhead_seconds()
    }

    fn prompt_tokens(&self) -> usize {
        self.inner.prompt_tokens()
    }

    fn prompt_token_ids(&self) -> Option<Vec<u32>> {
        self.inner.prompt_token_ids()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{ProblemSet, WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

    fn make() -> SynthLm {
        let spec = WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM);
        let p = ProblemSet::generate(&spec, 1, 9).problems.remove(0);
        SynthLm::new(p, 1)
    }

    #[test]
    fn expands_n_children_with_consistent_latents() {
        let mut lm = make();
        let mut tree = SearchTree::new();
        let root = tree.init_root(lm.prompt_tokens());
        let steps = lm.expand(&tree, root, 32);
        assert_eq!(steps.len(), 32);
        // same group from the same parent → same fate and same path id
        for a in &steps {
            for b in &steps {
                if a.sem == b.sem {
                    assert_eq!(a.alive, b.alive, "same group, different fate");
                    assert_eq!(a.path_id, b.path_id);
                }
            }
            assert!(!a.terminal, "first of 8 steps can't be terminal");
        }
    }

    #[test]
    fn doomed_parent_stays_doomed() {
        let mut lm = make();
        let mut tree = SearchTree::new();
        let root = tree.init_root(lm.prompt_tokens());
        // manufacture a doomed child
        let doomed = tree.add_child(
            root,
            StepInfo { tokens: 5, alive: false, path_id: 77, ..Default::default() },
            0.1,
        );
        for s in lm.expand(&tree, doomed, 16) {
            assert!(!s.alive);
        }
    }

    #[test]
    fn submit_poll_matches_expand_batch() {
        // The blanket sync adapter must be invisible: submit + poll on one
        // generator samples exactly what expand_batch samples on a clone
        // seeded identically, and the handle carries the results (Ready).
        let mut direct = make();
        let mut phased = make();
        let mut tree = SearchTree::new();
        let root = tree.init_root(direct.prompt_tokens());
        let requests = [(root, 4usize), (root, 3usize)];
        let expected = direct.expand_batch(&tree, &requests);
        let handle = phased.submit_batch(&tree, &requests);
        assert!(!handle.is_ticket(), "sync adapter resolves at submit time");
        let got = phased.poll_batch(handle);
        assert_eq!(expected.len(), got.len());
        for (e, g) in expected.iter().zip(&got) {
            assert_eq!(e.len(), g.len());
            for (a, b) in e.iter().zip(g) {
                assert_eq!(a.path_id, b.path_id);
                assert_eq!(a.sem, b.sem);
                assert_eq!(a.tokens, b.tokens);
                assert_eq!(a.paraphrase, b.paraphrase);
            }
        }
    }

    #[test]
    #[should_panic(expected = "never issues tickets")]
    fn sync_adapter_rejects_foreign_tickets() {
        let mut lm = make();
        let _ = lm.poll_batch(PendingBatch::Ticket(7));
    }

    #[test]
    fn try_poll_surfaces_foreign_tickets_as_typed_errors() {
        // The fallible surface degrades gracefully where poll_batch panics:
        // the error carries the same diagnosis and the generator survives.
        let mut lm = make();
        let err = lm.try_poll_batch(PendingBatch::Ticket(7)).unwrap_err();
        assert!(err.0.contains("never issues tickets"), "{err}");
        let mut tree = SearchTree::new();
        let root = tree.init_root(lm.prompt_tokens());
        let handle = lm.submit_batch(&tree, &[(root, 2)]);
        assert_eq!(lm.poll_batch(handle).len(), 1);
    }

    #[test]
    fn injected_latency_is_transparent_except_for_the_hint() {
        let mut plain = make();
        let mut wrapped = InjectedLatency::new(make(), 0.25);
        assert_eq!(plain.decode_overhead_seconds(), 0.0);
        assert_eq!(wrapped.decode_overhead_seconds(), 0.25);
        assert_eq!(plain.prompt_tokens(), wrapped.prompt_tokens());
        let mut tree = SearchTree::new();
        let root = tree.init_root(plain.prompt_tokens());
        let a = plain.expand(&tree, root, 8);
        let b = wrapped.expand(&tree, root, 8);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.path_id, y.path_id);
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn terminal_at_n_steps_with_correct_answer_iff_alive() {
        let mut lm = make();
        let n_steps = lm.problem.spec.dataset.n_steps;
        let truth = lm.problem.answer;
        let mut tree = SearchTree::new();
        let mut cur = tree.init_root(lm.prompt_tokens());
        // walk a chain of depth n_steps - 1
        for _ in 0..n_steps - 1 {
            let s = lm.expand(&tree, cur, 1).remove(0);
            assert!(!s.terminal);
            cur = tree.add_child(cur, s, 0.5);
        }
        let finals = lm.expand(&tree, cur, 20);
        for s in finals {
            assert!(s.terminal);
            let ans = s.answer.unwrap();
            if s.alive {
                assert_eq!(ans, truth);
            } else {
                assert_ne!(ans, truth);
            }
        }
    }
}
